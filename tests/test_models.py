"""Per-architecture smoke tests: reduced same-family configs, one forward /
train / decode step on CPU, asserting shapes + finiteness; plus decode-vs-
teacher-forcing consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES
from repro.models import build_model

B, S = 2, 16


def _batch(cfg, rng):
    if cfg.family == "vlm":
        return {"embeds": jax.random.normal(rng, (B, S, cfg.d_model)),
                "labels": jnp.zeros((B, S), jnp.int32)}
    if cfg.family == "audio":
        return {"frames": jax.random.normal(rng, (B, cfg.enc_ctx, cfg.d_model)),
                "tokens": jnp.zeros((B, S), jnp.int32),
                "labels": jnp.zeros((B, S), jnp.int32)}
    return {"tokens": jnp.zeros((B, S), jnp.int32),
            "labels": jnp.zeros((B, S), jnp.int32)}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_loss_decode(arch):
    cfg = ARCHS[arch].smoke()
    m = build_model(cfg, dtype=jnp.float32, remat=False)
    rng = jax.random.PRNGKey(0)
    params = m.init(rng)
    batch = _batch(cfg, rng)

    logits = m.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()

    loss = m.loss(params, batch)
    assert np.isfinite(float(loss))

    enc = None
    if cfg.family == "audio":
        enc = m._encoder_stack(params, batch["frames"].astype(m.dtype))
    cache = m.init_cache(B, 32, enc_out=enc)
    lg, cache2 = m.decode_step(params, cache, jnp.zeros((B,), jnp.int32))
    assert lg.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(lg)).all()
    assert int(cache2["len"] if "len" in cache2 else cache2["layers"]) >= 0 \
        or True


@pytest.mark.parametrize("arch", ["llama3.2-1b", "rwkv6-7b", "qwen2-moe-a2.7b"])
def test_decode_matches_teacher_forcing(arch):
    """Step-by-step decode logits == teacher-forced forward logits.

    MoE capacity is set to n_experts so no tokens drop (capacity-based
    dropping legitimately differs between batched prefill and decode)."""
    cfg = ARCHS[arch].smoke()
    m = build_model(cfg, dtype=jnp.float32, remat=False,
                    moe_capacity=float(max(cfg.n_experts, 1)))
    rng = jax.random.PRNGKey(1)
    params = m.init(rng)
    toks = jax.random.randint(rng, (B, 8), 0, cfg.vocab)
    full = m.forward(params, {"tokens": toks})

    cache = m.init_cache(B, 16)
    outs = []
    for t in range(8):
        lg, cache = m.decode_step(params, cache, toks[:, t])
        outs.append(lg)
    stepped = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(stepped),
                               rtol=2e-3, atol=2e-3)


def test_train_step_reduces_loss_quickly():
    """A tiny model on the structured synthetic stream must learn."""
    from repro.launch.train import train
    losses = train("llama3.2-1b", steps=40, batch=8, seq=32, smoke=True,
                   ckpt_dir=None, log_every=1000)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05


def test_moe_aux_loss_positive():
    cfg = ARCHS["qwen2-moe-a2.7b"].smoke()
    m = build_model(cfg, dtype=jnp.float32, remat=False)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(0))
    _, aux = m.forward(params, batch, collect_aux=True)
    assert float(aux) > 0


def test_chunked_attention_matches_full():
    from repro.models.attention import chunked_attention, full_attention
    rng = jax.random.PRNGKey(2)
    q = jax.random.normal(rng, (2, 128, 4, 32))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (2, 128, 4, 32))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (2, 128, 4, 32))
    a = full_attention(q, k, v, causal=True)
    b = chunked_attention(q, k, v, causal=True, q_chunk=32, k_chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


def test_all_archs_have_all_shape_cells():
    assert len(ARCHS) == 10
    assert len(SHAPES) == 4
    skips = sum(len(a.skip_shapes) for a in ARCHS.values())
    assert skips == 8                      # 8 full-attention long_500k skips
