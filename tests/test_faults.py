"""Fault-tolerant always-on evaluation (PR 6 invariants).

Covers: the seeded deterministic FaultPlan / ChaosPool chaos harness
(crash | hang | slow | corrupt, consumed exactly once); ShardedEvaluator
recovery — retry with backoff, shard timeouts declaring lost dispatches,
heartbeat eviction + re-registration, straggler-twin speculation, elastic
pool resize — all BIT-IDENTICAL to the fault-free run; the EvalService
graceful-degradation ladder (narrow -> proxy -> cached, plus deadline
demotion) with nothing unhandled reaching a client future; crash-safe
SweepEngine checkpoints (atomic tmp+replace with a content digest,
corrupt files quarantined not fatal, kill-mid-sweep resume exact, incl.
portfolio mode); and a CampaignRunner driven through the degrading
service under a seeded plan reproducing the clean campaign exactly.
"""
import os

import numpy as np
import pytest

from repro.core.campaign import CampaignRunner
from repro.distributed import (ChaosPool, EvalService, FaultEvent, FaultPlan,
                               ShardedEvaluator, WorkerFault)
from repro.distributed.faults import corrupt_report
from repro.distributed.sharded import ShardPayload, _InlinePool
from repro.perfmodel import (EvalRequest, ModelEvaluator, get_evaluator,
                             make_evaluator)
from repro.perfmodel.designspace import SPACE
from repro.perfmodel.sweep import SweepEngine
from repro.perfmodel.workload import zoo_suite
from repro.runtime import RetryPolicy

RNG = np.random.default_rng(6)
CH = 8_192                               # sweep chunk size used throughout


def _fresh(tier: str = "proxy") -> ModelEvaluator:
    return ModelEvaluator(get_evaluator(tier).models, tier=tier)


def _assert_reports_identical(a, b):
    assert a.workloads == b.workloads and a.detail == b.detail
    assert np.array_equal(a.area, b.area)
    for w in a.workloads:
        assert np.array_equal(a.latency[w], b.latency[w])
        if a.detail in ("ppa", "stalls"):
            assert np.array_equal(a.op_time[w], b.op_time[w])
            assert a.op_names[w] == b.op_names[w]
        if a.detail == "stalls":
            assert np.array_equal(a.stall[w], b.stall[w])
            assert np.array_equal(a.op_class[w], b.op_class[w])


@pytest.fixture(scope="module")
def sweep_eng():
    return SweepEngine(get_evaluator("proxy"), chunk_size=CH, stall_topk=4)


# ------------------------------------------------------------ fault plan
def test_fault_plan_seeded_deterministic_and_consumed_once():
    a = FaultPlan.seeded(7, workers=3, dispatches=64, rate=0.3)
    b = FaultPlan.seeded(7, workers=3, dispatches=64, rate=0.3)
    assert a.scheduled == b.scheduled == len(a) > 0
    assert sorted(a._events) == sorted(b._events)
    for k, e in a._events.items():
        assert b._events[k].kind == e.kind       # same seed -> same schedule
    c = FaultPlan.seeded(8, workers=3, dispatches=64, rate=0.3)
    assert sorted(c._events) != sorted(a._events)
    # events are consumed exactly once: a retry can't be re-killed
    (w, d) = sorted(a._events)[0]
    kind = a.peek(w, d).kind
    assert a.fire(w, d).kind == kind
    assert a.fire(w, d) is None and a.peek(w, d) is None
    assert a.fired[kind] >= 1
    assert len(a) == a.scheduled - 1


def test_fault_plan_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultEvent(0, 0, "meteor")
    with pytest.raises(ValueError, match="rate"):
        FaultPlan.seeded(0, workers=2, dispatches=4, rate=1.5)
    with pytest.raises(ValueError, match="kind"):
        FaultPlan.seeded(0, workers=2, dispatches=4, kinds=("crash", "nap"))


def test_chaos_pool_injects_each_kind():
    idx = SPACE.sample(RNG, 4)
    payload = ShardPayload(idx, "objectives", None)
    plan = FaultPlan([FaultEvent(0, 0, "crash"), FaultEvent(0, 1, "hang"),
                      FaultEvent(0, 2, "corrupt")])
    pool = ChaosPool(_InlinePool(_fresh()), plan)
    f = pool.submit(payload)                     # dispatch 0: crash
    with pytest.raises(WorkerFault, match="injected crash"):
        f.result(timeout=1)
    assert not pool.submit(payload).done()       # dispatch 1: hangs forever
    bad = pool.submit(payload).result(timeout=1)  # dispatch 2: corrupt
    assert (np.asarray(bad.area) <= 0).any()
    assert any(not np.isfinite(bad.latency[w]).all() for w in bad.workloads)
    good = pool.submit(payload).result(timeout=1)  # dispatch 3: clean
    _assert_reports_identical(good, _fresh().evaluate(
        EvalRequest(idx, "objectives")))
    assert pool.injected == {"crash": 1, "hang": 1, "slow": 0, "corrupt": 1}
    assert pool.dispatch_count == 4


def test_corrupt_report_fails_integrity_check():
    rep = _fresh().evaluate(EvalRequest(SPACE.sample(RNG, 3), "objectives"))
    bad = corrupt_report(rep)
    ev = ShardedEvaluator(_fresh(), workers=2)
    payload = ShardPayload(np.atleast_2d(SPACE.sample(RNG, 3)),
                           "objectives", None)
    ev._check_shard(payload, rep)                # the clean one passes
    with pytest.raises(WorkerFault, match="corrupt"):
        ev._check_shard(payload, bad)
    assert ev.corrupt_rejected == 1
    ev.close()


# ------------------------------------------- sharded evaluator recovery
def test_sharded_recovers_crash_corrupt_slow_bit_identical():
    """Acceptance: a plan killing worker dispatches mid-run leaves the
    reassembled report bit-identical to the fault-free evaluation."""
    idx = SPACE.sample(RNG, 16)
    local = _fresh().evaluate(EvalRequest(idx, "stalls"))
    plan = FaultPlan([FaultEvent(0, 0, "crash"),
                      FaultEvent(1, 1, "corrupt"),
                      FaultEvent(0, 2, "slow", delay_s=0.01)])
    ev = ShardedEvaluator(_fresh(), workers=2, fault_plan=plan)
    rep = ev.evaluate(EvalRequest(idx, "stalls"))
    _assert_reports_identical(rep, local)
    assert ev.retried == 2                       # crash + corrupt re-dispatch
    assert ev.corrupt_rejected == 1
    assert plan.fired["crash"] == 1 and plan.fired["corrupt"] == 1
    assert len(plan) == 0                        # every event consumed
    ev.close()


def test_sharded_hang_times_out_evicts_and_reregisters():
    """A hung dispatch is declared LOST at the shard timeout: the slot is
    evicted from the registry, a replacement re-registers, and the shard
    retries to a bit-identical report."""
    idx = SPACE.sample(RNG, 12)
    local = _fresh().evaluate(EvalRequest(idx, "ppa"))
    ev = ShardedEvaluator(_fresh(), workers=2,
                          fault_plan=FaultPlan([FaultEvent(0, 0, "hang")]),
                          shard_timeout_s=0.3, speculate=False)
    rep = ev.evaluate(EvalRequest(idx, "ppa"))
    _assert_reports_identical(rep, local)
    assert ev.timeouts == 1 and ev.retried == 1
    assert ev.registry.evictions == 1
    assert ev.registry.reregistrations == 1
    assert sorted(ev.registry.live()) == [0, 1]  # back to full strength
    ev.close()


def test_sharded_hang_speculative_twin_wins():
    """With speculation on, a hung shard's twin lands first and the hang
    never consumes retry budget."""
    idx = SPACE.sample(RNG, 12)
    local = _fresh().evaluate(EvalRequest(idx, "objectives"))
    ev = ShardedEvaluator(_fresh(), workers=2,
                          fault_plan=FaultPlan([FaultEvent(0, 0, "hang")]),
                          cold_straggler_s=0.2)
    rep = ev.evaluate(EvalRequest(idx, "objectives"))
    _assert_reports_identical(rep, local)
    assert ev.straggler_redispatches == 1
    assert ev.retried == 0 and ev.timeouts == 0
    ev.close()


def test_sharded_elastic_resizes_after_worker_loss():
    """elastic=True: after a crash evicts a slot, plan_elastic_pool picks
    the shrunken pool size instead of oversubscribing dead slots."""
    idx = SPACE.sample(RNG, 16)
    local = _fresh().evaluate(EvalRequest(idx, "objectives"))
    ev = ShardedEvaluator(_fresh(), workers=4, elastic=True,
                          fault_plan=FaultPlan([FaultEvent(0, 0, "crash")]))
    rep = ev.evaluate(EvalRequest(idx, "objectives"))
    _assert_reports_identical(rep, local)
    assert ev.resizes >= 1 and ev.workers < 4
    assert sorted(ev.registry.live()) == list(range(ev.workers))
    ev.close()


def test_sharded_single_shard_still_chaos_covered():
    """Under a fault plan even a one-shard request routes through the pool
    so injection + recovery cover the inline path too."""
    idx = SPACE.sample(RNG, 2)
    local = _fresh().evaluate(EvalRequest(idx, "objectives"))
    ev = ShardedEvaluator(_fresh(), workers=2, min_shard_rows=8,
                          fault_plan=FaultPlan([FaultEvent(0, 0, "crash")]))
    rep = ev.evaluate(EvalRequest(idx, "objectives"))
    _assert_reports_identical(rep, local)
    assert ev.retried == 1
    ev.close()


# ------------------------------------------------- service degradation
class _NarrowOnly:
    """Backend that only works single-worker — the worker-loss shape."""

    def __init__(self, base, workers=4):
        self._b, self.workers = base, workers
        self.space, self.tier = base.space, base.tier
        self.models = base.models
        self.workloads = base.workloads

    def resize(self, workers):
        self.workers = workers

    def evaluate(self, request):
        if self.workers > 1:
            raise WorkerFault("pool degraded")
        return self._b.evaluate(request)


class _ObjectivesOnly:
    """Backend whose detailed path is down — the proxy-demotion shape."""

    def __init__(self, base):
        self._b = base
        self.workloads = base.workloads

    def evaluate(self, request):
        if request.detail != "objectives":
            raise RuntimeError("detail backend down")
        return self._b.evaluate(request)


class _Dead:
    def __init__(self, base):
        self.workloads = base.workloads

    def evaluate(self, request):
        raise WorkerFault("backend down")


def test_service_degrades_by_narrowing_workers():
    svc = EvalService(_fresh())
    svc.evaluator = _NarrowOnly(_fresh(), workers=4)
    idx = SPACE.sample(RNG, 6)
    fut = svc.submit(EvalRequest(idx, "ppa"))
    svc.tick()
    rep = fut.result(timeout=1)
    assert rep.detail == "ppa"                   # detail preserved
    _assert_reports_identical(rep, _fresh().evaluate(EvalRequest(idx, "ppa")))
    assert svc.degraded["narrow"] == 2           # 4 -> 2 -> 1
    assert svc.evaluator.workers == 1


def test_service_degrades_to_objectives_proxy():
    svc = EvalService(_fresh())
    svc.evaluator = _ObjectivesOnly(_fresh())
    idx = SPACE.sample(RNG, 6)
    fut = svc.submit(EvalRequest(idx, "stalls"))
    svc.tick()
    rep = fut.result(timeout=1)
    assert rep.detail == "objectives"            # demoted but correct
    _assert_reports_identical(
        rep, _fresh().evaluate(EvalRequest(idx, "objectives")))
    assert svc.degraded["proxy"] == 1


def test_service_degrades_to_cached_rows_when_backend_dead():
    svc = EvalService(_fresh())
    idx = SPACE.sample(RNG, 6)
    svc.evaluate(EvalRequest(idx, "ppa"))        # warm the shared row cache
    svc.evaluator = _Dead(svc.evaluator)                      # then the backend dies
    fut = svc.submit(EvalRequest(idx, "stalls"))  # asks MORE than is cached
    assert svc.tick() == 0                       # no dispatch succeeded...
    rep = fut.result(timeout=1)                  # ...but the client is served
    assert rep.detail == "ppa"                   # floored to the cached level
    _assert_reports_identical(
        rep, _fresh().evaluate(EvalRequest(idx, "ppa")))
    assert svc.degraded["cached"] == 1


def test_service_deadline_demotes_instead_of_failing():
    svc = EvalService(_fresh())
    idx = SPACE.sample(RNG, 4)
    fut = svc.submit(EvalRequest(idx, "stalls"), deadline_s=0.0)
    svc.tick()                                   # deadline already expired
    rep = fut.result(timeout=1)
    assert rep.detail == "objectives"            # demoted to the cheap proxy
    assert svc.degraded["deadline"] == 1
    _assert_reports_identical(
        rep, _fresh().evaluate(EvalRequest(idx, "objectives")))


def test_service_never_raises_out_of_tick():
    """Acceptance: every rung down, the tick still returns (no unhandled
    exception escapes the service); the failure lands on the future."""
    svc = EvalService(_fresh())
    svc.evaluator = _Dead(svc.evaluator)
    fut = svc.submit(EvalRequest(SPACE.sample(RNG, 3), "ppa"))
    assert svc.tick() == 0                       # never raises
    with pytest.raises(WorkerFault, match="backend down"):
        fut.result(timeout=1)
    tel = svc.telemetry()
    assert tel["degraded"]["narrow"] == 0        # no resize surface -> skipped
    assert tel["fused_dispatches"] == 0


def test_service_validates_degrade_ladder():
    with pytest.raises(ValueError, match="degrade"):
        EvalService(_fresh(), degrade=("narrow", "panic"))


# --------------------------------------------------- crash-safe sweeps
def test_sweep_chaos_workers_bit_identical(sweep_eng, tmp_path):
    """Acceptance: a seeded plan crashing worker 0 mid-sweep (and slowing
    worker 1) leaves the merged N-worker result bit-identical to the
    fault-free single-process sweep — spans replay from their own atomic
    checkpoints."""
    n = 5 * CH
    clean = sweep_eng.run(0, n)
    ck = str(tmp_path / "ck")
    plan = FaultPlan([FaultEvent(0, 2, "crash"),
                      FaultEvent(1, 1, "slow", delay_s=0.01)])
    res = sweep_eng.run(0, n, workers=2, checkpoint_path=ck,
                        checkpoint_every=1, fault_plan=plan)
    assert plan.fired["crash"] == 1
    assert np.array_equal(clean.pareto_ids, res.pareto_ids)
    assert np.array_equal(clean.pareto_y, res.pareto_y)
    assert np.array_equal(clean.topk_ids, res.topk_ids)
    assert np.array_equal(clean.stall_topk_ids, res.stall_topk_ids)
    assert clean.n_superior == res.n_superior
    assert os.path.exists(f"{ck}.w0of2.npz")     # per-worker atomic file
    # no checkpoint at all: the crashed span replays from scratch instead
    plan2 = FaultPlan([FaultEvent(0, 1, "crash")])
    res2 = sweep_eng.run(0, n, workers=2, fault_plan=plan2)
    assert np.array_equal(clean.pareto_ids, res2.pareto_ids)


def test_sweep_span_retry_budget_exhausts(sweep_eng):
    plan = FaultPlan([FaultEvent(0, 0, "crash"), FaultEvent(0, 1, "crash")])
    with pytest.raises(RuntimeError, match="failed after 0 retries"):
        sweep_eng.run(0, 2 * CH, fault_plan=plan,
                      span_retry=RetryPolicy(max_retries=0))


def test_sweep_corrupt_checkpoint_quarantined_not_fatal(sweep_eng, tmp_path):
    """A truncated checkpoint (kill mid-write on a non-atomic filesystem,
    bit rot, ...) is quarantined with a warning and the span restarts
    fresh — resume NEVER crashes on a bad file, and the digest guard
    catches what np.load alone would not."""
    n = 2 * CH
    clean = sweep_eng.run(0, n)
    ck = str(tmp_path / "ck")
    sweep_eng.run(0, n, checkpoint_path=ck)
    fname = f"{ck}.npz"
    blob = open(fname, "rb").read()
    with open(fname, "wb") as f:
        f.write(blob[: len(blob) // 2])          # truncate mid-file
    with pytest.warns(RuntimeWarning, match="quarantined"):
        res = sweep_eng.run(0, n, resume_from=ck)
    assert os.path.exists(f"{fname}.quarantined")
    assert not os.path.exists(f"{fname}.tmp")    # atomic writes leave no tmp
    assert np.array_equal(clean.pareto_ids, res.pareto_ids)
    assert np.array_equal(clean.topk_val, res.topk_val)


def test_sweep_mid_kill_checkpoint_resume_bit_identical(sweep_eng, tmp_path):
    """Kill the sweep mid-run (retry budget 0 -> the crash surfaces), then
    resume from the atomic checkpoint: the finished result is bit-identical
    to the uninterrupted run."""
    n = 4 * CH
    clean = sweep_eng.run(0, n)
    ck = str(tmp_path / "kill")
    with pytest.raises(RuntimeError, match="failed after"):
        sweep_eng.run(0, n, checkpoint_path=ck, checkpoint_every=1,
                      fault_plan=FaultPlan([FaultEvent(0, 2, "crash")]),
                      span_retry=RetryPolicy(max_retries=0))
    assert os.path.exists(f"{ck}.npz")           # chunks 0-1 were persisted
    res = sweep_eng.run(0, n, resume_from=ck)
    assert res.n_evaluated == n
    assert np.array_equal(clean.pareto_ids, res.pareto_ids)
    assert np.array_equal(clean.pareto_y, res.pareto_y)
    assert np.array_equal(clean.stall_topk_ids, res.stall_topk_ids)
    assert clean.n_superior == res.n_superior


def test_portfolio_sweep_mid_kill_resume_bit_identical(tmp_path):
    """The same kill-and-resume guarantee in portfolio mode: per-scenario
    fronts, robust front and stall tables all match the clean run."""
    wls, scen = zoo_suite(archs=("qwen2-moe-a2.7b", "llama3.2-1b"),
                          smoke=True)
    ev = make_evaluator(wls, tier="proxy", scenarios=scen)
    eng = SweepEngine(ev, chunk_size=CH, stall_topk=4)
    n = 3 * CH
    clean = eng.run(0, n)
    ck = str(tmp_path / "pck")
    with pytest.raises(RuntimeError, match="failed after"):
        eng.run(0, n, checkpoint_path=ck, checkpoint_every=1,
                fault_plan=FaultPlan([FaultEvent(0, 2, "crash")]),
                span_retry=RetryPolicy(max_retries=0))
    res = eng.run(0, n, resume_from=ck)
    assert np.array_equal(clean.pareto_ids, res.pareto_ids)
    assert np.array_equal(clean.topk_ids, res.topk_ids)
    for nm in clean.scenario_names:
        assert np.array_equal(clean.scenario(nm).pareto_ids,
                              res.scenario(nm).pareto_ids)
        assert np.allclose(clean.scenario(nm).stall_topk_val,
                           res.scenario(nm).stall_topk_val, rtol=1e-7)
    assert clean.n_superior == res.n_superior


# ------------------------------------------- end-to-end: chaos campaign
def test_campaign_through_degrading_service_under_chaos():
    """Acceptance: a CampaignRunner driven through EvalService over a
    chaos-wrapped ShardedEvaluator reproduces the clean campaign exactly
    (samples AND hypervolume), with the fault traffic visible in the
    result's service counters and nothing unhandled."""
    budget = 12
    seeds = {"memory_bw": SPACE.sample(np.random.default_rng(1), 2),
             "tensor_compute": SPACE.sample(np.random.default_rng(2), 2)}
    clean = CampaignRunner(EvalService(_fresh()),
                           proxy=get_evaluator("proxy"), seed=0).run(
        budget=budget, seeds={k: v.copy() for k, v in seeds.items()})
    plan = FaultPlan.seeded(11, workers=2, dispatches=64, rate=0.3,
                            kinds=("crash", "slow", "corrupt"), delay_s=0.01)
    sharded = ShardedEvaluator(_fresh(), workers=2, retries=5,
                               shard_timeout_s=2.0, fault_plan=plan)
    svc = EvalService(sharded)
    res = CampaignRunner(svc, proxy=get_evaluator("proxy"), seed=0).run(
        budget=budget, seeds=seeds)
    assert plan.scheduled > len(plan)            # faults actually fired
    assert sharded.retried + sharded.corrupt_rejected > 0
    assert [s.idx.tolist() for s in res.samples] == \
           [s.idx.tolist() for s in clean.samples]
    assert res.phv == pytest.approx(clean.phv, rel=0, abs=0)
    assert res.service_counters is not None
    assert res.service_counters["campaign_resubmits"] == 0
    assert res.service_counters["evaluator_retried"] == sharded.retried
    assert res.service_counters["degraded"] == svc.degraded
    assert "service" in res.telemetry_dict()
    sharded.close()
