"""DSE Benchmark generator + accuracy harness tests (paper §4, Table 3)."""
import pytest

from repro.core.bench import (generate_suite, generate_bottleneck,
                              generate_prediction, generate_tuning,
                              evaluate_backend)
from repro.core.llm import (RuleOracle, DegradedOracle, TASK_BOTTLENECK,
                            TASK_PREDICTION, TASK_TUNING)


@pytest.fixture(scope="module")
def suite():
    return generate_suite(60, 30, 15)


def test_suite_composition(suite):
    assert len(suite.by_task(TASK_BOTTLENECK)) == 60
    assert len(suite.by_task(TASK_PREDICTION)) == 30
    assert len(suite.by_task(TASK_TUNING)) == 15
    for q in suite.questions:
        assert 0 <= q.answer < len(q.options)
        assert q.prompt and q.options


def test_full_scale_counts():
    """The paper's suite: 308 + 127 + 30 (generation only, no eval)."""
    qs = generate_bottleneck(10)          # spot-check the generators scale
    assert len(qs) == 10


def test_enhanced_beats_original(suite):
    """Table 3's central claim: corrective rules lift accuracy on every task."""
    enh = evaluate_backend(RuleOracle(enhanced=True), suite)
    orig = evaluate_backend(RuleOracle(enhanced=False), suite)
    for task in (TASK_BOTTLENECK, TASK_PREDICTION, TASK_TUNING):
        assert enh[task] >= orig[task], task
    assert enh[TASK_BOTTLENECK] >= 0.75
    assert enh[TASK_PREDICTION] >= 0.7
    assert enh[TASK_TUNING] >= 0.6


def test_degradation_ordering(suite):
    """Higher injected error => lower accuracy (the model-quality axis)."""
    a = evaluate_backend(DegradedOracle(0.1, seed=0), suite)
    b = evaluate_backend(DegradedOracle(0.5, seed=0), suite)
    for task in (TASK_BOTTLENECK, TASK_PREDICTION, TASK_TUNING):
        assert a[task] >= b[task], task


def test_render_is_mc_format(suite):
    txt = suite.questions[0].render()
    assert "(A)" in txt and "(B)" in txt
