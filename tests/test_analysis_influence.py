"""repro.analysis.influence: source-extracted influence graph.

The equivalence tests freeze the hand-coded AHK tables that used to live in
``repro.core.llm`` / ``repro.core.strategy`` / ``repro.core.quale_ast``
(deleted once extraction proved equivalent) and assert the extractor still
reproduces them from the perfmodel source alone.
"""
import json
from pathlib import Path

import pytest

from repro.analysis.influence import (ARTIFACT_PATH, RuleAudit,
                                      cross_validate,
                                      derive_influence_map_from_source,
                                      derived_to_metrics,
                                      extract_influence_graph, load_artifact,
                                      primary_resources)
from repro.core.quale import derive_influence_map
from repro.perfmodel import get_evaluator
from repro.perfmodel.critical_path import STALL_CLASSES
from repro.perfmodel.designspace import PARAM_NAMES

# ---------------------------------------------------------------------------
# frozen copies of the hand-coded tables this subsystem replaced, kept ONLY
# here as the historical reference the extraction is proven against
# ---------------------------------------------------------------------------

# was: the inline dict in RuleOracle._bottleneck / _tuning and the
# module-level PRIMARY_RESOURCE in repro.core.strategy
LEGACY_PRIMARY_RESOURCE = {
    "tensor_compute": "sa_dim",
    "vector_compute": "vector_width",
    "memory_bw": "mem_channels",
    "interconnect": "link_count",
}

# was: repro.core.quale_ast.DERIVED_TO_METRICS
LEGACY_DERIVED_TO_METRICS = {
    "tensor_flops": {"ttft", "tpot"},
    "vector_flops": {"ttft", "tpot"},
    "mem_bw": {"ttft", "tpot"},
    "ici_bw": {"ttft", "tpot"},
    "sram_kb": {"ttft", "tpot"},
    "gbuf_bytes": {"ttft", "tpot"},
    "sa_dim": {"ttft", "tpot"},
    "sublane_count": {"ttft", "tpot"},
    "core_count": {"ttft", "tpot"},
    "vector_width": {"ttft", "tpot"},
    "area_mm2": {"area"},
}


@pytest.fixture(scope="module")
def graph():
    return extract_influence_graph()


@pytest.fixture(scope="module")
def probed():
    return derive_influence_map(get_evaluator("proxy"), n_probes=6, seed=0)


# ---------------------------------------------------------------------------
# equivalence with the deleted hand-coded tables
# ---------------------------------------------------------------------------

def test_extracted_primaries_match_legacy_table():
    """The AHK stall->parameter primaries are now DERIVED from the perfmodel
    source; they must reproduce the hand-coded table they replaced."""
    assert primary_resources() == LEGACY_PRIMARY_RESOURCE


def test_derived_to_metrics_matches_legacy_table():
    """Same for derived->metric edges, modulo the ONE documented delta: the
    legacy table redundantly listed the ``vector_width`` passthrough key,
    which no roofline term ever reads (``vector_flops`` carries its
    influence) — the extractor only emits edges that exist in the source."""
    new = derived_to_metrics()
    legacy = {k: set(v) for k, v in LEGACY_DERIVED_TO_METRICS.items()}
    assert "vector_width" not in new
    legacy.pop("vector_width")
    assert new == legacy


def test_param_level_map_matches_legacy_ast_walker():
    """At the parameter level the redundancy washes out: every param keeps
    exactly the metric set the old quale_ast walker derived."""
    m = derive_influence_map_from_source()
    assert set(m) == set(PARAM_NAMES)
    for p in PARAM_NAMES:
        assert m[p] == {"ttft", "tpot", "area"}, p


# ---------------------------------------------------------------------------
# golden snapshot: the checked-in artifact guards the extraction in CI
# ---------------------------------------------------------------------------

def test_artifact_matches_fresh_extraction(graph):
    assert ARTIFACT_PATH.exists(), "run python -m repro.analysis.extract --write"
    assert load_artifact().signature() == graph.signature()


def test_artifact_is_committed_json():
    d = json.loads(ARTIFACT_PATH.read_text())
    assert d["primary"] == LEGACY_PRIMARY_RESOURCE
    assert len(d["edges"]) == len(extract_influence_graph().edges)


def test_signature_ignores_line_drift(graph):
    """The CI check must survive formatting-only perfmodel edits: the
    signature carries no line numbers."""
    sig = json.dumps(graph.signature())
    assert "line" not in sig and "site" not in sig


# ---------------------------------------------------------------------------
# structure + provenance
# ---------------------------------------------------------------------------

def test_graph_covers_the_full_model_surface(graph):
    assert set(graph.params) == set(PARAM_NAMES)
    assert set(graph.stalls) == set(STALL_CLASSES)
    assert set(graph.metrics) == {"ttft", "tpot", "area"}
    assert set(graph.terms) == {"t_compute", "t_memory", "t_comm"}
    # workload-kind guards discovered from the comparison constants
    assert graph.guard_kinds["is_mm"] == "MATMUL"
    assert graph.guard_kinds["is_mem"] == "MEMCPY"


def test_every_edge_has_real_provenance(graph):
    """Each edge's ``file:line`` sites must point into real source files."""
    src_root = Path(__file__).resolve().parents[1]   # sites are repo-relative
    lengths = {}
    for e in graph.edges:
        assert e.sites, (e.kind, e.src, e.dst)
        for s in e.sites:
            fname, _, line = s.rpartition(":")
            f = src_root / fname
            assert f.exists(), s
            if f not in lengths:
                lengths[f] = len(f.read_text().splitlines())
            assert 1 <= int(line) <= lengths[f], s


def test_render_param_chains(graph):
    txt = graph.render_param("mem_channels")
    assert "mem_bw" in txt and "memory_bw" in txt
    with pytest.raises(KeyError):
        graph.render_param("not_a_param")


# ---------------------------------------------------------------------------
# cross-validation against the probe-based QualE map (full surface)
# ---------------------------------------------------------------------------

def test_probed_metric_edges_subset_of_source(graph, probed):
    """Static reachability over-approximates observed influence: every
    probe-observed param->metric edge must exist in the source graph, for
    ALL params x {ttft, tpot, area}."""
    src = derive_influence_map_from_source()
    for p in PARAM_NAMES:
        assert probed.metric_edges[p] <= src[p], (
            p, probed.metric_edges[p], src[p])


def test_primary_edges_confirmed_by_probing(graph, probed):
    """Each extracted primary (stall -> param) must be exercised by the
    probe map: perturbing the primary param moves that stall class."""
    for stall, param in graph.primary_resources().items():
        assert stall in probed.stall_edges[param], (stall, param)


def test_sensitivity_consistent_with_source_graph(graph):
    """QuanE cross-validation: a parameter with a nonzero finite-difference
    sensitivity on a metric must carry that param->metric edge in the
    source-extracted graph (magnitudes confirm the structure)."""
    from repro.core.quane import sensitivity_analysis
    from repro.perfmodel.designspace import A100_REFERENCE, SPACE
    ev = get_evaluator("proxy")
    sens = sensitivity_analysis(ev, SPACE.encode_nearest(A100_REFERENCE))
    src = derive_influence_map_from_source()
    checked = 0
    for p, deltas in sens.delta.items():
        for metric, d in deltas.items():
            if abs(d) > 1e-12 and metric in ("ttft", "tpot", "area"):
                assert metric in src[p], (p, metric, d)
                checked += 1
    assert checked > 0    # the cross-validation actually exercised edges


def test_rule_audit_telemetry(graph, probed):
    audit = cross_validate(graph, probed)
    assert isinstance(audit, RuleAudit)
    # no probe-observed metric edge may be missing from the source graph
    # (that would be an extraction bug, and auto-correction would fire)
    assert all(not v for v in audit.metric_probe_only.values())
    counts = audit.counts()
    assert counts["metric_probe_only"] == 0
    # source reachability may exceed what 6 probes exercise, never less
    assert (counts["metric_agree"] + counts["metric_source_only"]
            == 3 * len(PARAM_NAMES))
    d = audit.as_dict()
    assert set(d) >= {"metric_agree", "stall_agree", "stall_probe_only",
                      "stall_source_only"}
    for line in audit.corrections():
        assert isinstance(line, str)
