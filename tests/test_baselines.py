"""Black-box baseline tests + the headline comparison at reduced budget."""
import numpy as np
import pytest

from repro.core.baselines import METHODS, run_method
from repro.core.loop import LuminaDSE
from repro.perfmodel import get_evaluator
from repro.perfmodel.designspace import SPACE, A100_REFERENCE


@pytest.fixture(scope="module")
def setup():
    evaluator = get_evaluator("proxy")   # callable: evaluator(X) -> (n, 3)
    ref = evaluator(SPACE.encode_nearest(A100_REFERENCE)[None, :])[0]
    return evaluator, ref


@pytest.mark.parametrize("name", sorted(METHODS))
def test_baseline_runs(name, setup):
    evaluator, ref = setup
    r = run_method(METHODS[name], evaluator, budget=40, ref_point=ref,
                   seed=0, batch=8)
    assert r.X.shape == (40, SPACE.n_params)
    assert r.Y.shape == (40, 3)
    assert np.isfinite(r.Y).all()
    assert r.phv >= 0


def test_ask_respects_cardinalities(setup):
    evaluator, ref = setup
    for name, cls in METHODS.items():
        opt = cls(space=SPACE, seed=1)
        X = np.atleast_2d(opt.ask(8))
        assert (X >= 0).all() and (X < SPACE.cardinalities[None, :]).all(), name


def test_lumina_beats_baselines_at_small_budget(setup):
    """Sample-efficiency headline (paper Fig. 4, scaled down): at a 60-sample
    budget Lumina's sample efficiency exceeds every black-box baseline's."""
    evaluator, ref = setup
    effs = {}
    for name, cls in METHODS.items():
        r = run_method(cls, evaluator, budget=60, ref_point=ref, seed=0,
                       batch=4)
        effs[name] = r.sample_efficiency
    res = LuminaDSE(evaluator, seed=0).run(budget=60)
    best = max(effs.values())
    assert res.sample_efficiency > best, (res.sample_efficiency, effs)
    assert res.sample_efficiency >= 3 * max(best, 1e-9)
