"""Dynamic worker membership: TTL leases, the registrar announce plane,
and elastic pool resize driven by join/leave/expiry.

Covers the PR 10 membership invariants: leases age out on the injected
clock with renewal NOT bumping the topology version (the O(1) sync
contract); the registrar grants/renews/withdraws leases over the
authenticated codec and refuses unauthenticated announcers; a
ShardedEvaluator pointed at a MembershipView stays bit-identical while
a worker's lease lapses mid-stream and again when it rejoins; gateway
telemetry surfaces the lease table; and admission RetryAfter hints stay
bounded and positive while the fleet churns underneath the queue.
"""
import threading
import time

import numpy as np
import pytest

from repro.distributed import ShardedEvaluator
from repro.obs.metrics import ManualClock, MetricsRegistry
from repro.perfmodel import EvalRequest, ModelEvaluator, get_evaluator
from repro.perfmodel.designspace import SPACE
from repro.serve import (Gateway, Keyring, MembershipView, Registrar,
                         RetryAfter, WorkerOptions, WorkerServer, wire)
from repro.serve import codec as codec_mod

RNG = np.random.default_rng(11)
KEYS = {"k1": b"membership-secret"}


def _fresh(tier: str = "proxy") -> ModelEvaluator:
    return ModelEvaluator(get_evaluator(tier).models, tier=tier)


def _assert_reports_identical(a, b):
    assert a.workloads == b.workloads and a.detail == b.detail
    assert np.array_equal(a.area, b.area)
    for w in a.workloads:
        assert np.array_equal(a.latency[w], b.latency[w])
        if a.detail == "stalls":
            assert np.array_equal(a.stall[w], b.stall[w])


# --------------------------------------------------------------- leases
def test_lease_lifecycle_on_manual_clock():
    """Join bumps the version; renewals do NOT; expiry and Bye do — and
    every transition lands in the membership counters."""
    clock = ManualClock()
    reg = MetricsRegistry()
    view = MembershipView(ttl_s=5.0, clock=clock, metrics=reg)
    assert view.live() == [] and view.version() == 0

    view.announce(("10.0.0.1", 7001), digests=("d1",), capacity=2)
    v_joined = view.version()
    assert view.live() == [("10.0.0.1", 7001)] and v_joined == 1
    assert reg.get("membership_joins").total() == 1
    assert reg.get("membership_live").value() == 1

    clock.advance(4.0)                      # renew inside the TTL window
    view.announce(("10.0.0.1", 7001), digests=("d1", "d2"))
    assert view.version() == v_joined       # renewal: topology unchanged
    assert reg.get("membership_renewals").total() == 1
    assert view.snapshot()["10.0.0.1:7001"]["digests"] == ["d1", "d2"]

    clock.advance(4.9)                      # renewed lease still alive
    assert len(view) == 1
    clock.advance(0.2)                      # ...and now past its TTL
    assert view.live() == []
    assert view.version() == v_joined + 1
    assert reg.get("membership_expirations").total() == 1
    assert reg.get("membership_live").value() == 0

    view.announce(("10.0.0.2", 7002))       # graceful leave path
    assert view.remove(("10.0.0.2", 7002)) is True
    assert view.remove(("10.0.0.2", 7002)) is False
    assert reg.get("membership_leaves").total() == 1


def test_lease_snapshot_reports_ttl_remaining():
    clock = ManualClock()
    view = MembershipView(ttl_s=10.0, clock=clock)
    view.announce(("h", 1), capacity=3)
    clock.advance(4.0)
    snap = view.snapshot()["h:1"]
    assert snap["capacity"] == 3 and snap["renewals"] == 0
    assert snap["ttl_remaining_s"] == pytest.approx(6.0)


# ------------------------------------------------------------ registrar
def test_registrar_grants_renews_and_withdraws_over_codec():
    """End to end over the wire: a signed Announce gets a LeaseAck with
    the view's TTL, renewals keep the lease, Bye withdraws it."""
    ring = Keyring(KEYS)
    view = MembershipView(ttl_s=2.0)
    reg = Registrar(view, keyring=ring).start()
    try:
        sock = wire.connect(reg.address)
        ch = codec_mod.Channel(sock, keyring=ring)
        ch.client_handshake()
        ch.send(wire.Announce(("10.9.9.9", 4242), ("dig",), 2))
        ack = ch.recv()
        assert isinstance(ack, wire.LeaseAck)
        assert ack.ttl_s == pytest.approx(2.0)
        assert view.live() == [("10.9.9.9", 4242)]
        ch.send(wire.Announce(("10.9.9.9", 4242), ("dig",), 2))
        assert isinstance(ch.recv(), wire.LeaseAck)
        assert view.snapshot()["10.9.9.9:4242"]["renewals"] == 1
        ch.send(wire.Bye("draining"))
        sock.close()
        deadline = time.monotonic() + 10
        while view.live() and time.monotonic() < deadline:
            time.sleep(0.01)
        assert view.live() == []
    finally:
        reg.close()


def test_registrar_refuses_unauthenticated_announcers():
    """An unsigned announcer cannot join the fleet (counted, no lease);
    neither can a legacy pickle client without insecure=True."""
    ring = Keyring(KEYS)
    view = MembershipView()
    reg = Registrar(view, keyring=ring).start()
    try:
        sock = wire.connect(reg.address)
        ch = codec_mod.Channel(sock)            # no keyring: unsigned
        ch.send(wire.Announce(("evil", 666)))
        sock.close()                            # server just drops us
        deadline = time.monotonic() + 10
        while reg.auth_rejected < 1 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert reg.auth_rejected == 1
        assert view.live() == []                # never joined

        sock = wire.connect(reg.address)
        wire.send_msg(sock, wire.Announce(("evil", 667)))   # legacy pickle
        sock.close()
        deadline = time.monotonic() + 10
        while reg.auth_rejected < 2 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert view.live() == []
    finally:
        reg.close()


def test_worker_announcer_joins_and_leaves_registrar():
    """A WorkerServer pointed at a registrar announces itself (with its
    served spec digests), renews, and withdraws with Bye on close."""
    ring = Keyring(KEYS)
    view = MembershipView(ttl_s=2.0)
    reg = Registrar(view, keyring=ring).start()
    srv = WorkerServer(options=WorkerOptions(
        keys=KEYS, registrar=reg.address, announce_interval_s=0.1))
    srv.start()
    try:
        assert view.wait_for(1, timeout_s=10.0)
        assert view.live() == [(srv.host, srv.port)]
        deadline = time.monotonic() + 10        # the heartbeat renews
        key = f"{srv.host}:{srv.port}"
        while (view.snapshot().get(key, {}).get("renewals", 0) < 2
               and time.monotonic() < deadline):
            time.sleep(0.02)
        assert view.snapshot()[key]["renewals"] >= 2
    finally:
        srv.close()
        reg.close()
    deadline = time.monotonic() + 10
    while view.live() and time.monotonic() < deadline:
        time.sleep(0.02)
    assert view.live() == []                    # Bye beat the TTL


# ----------------------------------------------- membership-driven pool
def test_sharded_evaluator_follows_membership_churn():
    """Acceptance: lease expiry shrinks the fleet mid-stream and a
    rejoin grows it back — reports stay bit-identical throughout, and
    the pool never dials a lapsed worker."""
    ring = Keyring(KEYS)
    view = MembershipView(ttl_s=1.0)
    reg = Registrar(view, keyring=ring).start()
    opts = WorkerOptions(keys=KEYS, registrar=reg.address,
                         announce_interval_s=0.1)
    s1 = WorkerServer(options=opts)
    s2 = WorkerServer(options=opts)
    s1.start()
    s2.start()
    ev = None
    try:
        assert view.wait_for(2, timeout_s=10.0)
        idx = SPACE.sample(RNG, 21)
        want = _fresh().evaluate(EvalRequest(idx, "stalls"))
        ev = ShardedEvaluator(_fresh(), mode="socket", membership=view,
                              keyring=ring)
        assert ev.workers == 2
        _assert_reports_identical(ev.evaluate(EvalRequest(idx, "stalls")),
                                  want)

        s2.close()                              # silent death: TTL ages it out
        deadline = time.monotonic() + 10
        while len(view) > 1 and time.monotonic() < deadline:
            time.sleep(0.05)
        assert view.live() == [(s1.host, s1.port)]
        _assert_reports_identical(ev.evaluate(EvalRequest(idx, "stalls")),
                                  want)
        assert ev.workers == 1                  # fleet shrank under us

        s3 = WorkerServer(options=opts)         # rejoin on a fresh port
        s3.start()
        try:
            assert view.wait_for(2, timeout_s=10.0)
            _assert_reports_identical(
                ev.evaluate(EvalRequest(idx, "stalls")), want)
            assert ev.workers == 2              # ...and grew back
        finally:
            s3.close()
    finally:
        if ev is not None:
            ev.close()
        s1.close()
        s2.close()
        reg.close()


def test_gateway_telemetry_shows_membership_leases():
    ring = Keyring(KEYS)
    view = MembershipView(ttl_s=5.0)
    reg = Registrar(view, keyring=ring).start()
    srv = WorkerServer(options=WorkerOptions(
        keys=KEYS, registrar=reg.address, announce_interval_s=0.1,
        capacity=4))
    srv.start()
    gw = None
    try:
        assert view.wait_for(1, timeout_s=10.0)
        sharded = ShardedEvaluator(_fresh(), mode="socket", membership=view,
                                   keyring=ring)
        gw = Gateway(sharded)
        idx = SPACE.sample(RNG, 5)
        assert np.array_equal(gw.objectives(idx), _fresh().objectives(idx))
        key = f"{srv.host}:{srv.port}"
        # the Ready handshake hands the spec digest to the announcer,
        # which carries it on its NEXT renewal — wait that beat out
        deadline = time.monotonic() + 10
        leases = gw.telemetry()["fleet"]["leases"]
        while (not leases.get(key, {}).get("digests")
               and time.monotonic() < deadline):
            time.sleep(0.05)
            leases = gw.telemetry()["fleet"]["leases"]
        assert key in leases
        assert leases[key]["capacity"] == 4
        assert leases[key]["ttl_remaining_s"] > 0
        assert leases[key]["digests"]           # Ready registered the digest
    finally:
        if gw is not None:
            gw.close()
        srv.close()
        reg.close()


def test_retry_after_hints_bounded_under_membership_churn():
    """Satellite: drain-ETA hints stay positive and bounded while
    workers join and leave under the gateway's queue — never negative,
    never unbounded."""
    ring = Keyring(KEYS)
    view = MembershipView(ttl_s=0.5)
    reg = Registrar(view, keyring=ring).start()
    opts = WorkerOptions(keys=KEYS, registrar=reg.address,
                         announce_interval_s=0.1)
    s1 = WorkerServer(options=opts)
    s1.start()
    gw = None
    stop = threading.Event()

    def churn():
        # a flapping second worker: join, lapse, rejoin...
        while not stop.is_set():
            w = WorkerServer(options=opts)
            w.start()
            time.sleep(0.15)
            w.close()
            time.sleep(0.15)

    t = threading.Thread(target=churn, daemon=True)
    try:
        assert view.wait_for(1, timeout_s=10.0)
        sharded = ShardedEvaluator(_fresh(), mode="socket", membership=view,
                                   keyring=ring)
        gw = Gateway(sharded, max_queued_rows=3)
        t.start()
        idx = SPACE.sample(RNG, 40)             # fresh rows every round:
        hints = []                              # the row cache must not
        for r in range(8):                      # short-circuit the queue
            base = r * 5
            for i in range(3):                  # fill the backlog, no ticks
                gw.submit(EvalRequest(idx[base + i:base + i + 1]),
                          tenant=f"t{i}")
            with pytest.raises(RetryAfter) as ei:
                gw.submit(EvalRequest(idx[base + 3:base + 5]), tenant="late")
            hints.append(ei.value.retry_after_s)
            gw.tick()                           # drain between rounds
            time.sleep(0.05)
        for h in hints:
            assert 0 < h <= 60.0, f"unbounded/negative drain ETA: {h}"
    finally:
        stop.set()
        t.join(timeout=10)
        if gw is not None:
            gw.close()
        s1.close()
        reg.close()
