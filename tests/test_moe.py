"""MoE dispatch invariants (hypothesis property tests) + shard_map parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # offline container: deterministic fallback
    from _hyp_compat import given, settings, st

from repro.models.moe import init_moe, moe_block


@given(st.integers(0, 10_000), st.sampled_from([1, 2, 4]),
       st.sampled_from([4, 8]), st.sampled_from([1, 2]))
@settings(max_examples=15, deadline=None)
def test_moe_capacity_conservation(seed, top_k, n_experts, groups):
    """With capacity >= T*k (no drops), every (token, k) assignment lands in
    the buffer exactly once: the output equals the explicit dense mixture."""
    rng = np.random.default_rng(seed)
    b, s, d, f = 2, 4, 8, 16
    params = init_moe(jax.random.PRNGKey(seed % 1000), d, f, n_experts, 0, 0,
                      dtype=jnp.float32)
    x = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
    out, aux = moe_block(params, x, n_experts=n_experts, top_k=top_k,
                         capacity_factor=float(n_experts), n_groups=groups)

    # explicit dense mixture oracle
    xf = x.reshape(-1, d)
    logits = xf @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gv, gi = jax.lax.top_k(probs, top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    ys = []
    for e in range(n_experts):
        g = xf @ params["w_gate"][e]
        u = xf @ params["w_up"][e]
        h = jax.nn.silu(g) * u
        ys.append(h @ params["w_down"][e])
    ys = jnp.stack(ys, axis=1)                       # (T, E, d)
    w = jnp.zeros((xf.shape[0], n_experts))
    for k in range(top_k):
        w = w.at[jnp.arange(xf.shape[0]), gi[:, k]].add(gv[:, k])
    ref = (ys * w[..., None]).sum(axis=1).reshape(b, s, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    assert float(aux) >= 0


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_moe_group_invariance(seed):
    """With ample capacity the group count must not change the output."""
    rng = np.random.default_rng(seed)
    b, s, d, f, E, k = 2, 8, 8, 16, 4, 2
    params = init_moe(jax.random.PRNGKey(seed % 997), d, f, E, 0, 0,
                      dtype=jnp.float32)
    x = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
    outs = [moe_block(params, x, n_experts=E, top_k=k,
                      capacity_factor=float(E), n_groups=g)[0]
            for g in (1, 2, 4)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=2e-4, atol=2e-4)


def test_expert_pad_never_selected():
    """Padding experts receive zero tokens (router has no logit for them)."""
    params = init_moe(jax.random.PRNGKey(0), 8, 16, n_experts=6, n_shared=0,
                      shared_ff=0, dtype=jnp.float32, expert_pad=2)
    assert params["w_up"].shape[0] == 8
    assert params["router"].shape[1] == 6
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 8))
    out, _ = moe_block(params, x, n_experts=6, top_k=2,
                       capacity_factor=6.0)
    assert np.isfinite(np.asarray(out)).all()
