"""Sweep engine + streaming Pareto machinery.

Covers the PR invariants: the vectorized ``pareto_mask`` is a drop-in for
the historical O(n^2) loop (including duplicate-row degeneracies), the
streaming ``ParetoArchive`` equals the batch front, and a truncated
full-space sweep reproduces brute-force evaluation exactly.
"""
import os

import numpy as np
import pytest

from repro.core.pareto import (ParetoArchive, dominates_ref, hypervolume,
                               pareto_front, pareto_mask)
from repro.perfmodel import get_evaluator
from repro.perfmodel.designspace import SPACE
from repro.perfmodel.sweep import SweepEngine, _unrank

SUBSPACE = 50_000


def _reference_pareto_mask(y):
    """The seed repo's O(n^2) Python-loop implementation (oracle)."""
    y = np.asarray(y, dtype=np.float64)
    n = y.shape[0]
    mask = np.ones(n, dtype=bool)
    for i in range(n):
        if not mask[i]:
            continue
        dominated_by_i = np.all(y >= y[i], axis=1) & np.any(y > y[i], axis=1)
        mask &= ~dominated_by_i
        mask[i] = True
        dominates_i = np.all(y <= y[i], axis=1) & np.any(y < y[i], axis=1)
        if dominates_i.any():
            mask[i] = False
    return mask


@pytest.fixture(scope="module")
def engine():
    return SweepEngine(get_evaluator("proxy"), chunk_size=16_384)


# ------------------------------------------------------------ pareto_mask
def test_pareto_mask_matches_reference_random():
    rng = np.random.default_rng(0)
    for trial in range(25):
        n = int(rng.integers(1, 400))
        m = int(rng.integers(2, 5))
        y = rng.random((n, m))
        assert np.array_equal(pareto_mask(y), _reference_pareto_mask(y)), trial


def test_pareto_mask_matches_reference_degenerate():
    rng = np.random.default_rng(1)
    # duplicate rows, constant columns, coarse grids with many exact ties
    cases = []
    y = rng.random((120, 3))
    cases.append(np.concatenate([y, y[:40]], axis=0))        # duplicates
    y = rng.random((100, 3)); y[:, 1] = 0.25                 # constant col
    cases.append(y)
    cases.append(np.round(rng.random((300, 3)), 1))          # tie-heavy grid
    cases.append(np.tile(rng.random((1, 4)), (32, 1)))       # all identical
    cases.append(rng.random((1, 3)))                         # single row
    for i, y in enumerate(cases):
        assert np.array_equal(pareto_mask(y), _reference_pareto_mask(y)), i


def test_pareto_mask_empty():
    assert pareto_mask(np.zeros((0, 3))).shape == (0,)


# ---------------------------------------------------------- ParetoArchive
def test_archive_streaming_equals_batch_front():
    rng = np.random.default_rng(2)
    for trial in range(10):
        n = int(rng.integers(1, 600))
        y = rng.random((n, 3))
        if trial % 3 == 0:
            y = np.concatenate([y, y[: max(1, n // 4)]], axis=0)
        arch = ParetoArchive(3)
        k = 0
        while k < len(y):
            b = int(rng.integers(1, 64))
            arch.insert(y[k:k + b], ids=np.arange(k, min(k + b, len(y))))
            k += b
        front = pareto_front(y)
        got = np.array(sorted(map(tuple, arch.y)))
        want = np.array(sorted(map(tuple, front)))
        assert got.shape == want.shape and np.allclose(got, want), trial
        assert arch.n_seen == len(y)
        # PHV of the streamed front == PHV of the full history
        assert hypervolume(arch.y, np.ones(3)) == pytest.approx(
            hypervolume(y, np.ones(3)), rel=1e-12)


def test_archive_ids_track_points():
    y = np.array([[0.5, 0.5], [0.2, 0.8], [0.6, 0.6], [0.1, 0.9]])
    arch = ParetoArchive(2)
    arch.insert(y, ids=np.arange(4))
    assert sorted(arch.ids.tolist()) == [0, 1, 3]            # row 2 dominated


def test_archive_capacity_prunes_by_crowding():
    rng = np.random.default_rng(3)
    arch = ParetoArchive(3, capacity=16)
    for _ in range(20):
        arch.insert(rng.random((100, 3)))
    assert len(arch) <= 16
    assert arch.truncated
    # extremes per objective must survive crowding pruning
    before = arch.y.copy()
    arch.insert(rng.random((200, 3)))
    for j in range(3):
        assert arch.y[:, j].min() <= before[:, j].min() + 1e-12


# ------------------------------------------------------------ SweepEngine
def test_unrank_matches_flat_to_idx():
    import jax.numpy as jnp
    rng = np.random.default_rng(4)
    flat = rng.integers(0, SPACE.size, size=512)
    cards = tuple(int(c) for c in SPACE.cardinalities)
    got = np.asarray(_unrank(jnp.asarray(flat, jnp.int32), cards))
    assert np.array_equal(got, SPACE.flat_to_idx(flat))


def test_truncated_sweep_matches_brute_force(engine):
    res = engine.run(0, SUBSPACE)
    assert res.n_evaluated == SUBSPACE

    evaluator = get_evaluator("proxy")
    ys = evaluator(SPACE.flat_to_idx(np.arange(SUBSPACE)))

    # exact superior-to-reference count
    assert res.n_superior == int(dominates_ref(ys, res.ref_point).sum())
    # exact Pareto front (ids and objective rows)
    front = pareto_front(ys)
    assert len(res.pareto_ids) == len(front)
    assert np.allclose(np.sort(res.pareto_y, axis=0),
                       np.sort(front, axis=0), rtol=1e-6)
    mask = pareto_mask(ys)
    assert np.array_equal(np.sort(res.pareto_ids), np.flatnonzero(mask))
    # per-objective minima + the ids that achieve them
    for o in range(3):
        assert res.topk_val[o][0] == pytest.approx(ys[:, o].min(), rel=1e-6)
        assert ys[int(res.topk_ids[o][0]), o] == pytest.approx(
            ys[:, o].min(), rel=1e-6)


def test_sweep_objectives_match_evaluator(engine):
    """Sweep-path objectives == the evaluator's public fused path."""
    res = engine.run(0, 4096)
    idx = SPACE.flat_to_idx(res.pareto_ids)
    direct = get_evaluator("proxy").objectives(idx)
    assert np.allclose(res.pareto_y, direct, rtol=1e-6)


def test_sweep_checkpoint_resume(engine, tmp_path):
    ck = os.path.join(tmp_path, "sweep_ck")
    full = engine.run(0, 40_000)
    engine.run(0, 20_000, checkpoint_path=ck)
    res = engine.run(0, 40_000, resume_from=ck)
    assert res.n_evaluated == full.n_evaluated
    assert res.n_superior == full.n_superior
    assert np.array_equal(res.pareto_ids, full.pareto_ids)
    assert np.allclose(res.pareto_y, full.pareto_y)
    assert np.allclose(res.topk_val, full.topk_val)


def test_sweep_checkpoint_rejects_mismatched_config(engine, tmp_path):
    ck = os.path.join(tmp_path, "sweep_ck2")
    engine.run(0, 20_000, checkpoint_path=ck)
    other = SweepEngine(get_evaluator("target"), chunk_size=16_384)
    with pytest.raises(ValueError, match="different"):
        other.run(0, 40_000, resume_from=ck)
    # same config but a different reference point: superiority counts could
    # not be continued, so resume must refuse too
    shifted = SweepEngine(get_evaluator("proxy"), chunk_size=16_384,
                          ref_point=engine.ref_point * 2.0)
    with pytest.raises(ValueError, match="reference point"):
        shifted.run(0, 40_000, resume_from=ck)


def test_pallas_backend_rejects_compass_models():
    with pytest.raises(ValueError, match="pallas"):
        SweepEngine(get_evaluator("target"), backend="pallas")


# ----------------------------------------------------- run_method plumbing
def test_run_method_incremental_phv_curve():
    from repro.core.baselines import METHODS, run_method
    evaluator = get_evaluator("proxy")
    from repro.perfmodel.designspace import A100_REFERENCE
    ref = evaluator(SPACE.encode_nearest(A100_REFERENCE)[None, :])[0]
    r = run_method(METHODS["GA"], evaluator, budget=100, ref_point=ref,
                   seed=0, batch=8, curve_stride=25)
    # one curve point per stride crossing, final == full-history PHV
    assert len(r.phv_curve) == 4
    assert r.phv == pytest.approx(hypervolume(r.Y, ref), rel=1e-12)
    assert r.phv_curve[0] == pytest.approx(hypervolume(r.Y[:32], ref), rel=1e-12)
    assert np.all(np.diff(r.phv_curve) >= -1e-15)            # monotone
