"""Unified tiered Evaluator API (the PR's redesign invariants).

Covers: the fused multi-workload dispatch is bit-identical to per-model
single-workload dispatches on both fidelity tiers; the batched multi-design
path is bit-identical to N single-design dispatches; the Pallas kernel
backend agrees with the traced roofline backend; one DSE step costs exactly
one fused dispatch; the pre-PR-2 deprecation shims are GONE; the oracle
tier normalizes PHV against the exhaustive front; and the sweep's
per-stall-class top-k matches brute force.
"""
import numpy as np
import pytest

from repro.core.pareto import hypervolume, pareto_front
from repro.perfmodel import (CompassModel, EvalRequest, ModelEvaluator,
                             OracleEvaluator, RooflineModel, attribute_stalls,
                             get_evaluator, make_evaluator,
                             gpt3_layer_prefill, gpt3_layer_decode)
from repro.perfmodel.designspace import SPACE, A100_REFERENCE
from repro.perfmodel.evaluator import (as_evaluator, evaluator_for_model,
                                       resolve_backend)
from repro.perfmodel.sweep import SweepEngine

RNG = np.random.default_rng(11)


@pytest.fixture(scope="module")
def sample_idx():
    return SPACE.sample(RNG, 64)


@pytest.fixture(scope="module", params=["proxy", "target"])
def tier_setup(request):
    cls = {"proxy": RooflineModel, "target": CompassModel}[request.param]
    mt, mp = cls(gpt3_layer_prefill()), cls(gpt3_layer_decode())
    ev = ModelEvaluator({"ttft": mt, "tpot": mp}, tier=request.param)
    return ev, mt, mp


# ------------------------------------------------- fused == single-workload
def test_fused_bit_identical_to_single_workload(tier_setup, sample_idx):
    """The fused two-workload stalls dispatch reproduces each model's
    single-workload evaluation EXACTLY (same traced subgraphs, shared
    decode)."""
    ev, mt, mp = tier_setup
    rep = ev.stalls(sample_idx)
    for name, model in (("ttft", mt), ("tpot", mp)):
        solo = evaluator_for_model(model, name).stalls(sample_idx)
        assert np.array_equal(rep.latency[name], solo.latency[name])
        assert np.array_equal(rep.stall[name], solo.stall[name])
        assert np.array_equal(rep.op_time[name], solo.op_time[name])
        assert np.array_equal(rep.op_class[name], solo.op_class[name])
        assert np.array_equal(rep.area, solo.area)


# ------------------------------------------------- batched == N x single
def test_batched_multi_design_bit_identical_to_singles(tier_setup):
    """The batched multi-design EvalRequest path (one fused dispatch for N
    designs) is bit-identical to N single-design dispatches — the invariant
    behind CampaignRunner's one-dispatch-per-round batching.  N equals the
    smallest bucket size so the padded single-design calls compile to the
    same executable shape."""
    ev, _, _ = tier_setup
    idx = SPACE.sample(np.random.default_rng(5), 8)
    batched = ev.evaluate(EvalRequest(idx, detail="stalls"))
    for i in range(idx.shape[0]):
        single = ev.evaluate(EvalRequest(idx[i], detail="stalls"))
        assert np.array_equal(batched.area[i:i + 1], single.area)
        for w in ev.workloads:
            assert np.array_equal(batched.latency[w][i:i + 1],
                                  single.latency[w])
            assert np.array_equal(batched.stall[w][i:i + 1], single.stall[w])
            assert np.array_equal(batched.op_time[w][i:i + 1],
                                  single.op_time[w])
        # the row() view extracts the same single-design report
        row = batched.row(i)
        assert np.array_equal(row.area, single.area)
        assert row.stall_report("ttft").dominant == \
            single.stall_report("ttft").dominant


def test_detail_levels_and_subsets(tier_setup, sample_idx):
    ev, _, _ = tier_setup
    lean = ev.evaluate(EvalRequest(sample_idx, detail="objectives"))
    assert lean.stall is None and lean.op_time is None
    assert lean.objectives.shape == (64, 3)
    ppa = ev.ppa(sample_idx)
    assert ppa.op_time is not None and ppa.stall is None
    with pytest.raises(ValueError):
        ppa.stall_report("ttft")
    sub = ev.evaluate(EvalRequest(sample_idx[:4], detail="stalls",
                                  workloads=("tpot",)))
    assert sub.workloads == ("tpot",)
    assert sub.stall_report("tpot").latency > 0
    with pytest.raises(KeyError):
        ev.evaluate(EvalRequest(sample_idx, workloads=("nope",)))
    with pytest.raises(ValueError):
        EvalRequest(sample_idx, detail="everything")


def test_stall_report_matches_attribute_stalls(tier_setup):
    ev, mt, _ = tier_setup
    idx = SPACE.encode_nearest(A100_REFERENCE)
    rep = ev.stalls(idx).stall_report("ttft")
    legacy = attribute_stalls(mt, idx)
    assert rep.dominant == legacy.dominant
    assert rep.latency == pytest.approx(legacy.latency, rel=0)
    assert rep.top_ops == legacy.top_ops


# ------------------------------------------------------- backend registry
def test_pallas_backend_parity(sample_idx):
    """Kernel-backend objectives agree with the traced roofline backend on a
    sampled id set (interpret-mode tolerance, cf. tests/test_kernels.py)."""
    base = get_evaluator("proxy")
    pal = ModelEvaluator(base.models, backend="pallas")
    y_ref = base.objectives(sample_idx)
    y_pal = pal.objectives(sample_idx)
    np.testing.assert_allclose(y_pal[:, :2], y_ref[:, :2], rtol=1e-4)
    np.testing.assert_allclose(y_pal[:, 2], y_ref[:, 2], rtol=1e-5)


def test_pallas_rejects_compass_models():
    ct = CompassModel(gpt3_layer_prefill())
    cp = CompassModel(gpt3_layer_decode())
    with pytest.raises(ValueError, match="roofline tier"):
        ModelEvaluator({"ttft": ct, "tpot": cp}, backend="pallas")


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="unknown backend"):
        ModelEvaluator(get_evaluator("proxy").models, backend="gem5")


def test_auto_backend_resolves_to_registered_name():
    models = get_evaluator("proxy").models
    name = resolve_backend("auto", models)
    assert name in ("roofline", "pallas")
    # compass-tier knobs are never routed to the kernel
    ct = {"ttft": CompassModel(gpt3_layer_prefill()),
          "tpot": CompassModel(gpt3_layer_decode())}
    assert resolve_backend("auto", ct) == "roofline"


# ------------------------------------------------------- dispatch counting
def test_one_fused_dispatch_per_dse_step():
    """Acceptance criterion: each budgeted DSE step issues exactly ONE fused
    jitted evaluation dispatch on the target tier."""
    from repro.core.loop import LuminaDSE
    target = ModelEvaluator(get_evaluator("target").models, tier="target")
    proxy = get_evaluator("proxy")
    d0 = target.dispatches
    res = LuminaDSE(target, proxy=proxy, seed=0).run(budget=8)
    assert len(res.samples) == 8
    # ref eval costs 1 dispatch; step 0 re-reads it from the report cache
    assert target.dispatches - d0 == 8


def test_evaluator_memoized_per_tier():
    assert get_evaluator("proxy") is get_evaluator("proxy")
    assert get_evaluator("proxy") is not get_evaluator("target")


# ------------------------------------------- deprecation shims are GONE
def test_legacy_shims_removed():
    """The one-release deprecation window closed: per-model eval paths and
    the (ttft, tpot) pair signature no longer exist."""
    mt, mp = (get_evaluator("proxy").models[w] for w in ("ttft", "tpot"))
    for attr in ("eval_ppa", "objectives", "latency"):
        assert not hasattr(mt, attr), attr
    with pytest.raises(TypeError):
        as_evaluator(mt, mp)
    with pytest.raises(TypeError):
        from repro.core.loop import LuminaDSE
        LuminaDSE(mt, mp)
    with pytest.raises(ImportError):
        from repro.perfmodel import make_paper_evaluator  # noqa: F401


def test_single_model_coercion():
    mt = get_evaluator("proxy").models["ttft"]
    ev = as_evaluator(mt)
    assert ev.workloads == ("lat",)
    assert as_evaluator(ev) is ev


# ------------------------------------------------------- oracle tier
SUB = 20_000


@pytest.fixture(scope="module")
def oracle():
    return OracleEvaluator(get_evaluator("proxy"),
                           sweep_kwargs=dict(chunk_size=8_192),
                           stop=SUB)


def test_oracle_front_matches_brute_force(oracle):
    ys = oracle.objectives(SPACE.flat_to_idx(np.arange(SUB)))
    front = pareto_front(ys)
    got = np.sort(oracle.front(), axis=0)
    assert np.allclose(got, np.sort(front, axis=0), rtol=1e-6)


def test_oracle_normalized_phv_bounds(oracle):
    # reference point dominated by the sub-front (ids [0, SUB) are a weak
    # corner of the space, so the A100 point would give zero PHV here)
    ref = oracle.front().max(axis=0) * 2.0
    # any sampled sub-front's PHV normalizes into [0, 1]
    ys = oracle.objectives(SPACE.flat_to_idx(np.arange(0, SUB, 7)))
    phv = hypervolume(ys, ref)
    frac = oracle.normalized_phv(phv, ref)
    assert 0.0 <= frac <= 1.0 + 1e-9
    assert oracle.normalized_phv(oracle.oracle_phv(ref), ref) == pytest.approx(1.0)
    # regret of the oracle's own front is ~zero
    assert np.allclose(oracle.regret(oracle.front()), 0.0, atol=1e-9)


# ------------------------------------------------------- sweep stall top-k
def test_sweep_stall_topk_matches_brute_force():
    ev = get_evaluator("proxy")
    eng = SweepEngine(ev, chunk_size=8_192, stall_topk=8)
    res = eng.run(0, SUB)
    idx = SPACE.flat_to_idx(np.arange(SUB))
    rep = ev.evaluate(EvalRequest(idx, detail="stalls"))
    dom = np.argmax(rep.stall["ttft"], axis=1)
    lat = rep.latency["ttft"]
    for c in range(4):
        lat_c = np.where(dom == c, lat, np.inf)
        want = np.sort(lat_c)[:8]
        got = res.stall_topk_val[c]
        finite = np.isfinite(want)
        assert np.allclose(got[finite], want[finite], rtol=1e-6), c
        # claimed ids really have this dominant class and latency
        for k in np.flatnonzero(np.isfinite(got)):
            fid = int(res.stall_topk_ids[c][k])
            assert fid >= 0
            assert lat[fid] == pytest.approx(got[k], rel=1e-6)
            assert dom[fid] == c
    seeds = res.stall_seeds()
    assert set(seeds) == {"tensor_compute", "vector_compute", "memory_bw",
                          "interconnect"}
    for arr in seeds.values():
        assert arr.ndim == 2 and arr.shape[1] == SPACE.n_params


def test_sweep_stall_topk_checkpoint_roundtrip(tmp_path):
    import os
    ev = get_evaluator("proxy")
    eng = SweepEngine(ev, chunk_size=8_192, stall_topk=4)
    ck = os.path.join(tmp_path, "ck")
    full = eng.run(0, SUB)
    eng.run(0, SUB // 2, checkpoint_path=ck)
    res = eng.run(0, SUB, resume_from=ck)
    assert np.allclose(res.stall_topk_val, full.stall_topk_val, rtol=1e-7)
    assert np.array_equal(res.stall_topk_ids, full.stall_topk_ids)
    # an engine without stall tracking refuses a stall-less checkpoint
    plain = SweepEngine(ev, chunk_size=8_192)
    plain.run(0, SUB // 2, checkpoint_path=ck + "2")
    strict = SweepEngine(ev, chunk_size=8_192, stall_topk=4)
    with pytest.raises(ValueError, match="stall"):
        strict.run(0, SUB, resume_from=ck + "2")


def test_sweep_engine_from_evaluator_matches_pair():
    mt, mp = (get_evaluator("proxy").models[w] for w in ("ttft", "tpot"))
    a = SweepEngine(get_evaluator("proxy"), chunk_size=8_192)
    b = SweepEngine(mt, mp, chunk_size=8_192)
    ra, rb = a.run(0, SUB // 2), b.run(0, SUB // 2)
    assert ra.n_superior == rb.n_superior
    assert np.array_equal(ra.pareto_ids, rb.pareto_ids)
