"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rwkv6_scan.ops import rwkv6_scan
from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref
from repro.kernels.ssm_scan.ops import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref
from repro.kernels.ppa_eval.ops import ppa_eval
from repro.kernels.ppa_eval.ref import ppa_eval_ref
from repro.perfmodel.designspace import SPACE
from repro.perfmodel.workload import gpt3_layer_prefill, gpt3_layer_decode

RNG = np.random.default_rng(0)


def _randn(shape, dtype):
    x = RNG.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,hd,bq,bk,causal", [
    (2, 128, 2, 64, 64, 64, True),
    (1, 256, 4, 128, 128, 64, True),
    (2, 64, 2, 32, 32, 32, False),
    (1, 128, 1, 64, 128, 128, True),
])
def test_flash_attention(b, s, h, hd, bq, bk, causal, dtype):
    q, k, v = (_randn((b, s, h, hd), dtype) for _ in range(3))
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=True)

    def fl(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, s, hd)

    ref = attention_ref(fl(q), fl(k), fl(v), causal=causal) \
        .reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,h,hd,bt", [
    (2, 64, 2, 16, 16), (1, 128, 4, 32, 64), (2, 32, 1, 64, 32),
])
def test_rwkv6_scan(b, t, h, hd, bt, dtype):
    r = _randn((b, t, h, hd), dtype) * 0.5
    k = _randn((b, t, h, hd), dtype) * 0.5
    v = _randn((b, t, h, hd), dtype) * 0.5
    w = jnp.asarray(RNG.uniform(0.3, 0.99, (b, t, h, hd)), dtype)
    u = jnp.asarray(RNG.standard_normal((h, hd)) * 0.1, jnp.float32)
    y = rwkv6_scan(r, k, v, w, u, block_t=bt, interpret=True)

    def fl(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, t, hd)

    uf = jnp.broadcast_to(u[None], (b, h, hd)).reshape(b * h, 1, hd)
    ref = rwkv6_scan_ref(fl(r), fl(k), fl(v), fl(w), uf) \
        .reshape(b, h, t, hd).transpose(0, 2, 1, 3)
    tol = 5e-2 if dtype == jnp.bfloat16 else 5e-5
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,d,n,bt,bd", [
    (2, 64, 32, 8, 16, 16), (1, 128, 64, 16, 64, 32), (2, 32, 16, 4, 32, 16),
])
def test_ssm_scan(b, t, d, n, bt, bd, dtype):
    u = _randn((b, t, d), dtype)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (b, t, d)), dtype)
    a = -jnp.asarray(RNG.uniform(0.5, 2.0, (d, n)), jnp.float32)
    B = _randn((b, t, n), dtype)
    C = _randn((b, t, n), dtype)
    y = ssm_scan(u, dt, a, B, C, block_t=bt, block_d=bd, interpret=True)
    ref = ssm_scan_ref(u, dt, a, B, C)
    tol = 5e-2 if dtype == jnp.bfloat16 else 5e-5
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("wl_fn", [gpt3_layer_prefill, gpt3_layer_decode])
@pytest.mark.parametrize("n", [64, 300])
def test_ppa_eval(wl_fn, n):
    wl = wl_fn()
    idx = SPACE.sample(np.random.default_rng(7), n)
    out = ppa_eval(idx, wl, interpret=True)
    ref = ppa_eval_ref(idx, wl)
    np.testing.assert_allclose(out["latency"], ref[:, 0], rtol=1e-4)
    np.testing.assert_allclose(out["area"], ref[:, 5], rtol=1e-5)
    np.testing.assert_allclose(out["stall"], ref[:, 1:5], rtol=1e-4, atol=1e-9)


def test_model_uses_chunked_for_long_seq():
    """The auto dispatch threshold guards prefill_32k memory."""
    from repro.models.attention import CHUNKED_THRESHOLD
    assert CHUNKED_THRESHOLD <= 8192
