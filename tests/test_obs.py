"""repro.obs: the unified metrics registry + causal tracing.

Covers: instrument semantics (typed counters/gauges/histograms, label
series, conflict rejection), tracer causality (nesting, detached spans,
adoption, loss), Perfetto export schema + tree completeness, the frozen
pre-registry telemetry() key sets (the bit-for-bit back-compat the
migration promised — checked under chaos), heartbeat RTT capture, and
one cross-machine causal tree over a 2-worker loopback socket fleet.
"""
import json

import numpy as np
import pytest

from repro.core.campaign import (TELEMETRY_VERSION, CampaignRunner,
                                 load_telemetry)
from repro.distributed import EvalService, ShardedEvaluator
from repro.distributed.faults import FaultEvent, FaultPlan
from repro.distributed.service import DEGRADE_RUNGS, QOS_TIERS
from repro.obs import (ManualClock, MetricsRegistry, NOOP, Span, Tracer,
                       completeness_errors, render_tree, trace_events,
                       validate_trace_events)
from repro.obs.metrics import Counter, CounterView
from repro.obs.report import fleet_report
from repro.perfmodel.evaluator import (EvalRequest, ModelEvaluator,
                                       get_evaluator)
from repro.perfmodel.designspace import SPACE
from repro.serve import Gateway, SocketPool, WorkerServer

RNG = np.random.default_rng(7)


def _fresh(tier: str = "proxy") -> ModelEvaluator:
    return ModelEvaluator(get_evaluator(tier).models, tier=tier)


@pytest.fixture(scope="module")
def servers():
    s1, s2 = WorkerServer(), WorkerServer()
    s1.start()
    s2.start()
    yield s1, s2
    s1.close()
    s2.close()


# ------------------------------------------------------------------ metrics
def test_counter_gauge_histogram_basics():
    m = MetricsRegistry()
    c = m.counter("reqs", "requests", labelnames=("tier",))
    c.inc(tier="fast")
    c.inc(2, tier="slow")
    assert c.value(tier="fast") == 1 and c.value(tier="slow") == 2
    assert c.total() == 3
    with pytest.raises(ValueError):
        c.inc(-1, tier="fast")                 # counters are monotonic
    with pytest.raises(ValueError):
        c.inc()                                # label schema enforced

    g = m.gauge("depth")
    g.set(4)
    g.set(2)
    assert g.value() == 2                      # last write wins

    h = m.histogram("lat", reservoir=100)
    assert h.stats()["p50"] is None            # empty -> None, not 0
    for v in range(1, 101):
        h.observe(v / 100)
    st = h.stats()
    assert st["count"] == 100 and st["min"] == 0.01 and st["max"] == 1.0
    assert abs(st["p50"] - 0.505) < 1e-9
    assert h.percentile(99) == pytest.approx(st["p99"])


def test_registry_get_or_create_and_conflicts():
    m = MetricsRegistry()
    c1 = m.counter("n", "first")
    assert m.counter("n") is c1                # same schema -> same object
    with pytest.raises(ValueError):
        m.gauge("n")                           # kind conflict
    with pytest.raises(ValueError):
        m.counter("n", labelnames=("x",))      # label-schema conflict
    assert m.get("n") is c1 and m.get("missing") is None


def test_counter_view_is_a_faithful_mapping():
    c = Counter("served", labelnames=("tier",))
    with pytest.raises(ValueError):
        CounterView(Counter("plain"))          # needs exactly one label
    view = CounterView(c)
    c.touch(tier="batch")
    c.inc(3, tier="interactive")
    assert view["interactive"] == 3 and view["batch"] == 0
    assert isinstance(view["batch"], int)
    assert dict(view) == {"batch": 0, "interactive": 3}
    assert sum(view.values()) == 3
    with pytest.raises(KeyError):
        view["never-touched"]


def test_flat_csv_and_snapshot_roundtrip():
    m = MetricsRegistry()
    m.counter("a", labelnames=("k",)).inc(k="x")
    m.histogram("h").observe(0.5)
    flat = m.flat()
    assert flat["a{k=x}"] == 1.0
    assert flat["h_count"] == 1.0 and flat["h_p50"] == 0.5
    lines = m.csv_lines()
    assert lines[0] == "metric,value" and any(
        line.startswith("a{k=x},") for line in lines)
    # snapshot is pure JSON (the gateway persists it verbatim)
    snap = json.loads(m.to_json())
    assert snap["a"]["type"] == "counter"
    assert snap["h"]["series"][0]["count"] == 1


def test_manual_clock_drives_deterministic_timing():
    clk = ManualClock()
    tr = Tracer(clock=clk, proc="t")
    with tr.span("op"):
        clk.advance(1.5)
    (sp,) = tr.spans()
    assert sp.duration_s == 1.5


# ------------------------------------------------------------------ tracer
def test_tracer_nests_and_marks_errors():
    tr = Tracer(clock=ManualClock(), proc="p")
    with tr.span("outer") as outer:
        with tr.span("inner", rows=3) as inner:
            assert tr.current() is inner
        with pytest.raises(RuntimeError):
            with tr.span("boom"):
                raise RuntimeError("no")
    spans = {s.name: s for s in tr.spans()}
    assert spans["inner"].parent_id == outer.span_id
    assert spans["inner"].trace_id == outer.trace_id
    assert spans["inner"].attrs == {"rows": 3}
    assert spans["boom"].status == "error"
    assert "RuntimeError" in spans["boom"].attrs["error"]
    assert spans["outer"].parent_id is None


def test_detached_activate_adopt_and_lose():
    tr = Tracer(clock=ManualClock(), proc="client")
    root = tr.start("root", detached=True)
    assert tr.current() is None                # detached: not on the stack
    with tr.activate(root):
        child = tr.start("child", detached=True)
    assert child.parent_id == root.span_id

    # a remote tracer parents under the shipped ctx and ships dicts back
    remote = Tracer(clock=ManualClock(), proc="worker:h:1")
    with remote.span("remote.eval", parent=root.ctx):
        pass
    assert tr.adopt(s.as_dict() for s in remote.drain()) == 1

    tr.lose(child, "worker died")
    tr.finish(root)
    tr.finish(root, status="error")            # idempotent: first wins
    by_name = {s.name: s for s in tr.spans()}
    assert by_name["root"].status == "ok"
    assert by_name["child"].status == "lost"
    assert by_name["child"].attrs["lost_reason"] == "worker died"
    assert by_name["remote.eval"].trace_id == root.trace_id
    assert completeness_errors(tr.spans()) == []


def test_noop_tracer_is_inert():
    assert NOOP.enabled is False
    with NOOP.span("x") as sp:
        sp.attrs["y"] = 1                      # harmless, unrecorded
    assert NOOP.current_ctx() is None
    assert NOOP.adopt([{"name": "z"}]) == 0
    assert NOOP.spans() == [] and NOOP.drain() == []


# ------------------------------------------------------------------ export
def test_trace_events_schema_and_tree_checks():
    tr = Tracer(clock=ManualClock(), proc="main")
    with tr.span("a"):
        with tr.span("b"):
            pass
    obj = trace_events(tr.spans())
    assert validate_trace_events(obj) == []
    assert obj["otherData"]["schema_version"] == 1
    phases = {e["ph"] for e in obj["traceEvents"]}
    assert phases == {"M", "X"}
    # the renderer shows the nesting and the validator catches breakage
    txt = render_tree(tr.spans())
    assert "a" in txt and "`-- " in txt
    assert validate_trace_events({"traceEvents": [{"ph": "Q"}]})
    dangling = Span("x", "t1", "s9", "missing", "p", "th", 0.0, t_end=None)
    errs = completeness_errors([dangling])
    assert any("dangling" in e for e in errs)
    assert any("never finished" in e for e in errs)


# ------------------------------------------- frozen telemetry key sets
SERVICE_KEYS = frozenset({"submits", "cache_hits", "fused_dispatches",
                          "coalesced_requests", "degraded", "tiers"})
EVALUATOR_KEYS = frozenset(
    f"evaluator_{n}" for n in ("dispatches", "worker_dispatches", "retried",
                               "straggler_redispatches", "timeouts",
                               "corrupt_rejected", "resizes"))
TIER_KEYS = frozenset({"weight", "served", "queued", "p50_ms", "p99_ms"})
TENANT_KEYS = frozenset({"rows_per_window", "used_rows", "admitted",
                         "admitted_rows", "rejected_budget",
                         "rejected_backpressure"})
ADMISSION_KEYS = frozenset({"admitted", "rejected", "max_queued_rows",
                            "rows_per_window", "window_s",
                            "observed_rows_per_s"})


def test_service_telemetry_keys_frozen_under_chaos():
    """The registry migration preserves every pre-registry telemetry()
    key, including while retries/timeouts are actually firing."""
    plan = FaultPlan([FaultEvent(0, 0, "crash"), FaultEvent(1, 1, "crash")])
    sharded = ShardedEvaluator(_fresh(), workers=2, mode="thread",
                               fault_plan=plan, speculate=False)
    svc = EvalService(sharded)
    svc.evaluate(EvalRequest(SPACE.sample(RNG, 8), detail="stalls"))
    tel = svc.telemetry()
    assert frozenset(tel) == SERVICE_KEYS | EVALUATOR_KEYS
    assert frozenset(tel["degraded"]) == {"deadline"} | set(DEGRADE_RUNGS)
    assert frozenset(tel["tiers"]) == frozenset(QOS_TIERS)
    for t in QOS_TIERS:
        assert frozenset(tel["tiers"][t]) == TIER_KEYS
    assert tel["evaluator_retried"] >= 2       # the chaos really happened
    assert all(isinstance(tel[k], int)
               for k in ("submits", "cache_hits", "fused_dispatches",
                         "coalesced_requests"))
    svc.close()


def test_gateway_telemetry_keys_frozen():
    gw = Gateway(_fresh(), rows_per_window=100, max_queued_rows=10_000)
    gw.evaluate(EvalRequest(SPACE.sample(RNG, 3)), tenant="acme")
    with pytest.raises(Exception):
        gw.submit(EvalRequest(SPACE.sample(RNG, 200)), tenant="acme")
    tel = gw.telemetry()
    assert frozenset(tel) == {"service", "tenants", "admission"}
    assert frozenset(tel["admission"]) == ADMISSION_KEYS
    assert frozenset(tel["tenants"]["acme"]) == TENANT_KEYS
    assert tel["tenants"]["acme"]["admitted"] == 1
    assert tel["tenants"]["acme"]["rejected_budget"] == 1
    assert tel["admission"] == gw.telemetry()["admission"]  # stable view
    gw.close()


def test_gateway_snapshot_merges_component_registries(tmp_path):
    sharded = ShardedEvaluator(_fresh(), workers=2, mode="thread")
    gw = Gateway(EvalService(sharded))
    gw.evaluate(EvalRequest(SPACE.sample(RNG, 4)))
    snap = gw.snapshot()
    assert frozenset(snap) == {"telemetry", "metrics"}
    assert {"gateway", "service", "evaluator"} <= set(snap["metrics"])
    assert snap["metrics"]["evaluator"]["sharded_dispatches"]["type"] \
        == "counter"
    path = tmp_path / "snap.json"
    gw.save_snapshot(path)
    loaded = json.loads(path.read_text())
    # the fleet dashboard renders straight off the persisted snapshot
    txt = fleet_report(loaded)
    assert "traffic" in txt and "gateway_admitted" not in txt
    gw.close()


# ---------------------------------------------------- heartbeat RTT
def test_heartbeat_rtt_histogram_per_worker(servers):
    s1, s2 = servers
    import time
    pool = SocketPool(_fresh(), 2,
                      addresses=[(s1.host, s1.port), (s2.host, s2.port)],
                      heartbeat_s=0.05)
    try:
        deadline = time.monotonic() + 5.0
        h = pool.metrics.get("heartbeat_rtt")
        while time.monotonic() < deadline:
            keys = set(h.series_keys())
            if keys == {("0",), ("1",)} and all(
                    h.count(worker=k[0]) >= 2 for k in keys):
                break
            time.sleep(0.02)
        assert set(h.series_keys()) == {("0",), ("1",)}
        for slot in ("0", "1"):
            st = h.stats(worker=slot)
            assert st["count"] >= 2
            assert 0 <= st["min"] <= st["max"] < 5.0
    finally:
        pool.close()


def test_gateway_surfaces_fleet_heartbeat_rtt(servers):
    s1, s2 = servers
    ev = ShardedEvaluator(_fresh(), mode="socket",
                          addresses=[(s1.host, s1.port), (s2.host, s2.port)])
    gw = Gateway(EvalService(ev))
    # deterministic: feed the registered histogram directly rather than
    # waiting out the 1 s heartbeat period
    ev.metrics.get("heartbeat_rtt").observe(0.002, worker="0")
    fleet = gw.telemetry()["fleet"]
    assert fleet["heartbeat_rtt"]["0"]["count"] == 1
    assert fleet["heartbeat_rtt"]["0"]["p50_ms"] == pytest.approx(2.0)
    assert fleet["heartbeat_rtt"]["0"]["p99_ms"] == pytest.approx(2.0)
    gw.close()


# ------------------------------------------- cross-machine causal tree
def _one_tree(spans, root_name):
    """Assert the spans form exactly one complete tree rooted at
    root_name and return {span name -> [spans]}."""
    roots = [s for s in spans if s.parent_id is None]
    assert [r.name for r in roots] == [root_name]
    assert completeness_errors(spans, trace_id=roots[0].trace_id) == []
    by_name = {}
    for s in spans:
        by_name.setdefault(s.name, []).append(s)
    return by_name


def test_socket_fleet_exports_single_causal_tree(servers):
    """Acceptance: one Gateway.evaluate against a 2-worker socket fleet
    exports ONE causal span tree spanning client and worker processes."""
    s1, s2 = servers
    tr = Tracer(proc="client")
    ev = ShardedEvaluator(_fresh(), mode="socket",
                          addresses=[(s1.host, s1.port), (s2.host, s2.port)],
                          tracer=tr)
    gw = Gateway(EvalService(ev, tracer=tr), tracer=tr)
    gw.evaluate(EvalRequest(SPACE.sample(RNG, 23), detail="stalls"),
                tenant="trace-me")
    spans = tr.spans()
    by_name = _one_tree(spans, "gateway.evaluate")
    for expected in ("service.tick", "service.dispatch", "sharded.evaluate",
                     "shard", "wire.dispatch", "worker.eval",
                     "sharded.reassemble"):
        assert expected in by_name, f"missing {expected} spans"
    # worker spans were minted in the worker process lane and adopted
    assert all(w.proc.startswith("worker:") for w in by_name["worker.eval"])
    assert len(by_name["worker.eval"]) >= 2    # really fanned out
    # wire span -> shard attempt -> sharded.evaluate chain holds
    shard_ids = {s.span_id for s in by_name["shard"]}
    assert all(w.parent_id in shard_ids for w in by_name["wire.dispatch"])
    wire_ids = {s.span_id for s in by_name["wire.dispatch"]}
    assert all(w.parent_id in wire_ids for w in by_name["worker.eval"])
    # and the whole thing round-trips through the Perfetto exporter
    obj = trace_events(spans)
    assert validate_trace_events(obj) == []
    gw.close()


def test_chaos_faults_close_spans_as_error_or_lost(servers):
    """Crash + hang chaos: the tree stays complete — failed attempts are
    closed error/lost, never left dangling."""
    s1, s2 = servers
    tr = Tracer(proc="client")
    plan = FaultPlan([FaultEvent(0, 0, "crash"), FaultEvent(1, 1, "hang")])
    ev = ShardedEvaluator(_fresh(), mode="socket",
                          addresses=[(s1.host, s1.port), (s2.host, s2.port)],
                          fault_plan=plan, shard_timeout_s=1.0,
                          speculate=False, tracer=tr)
    ev.evaluate(EvalRequest(SPACE.sample(RNG, 16), detail="stalls"))
    spans = tr.spans()
    by_name = _one_tree(spans, "sharded.evaluate")
    statuses = {s.status for s in by_name["shard"]}
    assert "ok" in statuses                    # the retries succeeded
    assert statuses & {"error", "lost"}        # and the faults left a mark
    ev.close()


# ------------------------------------------- campaign telemetry format
def test_campaign_result_carries_metrics_and_v4_loads(tmp_path):
    runner = CampaignRunner(_fresh(), seed=3)
    res = runner.run(budget=3)
    tel = res.telemetry_dict()
    assert tel["version"] == TELEMETRY_VERSION == 5
    assert tel["metrics"]["campaign_rounds"]["series"][0]["value"] >= 1
    obs = tel["metrics"]["campaign_observations"]["series"]
    assert sum(s["value"] for s in obs) == len(res.telemetry)
    path = tmp_path / "tel.json"
    res.save_telemetry(path)
    assert load_telemetry(path)["version"] == TELEMETRY_VERSION

    # a v4 file (pre-metrics) upgrades in memory
    v4 = dict(tel)
    v4.pop("metrics")
    v4["version"] = 4
    p4 = tmp_path / "v4.json"
    p4.write_text(json.dumps(v4))
    up = load_telemetry(p4)
    assert up["version"] == TELEMETRY_VERSION and up["metrics"] is None

    # a FUTURE format refuses to load
    v9 = dict(v4, version=TELEMETRY_VERSION + 1)
    p9 = tmp_path / "v9.json"
    p9.write_text(json.dumps(v9))
    with pytest.raises(ValueError):
        load_telemetry(p9)
