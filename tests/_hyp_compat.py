"""Deterministic fallback for `hypothesis` in offline containers.

The property-test modules import hypothesis when available and fall back to
this shim otherwise, so tier-1 collection never depends on an optional
package.  The shim re-implements the tiny strategy surface those tests use
(`integers`, `floats`, `sampled_from`, `tuples`, `lists`) and runs each test
body on a fixed-seed random sample of examples — no shrinking, no database,
but the same oracle assertions get exercised.
"""
from __future__ import annotations

import random

_FALLBACK_SEED = 0xC0FFEE
_MAX_FALLBACK_EXAMPLES = 12


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


class _Strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda r: seq[r.randrange(len(seq))])

    @staticmethod
    def tuples(*strategies):
        return _Strategy(lambda r: tuple(s.draw(r) for s in strategies))

    @staticmethod
    def lists(strategy, min_size=0, max_size=10):
        return _Strategy(
            lambda r: [strategy.draw(r)
                       for _ in range(r.randint(min_size, max_size))])


st = _Strategies()


def settings(max_examples=20, deadline=None, **_kw):
    def deco(fn):
        fn._hyp_max_examples = max_examples
        return fn
    return deco


def given(*strategies):
    def deco(fn):
        n = min(getattr(fn, "_hyp_max_examples", 20), _MAX_FALLBACK_EXAMPLES)

        def wrapper():
            rng = random.Random(_FALLBACK_SEED)
            for _ in range(n):
                fn(*(s.draw(rng) for s in strategies))
        # NOT functools.wraps: pytest must see a zero-arg signature, or it
        # treats the strategy-filled parameters as missing fixtures.
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco
