"""Substrate tests: optimizer, compression, data, checkpointing, fault
tolerance, elastic planning."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # offline container: deterministic fallback
    from _hyp_compat import given, settings, st

from repro.optim import (AdamWConfig, adamw_init, adamw_update, cosine_lr,
                         compress_grads, decompress_grads, ef_init)
from repro.data import SyntheticLMDataset, make_batch_iter
from repro.checkpoint import (save_checkpoint, restore_checkpoint,
                              AsyncCheckpointer, latest_step)
from repro.runtime import (Heartbeat, PoolPlan, RetryPolicy, run_with_retries,
                           StragglerMonitor, plan_elastic_mesh,
                           plan_elastic_pool)


# ----------------------------------------------------------------- optimizer
def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                      total_steps=200)
    params = {"x": jnp.ones((4,)) * 5.0}
    opt = adamw_init(params)
    loss = lambda p: jnp.sum(p["x"] ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(cfg, g, opt, params)
    assert float(loss(params)) < 0.1


def test_cosine_lr_schedule():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(cosine_lr(cfg, jnp.asarray(0))) == pytest.approx(0.0)
    assert float(cosine_lr(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(cosine_lr(cfg, jnp.asarray(100))) == pytest.approx(0.0, abs=1e-6)


def test_grad_clipping_bounds_update():
    cfg = AdamWConfig(lr=1e-2, clip_norm=1.0, weight_decay=0.0)
    params = {"x": jnp.zeros((3,))}
    opt = adamw_init(params)
    huge = {"x": jnp.ones((3,)) * 1e6}
    _, _, m = adamw_update(cfg, huge, opt, params)
    assert float(m["grad_norm"]) > 1e5          # reported pre-clip


# ----------------------------------------------------------------- compression
@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_compression_error_feedback_is_unbiased_over_time(seed):
    """Repeatedly compressing the SAME gradient with error feedback must
    converge so the accumulated applied update matches the true sum."""
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)}
    ef = ef_init(g)
    applied = jnp.zeros_like(g["w"])
    n = 20
    for _ in range(n):
        comp, ef = compress_grads(g, ef)
        applied = applied + decompress_grads(comp, g)["w"]
    true = g["w"] * n
    # residual is bounded by one quantization step, not growing with n
    err = np.abs(np.asarray(applied - true)).max()
    scale = float(jnp.abs(g["w"]).max()) / 127.0
    assert err <= 2 * scale + 1e-6


def test_compression_ratio():
    g = {"w": jnp.ones((64, 64), jnp.float32)}
    comp, _ = compress_grads(g, ef_init(g))
    raw = 64 * 64 * 4
    sent = comp["w"]["q"].size + comp["w"]["scale"].size * 4
    assert sent < raw / 3.5                     # ~4x wire reduction


# ----------------------------------------------------------------- data
def test_dataset_deterministic_replay():
    ds = SyntheticLMDataset(vocab=256, seq_len=32, global_batch=4, seed=1)
    a = ds.batch_at(7)
    b = ds.batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch_at(8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_prefetch_iterator_order():
    ds = SyntheticLMDataset(vocab=64, seq_len=8, global_batch=2)
    it = make_batch_iter(ds, start_step=3, num_steps=5)
    got = [b["tokens"] for b in it]
    assert len(got) == 5
    np.testing.assert_array_equal(got[0], ds.batch_at(3)["tokens"])
    np.testing.assert_array_equal(got[4], ds.batch_at(7)["tokens"])


# ----------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.int32)}}
    save_checkpoint(str(tmp_path), 5, tree)
    assert latest_step(str(tmp_path)) == 5
    back = restore_checkpoint(str(tmp_path), 5, tree)
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(back["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_async_checkpointer_gc(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    tree = {"x": jnp.ones((4,))}
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    ck.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]


def test_restore_with_different_sharding(tmp_path):
    """Elastic-restart path: restore onto explicit (single-device) sharding."""
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(str(tmp_path), 1, tree)
    sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    back = restore_checkpoint(str(tmp_path), 1, tree, shardings={"w": sh})
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(tree["w"]))


# ----------------------------------------------------------------- fault
def test_retries_then_success():
    calls = {"n": 0, "restores": 0}

    def step():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("simulated preemption")
        return "ok"

    out = run_with_retries(step, lambda a: calls.__setitem__(
        "restores", calls["restores"] + 1), RetryPolicy(max_retries=3))
    assert out == "ok"
    assert calls["restores"] == 2


def test_retries_exhausted():
    def step():
        raise RuntimeError("dead host")

    with pytest.raises(RuntimeError, match="after 2 retries"):
        run_with_retries(step, lambda a: None, RetryPolicy(max_retries=2))


def test_retry_policy_fresh_default_per_call():
    """run_with_retries(policy=None) builds a NEW default policy per call —
    the old module-level default instance was shared by every caller."""
    import repro.runtime.fault as fault_mod
    import inspect
    sig = inspect.signature(run_with_retries)
    assert sig.parameters["policy"].default is None
    assert isinstance(fault_mod.RetryPolicy(), RetryPolicy)
    # and None still retries with the default budget
    calls = {"n": 0}

    def step():
        calls["n"] += 1
        if calls["n"] < 2:
            raise RuntimeError("flake")
        return calls["n"]

    assert run_with_retries(step, lambda a: None) == 2


def test_retry_policy_backoff_capped_exponential():
    p = RetryPolicy(backoff_s=0.5, max_backoff_s=3.0, jitter=0.0)
    assert p.delay(0) == pytest.approx(0.5)
    assert p.delay(1) == pytest.approx(1.0)
    assert p.delay(2) == pytest.approx(2.0)
    assert p.delay(3) == pytest.approx(3.0)      # capped
    assert p.delay(10) == pytest.approx(3.0)
    assert RetryPolicy(backoff_s=0.0).delay(5) == 0.0


def test_retry_policy_jitter_spreads_and_bounds():
    import random as _random
    p = RetryPolicy(backoff_s=1.0, max_backoff_s=8.0, jitter=0.25)
    rng = _random.Random(0)
    ds = [p.delay(1, rng=rng) for _ in range(200)]
    assert all(2.0 * 0.75 <= d <= 2.0 * 1.25 for d in ds)
    assert len({round(d, 6) for d in ds}) > 50    # actually randomized


def test_retry_policy_retryable_is_typed_tuple():
    p = RetryPolicy()
    assert isinstance(p.retryable, tuple)
    assert all(isinstance(t, type) for t in p.retryable)
    # non-retryable exceptions propagate unchanged
    with pytest.raises(KeyError):
        run_with_retries(lambda: (_ for _ in ()).throw(KeyError("x")),
                         lambda a: None,
                         RetryPolicy(retryable=(RuntimeError,)))
    # frozen: policies are shareable without aliasing state
    with pytest.raises(Exception):
        p.max_retries = 99


def test_heartbeat_file_liveness(tmp_path):
    path = str(tmp_path / "hb")
    hb = Heartbeat(path, interval_s=0.0)
    assert not Heartbeat.is_alive(path, timeout_s=10.0)   # no file yet
    hb.beat(step=3)
    assert Heartbeat.is_alive(path, timeout_s=10.0)
    assert not Heartbeat.is_alive(path, timeout_s=0.0)    # already expired


def test_straggler_monitor():
    mon = StragglerMonitor(window=16, threshold=2.0)
    for i in range(12):
        assert not mon.record(i, 0.1)
    assert mon.record(12, 0.5)             # 5x the median
    assert len(mon.flagged) == 1


# ----------------------------------------------------------------- elastic
def test_elastic_plan_shrinks_data_axis():
    p = plan_elastic_mesh(512, model_axis=16)
    assert p.shape == (2, 16, 16)
    p = plan_elastic_mesh(496, model_axis=16)   # lost one host of 16
    assert p.dp_degree == 31 - 0                # 496 // 16
    assert p.devices_used == 496
    p = plan_elastic_mesh(8, model_axis=16)
    assert p is None


def test_elastic_mesh_edge_cases():
    # fewer devices than one model group -> no plan at all
    assert plan_elastic_mesh(15, model_axis=16) is None
    # exactly one group: single pod, DP degree 1
    p = plan_elastic_mesh(16, model_axis=16)
    assert p.shape == (1, 16) and p.dp_degree == 1 and p.devices_used == 16
    # odd group count (5 groups of 16): cannot split into 2 balanced pods
    p = plan_elastic_mesh(80, model_axis=16)
    assert p.shape == (5, 16) and p.axes == ("data", "model")
    assert "single pod" in p.note
    # even group count >= 4 prefers two pods
    p = plan_elastic_mesh(96, model_axis=16)    # 6 groups -> 2 pods x 3
    assert p.shape == (2, 3, 16) and p.dp_degree == 6
    # pod preference off: stays a single flat mesh
    p = plan_elastic_mesh(96, model_axis=16, prefer_pods=False)
    assert p.shape == (6, 16)
    # leftover devices are dropped, not oversubscribed
    p = plan_elastic_mesh(50, model_axis=16)
    assert p.devices_used == 48 and p.dp_degree == 3


def test_elastic_pool_plan():
    # no backlog: shrink to the survivors, never below min_workers
    p = plan_elastic_pool(3, 0, min_workers=1, max_workers=8)
    assert isinstance(p, PoolPlan)
    assert p.workers == 3 and not p.grow and "hold" in p.note
    p = plan_elastic_pool(0, 0, min_workers=2, max_workers=8)
    assert p.workers == 2                        # clamped up to min
    # backlog pressure grows toward the cap
    p = plan_elastic_pool(2, 12, max_workers=8, target_queue=2.0)
    assert p.workers == 6 and p.grow and "grow" in p.note
    p = plan_elastic_pool(2, 100, max_workers=8)
    assert p.workers == 8                        # clamped to max
    # light backlog after worker loss: shrink instead of oversubscribing
    p = plan_elastic_pool(6, 2, min_workers=1, max_workers=8)
    assert p.workers == 1 and "shrink" in p.note
    with pytest.raises(ValueError, match="min_workers"):
        plan_elastic_pool(2, 0, min_workers=0)
    with pytest.raises(ValueError, match="max_workers"):
        plan_elastic_pool(2, 0, min_workers=4, max_workers=2)
