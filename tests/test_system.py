"""End-to-end behaviour tests for the Lumina DSE system (paper core)."""
import numpy as np
import pytest

from repro.perfmodel import (gpt3_layer_prefill, gpt3_layer_decode,
                             RooflineModel, CompassModel, ModelEvaluator,
                             attribute_stalls)
from repro.perfmodel.designspace import SPACE, A100_REFERENCE
from repro.core.loop import LuminaDSE
from repro.core.llm import RuleOracle, DegradedOracle


@pytest.fixture(scope="module")
def models():
    pre, dec = gpt3_layer_prefill(), gpt3_layer_decode()
    target = ModelEvaluator({"ttft": CompassModel(pre),
                             "tpot": CompassModel(dec)}, tier="target")
    proxy = ModelEvaluator({"ttft": RooflineModel(pre),
                            "tpot": RooflineModel(dec)})
    return target, proxy


def test_lumina_20_budget_finds_superior_designs(models):
    """Paper §5.3: under a strict 20-evaluation budget on the LLMCompass
    model, Lumina finds >= 6 designs that dominate the A100 reference."""
    target, proxy = models
    dse = LuminaDSE(target, proxy=proxy, seed=0)
    res = dse.run(budget=20)
    assert len(res.samples) == 20        # budget counts every simulator eval
    assert res.superior_count >= 6
    assert res.phv > 0


def test_lumina_no_duplicate_evaluations(models):
    target, proxy = models
    res = LuminaDSE(target, proxy=proxy, seed=1).run(budget=15)
    keys = {tuple(s.idx) for s in res.samples}
    assert len(keys) == len(res.samples)


def test_lumina_discovers_paper_strategy(models):
    """The discovered Pareto designs should reflect Table 4's pattern:
    fewer-or-equal cores than A100 with a larger systolic array, and at
    least as many memory channels."""
    target, proxy = models
    res = LuminaDSE(target, proxy=proxy, seed=0).run(budget=20)
    ref = SPACE.decode_np(SPACE.encode_nearest(A100_REFERENCE))
    hits = 0
    for s in res.pareto:
        v = SPACE.decode_np(s.idx)
        if v["sa_dim"] > ref["sa_dim"] and v["core_count"] <= ref["core_count"]:
            hits += 1
    assert hits >= 1, "no Pareto design shows the fewer-cores/bigger-SA pattern"


def test_refinement_recovers_from_degraded_oracle(models):
    """With an error-injecting oracle, the deny-list/refinement loop should
    still produce superior designs (robustness, paper §3.4)."""
    target, proxy = models
    dse = LuminaDSE(target, proxy=proxy,
                    llm=DegradedOracle(0.3, seed=3), seed=3)
    res = dse.run(budget=20)
    assert res.superior_count >= 2


def test_stall_attribution_sums_to_latency(models):
    target, proxy = models
    idx = SPACE.encode_nearest(A100_REFERENCE)
    for model in (target.models["ttft"], proxy.models["ttft"]):
        rep = attribute_stalls(model, idx)
        total = sum(rep.stall_seconds.values())
        assert total == pytest.approx(rep.latency, rel=1e-5)
        assert rep.dominant in rep.stall_seconds
