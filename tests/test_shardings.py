"""Sharding-rule tests: every sharded dim of every (arch x shape) spec must
divide the production mesh axes exactly (jax rejects uneven arg shardings —
these tests catch rule regressions without needing 512 devices)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES
from repro.launch import shardings as SH
from repro.models import build_model

MODEL = 16
DATA = {"single": 16, "multi": 32}
DP = {"single": ("data",), "multi": ("pod", "data")}


def _axis_size(ax, mesh_kind):
    if ax is None:
        return 1
    axes = (ax,) if isinstance(ax, str) else tuple(ax)
    n = 1
    for a in axes:
        n *= MODEL if a == "model" else (2 if a == "pod" else 16)
    return n


def _check_tree(spec_tree, shape_tree, tag, mesh_kind):
    specs = jax.tree_util.tree_leaves_with_path(
        spec_tree, is_leaf=lambda x: isinstance(x, P))
    shapes = jax.tree_util.tree_leaves(shape_tree)
    assert len(specs) == len(shapes), tag
    for (path, spec), leaf in zip(specs, shapes):
        shp = tuple(getattr(leaf, "shape", ()))
        parts = list(spec)
        assert len(parts) <= len(shp), (tag, path, spec, shp)
        for dim, ax in zip(shp, parts):
            size = _axis_size(ax, mesh_kind)
            assert dim % size == 0, \
                f"{tag} {jax.tree_util.keystr(path)}: dim {dim} % {size} != 0 ({spec}, {shp})"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_specs_divisible(arch):
    cfg = ARCHS[arch]
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.key(0))
    spec = SH.param_specs(params, MODEL)
    _check_tree(spec, params, arch, "single")


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("mesh_kind", ["single", "multi"])
def test_cache_specs_divisible(arch, mesh_kind):
    import functools
    cfg = ARCHS[arch]
    model = build_model(cfg)
    for sname, shape in SHAPES.items():
        if shape.mode != "decode" or sname in cfg.skip_shapes:
            continue
        spec = SH.cache_spec(cfg, shape, DP[mesh_kind], DATA[mesh_kind], MODEL)
        fn = functools.partial(model.init_cache, shape.global_batch,
                               shape.seq_len)
        if cfg.family == "audio":
            enc = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.enc_ctx, cfg.d_model), jnp.bfloat16)
            cache = jax.eval_shape(lambda e: fn(enc_out=e), enc)
        else:
            cache = jax.eval_shape(fn)
        _check_tree(spec, cache, f"{arch}/{sname}", mesh_kind)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_batch_specs_divisible(arch):
    cfg = ARCHS[arch]
    for sname, shape in SHAPES.items():
        if sname in cfg.skip_shapes:
            continue
        for mesh_kind in ("single", "multi"):
            spec = SH.batch_spec(cfg, shape, DP[mesh_kind], DATA[mesh_kind])
            b = shape.global_batch
            bspec = spec["tokens"][0]
            size = _axis_size(bspec, mesh_kind)
            assert b % size == 0, (arch, sname, mesh_kind)


def test_moe_ep_rules():
    """qwen2-moe pads 60 -> 64 experts so EP applies on a 16-mesh (perf
    iteration); arctic (128) EP-shards natively.  A hypothetical unpadded
    60-expert stack falls back to TP on the expert FF dim."""
    import dataclasses
    qcfg, acfg = ARCHS["qwen2-moe-a2.7b"], ARCHS["arctic-480b"]
    qm = jax.eval_shape(build_model(qcfg).init, jax.random.key(0))
    am = jax.eval_shape(build_model(acfg).init, jax.random.key(0))
    qs = SH.param_specs(qm, MODEL)["layers"]["moe"]["w_up"]
    as_ = SH.param_specs(am, MODEL)["layers"]["moe"]["w_up"]
    assert qs[1] == "model"                        # EP via padding (60 -> 64)
    assert as_[1] == "model"                       # EP natively
    raw = dataclasses.replace(qcfg, expert_pad=0)
    rm = jax.eval_shape(build_model(raw).init, jax.random.key(0))
    rs = SH.param_specs(rm, MODEL)["layers"]["moe"]["w_up"]
    assert rs[1] is None and rs[-1] == "model"     # fallback: TP on ff dim


def test_whisper_vocab_fallback():
    """51865 doesn't divide 16: embed falls back to d_model sharding."""
    cfg = ARCHS["whisper-medium"]
    params = jax.eval_shape(build_model(cfg).init, jax.random.key(0))
    spec = SH.param_specs(params, MODEL)
    assert spec["embed"] == P(None, "model")


def test_gqa_cache_fallback():
    """kv=8 archs shard the KV sequence (flash-decode), kv>=16 shard heads."""
    nemo = SH.cache_spec(ARCHS["mistral-nemo-12b"], SHAPES["decode_32k"],
                         ("data",), 16, MODEL)
    cq = SH.cache_spec(ARCHS["codeqwen1.5-7b"], SHAPES["decode_32k"],
                       ("data",), 16, MODEL)
    assert nemo["k"][2] in ("model", ("model",)) and nemo["k"][3] is None
    assert cq["k"][3] == "model" and cq["k"][2] is None
