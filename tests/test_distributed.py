"""Distributed evaluation service layer.

Covers the PR invariants: a ShardedEvaluator's reassembled PPAReport is
bit-identical to the local ModelEvaluator on the same EvalRequest (both
fidelity tiers, every pool mode incl. the workers=1 in-process fallback
and spawned processes); shard failures retry and stragglers re-dispatch;
an N-worker SweepEngine run reproduces the single-process Pareto front,
top-k tables and stall seeds EXACTLY (and multi-worker checkpoints refuse
mismatched spans); chunk_size="auto" picks a candidate by timed probe;
the EvalService coalesces K concurrent clients' requests into ONE fused
dispatch per tick with a shared cross-client cache; and a CampaignRunner
driven through the service keeps the ~1-dispatch-per-round invariant
without owning the batching.
"""
from concurrent.futures import Future

import numpy as np
import pytest

from repro.core.campaign import CampaignRunner
from repro.core.loop import LuminaDSE
from repro.distributed import EvalService, ShardedEvaluator
from repro.distributed.sharded import _InlinePool
from repro.perfmodel import (EvalRequest, ModelEvaluator, get_evaluator,
                             as_evaluator)
from repro.perfmodel.designspace import SPACE
from repro.perfmodel.sweep import SweepEngine

RNG = np.random.default_rng(3)


def _fresh(tier: str = "proxy") -> ModelEvaluator:
    """A fresh evaluator (own dispatch counter) over the memoized models."""
    return ModelEvaluator(get_evaluator(tier).models, tier=tier)


def _assert_reports_identical(a, b):
    assert a.workloads == b.workloads and a.detail == b.detail
    assert np.array_equal(a.area, b.area)
    for w in a.workloads:
        assert np.array_equal(a.latency[w], b.latency[w])
        if a.detail in ("ppa", "stalls"):
            assert np.array_equal(a.op_time[w], b.op_time[w])
            assert a.op_names[w] == b.op_names[w]
        if a.detail == "stalls":
            assert np.array_equal(a.stall[w], b.stall[w])
            assert np.array_equal(a.op_class[w], b.op_class[w])


# ------------------------------------------------------- sharded evaluator
@pytest.mark.parametrize("tier", ["proxy", "target"])
def test_sharded_bit_identical_to_local(tier):
    """Acceptance: ShardedEvaluator(workers=N) reassembles a PPAReport
    bit-identical to the local fused path, on both fidelity tiers."""
    idx = SPACE.sample(RNG, 23)                  # odd size: uneven shards
    local = _fresh(tier)
    sharded = ShardedEvaluator(_fresh(tier), workers=3)
    for detail in ("objectives", "stalls"):
        req = EvalRequest(idx, detail=detail)
        _assert_reports_identical(sharded.evaluate(req), local.evaluate(req))
    assert np.array_equal(sharded.objectives(idx), local.objectives(idx))
    sharded.close()


def test_sharded_workers1_inline_fallback():
    idx = SPACE.sample(RNG, 9)
    local = _fresh()
    sharded = ShardedEvaluator(_fresh(), workers=1, mode="auto")
    assert sharded.mode == "inline"
    _assert_reports_identical(sharded.evaluate(EvalRequest(idx, "stalls")),
                              local.evaluate(EvalRequest(idx, "stalls")))
    assert sharded.dispatches == 1               # one logical fused request
    assert sharded.worker_dispatches == 1        # served on-thread


def test_sharded_small_batch_stays_on_one_worker():
    sharded = ShardedEvaluator(_fresh(), workers=4, min_shard_rows=8)
    sharded.evaluate(EvalRequest(SPACE.sample(RNG, 5), "objectives"))
    assert sharded.worker_dispatches == 1        # below min_shard_rows x 2
    sharded.close()


def test_sharded_process_mode_bit_identical():
    """Spawned-process workers rebuild the evaluator from its pickled spec
    and still reproduce the local result exactly."""
    idx = SPACE.sample(RNG, 12)
    local = _fresh()
    sharded = ShardedEvaluator(_fresh(), workers=2, mode="process")
    try:
        _assert_reports_identical(
            sharded.evaluate(EvalRequest(idx, "stalls")),
            local.evaluate(EvalRequest(idx, "stalls")))
        assert sharded.worker_dispatches == 2
    finally:
        sharded.close()


class _FlakyPool:
    """Fails the first `fail_first` shard submissions, then delegates."""
    mode = "thread"

    def __init__(self, base, fail_first: int):
        self._inner = _InlinePool(base)
        self.workers = 3
        self._fails = fail_first

    def submit(self, payload):
        if self._fails > 0:
            self._fails -= 1
            fut: Future = Future()
            fut.set_exception(RuntimeError("worker died"))
            return fut
        return self._inner.submit(payload)

    def close(self):
        pass


def test_sharded_retries_failed_workers():
    idx = SPACE.sample(RNG, 21)
    local = _fresh()
    sharded = ShardedEvaluator(_fresh(), workers=3, retries=2)
    sharded._pool = _FlakyPool(sharded.base, fail_first=2)
    rep = sharded.evaluate(EvalRequest(idx, "stalls"))
    _assert_reports_identical(rep, local.evaluate(EvalRequest(idx, "stalls")))
    assert sharded.retried == 2


def test_sharded_raises_after_retry_budget():
    sharded = ShardedEvaluator(_fresh(), workers=3, retries=1)
    sharded._pool = _FlakyPool(sharded.base, fail_first=100)
    with pytest.raises(RuntimeError, match="failed after"):
        sharded.evaluate(EvalRequest(SPACE.sample(RNG, 9), "objectives"))


class _HangOnePool:
    """First submission of shard `hang_nth` returns a future that never
    resolves; everything else (incl. its backup) evaluates inline."""
    mode = "thread"

    def __init__(self, base, hang_nth: int):
        self._inner = _InlinePool(base)
        self.workers = 3
        self._hang_nth = hang_nth
        self._n = 0

    def submit(self, payload):
        n = self._n
        self._n += 1
        if n == self._hang_nth:
            return Future()                      # pending forever
        return self._inner.submit(payload)

    def close(self):
        pass


def test_sharded_straggler_redispatch():
    """A shard whose worker hangs is speculatively re-dispatched; the twin's
    result is used and the report stays identical."""
    idx = SPACE.sample(RNG, 21)
    local = _fresh()
    sharded = ShardedEvaluator(_fresh(), workers=3, straggler_min_s=0.01)
    sharded._pool = _HangOnePool(sharded.base, hang_nth=1)
    rep = sharded.evaluate(EvalRequest(idx, "stalls"))
    _assert_reports_identical(rep, local.evaluate(EvalRequest(idx, "stalls")))
    assert sharded.straggler_redispatches == 1


def test_get_evaluator_workers_knob():
    ev = get_evaluator("proxy", workers=2)
    assert isinstance(ev, ShardedEvaluator) and ev.workers == 2
    assert get_evaluator("proxy", workers=2) is ev         # memoized
    assert isinstance(get_evaluator("proxy"), ModelEvaluator)
    # inert knobs collapse onto the memoized base instance; bad modes raise
    assert get_evaluator("proxy", workers=1, mode="thread") \
        is get_evaluator("proxy")
    with pytest.raises(ValueError, match="mode"):
        get_evaluator("proxy", workers=2, mode="procss")
    assert as_evaluator(ev) is ev                          # protocol member
    idx = SPACE.sample(RNG, 6)
    assert np.array_equal(ev.objectives(idx),
                          get_evaluator("proxy").objectives(idx))


# ------------------------------------------------------- multi-worker sweep
@pytest.fixture(scope="module")
def sweep_engine():
    return SweepEngine(get_evaluator("proxy"), chunk_size=8_192,
                       stall_topk=4, stall_rank="ref")


def test_n_worker_sweep_identical_to_single(sweep_engine):
    """Acceptance: the N-worker sweep reproduces the single-process Pareto
    front, top-k tables and stall_seeds() exactly."""
    single = sweep_engine.run(0, 60_000)
    multi = sweep_engine.run(0, 60_000, workers=3)
    assert multi.n_evaluated == single.n_evaluated
    assert multi.n_superior == single.n_superior
    assert np.array_equal(multi.pareto_ids, single.pareto_ids)
    assert np.array_equal(multi.pareto_y, single.pareto_y)
    assert np.array_equal(multi.topk_val, single.topk_val)
    assert np.array_equal(multi.topk_ids, single.topk_ids)
    assert np.array_equal(multi.stall_topk_val, single.stall_topk_val)
    assert np.array_equal(multi.stall_topk_ids, single.stall_topk_ids)
    ss, ms = single.stall_seeds(), multi.stall_seeds()
    assert set(ss) == set(ms)
    for k in ss:
        assert np.array_equal(ss[k], ms[k])


def test_worker_checkpoints_roundtrip_and_span_guard(sweep_engine, tmp_path):
    ck = str(tmp_path / "wsweep")
    full = sweep_engine.run(0, 32_768, workers=2, checkpoint_path=ck)
    resumed = sweep_engine.run(0, 32_768, workers=2, resume_from=ck)
    assert np.array_equal(resumed.pareto_ids, full.pareto_ids)
    assert np.array_equal(resumed.topk_val, full.topk_val)
    assert resumed.n_evaluated == full.n_evaluated
    # a different range re-spans the workers; stale checkpoints must refuse
    with pytest.raises(ValueError, match="different"):
        sweep_engine.run(0, 65_536, workers=2, resume_from=ck)


def test_chunk_autotune_picks_candidate():
    cands = (8_192, 16_384)
    eng = SweepEngine(get_evaluator("proxy"), chunk_size="auto",
                      chunk_candidates=cands)
    assert eng.chunk_size in cands
    # memoized per process: an identical engine skips the probe
    eng2 = SweepEngine(get_evaluator("proxy"), chunk_size="auto",
                       chunk_candidates=cands)
    assert eng2.chunk_size == eng.chunk_size
    with pytest.raises(ValueError, match="auto"):
        SweepEngine(get_evaluator("proxy"), chunk_size="fastest")


# ------------------------------------------------------------- EvalService
def test_service_coalesces_k_clients_into_one_dispatch():
    """Acceptance: K concurrent clients' requests fuse into ONE dispatch
    per tick, each future resolving to the same report a direct evaluation
    would produce."""
    ev = _fresh()
    svc = EvalService(ev)
    local = _fresh()
    reqs = [EvalRequest(SPACE.sample(RNG, 3), detail="stalls")
            for _ in range(3)]
    reqs.append(EvalRequest(reqs[0].idx[:2], detail="objectives"))  # overlap
    d0 = ev.dispatches
    futs = [svc.submit(r) for r in reqs]
    rows = svc.tick()
    assert ev.dispatches - d0 == 1               # ONE fused dispatch
    assert rows == 9                             # overlapping rows deduped
    for r, f in zip(reqs, futs):
        _assert_reports_identical(f.result(), local.evaluate(r))
    assert svc.fused_dispatches == 1
    assert svc.coalesced_requests == len(reqs)


def test_service_shared_cache_across_clients():
    ev = _fresh()
    svc = EvalService(ev)
    idx = SPACE.sample(RNG, 5)
    svc.submit(EvalRequest(idx, detail="stalls"))
    svc.tick()
    d0 = ev.dispatches
    # a second client asking for any subset/detail of those rows resolves
    # at submit time, no queue, no dispatch
    fut = svc.submit(EvalRequest(idx[2:4], detail="objectives"))
    assert fut.done() and svc.cache_hits == 1
    assert svc.tick() == 0                       # nothing left to dispatch
    assert ev.dispatches == d0
    _assert_reports_identical(fut.result(),
                              _fresh().evaluate(EvalRequest(idx[2:4],
                                                            "objectives")))


def test_service_detail_promotion_reevaluates():
    """Rows cached at a lower detail than requested are re-dispatched at
    the higher detail (and upgraded in the cache)."""
    ev = _fresh()
    svc = EvalService(ev)
    idx = SPACE.sample(RNG, 4)
    svc.submit(EvalRequest(idx, detail="objectives"))
    assert svc.tick() == 4
    fut = svc.submit(EvalRequest(idx, detail="stalls"))
    assert not fut.done()                        # cached too shallow
    assert svc.tick() == 4                       # re-dispatched at "stalls"
    _assert_reports_identical(fut.result(),
                              _fresh().evaluate(EvalRequest(idx, "stalls")))
    fut2 = svc.submit(EvalRequest(idx, detail="objectives"))
    assert fut2.done()                           # upgraded entries serve all


def test_service_dispatch_failure_lands_on_futures():
    """An evaluator failure during tick() must resolve the drained futures
    with the exception — never orphan them (clients would hang forever)."""
    svc = EvalService(_fresh())
    fut = svc.submit(EvalRequest(SPACE.sample(RNG, 3), "objectives"))

    class _Broken:
        def evaluate(self, request):
            raise RuntimeError("backend down")

    svc.evaluator = _Broken()
    assert svc.tick() == 0
    with pytest.raises(RuntimeError, match="backend down"):
        fut.result(timeout=1)
    assert svc.fused_dispatches == 0


def test_service_is_a_drop_in_evaluator():
    """The service satisfies the Evaluator protocol: the single-campaign
    DSE loop runs through it unchanged (self-ticking synchronous calls)."""
    svc = EvalService(_fresh())
    assert as_evaluator(svc) is svc
    res = LuminaDSE(svc, proxy=get_evaluator("proxy"), seed=0).run(budget=4)
    assert len(res.samples) == 4


def test_campaign_runner_through_service_one_dispatch_per_round():
    """Acceptance: K campaigns driven through the EvalService issue ONE
    fused dispatch per round (the PR 3 ~B/K + O(1) invariant) with the
    SERVICE owning the batching, not the runner."""
    ev = _fresh()
    svc = EvalService(ev)
    runner = CampaignRunner(svc, proxy=get_evaluator("proxy"), seed=0)
    assert runner._service is svc
    budget = 12
    seeds = {"memory_bw": SPACE.sample(RNG, 2),
             "tensor_compute": SPACE.sample(RNG, 2)}
    res = runner.run(budget=budget, seeds=seeds)
    k = len(res.per_campaign)
    assert k >= 3
    assert len(res.samples) == budget
    assert res.rounds <= -(-budget // k) + 1
    # one fused dispatch per round + O(1) setup (reference eval + per-class
    # seed scoring), far below one dispatch per evaluation
    assert res.dispatches <= res.rounds + k + 2
    assert res.dispatches < budget
    assert svc.fused_dispatches <= res.rounds + k + 2


def test_service_round_robin_fairness_no_starvation():
    """A chatty client flooding the queue cannot starve a quiet one: with a
    per-tick row cap, the round-robin drain serves EVERY client's first
    request before any client's second — the quiet client's future resolves
    on the very next tick, not after the flood drains."""
    svc = EvalService(_fresh(), max_rows_per_tick=4)
    chatty = [svc.submit(EvalRequest(SPACE.sample(RNG, 1), "objectives"),
                         client="chatty") for _ in range(24)]
    quiet = svc.submit(EvalRequest(SPACE.sample(RNG, 1), "objectives"),
                       client="quiet")
    svc.tick()
    assert quiet.done()                          # served in the FIRST tick
    assert not all(f.done() for f in chatty)     # the flood keeps queueing
    ticks = 1
    while not all(f.done() for f in chatty):
        assert svc.tick() >= 0
        ticks += 1
        assert ticks < 50
    assert ticks > 2                             # the cap really paced it
    assert all(f.result().n == 1 for f in chatty)


def test_service_fair_drain_rotates_between_clients():
    """Unbounded ticks still resolve everything at once (the CampaignRunner
    invariant), and leftovers preserve per-client FIFO order under a cap."""
    svc = EvalService(_fresh())
    futs = [svc.submit(EvalRequest(SPACE.sample(RNG, 2), "objectives"),
                       client=f"c{i % 3}") for i in range(9)]
    svc.tick()
    assert all(f.done() for f in futs)           # one tick, everyone served
    # capped: client order within a lane stays FIFO
    svc2 = EvalService(_fresh(), max_rows_per_tick=1)
    a1 = svc2.submit(EvalRequest(SPACE.sample(RNG, 1), "objectives"),
                     client="a")
    a2 = svc2.submit(EvalRequest(SPACE.sample(RNG, 1), "objectives"),
                     client="a")
    svc2.tick()
    assert a1.done() and not a2.done()           # FIFO within the lane
    svc2.tick()
    assert a2.done()


def test_service_composes_with_sharded_evaluator():
    """EvalService(ShardedEvaluator(...)): coalesce across clients, then
    shard the fused batch across workers — reports stay bit-identical."""
    sharded = ShardedEvaluator(_fresh(), workers=2)
    svc = EvalService(sharded)
    idx = SPACE.sample(RNG, 8)
    futs = [svc.submit(EvalRequest(idx[:5], "stalls")),
            svc.submit(EvalRequest(idx[3:], "stalls"))]
    svc.tick()
    assert sharded.dispatches == 1               # one fused, sharded dispatch
    local = _fresh()
    _assert_reports_identical(futs[0].result(),
                              local.evaluate(EvalRequest(idx[:5], "stalls")))
    _assert_reports_identical(futs[1].result(),
                              local.evaluate(EvalRequest(idx[3:], "stalls")))
    sharded.close()

# -------------------------------------------------------- worker liveness
def test_worker_registry_heartbeat_roundtrip():
    """Heartbeat expiry -> eviction -> re-registration, on a fake clock."""
    from repro.distributed import WorkerRegistry
    clock = {"t": 0.0}
    reg = WorkerRegistry(timeout_s=10.0, now=lambda: clock["t"])
    for w in (0, 1, 2):
        reg.register(w)
    assert reg.live() == [0, 1, 2] and len(reg) == 3
    clock["t"] = 8.0
    reg.beat(1)                                  # only worker 1 stays fresh
    clock["t"] = 12.0                            # 0 and 2 expire (12 >= 10)
    assert reg.live() == [1]
    assert reg.alive(1) and not reg.alive(0)
    assert reg.evict_dead() == [0, 2]
    assert reg.evictions == 2 and len(reg) == 1
    # explicit death attribution beats the passive clock
    reg.mark_dead(1)
    assert not reg.alive(1)
    assert reg.evict_dead() == [1]
    # the worker comes back: same id, counted as a RE-registration
    reg.register(1)
    assert reg.reregistrations == 1
    assert reg.alive(1) and reg.live() == [1]
    # beating an unknown id is a no-op, not a resurrection
    reg.beat(7)
    assert not reg.alive(7)


def test_sharded_resize_rewires_pool_and_registry():
    """resize() changes the live pool fan-out and the liveness registry
    in lock-step, clamped to [1, max_workers]."""
    ev = ShardedEvaluator(_fresh(), workers=4, mode="thread", max_workers=4)
    try:
        idx = SPACE.sample(RNG, 12)
        before = ev.evaluate(EvalRequest(idx, "ppa"))
        assert sorted(ev.registry.live()) == [0, 1, 2, 3]
        ev.resize(2)
        assert ev.workers == 2 and ev._pool.workers == 2
        assert sorted(ev.registry.live()) == [0, 1]
        assert ev.resizes == 1
        after = ev.evaluate(EvalRequest(idx, "ppa"))
        _assert_reports_identical(before, after)  # size never changes results
        ev.resize(99)                             # clamped to max_workers
        assert ev.workers == 4
        ev.resize(0)                              # clamped to 1
        assert ev.workers == 1
        assert sorted(ev.registry.live()) == [0]
    finally:
        ev.close()
