"""Perfmodel calibration + structure tests (paper Tables 1 & 4)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.perfmodel import (gpt3_layer_prefill, gpt3_layer_decode,
                             RooflineModel, CompassModel)
from repro.perfmodel.designspace import (SPACE, A100_REFERENCE, DESIGN_A,
                                         DESIGN_B)
from repro.perfmodel.hardware import derive_hardware, area_mm2
from repro.perfmodel.workload import from_arch
from repro.core.quale import derive_influence_map
from repro.core.quane import sensitivity_analysis
from repro.configs import ARCHS


def _hw(values):
    v = {k: jnp.asarray([float(values[k])]) for k in SPACE.names}
    return {k: float(x[0]) for k, x in derive_hardware(v).items()}


def test_design_space_cardinality():
    assert SPACE.size == 4_741_632        # ~4.7M, paper Table 1


def test_a100_calibration():
    hw = _hw(A100_REFERENCE)
    assert hw["tensor_flops"] == pytest.approx(312e12, rel=0.01)   # TC FP16
    assert hw["mem_bw"] == pytest.approx(1555e9, rel=0.01)         # HBM2
    assert hw["ici_bw"] == pytest.approx(300e9, rel=0.01)          # NVLink3
    assert hw["area_mm2"] == pytest.approx(826, rel=0.01)          # die area


def test_table4_area_ratios():
    a100 = _hw(A100_REFERENCE)["area_mm2"]
    a = _hw(DESIGN_A)["area_mm2"] / a100
    b = _hw(DESIGN_B)["area_mm2"] / a100
    assert a == pytest.approx(0.772, abs=0.01)    # paper: 0.772
    assert b == pytest.approx(0.952, abs=0.02)    # paper: 0.952


@pytest.fixture(scope="module")
def target_ev():
    from repro.perfmodel import get_evaluator
    return get_evaluator("target")


def test_table4_perf_ratios(target_ev):
    """Normalized TTFT/TPOT of Lumina's designs A/B vs the A100, against the
    paper's reported values (TTFT exact to ~1%, TPOT within ~6%)."""
    vals = {}
    for tag, des in (("A100", A100_REFERENCE), ("A", DESIGN_A), ("B", DESIGN_B)):
        y = target_ev.objectives(SPACE.encode_nearest(des))[0]
        vals[tag] = (y[0], y[1])
    ttft_a = vals["A"][0] / vals["A100"][0]
    ttft_b = vals["B"][0] / vals["A100"][0]
    tpot_a = vals["A"][1] / vals["A100"][1]
    assert ttft_a == pytest.approx(0.717, abs=0.02)   # paper: 0.717
    assert ttft_b == pytest.approx(0.592, abs=0.02)   # paper: 0.592
    assert tpot_a == pytest.approx(0.947, abs=0.06)   # paper: 0.947


def test_more_channels_never_slower(target_ev):
    """Monotonicity: adding a memory channel can't increase latency."""
    idx = SPACE.encode_nearest(A100_REFERENCE)
    ci = SPACE.names.index("mem_channels")
    batch = np.repeat(idx[None, :], int(SPACE.cardinalities[ci]), axis=0)
    batch[:, ci] = np.arange(batch.shape[0])
    lats = target_ev.objectives(batch)[:, 0]
    assert all(lats[i + 1] <= lats[i] * 1.0001 for i in range(len(lats) - 1))


def test_influence_map_structure():
    """§3.2.1's example: vector throughput depends on core/sublane/vector
    width but NOT on the systolic array; interconnect only on links."""
    from repro.perfmodel import get_evaluator
    imap = derive_influence_map(get_evaluator("proxy"), n_probes=6, seed=0)
    assert "interconnect" in imap.stall_edges["link_count"]
    assert "interconnect" not in imap.stall_edges["sa_dim"]
    assert "area" in imap.metric_edges["core_count"]
    # every param influences area
    for p in SPACE.names:
        assert "area" in imap.metric_edges[p], p


def test_sensitivity_signs():
    from repro.perfmodel import get_evaluator
    idx = SPACE.encode_nearest(A100_REFERENCE)
    sens = sensitivity_analysis(get_evaluator("proxy"), idx)
    assert sens.delta["mem_channels"]["area"] > 0       # +channel = +area
    assert sens.delta["mem_channels"]["tpot"] < 0       # +channel = faster decode
    assert sens.delta["link_count"]["ttft"] < 0         # +links = faster prefill
    assert sens.delta["core_count"]["area"] > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_workloads_evaluate(arch):
    """Every assigned architecture doubles as a DSE workload."""
    cfg = ARCHS[arch]
    for decode in (False, True):
        wl = from_arch(cfg, batch=4, seq=512, decode=decode, kv_len=512)
        from repro.perfmodel.evaluator import evaluator_for_model
        rep = evaluator_for_model(RooflineModel(wl)).stalls(
            SPACE.encode_nearest(A100_REFERENCE))
        lat = rep.latency[rep.workloads[0]]
        assert np.isfinite(lat).all() and (lat > 0).all()
