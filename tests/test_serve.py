"""repro.serve: the cross-machine DSE-as-a-service layer.

Covers the PR invariants: the length-prefixed pickle wire round-trips
every message type and rejects oversized frames before allocation; the
pickled worker spec rides pickle.HIGHEST_PROTOCOL and rebuilds a
bit-identical evaluator; a ShardedEvaluator over a 2-worker loopback
socket pool is bit-identical to the local ModelEvaluator on both
fidelity tiers, under chaos injection, and across a worker SIGKILL
mid-stream (eviction -> elastic resize -> retry); dead connections
reconnect and re-register; the QoS weighted-deficit drain keeps
scavenger throughput > 0 under saturating interactive load while tier
weights shape relative throughput; the Gateway enforces per-tenant row
budgets and queue-depth backpressure with reject-with-retry-after; and
the persistent oracle store turns a repeat OracleEvaluator into an O(1)
artifact load with corrupt artifacts quarantined, never trusted.
"""
import os
import socket as socket_mod
import threading
import time

import numpy as np
import pytest

from repro.distributed import (EvalService, ShardedEvaluator, ShardPayload,
                               WorkerFault)
from repro.distributed.faults import FaultEvent, FaultPlan
from repro.distributed.sharded import _worker_spec, evaluator_from_spec
from repro.perfmodel import (EvalRequest, ModelEvaluator, OracleEvaluator,
                             get_evaluator)
from repro.perfmodel.designspace import SPACE
from repro.distributed.faults import QuotaExceeded
from repro.serve import (Gateway, Keyring, RetryAfter, SocketPool,
                         WIRE_VERSION, WorkerOptions, WorkerServer,
                         start_worker_process, wire)
from repro.serve import codec as codec_mod

RNG = np.random.default_rng(7)


def _fresh(tier: str = "proxy") -> ModelEvaluator:
    """A fresh evaluator (own dispatch counter) over the memoized models."""
    return ModelEvaluator(get_evaluator(tier).models, tier=tier)


def _assert_reports_identical(a, b):
    assert a.workloads == b.workloads and a.detail == b.detail
    assert np.array_equal(a.area, b.area)
    for w in a.workloads:
        assert np.array_equal(a.latency[w], b.latency[w])
        if a.detail in ("ppa", "stalls"):
            assert np.array_equal(a.op_time[w], b.op_time[w])
            assert a.op_names[w] == b.op_names[w]
        if a.detail == "stalls":
            assert np.array_equal(a.stall[w], b.stall[w])
            assert np.array_equal(a.op_class[w], b.op_class[w])


@pytest.fixture(scope="module")
def servers():
    """Two in-process worker daemons on loopback ephemeral ports."""
    s1, s2 = WorkerServer(), WorkerServer()
    s1.start()
    s2.start()
    yield s1, s2
    s1.close()
    s2.close()


# ---------------------------------------------------------------- wire
def test_wire_roundtrip_every_message_type():
    a, b = socket_mod.socketpair()
    try:
        for msg in (wire.Hello(b"spec"), wire.Ready("digest", ("lat",)),
                    wire.Dispatch(3, "payload"), wire.ResultMsg(3, "rep"),
                    wire.ErrorMsg(3, "boom"), wire.Ping(1), wire.Pong(1),
                    wire.Bye("done")):
            wire.send_msg(a, msg)
            assert wire.recv_msg(b) == msg
    finally:
        a.close()
        b.close()


def test_wire_rejects_oversized_frames_before_allocation():
    a, b = socket_mod.socketpair()
    try:
        wire.send_msg(a, wire.Dispatch(0, b"x" * 4096))
        with pytest.raises(wire.WireError, match="exceeds"):
            wire.recv_msg(b, max_bytes=64)
    finally:
        a.close()
        b.close()


def test_wire_eof_raises_connection_closed():
    a, b = socket_mod.socketpair()
    a.close()
    try:
        with pytest.raises(wire.ConnectionClosed):
            wire.recv_msg(b)
    finally:
        b.close()


def test_check_hello_gates_type_and_version():
    with pytest.raises(wire.WireError, match="expected Hello"):
        wire.check_hello(wire.Ping(0))
    with pytest.raises(wire.WireError, match="version"):
        wire.check_hello(wire.Hello(b"", wire_version=WIRE_VERSION + 1))
    hello = wire.Hello(b"spec")
    assert wire.check_hello(hello) is hello


# ---------------------------------------------------------------- spec
def test_spec_highest_protocol_and_roundtrip():
    """The worker spec rides pickle.HIGHEST_PROTOCOL and rebuilds an
    evaluator bit-identical to its source."""
    import pickle
    spec = _worker_spec(_fresh())
    assert spec[0] == 0x80                      # pickle protocol opcode
    assert spec[1] == pickle.HIGHEST_PROTOCOL
    rebuilt = evaluator_from_spec(spec)
    local = _fresh()
    idx = SPACE.sample(RNG, 9)
    for detail in ("objectives", "stalls"):
        req = EvalRequest(idx, detail=detail)
        _assert_reports_identical(rebuilt.evaluate(req), local.evaluate(req))


# -------------------------------------------------------- socket fabric
def test_socket_mode_argument_validation():
    with pytest.raises(ValueError, match="addresses"):
        ShardedEvaluator(_fresh(), mode="socket")
    with pytest.raises(ValueError, match="socket"):
        ShardedEvaluator(_fresh(), workers=2, addresses=[("h", 1)])


@pytest.mark.parametrize("tier", ["proxy", "target"])
def test_socket_sharded_bit_identical_to_local(servers, tier):
    """Acceptance: a 2-worker loopback socket pool reassembles reports
    bit-identical to the in-process evaluator, on both fidelity tiers."""
    s1, s2 = servers
    idx = SPACE.sample(RNG, 23)                 # odd size: uneven shards
    local = _fresh(tier)
    ev = ShardedEvaluator(_fresh(tier), mode="socket",
                          addresses=[(s1.host, s1.port), (s2.host, s2.port)])
    assert ev.mode == "socket" and ev.workers == 2
    for detail in ("objectives", "stalls"):
        req = EvalRequest(idx, detail=detail)
        _assert_reports_identical(ev.evaluate(req), local.evaluate(req))
    assert ev.worker_dispatches >= 2            # really fanned out
    snap = ev.registry.snapshot()
    assert sorted(snap["live"]) == [0, 1]
    ev.close()


def test_socket_chaos_crash_hang_bit_identical(servers):
    """FaultPlan chaos composes with the socket pool: a crashed dispatch
    retries and a hung one times out + retries, bit-identical result."""
    s1, s2 = servers
    idx = SPACE.sample(RNG, 16)
    local = _fresh().evaluate(EvalRequest(idx, "stalls"))
    plan = FaultPlan([FaultEvent(0, 0, "crash"), FaultEvent(1, 1, "hang")])
    ev = ShardedEvaluator(_fresh(), mode="socket",
                          addresses=[(s1.host, s1.port), (s2.host, s2.port)],
                          fault_plan=plan, shard_timeout_s=1.0,
                          speculate=False)
    rep = ev.evaluate(EvalRequest(idx, "stalls"))
    _assert_reports_identical(rep, local)
    assert ev.retried >= 2                      # crash + hang both retried
    assert ev.timeouts >= 1
    assert len(plan) == 0                       # every event consumed
    ev.close()


def test_socket_remote_evaluation_error_is_not_fatal(servers):
    """A worker-side evaluation failure surfaces as WorkerFault WITHOUT
    tearing the connection down — the next dispatch reuses it."""
    s1, _ = servers
    pool = SocketPool(_fresh(), addresses=[(s1.host, s1.port)])
    bad = ShardPayload(SPACE.sample(RNG, 2), "nonsense_detail", None)
    # the worker's EvalRequest validation rejects the detail remotely
    with pytest.raises(WorkerFault, match="remote evaluation"):
        pool.submit(bad).result(timeout=60)
    idx = SPACE.sample(RNG, 4)
    rep = pool.submit(ShardPayload(idx, "objectives", None)).result(timeout=60)
    _assert_reports_identical(rep, _fresh().evaluate(
        EvalRequest(idx, "objectives")))
    assert pool.live_workers() == 1 and pool.reconnects == 0
    pool.close()


def test_socket_pool_reconnect_reregisters(servers):
    """A dead connection fails in-flight work, is evicted from the
    registry, and the next submit redials + re-registers the slot."""
    s1, _ = servers
    pool = SocketPool(_fresh(), addresses=[(s1.host, s1.port)],
                      reconnect_cooldown_s=0.0)
    payload = ShardPayload(SPACE.sample(RNG, 4), "objectives", None)
    rep = pool.submit(payload).result(timeout=60)
    assert pool.registry.alive(0)
    pool._conns[0].die("simulated network partition")
    assert not pool.registry.alive(0)
    assert pool.registry.evictions >= 1
    rep2 = pool.submit(payload).result(timeout=60)
    _assert_reports_identical(rep, rep2)
    assert pool.reconnects == 1
    assert pool.registry.reregistrations >= 1
    assert pool.registry.alive(0)
    pool.close()


def test_socket_worker_sigkill_mid_stream_bit_identical():
    """Acceptance: SIGKILL a worker process while a stream of requests is
    in flight — the dead slot is evicted (elastic resize included) and
    every reassembled report stays bit-identical."""
    w1 = start_worker_process()
    w2 = start_worker_process()
    ev = None
    try:
        idx = SPACE.sample(RNG, 64)
        want = _fresh().evaluate(EvalRequest(idx, "stalls"))
        ev = ShardedEvaluator(_fresh(), mode="socket",
                              addresses=[w1.address, w2.address],
                              elastic=True)
        reports, errors = [], []

        def stream():
            try:
                for _ in range(30):
                    reports.append(ev.evaluate(EvalRequest(idx, "stalls")))
            except Exception as exc:            # noqa: BLE001 — reraised
                errors.append(exc)

        t = threading.Thread(target=stream)
        t.start()
        while len(reports) < 3 and t.is_alive():
            time.sleep(0.01)
        w2.kill()                               # SIGKILL, no goodbye
        t.join(timeout=300)
        assert not t.is_alive()
        assert not errors, errors
        assert len(reports) == 30
        for rep in reports:
            _assert_reports_identical(rep, want)
        snap = ev.registry.snapshot()
        assert snap["evictions"] >= 1           # the dead slot was noticed
        assert 0 in snap["live"]                # the survivor serves on
    finally:
        if ev is not None:
            ev.close()
        for w in (w1, w2):
            if w.alive():
                w.kill()


# ------------------------------------------------------------ QoS tiers
def test_service_tier_validation():
    ev = _fresh()
    with pytest.raises(ValueError, match="tier"):
        EvalService(ev).submit(EvalRequest(SPACE.sample(RNG, 1)),
                               tier="bulk")
    with pytest.raises(ValueError, match="unknown QoS tiers"):
        EvalService(ev, tier_weights={"bulk": 1.0})
    with pytest.raises(ValueError, match="> 0"):
        EvalService(ev, tier_weights={"batch": 0.0})


def test_qos_scavenger_never_starved_under_interactive_flood():
    """Acceptance: with a saturating interactive backlog and a row-capped
    tick, the anti-starvation floor keeps scavenger throughput > 0."""
    svc = EvalService(_fresh(), max_rows_per_tick=4)
    idx = SPACE.sample(RNG, 66)
    inter = [svc.submit(EvalRequest(idx[i:i + 1]), client=f"i{i}",
                        tier="interactive") for i in range(60)]
    scav = [svc.submit(EvalRequest(idx[60 + j:61 + j]), client="bg",
                       tier="scavenger") for j in range(6)]
    ticks = 0
    while not all(f.done() for f in scav):
        svc.tick()
        ticks += 1
        assert ticks <= 10                      # floor: >= 1 scavenger/tick
    assert svc.tier_served["scavenger"] == 6
    assert any(not f.done() for f in inter)     # the flood is still queued
    svc.close()


def test_qos_tier_weights_shape_throughput():
    """Equal offered load per tier + a row-capped tick: throughput orders
    by weight (8:3:1) and the cap is spent exactly every tick."""
    svc = EvalService(_fresh(), max_rows_per_tick=13)
    idx = SPACE.sample(RNG, 240)
    k = 0
    for t in ("interactive", "batch", "scavenger"):
        for _ in range(80):
            svc.submit(EvalRequest(idx[k:k + 1]), client=t, tier=t)
            k += 1
    for _ in range(8):
        svc.tick()
    served = dict(svc.tier_served)
    assert sum(served.values()) == 8 * 13       # cap spent exactly
    assert served["scavenger"] >= 8             # the floor, every tick
    assert served["interactive"] > 1.5 * served["batch"]
    assert served["batch"] > 1.5 * served["scavenger"]
    svc.close()


def test_service_tier_telemetry_percentiles():
    svc = EvalService(_fresh())
    idx = SPACE.sample(RNG, 2)
    svc.submit(EvalRequest(idx[:1]), tier="interactive")
    svc.submit(EvalRequest(idx[1:]), tier="batch")
    svc.tick()
    tiers = svc.telemetry()["tiers"]
    assert set(tiers) == {"interactive", "batch", "scavenger"}
    assert tiers["interactive"]["served"] == 1
    assert tiers["interactive"]["p50_ms"] is not None
    assert tiers["interactive"]["p99_ms"] >= tiers["interactive"]["p50_ms"]
    assert tiers["batch"]["weight"] == 3.0
    assert tiers["scavenger"]["served"] == 0
    assert tiers["scavenger"]["p50_ms"] is None
    svc.close()


# ------------------------------------------------------------- gateway
def test_gateway_budget_exhaustion_and_window_roll():
    clock = [0.0]
    gw = Gateway(_fresh(), rows_per_window=10, window_s=60.0,
                 now=lambda: clock[0])
    idx = SPACE.sample(RNG, 13)
    fut = gw.submit(EvalRequest(idx[:10]), tenant="acme")
    gw.tick()
    assert fut.done()
    with pytest.raises(RetryAfter) as ei:
        gw.submit(EvalRequest(idx[10:11]), tenant="acme")
    assert 0 < ei.value.retry_after_s <= 60.0
    tel = gw.telemetry()
    assert tel["tenants"]["acme"]["rejected_budget"] == 1
    assert tel["tenants"]["acme"]["used_rows"] == 10   # rejects cost nothing
    assert tel["admission"]["rejected"] == 1
    clock[0] += 61.0                            # the window rolls
    fut2 = gw.submit(EvalRequest(idx[10:12]), tenant="acme")
    gw.tick()
    assert fut2.done()
    assert gw.telemetry()["tenants"]["acme"]["used_rows"] == 2
    gw.close()


def test_gateway_backpressure_rejects_with_drain_eta():
    gw = Gateway(_fresh(), max_queued_rows=4)
    idx = SPACE.sample(RNG, 6)
    for i in range(4):                          # fill the backlog, no ticks
        gw.submit(EvalRequest(idx[i:i + 1]), tenant=f"t{i}")
    with pytest.raises(RetryAfter) as ei:
        gw.submit(EvalRequest(idx[4:5]), tenant="late")
    assert ei.value.retry_after_s > 0
    assert gw.telemetry()["tenants"]["late"]["rejected_backpressure"] == 1
    gw.tick()                                   # the backlog drains
    fut = gw.submit(EvalRequest(idx[4:5]), tenant="late")
    gw.tick()
    assert fut.done()
    gw.close()


def test_gateway_per_tenant_quota_overrides():
    gw = Gateway(_fresh(), rows_per_window=100, tenants={"small": 2})
    idx = SPACE.sample(RNG, 5)
    gw.submit(EvalRequest(idx[:2]), tenant="small")
    with pytest.raises(RetryAfter):
        gw.submit(EvalRequest(idx[2:3]), tenant="small")
    # unknown tenants get the default quota — config, not an allow-list
    gw.submit(EvalRequest(idx[:3]), tenant="unheard_of")
    gw.tick()
    gw.close()


def test_gateway_validation_and_tier_pass_through():
    with pytest.raises(ValueError, match="default_tier"):
        Gateway(_fresh(), default_tier="bulk")
    gw = Gateway(_fresh(), default_tier="scavenger")
    gw.submit(EvalRequest(SPACE.sample(RNG, 1)), tenant="t")
    gw.tick()
    assert gw.service.tier_served["scavenger"] == 1
    gw.close()


def test_gateway_is_drop_in_evaluator_with_fleet_telemetry():
    """The gateway implements the Evaluator protocol, and telemetry
    merges service counters, tenant ledgers and the fleet registry."""
    sharded = ShardedEvaluator(_fresh(), workers=2)
    gw = Gateway(EvalService(sharded))
    idx = SPACE.sample(RNG, 7)
    assert np.array_equal(gw.objectives(idx), _fresh().objectives(idx))
    tel = gw.telemetry()
    assert tel["service"]["submits"] >= 1
    assert tel["fleet"]["workers"] == 2
    assert sorted(tel["fleet"]["live"]) == [0, 1]
    assert tel["tenants"]["default"]["admitted"] == 1
    gw.close()
    sharded.close()


# --------------------------------------------------------- oracle store
SUB = 6_000


def test_oracle_store_repeat_is_o1_load(tmp_path, monkeypatch):
    from repro.perfmodel.sweep import SweepEngine
    calls = {"n": 0}
    orig = SweepEngine.run

    def counting(self, *a, **kw):
        calls["n"] += 1
        return orig(self, *a, **kw)

    monkeypatch.setattr(SweepEngine, "run", counting)
    store = str(tmp_path / "oracle")
    kw = dict(sweep_kwargs=dict(chunk_size=4_096), stop=SUB,
              oracle_store=store)
    r1 = OracleEvaluator(get_evaluator("proxy"), **kw).sweep_result()
    assert calls["n"] == 1
    assert len(os.listdir(store)) == 1
    r2 = OracleEvaluator(get_evaluator("proxy"), **kw).sweep_result()
    assert calls["n"] == 1                      # loaded, not re-swept
    assert r1.n_evaluated == r2.n_evaluated
    assert np.array_equal(r1.pareto_y, r2.pareto_y)
    assert np.array_equal(r1.pareto_ids, r2.pareto_ids)
    assert np.array_equal(r1.topk_val, r2.topk_val)
    assert np.array_equal(r1.topk_ids, r2.topk_ids)
    # a different sweep config is a different key -> fresh artifact
    OracleEvaluator(get_evaluator("proxy"),
                    sweep_kwargs=dict(chunk_size=4_096), stop=SUB - 1_000,
                    oracle_store=store).sweep_result()
    assert calls["n"] == 2
    assert len(os.listdir(store)) == 2


def test_oracle_store_corrupt_artifact_quarantined(tmp_path):
    store = str(tmp_path / "oracle")
    kw = dict(sweep_kwargs=dict(chunk_size=4_096), stop=SUB,
              oracle_store=store)
    r1 = OracleEvaluator(get_evaluator("proxy"), **kw).sweep_result()
    (fname,) = os.listdir(store)
    path = os.path.join(store, fname)
    with open(path, "wb") as f:
        f.write(b"not an npz artifact")
    with pytest.warns(RuntimeWarning, match="quarantined"):
        r2 = OracleEvaluator(get_evaluator("proxy"), **kw).sweep_result()
    assert np.array_equal(r1.pareto_y, r2.pareto_y)
    assert os.path.exists(path + ".quarantined")
    assert os.path.exists(path)                 # re-swept artifact rewritten


def test_sweep_result_save_load_guards(tmp_path):
    from repro.perfmodel.sweep import (SweepEngine, load_sweep_result,
                                       save_sweep_result)
    res = SweepEngine(get_evaluator("proxy"),
                      chunk_size=4_096).run(0, 3_000)
    path = str(tmp_path / "art.npz")
    save_sweep_result(path, res, key="k1")
    back = load_sweep_result(path, key="k1")
    assert np.array_equal(back.pareto_y, res.pareto_y)
    assert np.array_equal(back.topk_val, res.topk_val)
    with pytest.raises(ValueError, match="different"):
        load_sweep_result(path, key="some-other-study")
    with pytest.raises(FileNotFoundError):
        load_sweep_result(str(tmp_path / "missing.npz"))


# ------------------------------------------------- trusted wire (PR 10)
KEYS = {"k1": b"alpha-secret", "k2": b"beta-secret"}


def _keyring(active="k1"):
    return Keyring(KEYS, active=active)


def test_codec_value_roundtrip_restricted_types():
    """The binary codec round-trips exactly the frame vocabulary's types,
    arrays bit-identically across the dtype allowlist."""
    cases = [
        None, True, False, 0, -1, 2**40, -(2**70), 1.5, float("inf"),
        "héllo", b"\x00\xff raw", (1, "two", None), [1.0, [2, 3]],
        {"k": (1, 2), "nested": {"x": b"y"}}, (),
    ]
    for v in cases:
        assert codec_mod.decode_value(codec_mod.encode_value(v)) == v
    for dtype in sorted(codec_mod.ALLOWED_DTYPES):
        arr = (RNG.random((3, 4)) * 100).astype(dtype)
        back = codec_mod.decode_value(codec_mod.encode_value(arr))
        assert back.dtype == arr.dtype and np.array_equal(back, arr)
    # NaN payloads survive bit-exactly too (array path is raw bytes)
    arr = np.array([np.nan, 1.0, -np.inf])
    back = codec_mod.decode_value(codec_mod.encode_value(arr))
    assert arr.tobytes() == back.tobytes()


def test_codec_rejects_offschema():
    """Anything outside the schema is a typed CodecError, never an
    object: bad dtypes, non-str dict keys, arbitrary classes, trailing
    or truncated bytes, unknown tags."""
    with pytest.raises(codec_mod.CodecError, match="dtype"):
        codec_mod.encode_value(np.array([object()]))
    with pytest.raises(codec_mod.CodecError, match="keys"):
        codec_mod.encode_value({1: "x"})
    with pytest.raises(codec_mod.CodecError, match="not wire-encodable"):
        codec_mod.encode_value(Keyring(KEYS))
    with pytest.raises(codec_mod.CodecError, match="unknown value tag"):
        codec_mod.decode_value(b"Z")
    with pytest.raises(codec_mod.CodecError, match="truncated"):
        codec_mod.decode_value(codec_mod.encode_value("hello")[:-2])
    with pytest.raises(codec_mod.CodecError, match="trailing"):
        codec_mod.decode_value(codec_mod.encode_value(1) + b"junk")


def test_codec_bounds_nesting_depth():
    """A hostile frame of stacked container headers is a typed
    CodecError, never a RecursionError that would escape the reader
    threads' typed except clauses."""
    import struct as struct_mod
    one = struct_mod.pack(">I", 1)
    # schema-depth structures stay well inside the bound
    v = {"a": [( {"b": [1]}, )]}
    assert codec_mod.decode_value(codec_mod.encode_value(v)) == v
    for header in (b"L" + one, b"U" + one,
                   b"M" + one + struct_mod.pack(">I", 1) + b"k"):
        hostile = header * (codec_mod.MAX_NESTING_DEPTH + 8) + b"N"
        with pytest.raises(codec_mod.CodecError, match="nesting deeper"):
            codec_mod.decode_value(hostile)


def test_codec_message_roundtrip_every_type():
    idx = SPACE.sample(RNG, 5)
    payload = ShardPayload(idx, "stalls", ("ttft", "tpot"))
    report = _fresh().evaluate(EvalRequest(idx, "stalls"))
    span = {"name": "worker.eval", "trace_id": "t", "span_id": "s",
            "parent_id": None, "proc": "w:1", "thread": "serve-eval",
            "t_start": 0.1, "t_end": 0.2, "status": "ok",
            "attrs": {"rows": 5}}
    msgs = [wire.Hello(b"spec-bytes"), wire.Ready("digest", ("a", "b")),
            wire.Dispatch(7, payload, ("tid", "sid")),
            wire.ResultMsg(7, report, (span,)),
            wire.ErrorMsg(7, "boom", (), "quota.rows"),
            wire.ErrorMsg(-1, "fatal"),
            wire.Ping(3), wire.Pong(3), wire.Bye("done"),
            wire.Announce(("10.0.0.7", 9707), ("d1", "d2"), 4),
            wire.LeaseAck(2.5)]
    for msg in msgs:
        back = codec_mod.decode_msg(codec_mod.encode_msg(msg))
        assert type(back) is type(msg)
        if isinstance(msg, wire.Dispatch):
            assert back.seq == msg.seq and back.trace_ctx == msg.trace_ctx
            assert np.array_equal(back.payload.idx, payload.idx)
            assert back.payload.detail == payload.detail
            assert back.payload.workloads == payload.workloads
        elif isinstance(msg, wire.ResultMsg):
            _assert_reports_identical(back.report, report)
            assert back.spans == (span,)
        else:
            assert back == msg


def test_auth_sign_verify_rotation_and_rejects():
    """Frames are HMAC-signed with the key id in the header (so rings
    rotate without downtime); unsigned / unknown-key / tampered /
    replayed frames raise typed AuthErrors before any decoding."""
    ring = _keyring("k1")
    body = codec_mod.encode_msg(wire.Ping(1))
    # signing key rotates per-frame via key_id; both verify on one ring
    for kid in ("k1", "k2"):
        frame = codec_mod.seal_frame(body, ring, seq=0, key_id=kid)
        assert codec_mod.open_frame(frame, ring, expected_seq=0) == body
    # unsigned frame against a keyed receiver
    with pytest.raises(codec_mod.AuthError, match="unsigned"):
        codec_mod.open_frame(codec_mod.seal_frame(body, None, 0), ring, 0)
    # unknown key id
    other = Keyring({"k9": b"stranger"})
    with pytest.raises(codec_mod.AuthError, match="unknown_key"):
        codec_mod.open_frame(codec_mod.seal_frame(body, other, 0), ring, 0)
    # tampered body (bit flip after sealing)
    frame = bytearray(codec_mod.seal_frame(body, ring, 0))
    frame[-1] ^= 0x01
    with pytest.raises(codec_mod.AuthError, match="tamper"):
        codec_mod.open_frame(bytes(frame), ring, 0)
    # replay: stale sequence number, valid MAC
    frame = codec_mod.seal_frame(body, ring, seq=0)
    assert codec_mod.open_frame(frame, ring, 0) == body
    with pytest.raises(codec_mod.AuthError, match="replay"):
        codec_mod.open_frame(frame, ring, 1)
    # session binding: a frame sealed under one connection's nonces
    # never verifies under another's (cross-connection replay)
    frame = codec_mod.seal_frame(body, ring, seq=0, binding=b"sess-A")
    assert codec_mod.open_frame(frame, ring, 0, binding=b"sess-A") == body
    with pytest.raises(codec_mod.AuthError, match="tamper"):
        codec_mod.open_frame(frame, ring, 0, binding=b"sess-B")
    with pytest.raises(codec_mod.AuthError, match="tamper"):
        codec_mod.open_frame(frame, ring, 0)


def test_restricted_loads_blocks_gadgets_allows_spec():
    """The allowlisted constructor table rebuilds real evaluator specs
    but refuses pickle gadgets before construction."""
    import pickle
    spec = _worker_spec(_fresh())
    rebuilt = evaluator_from_spec(spec, loads=codec_mod.restricted_loads)
    idx = SPACE.sample(RNG, 6)
    _assert_reports_identical(
        rebuilt.evaluate(EvalRequest(idx, "objectives")),
        _fresh().evaluate(EvalRequest(idx, "objectives")))

    class Gadget:                       # classic reduce-to-call payload
        def __reduce__(self):
            return (os.system, ("true",))

    evil = pickle.dumps(Gadget())
    with pytest.raises(codec_mod.CodecError, match="not allowlisted"):
        codec_mod.restricted_loads(evil)
    evil2 = pickle.dumps(pytest.raises)  # callable outside repro/numpy
    with pytest.raises(codec_mod.CodecError, match="not allowlisted"):
        codec_mod.restricted_loads(evil2)


def test_restricted_loads_blocks_module_attribute_traversal():
    """Hand-crafted pickles cannot laterally escape the allowlist: a
    repro module's re-exported ``os`` resolves to a module (not a
    class) and is refused, and ``builtins.getattr`` — the gadget that
    would turn any such module into ``os.system`` — is not allowlisted
    at all."""
    def su(s):                       # SHORT_BINUNICODE opcode
        b = s.encode("utf-8")
        return b"\x8c" + bytes([len(b)]) + b

    PROTO, STACK_GLOBAL, STOP = b"\x80\x04", b"\x93", b"."
    TUPLE2, REDUCE = b"\x86", b"R"
    # STACK_GLOBAL('repro.runtime.fault', 'os'): an allowlisted module's
    # top-level `import os` must not resolve through find_class
    evil = (PROTO + su("repro.runtime.fault") + su("os")
            + STACK_GLOBAL + STOP)
    with pytest.raises(codec_mod.CodecError, match="not a class"):
        codec_mod.restricted_loads(evil)
    # the full traversal chain: getattr(<module os>, 'system')('true')
    evil = (PROTO
            + su("builtins") + su("getattr") + STACK_GLOBAL
            + su("repro.runtime.fault") + su("os") + STACK_GLOBAL
            + su("system") + TUPLE2 + REDUCE
            + su("true") + b"\x85" + REDUCE       # TUPLE1 + call
            + STOP)
    with pytest.raises(codec_mod.CodecError, match="not allowlisted"):
        codec_mod.restricted_loads(evil)
    # plain pickled specs still cannot smuggle getattr either
    import pickle
    with pytest.raises(codec_mod.CodecError, match="not allowlisted"):
        codec_mod.restricted_loads(pickle.dumps(getattr))


@pytest.mark.parametrize("tier", ["proxy", "target"])
def test_secure_socket_bit_identical_both_tiers(tier):
    """Acceptance: codec + HMAC end-to-end — a keyed 2-worker fleet is
    bit-identical to in-process on both fidelity tiers, with zero auth
    or quota noise."""
    s1 = WorkerServer(options=WorkerOptions(keys=KEYS))
    s2 = WorkerServer(options=WorkerOptions(keys=KEYS))
    s1.start()
    s2.start()
    ev = None
    try:
        idx = SPACE.sample(RNG, 23)
        local = _fresh(tier)
        ev = ShardedEvaluator(_fresh(tier), mode="socket",
                              addresses=[(s1.host, s1.port),
                                         (s2.host, s2.port)],
                              keyring=_keyring())
        for detail in ("objectives", "stalls"):
            req = EvalRequest(idx, detail=detail)
            _assert_reports_identical(ev.evaluate(req), local.evaluate(req))
        assert s1.auth_rejected() == 0 and s2.auth_rejected() == 0
        assert ev.quota_rerouted == 0
    finally:
        if ev is not None:
            ev.close()
        s1.close()
        s2.close()


def test_secure_worker_refuses_legacy_pickle_and_unsigned():
    """A hardened worker refuses the pickle codec outright and, when
    keyed, refuses unsigned binary frames — both counted, neither
    evaluated."""
    srv = WorkerServer(options=WorkerOptions(keys=KEYS))
    srv.start()
    try:
        # legacy pickle client (insecure pool) against a secure worker
        with pytest.raises(RuntimeError, match="binary codec"):
            SocketPool(_fresh(), addresses=[(srv.host, srv.port)],
                       insecure=True)
        assert srv.auth_rejected("pickle_codec") == 1
        # unsigned binary client against a keyed worker
        with pytest.raises(RuntimeError, match="no repro.serve worker"):
            SocketPool(_fresh(), addresses=[(srv.host, srv.port)])
        assert srv.auth_rejected("unsigned") >= 1
        assert srv.dispatches_served == 0
    finally:
        srv.close()


def test_insecure_flag_restores_legacy_pickle_mode():
    """insecure=True on both ends keeps the PR 7 single-trust-domain
    transport working (explicitly opted into, never default)."""
    srv = WorkerServer(options=WorkerOptions(insecure=True))
    srv.start()
    ev = None
    try:
        idx = SPACE.sample(RNG, 8)
        ev = ShardedEvaluator(_fresh(), mode="socket",
                              addresses=[(srv.host, srv.port)],
                              insecure=True)
        _assert_reports_identical(
            ev.evaluate(EvalRequest(idx, "objectives")),
            _fresh().evaluate(EvalRequest(idx, "objectives")))
    finally:
        if ev is not None:
            ev.close()
        srv.close()


def test_wire_tamper_and_replay_counted_never_evaluated():
    """Acceptance: a tampered or replayed frame on a live connection is
    rejected + counted by the worker and the dispatch never evaluates."""
    srv = WorkerServer(options=WorkerOptions(keys=KEYS))
    srv.start()
    try:
        ring = _keyring()
        # --- tampered Dispatch ------------------------------------------
        sock = wire.connect((srv.host, srv.port))
        ch = codec_mod.Channel(sock, keyring=ring)
        ch.client_handshake()
        ch.send(wire.Hello(_worker_spec(_fresh())))
        assert isinstance(ch.recv(), wire.Ready)
        dispatch = wire.Dispatch(0, ShardPayload(SPACE.sample(RNG, 2),
                                                 "objectives", None))
        frame = bytearray(codec_mod.seal_frame(
            codec_mod.encode_msg(dispatch), ring, seq=1,
            binding=ch.binding))
        frame[-3] ^= 0xFF                        # corrupt the body
        wire.send_frame(sock, bytes(frame))
        reply = ch.recv()
        assert isinstance(reply, wire.ErrorMsg) and reply.code == "auth.tamper"
        sock.close()
        deadline = time.monotonic() + 10
        while srv.auth_rejected("tamper") < 1 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert srv.auth_rejected("tamper") == 1
        # --- replayed Dispatch ------------------------------------------
        sock = wire.connect((srv.host, srv.port))
        ch = codec_mod.Channel(sock, keyring=ring)
        ch.client_handshake()
        ch.send(wire.Hello(_worker_spec(_fresh())))
        assert isinstance(ch.recv(), wire.Ready)
        good = codec_mod.seal_frame(codec_mod.encode_msg(dispatch), ring,
                                    seq=1, binding=ch.binding)
        wire.send_frame(sock, good)
        first = ch.recv()
        assert isinstance(first, wire.ResultMsg)  # the original lands
        wire.send_frame(sock, good)               # verbatim replay
        reply = ch.recv()
        assert isinstance(reply, wire.ErrorMsg) and reply.code == "auth.replay"
        sock.close()
        deadline = time.monotonic() + 10
        while srv.auth_rejected("replay") < 1 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert srv.auth_rejected("replay") == 1
        assert srv.dispatches_served == 1         # replay never evaluated
    finally:
        srv.close()


class _RecordingSocket:
    """Socket proxy that keeps a copy of every outbound chunk — the
    network attacker's tape recorder."""

    def __init__(self, sock):
        self._sock = sock
        self.sent = []

    def sendall(self, data):
        self.sent.append(bytes(data))
        self._sock.sendall(data)

    def recv(self, n):
        return self._sock.recv(n)

    def close(self):
        self._sock.close()


def test_recorded_session_replayed_on_new_connection_is_rejected():
    """Cross-connection replay: record an entire valid signed session,
    replay it verbatim over a fresh TCP connection — the worker's fresh
    session nonce changes every expected MAC, so nothing verifies,
    nothing evaluates, and the reject is counted."""
    srv = WorkerServer(options=WorkerOptions(keys=KEYS))
    srv.start()
    try:
        ring = _keyring()
        rec = _RecordingSocket(wire.connect((srv.host, srv.port)))
        ch = codec_mod.Channel(rec, keyring=ring)
        ch.client_handshake()
        ch.send(wire.Hello(_worker_spec(_fresh())))
        assert isinstance(ch.recv(), wire.Ready)
        ch.send(wire.Dispatch(0, ShardPayload(SPACE.sample(RNG, 2),
                                              "objectives", None)))
        assert isinstance(ch.recv(), wire.ResultMsg)
        rec.close()
        deadline = time.monotonic() + 10
        # the reply races the worker-side counter inc: wait it out
        while srv.dispatches_served < 1 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert srv.dispatches_served == 1
        # the attacker replays the recorded byte stream on a new socket
        replay_sock = wire.connect((srv.host, srv.port))
        for chunk in rec.sent:
            try:
                replay_sock.sendall(chunk)
            except OSError:
                break                 # server already dropped the replay
        deadline = time.monotonic() + 10
        while srv.auth_rejected() < 1 and time.monotonic() < deadline:
            time.sleep(0.01)
        replay_sock.close()
        # replayed Hello fails its MAC under the fresh server nonce
        assert srv.auth_rejected("tamper") >= 1
        assert srv.dispatches_served == 1     # nothing re-evaluated
    finally:
        srv.close()


def test_signed_frames_without_session_handshake_are_rejected():
    """A keyed endpoint refuses signed traffic outside a nonce-bound
    session (the window a handshake-stripping replay would need)."""
    srv = WorkerServer(options=WorkerOptions(keys=KEYS))
    srv.start()
    try:
        ring = _keyring()
        sock = wire.connect((srv.host, srv.port))
        body = codec_mod.encode_msg(wire.Hello(b"spec"))
        wire.send_frame(sock, codec_mod.seal_frame(body, ring, seq=0))
        deadline = time.monotonic() + 10
        while srv.auth_rejected("replay") < 1 \
                and time.monotonic() < deadline:
            time.sleep(0.01)
        sock.close()
        assert srv.auth_rejected("replay") == 1
        assert srv.dispatches_served == 0
    finally:
        srv.close()


def test_pickle_channel_serializes_concurrent_sends():
    """The legacy pickle path locks the socket write like the binary
    path: many threads sharing one channel (reader Pong, eval Result,
    deadline timer) never interleave the length-prefixed stream."""
    a, b = socket_mod.socketpair()
    try:
        ch = codec_mod.Channel(a, codec=codec_mod.CODEC_PICKLE)
        peer = codec_mod.Channel(b, codec=codec_mod.CODEC_PICKLE)
        n_threads, per_thread = 8, 40
        pad = "x" * 4096            # big enough to straddle sendall calls
        got, errs = [], []

        def reader():
            try:
                for _ in range(n_threads * per_thread):
                    got.append(peer.recv().seq)
            except Exception as exc:     # noqa: BLE001 — test harness
                errs.append(exc)

        def blast(t):
            for i in range(per_thread):
                ch.send(wire.ErrorMsg(t * per_thread + i, pad))

        rt = threading.Thread(target=reader)
        rt.start()
        threads = [threading.Thread(target=blast, args=(t,))
                   for t in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        rt.join(timeout=30)
        assert not errs and not rt.is_alive()
        assert sorted(got) == list(range(n_threads * per_thread))
    finally:
        a.close()
        b.close()


def test_worker_prunes_idle_peer_rate_buckets():
    """Per-peer token buckets are evicted once fully refilled, so the
    worker does not grow one bucket per client IP forever."""
    from repro.obs.metrics import ManualClock
    clk = ManualClock()
    srv = WorkerServer(options=WorkerOptions(rate_limit=10.0), clock=clk)
    try:
        msg = wire.Dispatch(0, ShardPayload(SPACE.sample(RNG, 1),
                                            "objectives", None))
        for i in range(50):
            assert srv._check_quota(msg, f"10.0.0.{i}") is None
        assert len(srv._buckets) == 50
        clk.advance(60.0)              # every bucket refills (burst/rate=2s)
        assert srv._check_quota(msg, "10.1.0.1") is None
        assert set(srv._buckets) == {"10.1.0.1"}
        # a still-active peer is never pruned out from under its debit
        clk.advance(0.05)
        assert srv._check_quota(msg, "10.1.0.1") is None
        assert "10.1.0.1" in srv._buckets
    finally:
        srv.close()


# ------------------------------------------------- frame-size satellite
def test_max_frame_bytes_oversized_dispatch_integration():
    """The frame bound is configurable end to end: an oversized Dispatch
    is refused client-side BEFORE it hits the wire (loud, connection
    intact), and small dispatches keep flowing."""
    srv = WorkerServer(options=WorkerOptions(keys=KEYS))
    srv.start()
    try:
        pool = SocketPool(_fresh(), addresses=[(srv.host, srv.port)],
                          keyring=_keyring(), max_frame_bytes=1 << 15)
        with pytest.raises(codec_mod.FrameTooLarge, match="frame bound"):
            pool.submit(ShardPayload(SPACE.sample(RNG, 3000),
                                     "objectives", None))
        idx = SPACE.sample(RNG, 4)               # small one still flows
        rep = pool.submit(ShardPayload(idx, "objectives", None)) \
            .result(timeout=60)
        _assert_reports_identical(
            rep, _fresh().evaluate(EvalRequest(idx, "objectives")))
        assert pool.live_workers() == 1 and pool.reconnects == 0
        pool.close()
    finally:
        srv.close()


# ---------------------------------------------------------- worker quotas
def test_quota_rows_rerouted_not_hammered():
    """A worker refusing shards by rows-quota gets rerouted around, not
    retried-at: the merged report stays bit-identical, the refusal is
    counted on both ends, and the refusing worker is NOT evicted."""
    tight = WorkerServer(options=WorkerOptions(
        keys=KEYS, max_rows_per_dispatch=4))
    open_ = WorkerServer(options=WorkerOptions(keys=KEYS))
    tight.start()
    open_.start()
    ev = None
    try:
        idx = SPACE.sample(RNG, 30)             # 15-row shards: over quota
        ev = ShardedEvaluator(_fresh(), mode="socket",
                              addresses=[(tight.host, tight.port),
                                         (open_.host, open_.port)],
                              keyring=_keyring(), retries=1)
        rep = ev.evaluate(EvalRequest(idx, "stalls"))
        _assert_reports_identical(
            rep, _fresh().evaluate(EvalRequest(idx, "stalls")))
        assert tight.quota_rejected("rows") >= 1
        assert ev.quota_rerouted >= 1
        assert ev.retried == 0                  # reroute consumed NO budget
        snap = ev.registry.snapshot()
        assert sorted(snap["live"]) == [0, 1]   # refusing worker not evicted
    finally:
        if ev is not None:
            ev.close()
        tight.close()
        open_.close()


def test_quota_rate_limit_token_bucket():
    """Per-peer token bucket: burst dispatches above the rate come back
    as typed QuotaExceeded, worker healthy throughout."""
    srv = WorkerServer(options=WorkerOptions(
        keys=KEYS, rate_limit=0.001, rate_burst=2))
    srv.start()
    try:
        pool = SocketPool(_fresh(), addresses=[(srv.host, srv.port)],
                          keyring=_keyring())
        payload = ShardPayload(SPACE.sample(RNG, 2), "objectives", None)
        futs = [pool.submit(payload) for _ in range(4)]
        outcomes = []
        for f in futs:
            try:
                f.result(timeout=60)
                outcomes.append("ok")
            except QuotaExceeded as exc:
                assert exc.code == "quota.rate"
                outcomes.append("quota")
        assert outcomes.count("ok") == 2        # the burst allowance
        assert outcomes.count("quota") == 2
        assert srv.quota_rejected("rate") == 2
        assert pool.quota_rejected == 2
        assert pool.live_workers() == 1         # refusals keep the wire up
        pool.close()
    finally:
        srv.close()


def test_quota_deadline_rejects_long_dispatch():
    """A dispatch past the wall-clock deadline answers with
    quota.deadline (typed, counted) instead of hanging the client."""
    srv = WorkerServer(options=WorkerOptions(keys=KEYS, deadline_s=1e-4))
    srv.start()
    try:
        pool = SocketPool(_fresh(), addresses=[(srv.host, srv.port)],
                          keyring=_keyring())
        fut = pool.submit(ShardPayload(SPACE.sample(RNG, 64),
                                       "stalls", None))
        with pytest.raises(QuotaExceeded, match="deadline"):
            fut.result(timeout=60)
        assert srv.quota_rejected("deadline") == 1
        assert pool.live_workers() == 1
        pool.close()
    finally:
        srv.close()


def test_quota_concurrency_admission_is_checked_before_eval():
    """max_concurrent_evals admits on the reader thread: the semaphore
    refuses the N+1th in-flight dispatch deterministically."""
    srv = WorkerServer(options=WorkerOptions(max_concurrent_evals=1))
    payload = ShardPayload(SPACE.sample(RNG, 2), "objectives", None)
    d1, d2 = wire.Dispatch(0, payload), wire.Dispatch(1, payload)
    assert srv._check_quota(d1, "peer") is None          # takes the slot
    kind, detail = srv._check_quota(d2, "peer")
    assert kind == "concurrency" and "max_concurrent_evals=1" in detail
    srv._eval_slots.release()                            # eval finished
    assert srv._check_quota(d2, "peer") is None
    srv._eval_slots.release()
    srv.close()


# ------------------------------------------------------------------ TLS
def _make_tls_certs(tmp_path):
    import shutil
    import subprocess
    if shutil.which("openssl") is None:
        pytest.skip("openssl CLI not available for test certs")
    cert, key = str(tmp_path / "cert.pem"), str(tmp_path / "key.pem")
    subprocess.run(
        ["openssl", "req", "-x509", "-newkey", "rsa:2048", "-nodes",
         "-keyout", key, "-out", cert, "-days", "1", "-subj",
         "/CN=127.0.0.1"],
        check=True, capture_output=True)
    return cert, key


def test_tls_wrapped_socket_bit_identical(tmp_path):
    """Optional TLS: worker wraps its accept loop, client wraps its
    dials, reports stay bit-identical over the encrypted wire."""
    import ssl
    cert, key = _make_tls_certs(tmp_path)
    srv = WorkerServer(options=WorkerOptions(keys=KEYS, certfile=cert,
                                             keyfile=key))
    srv.start()
    ev = None
    try:
        ctx = ssl.SSLContext(ssl.PROTOCOL_TLS_CLIENT)
        ctx.check_hostname = False
        ctx.verify_mode = ssl.CERT_NONE         # self-signed test cert
        idx = SPACE.sample(RNG, 10)
        ev = ShardedEvaluator(_fresh(), mode="socket",
                              addresses=[(srv.host, srv.port)],
                              keyring=_keyring(), ssl_context=ctx)
        _assert_reports_identical(
            ev.evaluate(EvalRequest(idx, "stalls")),
            _fresh().evaluate(EvalRequest(idx, "stalls")))
    finally:
        if ev is not None:
            ev.close()
        srv.close()


def test_secure_fabric_survives_chaos_and_sigkill():
    """Acceptance: the full hardened stack (codec + HMAC, spawned worker
    processes) stays bit-identical through chaos crash/hang and a
    SIGKILL mid-stream."""
    opts = WorkerOptions(keys=KEYS)
    w1 = start_worker_process(options=opts)
    w2 = start_worker_process(options=opts)
    ev = None
    try:
        idx = SPACE.sample(RNG, 32)
        want = _fresh().evaluate(EvalRequest(idx, "stalls"))
        plan = FaultPlan([FaultEvent(0, 0, "crash"),
                          FaultEvent(1, 1, "hang")])
        ev = ShardedEvaluator(_fresh(), mode="socket",
                              addresses=[w1.address, w2.address],
                              keyring=_keyring(), fault_plan=plan,
                              shard_timeout_s=5.0, speculate=False,
                              elastic=True)
        reports, errors = [], []

        def stream():
            try:
                for _ in range(12):
                    reports.append(ev.evaluate(EvalRequest(idx, "stalls")))
            except Exception as exc:            # noqa: BLE001 — reraised
                errors.append(exc)

        t = threading.Thread(target=stream)
        t.start()
        while len(reports) < 2 and t.is_alive():
            time.sleep(0.01)
        w2.kill()                               # SIGKILL, no goodbye
        t.join(timeout=300)
        assert not t.is_alive()
        assert not errors, errors
        assert len(reports) == 12
        for rep in reports:
            _assert_reports_identical(rep, want)
        assert ev.registry.snapshot()["evictions"] >= 1
    finally:
        if ev is not None:
            ev.close()
        for w in (w1, w2):
            if w.alive():
                w.kill()
