"""repro.serve: the cross-machine DSE-as-a-service layer.

Covers the PR invariants: the length-prefixed pickle wire round-trips
every message type and rejects oversized frames before allocation; the
pickled worker spec rides pickle.HIGHEST_PROTOCOL and rebuilds a
bit-identical evaluator; a ShardedEvaluator over a 2-worker loopback
socket pool is bit-identical to the local ModelEvaluator on both
fidelity tiers, under chaos injection, and across a worker SIGKILL
mid-stream (eviction -> elastic resize -> retry); dead connections
reconnect and re-register; the QoS weighted-deficit drain keeps
scavenger throughput > 0 under saturating interactive load while tier
weights shape relative throughput; the Gateway enforces per-tenant row
budgets and queue-depth backpressure with reject-with-retry-after; and
the persistent oracle store turns a repeat OracleEvaluator into an O(1)
artifact load with corrupt artifacts quarantined, never trusted.
"""
import os
import socket as socket_mod
import threading
import time

import numpy as np
import pytest

from repro.distributed import (EvalService, ShardedEvaluator, ShardPayload,
                               WorkerFault)
from repro.distributed.faults import FaultEvent, FaultPlan
from repro.distributed.sharded import _worker_spec, evaluator_from_spec
from repro.perfmodel import (EvalRequest, ModelEvaluator, OracleEvaluator,
                             get_evaluator)
from repro.perfmodel.designspace import SPACE
from repro.serve import (Gateway, RetryAfter, SocketPool, WIRE_VERSION,
                         WorkerServer, start_worker_process, wire)

RNG = np.random.default_rng(7)


def _fresh(tier: str = "proxy") -> ModelEvaluator:
    """A fresh evaluator (own dispatch counter) over the memoized models."""
    return ModelEvaluator(get_evaluator(tier).models, tier=tier)


def _assert_reports_identical(a, b):
    assert a.workloads == b.workloads and a.detail == b.detail
    assert np.array_equal(a.area, b.area)
    for w in a.workloads:
        assert np.array_equal(a.latency[w], b.latency[w])
        if a.detail in ("ppa", "stalls"):
            assert np.array_equal(a.op_time[w], b.op_time[w])
            assert a.op_names[w] == b.op_names[w]
        if a.detail == "stalls":
            assert np.array_equal(a.stall[w], b.stall[w])
            assert np.array_equal(a.op_class[w], b.op_class[w])


@pytest.fixture(scope="module")
def servers():
    """Two in-process worker daemons on loopback ephemeral ports."""
    s1, s2 = WorkerServer(), WorkerServer()
    s1.start()
    s2.start()
    yield s1, s2
    s1.close()
    s2.close()


# ---------------------------------------------------------------- wire
def test_wire_roundtrip_every_message_type():
    a, b = socket_mod.socketpair()
    try:
        for msg in (wire.Hello(b"spec"), wire.Ready("digest", ("lat",)),
                    wire.Dispatch(3, "payload"), wire.ResultMsg(3, "rep"),
                    wire.ErrorMsg(3, "boom"), wire.Ping(1), wire.Pong(1),
                    wire.Bye("done")):
            wire.send_msg(a, msg)
            assert wire.recv_msg(b) == msg
    finally:
        a.close()
        b.close()


def test_wire_rejects_oversized_frames_before_allocation():
    a, b = socket_mod.socketpair()
    try:
        wire.send_msg(a, wire.Dispatch(0, b"x" * 4096))
        with pytest.raises(wire.WireError, match="exceeds"):
            wire.recv_msg(b, max_bytes=64)
    finally:
        a.close()
        b.close()


def test_wire_eof_raises_connection_closed():
    a, b = socket_mod.socketpair()
    a.close()
    try:
        with pytest.raises(wire.ConnectionClosed):
            wire.recv_msg(b)
    finally:
        b.close()


def test_check_hello_gates_type_and_version():
    with pytest.raises(wire.WireError, match="expected Hello"):
        wire.check_hello(wire.Ping(0))
    with pytest.raises(wire.WireError, match="version"):
        wire.check_hello(wire.Hello(b"", wire_version=WIRE_VERSION + 1))
    hello = wire.Hello(b"spec")
    assert wire.check_hello(hello) is hello


# ---------------------------------------------------------------- spec
def test_spec_highest_protocol_and_roundtrip():
    """The worker spec rides pickle.HIGHEST_PROTOCOL and rebuilds an
    evaluator bit-identical to its source."""
    import pickle
    spec = _worker_spec(_fresh())
    assert spec[0] == 0x80                      # pickle protocol opcode
    assert spec[1] == pickle.HIGHEST_PROTOCOL
    rebuilt = evaluator_from_spec(spec)
    local = _fresh()
    idx = SPACE.sample(RNG, 9)
    for detail in ("objectives", "stalls"):
        req = EvalRequest(idx, detail=detail)
        _assert_reports_identical(rebuilt.evaluate(req), local.evaluate(req))


# -------------------------------------------------------- socket fabric
def test_socket_mode_argument_validation():
    with pytest.raises(ValueError, match="addresses"):
        ShardedEvaluator(_fresh(), mode="socket")
    with pytest.raises(ValueError, match="socket"):
        ShardedEvaluator(_fresh(), workers=2, addresses=[("h", 1)])


@pytest.mark.parametrize("tier", ["proxy", "target"])
def test_socket_sharded_bit_identical_to_local(servers, tier):
    """Acceptance: a 2-worker loopback socket pool reassembles reports
    bit-identical to the in-process evaluator, on both fidelity tiers."""
    s1, s2 = servers
    idx = SPACE.sample(RNG, 23)                 # odd size: uneven shards
    local = _fresh(tier)
    ev = ShardedEvaluator(_fresh(tier), mode="socket",
                          addresses=[(s1.host, s1.port), (s2.host, s2.port)])
    assert ev.mode == "socket" and ev.workers == 2
    for detail in ("objectives", "stalls"):
        req = EvalRequest(idx, detail=detail)
        _assert_reports_identical(ev.evaluate(req), local.evaluate(req))
    assert ev.worker_dispatches >= 2            # really fanned out
    snap = ev.registry.snapshot()
    assert sorted(snap["live"]) == [0, 1]
    ev.close()


def test_socket_chaos_crash_hang_bit_identical(servers):
    """FaultPlan chaos composes with the socket pool: a crashed dispatch
    retries and a hung one times out + retries, bit-identical result."""
    s1, s2 = servers
    idx = SPACE.sample(RNG, 16)
    local = _fresh().evaluate(EvalRequest(idx, "stalls"))
    plan = FaultPlan([FaultEvent(0, 0, "crash"), FaultEvent(1, 1, "hang")])
    ev = ShardedEvaluator(_fresh(), mode="socket",
                          addresses=[(s1.host, s1.port), (s2.host, s2.port)],
                          fault_plan=plan, shard_timeout_s=1.0,
                          speculate=False)
    rep = ev.evaluate(EvalRequest(idx, "stalls"))
    _assert_reports_identical(rep, local)
    assert ev.retried >= 2                      # crash + hang both retried
    assert ev.timeouts >= 1
    assert len(plan) == 0                       # every event consumed
    ev.close()


def test_socket_remote_evaluation_error_is_not_fatal(servers):
    """A worker-side evaluation failure surfaces as WorkerFault WITHOUT
    tearing the connection down — the next dispatch reuses it."""
    s1, _ = servers
    pool = SocketPool(_fresh(), addresses=[(s1.host, s1.port)])
    bad = ShardPayload(SPACE.sample(RNG, 2), "nonsense_detail", None)
    # the worker's EvalRequest validation rejects the detail remotely
    with pytest.raises(WorkerFault, match="remote evaluation"):
        pool.submit(bad).result(timeout=60)
    idx = SPACE.sample(RNG, 4)
    rep = pool.submit(ShardPayload(idx, "objectives", None)).result(timeout=60)
    _assert_reports_identical(rep, _fresh().evaluate(
        EvalRequest(idx, "objectives")))
    assert pool.live_workers() == 1 and pool.reconnects == 0
    pool.close()


def test_socket_pool_reconnect_reregisters(servers):
    """A dead connection fails in-flight work, is evicted from the
    registry, and the next submit redials + re-registers the slot."""
    s1, _ = servers
    pool = SocketPool(_fresh(), addresses=[(s1.host, s1.port)],
                      reconnect_cooldown_s=0.0)
    payload = ShardPayload(SPACE.sample(RNG, 4), "objectives", None)
    rep = pool.submit(payload).result(timeout=60)
    assert pool.registry.alive(0)
    pool._conns[0].die("simulated network partition")
    assert not pool.registry.alive(0)
    assert pool.registry.evictions >= 1
    rep2 = pool.submit(payload).result(timeout=60)
    _assert_reports_identical(rep, rep2)
    assert pool.reconnects == 1
    assert pool.registry.reregistrations >= 1
    assert pool.registry.alive(0)
    pool.close()


def test_socket_worker_sigkill_mid_stream_bit_identical():
    """Acceptance: SIGKILL a worker process while a stream of requests is
    in flight — the dead slot is evicted (elastic resize included) and
    every reassembled report stays bit-identical."""
    w1 = start_worker_process()
    w2 = start_worker_process()
    ev = None
    try:
        idx = SPACE.sample(RNG, 64)
        want = _fresh().evaluate(EvalRequest(idx, "stalls"))
        ev = ShardedEvaluator(_fresh(), mode="socket",
                              addresses=[w1.address, w2.address],
                              elastic=True)
        reports, errors = [], []

        def stream():
            try:
                for _ in range(30):
                    reports.append(ev.evaluate(EvalRequest(idx, "stalls")))
            except Exception as exc:            # noqa: BLE001 — reraised
                errors.append(exc)

        t = threading.Thread(target=stream)
        t.start()
        while len(reports) < 3 and t.is_alive():
            time.sleep(0.01)
        w2.kill()                               # SIGKILL, no goodbye
        t.join(timeout=300)
        assert not t.is_alive()
        assert not errors, errors
        assert len(reports) == 30
        for rep in reports:
            _assert_reports_identical(rep, want)
        snap = ev.registry.snapshot()
        assert snap["evictions"] >= 1           # the dead slot was noticed
        assert 0 in snap["live"]                # the survivor serves on
    finally:
        if ev is not None:
            ev.close()
        for w in (w1, w2):
            if w.alive():
                w.kill()


# ------------------------------------------------------------ QoS tiers
def test_service_tier_validation():
    ev = _fresh()
    with pytest.raises(ValueError, match="tier"):
        EvalService(ev).submit(EvalRequest(SPACE.sample(RNG, 1)),
                               tier="bulk")
    with pytest.raises(ValueError, match="unknown QoS tiers"):
        EvalService(ev, tier_weights={"bulk": 1.0})
    with pytest.raises(ValueError, match="> 0"):
        EvalService(ev, tier_weights={"batch": 0.0})


def test_qos_scavenger_never_starved_under_interactive_flood():
    """Acceptance: with a saturating interactive backlog and a row-capped
    tick, the anti-starvation floor keeps scavenger throughput > 0."""
    svc = EvalService(_fresh(), max_rows_per_tick=4)
    idx = SPACE.sample(RNG, 66)
    inter = [svc.submit(EvalRequest(idx[i:i + 1]), client=f"i{i}",
                        tier="interactive") for i in range(60)]
    scav = [svc.submit(EvalRequest(idx[60 + j:61 + j]), client="bg",
                       tier="scavenger") for j in range(6)]
    ticks = 0
    while not all(f.done() for f in scav):
        svc.tick()
        ticks += 1
        assert ticks <= 10                      # floor: >= 1 scavenger/tick
    assert svc.tier_served["scavenger"] == 6
    assert any(not f.done() for f in inter)     # the flood is still queued
    svc.close()


def test_qos_tier_weights_shape_throughput():
    """Equal offered load per tier + a row-capped tick: throughput orders
    by weight (8:3:1) and the cap is spent exactly every tick."""
    svc = EvalService(_fresh(), max_rows_per_tick=13)
    idx = SPACE.sample(RNG, 240)
    k = 0
    for t in ("interactive", "batch", "scavenger"):
        for _ in range(80):
            svc.submit(EvalRequest(idx[k:k + 1]), client=t, tier=t)
            k += 1
    for _ in range(8):
        svc.tick()
    served = dict(svc.tier_served)
    assert sum(served.values()) == 8 * 13       # cap spent exactly
    assert served["scavenger"] >= 8             # the floor, every tick
    assert served["interactive"] > 1.5 * served["batch"]
    assert served["batch"] > 1.5 * served["scavenger"]
    svc.close()


def test_service_tier_telemetry_percentiles():
    svc = EvalService(_fresh())
    idx = SPACE.sample(RNG, 2)
    svc.submit(EvalRequest(idx[:1]), tier="interactive")
    svc.submit(EvalRequest(idx[1:]), tier="batch")
    svc.tick()
    tiers = svc.telemetry()["tiers"]
    assert set(tiers) == {"interactive", "batch", "scavenger"}
    assert tiers["interactive"]["served"] == 1
    assert tiers["interactive"]["p50_ms"] is not None
    assert tiers["interactive"]["p99_ms"] >= tiers["interactive"]["p50_ms"]
    assert tiers["batch"]["weight"] == 3.0
    assert tiers["scavenger"]["served"] == 0
    assert tiers["scavenger"]["p50_ms"] is None
    svc.close()


# ------------------------------------------------------------- gateway
def test_gateway_budget_exhaustion_and_window_roll():
    clock = [0.0]
    gw = Gateway(_fresh(), rows_per_window=10, window_s=60.0,
                 now=lambda: clock[0])
    idx = SPACE.sample(RNG, 13)
    fut = gw.submit(EvalRequest(idx[:10]), tenant="acme")
    gw.tick()
    assert fut.done()
    with pytest.raises(RetryAfter) as ei:
        gw.submit(EvalRequest(idx[10:11]), tenant="acme")
    assert 0 < ei.value.retry_after_s <= 60.0
    tel = gw.telemetry()
    assert tel["tenants"]["acme"]["rejected_budget"] == 1
    assert tel["tenants"]["acme"]["used_rows"] == 10   # rejects cost nothing
    assert tel["admission"]["rejected"] == 1
    clock[0] += 61.0                            # the window rolls
    fut2 = gw.submit(EvalRequest(idx[10:12]), tenant="acme")
    gw.tick()
    assert fut2.done()
    assert gw.telemetry()["tenants"]["acme"]["used_rows"] == 2
    gw.close()


def test_gateway_backpressure_rejects_with_drain_eta():
    gw = Gateway(_fresh(), max_queued_rows=4)
    idx = SPACE.sample(RNG, 6)
    for i in range(4):                          # fill the backlog, no ticks
        gw.submit(EvalRequest(idx[i:i + 1]), tenant=f"t{i}")
    with pytest.raises(RetryAfter) as ei:
        gw.submit(EvalRequest(idx[4:5]), tenant="late")
    assert ei.value.retry_after_s > 0
    assert gw.telemetry()["tenants"]["late"]["rejected_backpressure"] == 1
    gw.tick()                                   # the backlog drains
    fut = gw.submit(EvalRequest(idx[4:5]), tenant="late")
    gw.tick()
    assert fut.done()
    gw.close()


def test_gateway_per_tenant_quota_overrides():
    gw = Gateway(_fresh(), rows_per_window=100, tenants={"small": 2})
    idx = SPACE.sample(RNG, 5)
    gw.submit(EvalRequest(idx[:2]), tenant="small")
    with pytest.raises(RetryAfter):
        gw.submit(EvalRequest(idx[2:3]), tenant="small")
    # unknown tenants get the default quota — config, not an allow-list
    gw.submit(EvalRequest(idx[:3]), tenant="unheard_of")
    gw.tick()
    gw.close()


def test_gateway_validation_and_tier_pass_through():
    with pytest.raises(ValueError, match="default_tier"):
        Gateway(_fresh(), default_tier="bulk")
    gw = Gateway(_fresh(), default_tier="scavenger")
    gw.submit(EvalRequest(SPACE.sample(RNG, 1)), tenant="t")
    gw.tick()
    assert gw.service.tier_served["scavenger"] == 1
    gw.close()


def test_gateway_is_drop_in_evaluator_with_fleet_telemetry():
    """The gateway implements the Evaluator protocol, and telemetry
    merges service counters, tenant ledgers and the fleet registry."""
    sharded = ShardedEvaluator(_fresh(), workers=2)
    gw = Gateway(EvalService(sharded))
    idx = SPACE.sample(RNG, 7)
    assert np.array_equal(gw.objectives(idx), _fresh().objectives(idx))
    tel = gw.telemetry()
    assert tel["service"]["submits"] >= 1
    assert tel["fleet"]["workers"] == 2
    assert sorted(tel["fleet"]["live"]) == [0, 1]
    assert tel["tenants"]["default"]["admitted"] == 1
    gw.close()
    sharded.close()


# --------------------------------------------------------- oracle store
SUB = 6_000


def test_oracle_store_repeat_is_o1_load(tmp_path, monkeypatch):
    from repro.perfmodel.sweep import SweepEngine
    calls = {"n": 0}
    orig = SweepEngine.run

    def counting(self, *a, **kw):
        calls["n"] += 1
        return orig(self, *a, **kw)

    monkeypatch.setattr(SweepEngine, "run", counting)
    store = str(tmp_path / "oracle")
    kw = dict(sweep_kwargs=dict(chunk_size=4_096), stop=SUB,
              oracle_store=store)
    r1 = OracleEvaluator(get_evaluator("proxy"), **kw).sweep_result()
    assert calls["n"] == 1
    assert len(os.listdir(store)) == 1
    r2 = OracleEvaluator(get_evaluator("proxy"), **kw).sweep_result()
    assert calls["n"] == 1                      # loaded, not re-swept
    assert r1.n_evaluated == r2.n_evaluated
    assert np.array_equal(r1.pareto_y, r2.pareto_y)
    assert np.array_equal(r1.pareto_ids, r2.pareto_ids)
    assert np.array_equal(r1.topk_val, r2.topk_val)
    assert np.array_equal(r1.topk_ids, r2.topk_ids)
    # a different sweep config is a different key -> fresh artifact
    OracleEvaluator(get_evaluator("proxy"),
                    sweep_kwargs=dict(chunk_size=4_096), stop=SUB - 1_000,
                    oracle_store=store).sweep_result()
    assert calls["n"] == 2
    assert len(os.listdir(store)) == 2


def test_oracle_store_corrupt_artifact_quarantined(tmp_path):
    store = str(tmp_path / "oracle")
    kw = dict(sweep_kwargs=dict(chunk_size=4_096), stop=SUB,
              oracle_store=store)
    r1 = OracleEvaluator(get_evaluator("proxy"), **kw).sweep_result()
    (fname,) = os.listdir(store)
    path = os.path.join(store, fname)
    with open(path, "wb") as f:
        f.write(b"not an npz artifact")
    with pytest.warns(RuntimeWarning, match="quarantined"):
        r2 = OracleEvaluator(get_evaluator("proxy"), **kw).sweep_result()
    assert np.array_equal(r1.pareto_y, r2.pareto_y)
    assert os.path.exists(path + ".quarantined")
    assert os.path.exists(path)                 # re-swept artifact rewritten


def test_sweep_result_save_load_guards(tmp_path):
    from repro.perfmodel.sweep import (SweepEngine, load_sweep_result,
                                       save_sweep_result)
    res = SweepEngine(get_evaluator("proxy"),
                      chunk_size=4_096).run(0, 3_000)
    path = str(tmp_path / "art.npz")
    save_sweep_result(path, res, key="k1")
    back = load_sweep_result(path, key="k1")
    assert np.array_equal(back.pareto_y, res.pareto_y)
    assert np.array_equal(back.topk_val, res.topk_val)
    with pytest.raises(ValueError, match="different"):
        load_sweep_result(path, key="some-other-study")
    with pytest.raises(FileNotFoundError):
        load_sweep_result(str(tmp_path / "missing.npz"))
