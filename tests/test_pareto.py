"""Property tests for Pareto/PHV machinery (hypothesis)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # offline container: deterministic fallback
    from _hyp_compat import given, settings, st

from repro.core.pareto import (pareto_mask, pareto_front, hypervolume,
                               hypervolume_mc, dominates_ref,
                               sample_efficiency)

pts3 = st.lists(
    st.tuples(st.floats(0.1, 0.9), st.floats(0.1, 0.9), st.floats(0.1, 0.9)),
    min_size=1, max_size=24)


@given(pts3)
@settings(max_examples=40, deadline=None)
def test_hypervolume_matches_monte_carlo(pts):
    y = np.array(pts)
    ref = np.ones(3)
    hv = hypervolume(y, ref)
    mc = hypervolume_mc(y, ref, lo=np.zeros(3), n=60_000, seed=1)
    assert hv == pytest.approx(mc, abs=0.02)


@given(pts3)
@settings(max_examples=40, deadline=None)
def test_pareto_front_is_nondominated(pts):
    y = np.array(pts)
    front = pareto_front(y)
    for i in range(len(front)):
        dominated = np.all(front <= front[i], axis=1) & \
            np.any(front < front[i], axis=1)
        assert not dominated.any()


@given(pts3, pts3)
@settings(max_examples=30, deadline=None)
def test_hypervolume_monotone_in_points(a, b):
    """Adding points can only grow the hypervolume."""
    ya, yab = np.array(a), np.array(a + b)
    ref = np.ones(3)
    assert hypervolume(yab, ref) >= hypervolume(ya, ref) - 1e-12


@given(pts3)
@settings(max_examples=30, deadline=None)
def test_hypervolume_only_counts_front(pts):
    """Dominated points contribute nothing."""
    y = np.array(pts)
    ref = np.ones(3)
    assert hypervolume(y, ref) == pytest.approx(
        hypervolume(pareto_front(y), ref), rel=1e-9)


def test_hv_known_value_2d():
    y = np.array([[0.5, 0.5]])
    assert hypervolume(y, [1.0, 1.0]) == pytest.approx(0.25)
    y2 = np.array([[0.5, 0.5], [0.25, 0.75]])
    assert hypervolume(y2, [1.0, 1.0]) == pytest.approx(0.25 + 0.25 * 0.25)


def test_hv_known_value_3d():
    y = np.array([[0.5, 0.5, 0.5]])
    assert hypervolume(y, [1, 1, 1]) == pytest.approx(0.125)


def test_sample_efficiency():
    ref = np.array([1.0, 1.0, 1.0])
    y = np.array([[0.5, 0.5, 0.5], [1.5, 0.5, 0.5], [0.9, 0.9, 0.9]])
    assert sample_efficiency(y, ref) == pytest.approx(2 / 3)
    assert dominates_ref(y, ref).tolist() == [True, False, True]
