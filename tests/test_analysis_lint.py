"""repro.analysis.lint: every rule must fire on its known-bad fixture and
stay quiet on the clean twin; the baseline workflow gates CI on NEW findings
only."""
import json
import textwrap

import pytest

from repro.analysis.lint import (RULE_NAMES, Finding, lint_file, lint_paths,
                                 load_baseline, main, write_baseline)

# one known-bad snippet per rule (and a clean twin where the hazard is
# resolved the way the codebase actually resolves it)
CORPUS = {
    "mutable-default": """
        def enqueue(job, queue=[]):
            queue.append(job)
            return queue
    """,
    "future-swallow": """
        from concurrent.futures import Future

        def submit(work):
            fut = Future()
            try:
                work()
            except Exception:
                pass
            return fut
    """,
    "thread-not-daemon": """
        import threading

        def start():
            t = threading.Thread(target=print)
            t.start()
            return t
    """,
    "executor-leak": """
        from concurrent.futures import ThreadPoolExecutor

        def fanout(jobs):
            ex = ThreadPoolExecutor(4)
            return [ex.submit(j) for j in jobs]
    """,
    "jit-static-mutable": """
        import jax

        def compile_step(fn):
            return jax.jit(fn, static_argnames=["mode"])
    """,
    "jit-traced-branch": """
        import jax

        @jax.jit
        def step(x, threshold):
            if threshold > 0:
                return x * 2
            return x
    """,
    "host-sync-hot-loop": """
        import jax.numpy as jnp

        def decode(steps):
            out = []
            for _ in range(steps):
                tok = jnp.argmax(jnp.ones(4))
                out.append(int(tok))
            return out
    """,
}

CLEAN = {
    "mutable-default": """
        def enqueue(job, queue=None):
            queue = [] if queue is None else queue
            queue.append(job)
            return queue
    """,
    "future-swallow": """
        from concurrent.futures import Future

        def submit(work):
            fut = Future()
            try:
                work()
            except Exception as exc:
                fut.set_exception(exc)
            return fut
    """,
    "thread-not-daemon": """
        import threading

        def start():
            t = threading.Thread(target=print, daemon=True)
            t.start()
            return t
    """,
    "executor-leak": """
        from concurrent.futures import ThreadPoolExecutor

        def fanout(jobs):
            with ThreadPoolExecutor(4) as ex:
                return [f.result() for f in [ex.submit(j) for j in jobs]]
    """,
    "jit-static-mutable": """
        import jax

        def compile_step(fn):
            return jax.jit(fn, static_argnames=("mode",))
    """,
    "jit-traced-branch": """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def step(x, threshold):
            return jnp.where(threshold > 0, x * 2, x)
    """,
    "host-sync-hot-loop": """
        import jax.numpy as jnp

        def decode(steps):
            out = []
            for _ in range(steps):
                tok = jnp.argmax(jnp.ones(4))
                out.append(tok)       # stays on device
            return [int(t) for t in out]
    """,
}

# the shared-write rule needs a src/distributed/ path, handled separately
UNLOCKED_BAD = """
    import threading

    class Registry:
        def __init__(self):
            self._lock = threading.Lock()
            self._jobs = {}

        def put(self, k, v):
            self._jobs[k] = v

        def drop(self, k):
            self._jobs.pop(k, None)
"""

UNLOCKED_CLEAN = """
    import threading

    class Registry:
        def __init__(self):
            self._lock = threading.Lock()
            self._jobs = {}

        def put(self, k, v):
            with self._lock:
                self._jobs[k] = v

        def _drop(self, k):
            \"\"\"Caller holds the lock.\"\"\"
            self._jobs.pop(k, None)
"""

# the raw-telemetry-dict rule is also distributed//serve/-scoped
RAW_TELEMETRY_BAD = """
    class Service:
        def __init__(self):
            self.submits = 0
            self.served = {"fast": 0, "slow": 0}

        def submit(self, req, lane):
            self.submits += 1
            self.served[lane] += 1
"""

RAW_TELEMETRY_CLEAN = """
    from repro.obs.metrics import MetricsRegistry

    class Service:
        def __init__(self):
            self.metrics = MetricsRegistry()
            self._c_submits = self.metrics.counter("submits", "requests")
            self._retries_left = 0          # internal state, not telemetry

        def submit(self, req):
            self._c_submits.inc()
            self._retries_left += 1
"""


# pickle-outside-codec is serve/-scoped: deserializing attacker-reachable
# bytes belongs in codec.py's restricted loader, nowhere else
PICKLE_BAD = """
    import pickle
    from pickle import loads

    def read_spec(raw):
        return pickle.loads(raw)

    class Handler:
        def on_frame(self, data):
            return loads(data)
"""

PICKLE_CLEAN = """
    import pickle

    def write_spec(obj):
        return pickle.dumps(obj)            # serializing is fine

    def read_spec(raw, loads):
        return loads(raw)                   # injected restricted loader
"""


def _write(tmp_path, name, text):
    p = tmp_path / name
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(text))
    return p


@pytest.mark.parametrize("rule", sorted(CORPUS))
def test_rule_fires_on_bad_fixture(tmp_path, rule):
    findings = lint_file(_write(tmp_path, f"{rule}.py", CORPUS[rule]))
    assert [f.rule for f in findings] == [rule], findings


@pytest.mark.parametrize("rule", sorted(CLEAN))
def test_rule_quiet_on_clean_fixture(tmp_path, rule):
    findings = lint_file(_write(tmp_path, f"{rule}.py", CLEAN[rule]))
    assert findings == [], findings


def test_unlocked_shared_write_fires_in_scope(tmp_path):
    p = _write(tmp_path, "src/distributed/registry.py", UNLOCKED_BAD)
    findings = lint_file(p)
    assert {f.rule for f in findings} == {"unlocked-shared-write"}
    assert {f.symbol for f in findings} == {"Registry.put", "Registry.drop"}


def test_unlocked_shared_write_respects_lock_and_docstring(tmp_path):
    p = _write(tmp_path, "src/serve/registry.py", UNLOCKED_CLEAN)
    assert lint_file(p) == []


def test_unlocked_shared_write_out_of_scope(tmp_path):
    # same hazard outside distributed/ or serve/: not this rule's business
    p = _write(tmp_path, "src/other/registry.py", UNLOCKED_BAD)
    assert lint_file(p) == []


def test_raw_telemetry_dict_fires_in_scope(tmp_path):
    p = _write(tmp_path, "src/serve/service.py", RAW_TELEMETRY_BAD)
    findings = lint_file(p)
    assert {f.rule for f in findings} == {"raw-telemetry-dict"}
    assert len(findings) == 2                    # int counter + dict lane
    assert all(f.symbol == "Service.submit" for f in findings)


def test_raw_telemetry_dict_quiet_on_registry_and_private(tmp_path):
    p = _write(tmp_path, "src/distributed/service.py", RAW_TELEMETRY_CLEAN)
    assert lint_file(p) == []


def test_raw_telemetry_dict_out_of_scope(tmp_path):
    p = _write(tmp_path, "src/perfmodel/service.py", RAW_TELEMETRY_BAD)
    assert lint_file(p) == []


def test_pickle_outside_codec_fires_in_scope(tmp_path):
    p = _write(tmp_path, "src/serve/worker.py", PICKLE_BAD)
    findings = lint_file(p)
    assert {f.rule for f in findings} == {"pickle-outside-codec"}
    assert {f.symbol for f in findings} == {"read_spec", "Handler.on_frame"}


def test_pickle_outside_codec_exempts_the_codec_itself(tmp_path):
    # codec.py IS the trust boundary: its legacy shim is the one
    # sanctioned deserialization site
    p = _write(tmp_path, "src/serve/codec.py", PICKLE_BAD)
    assert lint_file(p) == []


def test_pickle_outside_codec_quiet_on_dumps_and_injected(tmp_path):
    p = _write(tmp_path, "src/serve/spec.py", PICKLE_CLEAN)
    assert lint_file(p) == []


def test_pickle_outside_codec_out_of_scope(tmp_path):
    # single-trust-domain pickle outside serve/ is not this rule's business
    p = _write(tmp_path, "src/perfmodel/cachefile.py", PICKLE_BAD)
    assert lint_file(p) == []


def test_every_rule_has_a_fixture():
    assert set(RULE_NAMES) == set(CORPUS) | {"unlocked-shared-write",
                                             "raw-telemetry-dict",
                                             "pickle-outside-codec"}


def test_syntax_error_is_reported_not_raised(tmp_path):
    p = _write(tmp_path, "broken.py", "def broken(:\n")
    findings = lint_file(p)
    assert [f.rule for f in findings] == ["syntax-error"]


# ---------------------------------------------------------------------------
# baseline workflow + CLI
# ---------------------------------------------------------------------------

def test_baseline_round_trip(tmp_path):
    findings = lint_file(_write(tmp_path, "a.py", CORPUS["mutable-default"]))
    bl = tmp_path / "baseline.json"
    write_baseline(bl, findings, {})
    accepted = load_baseline(bl)
    assert set(accepted) == {f.key for f in findings}
    # keys are line-free: shifting the code must not churn the baseline
    (tmp_path / "a.py").write_text(
        "# comment\n\n" + textwrap.dedent(CORPUS["mutable-default"]))
    shifted = lint_file(tmp_path / "a.py")
    assert {f.key for f in shifted} == set(accepted)
    # re-writing preserves hand-edited justifications
    d = json.loads(bl.read_text())
    d["findings"][0]["justification"] = "intentional"
    bl.write_text(json.dumps(d))
    write_baseline(bl, findings, load_baseline(bl))
    assert load_baseline(bl)[findings[0].key] == "intentional"


def test_cli_exit_codes(tmp_path):
    bad = _write(tmp_path, "bad.py", CORPUS["thread-not-daemon"])
    clean = _write(tmp_path, "ok.py", CLEAN["thread-not-daemon"])
    bl = tmp_path / "bl.json"
    assert main([str(clean)]) == 0
    assert main([str(bad)]) == 1                       # new finding
    assert main([str(bad), "--write-baseline", str(bl)]) == 0
    assert main([str(bad), "--baseline", str(bl)]) == 0   # accepted now
    assert main([str(clean), "--baseline", str(bl)]) == 0  # stale entry only


def test_repo_is_clean_against_committed_baseline():
    """The committed baseline accepts every current repo finding — the CI
    gate (`python -m repro.analysis.lint --baseline .lint-baseline.json`)
    must hold for the tree under test."""
    import pathlib
    repo = pathlib.Path(__file__).resolve().parents[1]
    accepted = load_baseline(repo / ".lint-baseline.json")
    findings = lint_paths([repo / "src" / "repro"])
    new = [f for f in findings if f.key not in accepted]
    assert new == [], new


def test_finding_str_and_key():
    f = Finding("r", "src/x.py", 3, "C.m", "msg")
    assert f.key == ("r", "src/x.py", "C.m")
    assert str(f) == "src/x.py:3: [r] C.m: msg"
