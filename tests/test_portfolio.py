"""Stacked-workload evaluation + portfolio sweep (PR 5 invariants).

Covers: stacked vs looped bit-identity at every detail level across zoo
workloads (incl. a MoE and an SSM config) on both fidelity tiers; the
WorkloadStack dedup / count-matrix / gather-map correctness vs brute-force
concatenation; ONE compiled executable per (detail, suite) regardless of
the workload count; the zoo-suite evaluator wiring
(``get_evaluator(suite="zoo")``); the portfolio sweep's per-scenario
fronts / top-k / stall seeds and the robust front vs brute force, its
worker sharding and checkpoint resume; archive auto-capacity; and
scenario-class seeded campaigns through ``CampaignRunner``.
"""
import numpy as np
import pytest

from repro.core.campaign import CampaignRunner
from repro.core.pareto import ParetoArchive, dominates_ref, pareto_front
from repro.perfmodel import (CompassModel, EvalRequest, ModelEvaluator,
                             RooflineModel, get_evaluator, make_evaluator)
from repro.perfmodel.designspace import SPACE
from repro.perfmodel.roofline import _JIT_CACHE
from repro.perfmodel.sweep import SweepEngine
from repro.perfmodel.workload import (STACK_KEY_FIELDS, WorkloadStack,
                                      zoo_suite)

RNG = np.random.default_rng(23)

# a MoE, an SSM and a dense config — the families with the most distinct
# operator graphs (satellite requirement: >= 3 zoo workloads incl. MoE+SSM)
TEST_ARCHS = ("qwen2-moe-a2.7b", "rwkv6-7b", "llama3.2-1b")


@pytest.fixture(scope="module")
def suite():
    return zoo_suite(archs=TEST_ARCHS, smoke=True)


@pytest.fixture(scope="module")
def zoo_ev(suite):
    wls, scen = suite
    return make_evaluator(wls, tier="proxy", scenarios=scen)


# --------------------------------------------------- stacked == looped
@pytest.mark.parametrize("cls", [RooflineModel, CompassModel],
                         ids=["proxy", "target"])
@pytest.mark.parametrize("detail", ["objectives", "ppa", "stalls"])
def test_stacked_bit_identical_to_looped(suite, cls, detail):
    """The stacked union pass reproduces the per-workload looped dispatch
    EXACTLY — every field, every detail level, both tiers, across MoE/SSM/
    dense zoo workloads."""
    wls, _ = suite
    models = {nm: cls(wl) for nm, wl in wls.items()}
    stacked = ModelEvaluator(models, stacked=True)
    looped = ModelEvaluator(models, stacked=False)
    idx = SPACE.sample(np.random.default_rng(1), 16)
    a = stacked.evaluate(EvalRequest(idx, detail=detail))
    b = looped.evaluate(EvalRequest(idx, detail=detail))
    assert np.array_equal(a.area, b.area)
    for w in stacked.workloads:
        assert np.array_equal(a.latency[w], b.latency[w]), w
        if detail in ("ppa", "stalls"):
            assert np.array_equal(a.op_time[w], b.op_time[w]), w
        if detail == "stalls":
            assert np.array_equal(a.stall[w], b.stall[w]), w
            assert np.array_equal(a.op_class[w], b.op_class[w]), w


def test_stacked_rejects_heterogeneous_models(suite):
    wls, _ = suite
    names = list(wls)
    models = {names[0]: RooflineModel(wls[names[0]]),
              names[1]: CompassModel(wls[names[1]])}
    with pytest.raises(ValueError, match="stacked"):
        ModelEvaluator(models, stacked=True)
    # auto mode silently falls back to the looped path
    ev = ModelEvaluator(models)
    assert ev.stacked is False


# --------------------------------------------------- WorkloadStack dedup
def test_workload_stack_matches_brute_force_concat(suite):
    """Dedup bookkeeping vs the brute-force concatenated table: gather maps
    reconstruct every workload's op rows exactly, the count matrix equals
    the per-key count sums, and the union is genuinely deduplicated."""
    wls, _ = suite
    stack = WorkloadStack.build(wls)
    assert stack.total_ops == sum(len(w.ops) for w in wls.values())
    assert stack.n_unique < stack.total_ops        # real cross-workload dedup

    def key_of(arrs, i):
        return tuple(arrs[f][i] for f in STACK_KEY_FIELDS)

    union_keys = [key_of(stack.unique, u) for u in range(stack.n_unique)]
    assert len(set(union_keys)) == stack.n_unique  # unique rows ARE unique
    for w, (nm, wl) in enumerate(wls.items()):
        a = wl.arrays()
        # gather map reconstructs the original op table field-for-field
        for i in range(len(wl.ops)):
            assert union_keys[stack.op_map[nm][i]] == key_of(a, i)
        assert np.array_equal(stack.counts[nm], a["count"])
        # count matrix == brute-force per-key count accumulation
        want = np.zeros(stack.n_unique)
        for i in range(len(wl.ops)):
            want[stack.op_map[nm][i]] += a["count"][i]
        assert np.array_equal(stack.count_matrix[w], want)


# --------------------------------------------------- compile counting
def test_one_jit_entry_per_detail_regardless_of_w():
    """Acceptance: evaluating a suite costs exactly ONE compiled executable
    per (detail, suite) — the workload count W never multiplies the
    jit-cache population (a fresh batch=4 suite guarantees fresh keys)."""
    for archs in (TEST_ARCHS[:1], TEST_ARCHS):         # W=2 and W=6
        wls, scen = zoo_suite(archs=archs, smoke=True, batch=4)
        ev = make_evaluator(wls, tier="proxy", scenarios=scen)
        idx = SPACE.sample(np.random.default_rng(2), 8)
        before = set(_JIT_CACHE)
        for detail in ("objectives", "ppa", "stalls"):
            ev.evaluate(EvalRequest(idx, detail=detail))
            ev.evaluate(EvalRequest(idx[:3], detail=detail))  # same exec
        assert len(set(_JIT_CACHE) - before) == 3, len(wls)
        assert ev.dispatches == 6


# --------------------------------------------------- zoo evaluator wiring
def test_get_evaluator_zoo_suite():
    ev = get_evaluator("proxy", suite="zoo")
    assert ev is get_evaluator("proxy", suite="zoo")       # memoized
    assert ev is not get_evaluator("proxy")                # distinct key
    assert ev.stacked
    assert len(ev.scenarios) == 10                         # every arch config
    names = {s.name for s in ev.scenarios}
    assert {"arctic-480b", "rwkv6-7b", "whisper-medium"} <= names
    for s in ev.scenarios:
        assert s.prefill in ev.workloads and s.decode in ev.workloads
    with pytest.raises(ValueError, match="suite"):
        get_evaluator("proxy", suite="menagerie")


# --------------------------------------------------- portfolio sweep
SUB = 24_000


@pytest.fixture(scope="module")
def swept(zoo_ev):
    eng = SweepEngine(zoo_ev, chunk_size=8_192, stall_topk=4)
    return eng, eng.run(0, SUB)


def test_portfolio_per_scenario_matches_brute_force(zoo_ev, swept):
    """Every scenario's front, top-k, superiority count and stall-class
    seeds equal the brute-force reduction of that scenario's objectives."""
    eng, res = swept
    assert res.scenario_names == tuple(s.name for s in zoo_ev.scenarios)
    idx = SPACE.flat_to_idx(np.arange(SUB))
    rep = zoo_ev.evaluate(EvalRequest(idx, detail="stalls"))
    for s in zoo_ev.scenarios:
        ys = np.stack([rep.latency[s.prefill], rep.latency[s.decode],
                       rep.area], axis=1)
        r = res.scenario(s.name)
        front = pareto_front(ys)
        assert len(r.pareto_ids) == len(front)
        assert np.allclose(np.sort(r.pareto_y, axis=0),
                           np.sort(front, axis=0), rtol=1e-5)
        assert r.n_superior == int(dominates_ref(ys, r.ref_point).sum())
        assert np.allclose(r.topk_val[:, 0], ys.min(axis=0), rtol=1e-5)
        dom = np.argmax(rep.stall[s.prefill], axis=1)
        lat = rep.latency[s.prefill]
        for c in range(4):
            want = np.sort(np.where(dom == c, lat, np.inf))[:4]
            got = r.stall_topk_val[c]
            fin = np.isfinite(want)
            assert np.allclose(got[fin], want[fin], rtol=1e-5), (s.name, c)


def test_portfolio_robust_front_matches_brute_force(zoo_ev, swept):
    """The robust front equals the brute-force front of the worst-case
    reference-normalized objectives (float32, like the device path)."""
    eng, res = swept
    assert res.robust == "worst"
    idx = SPACE.flat_to_idx(np.arange(SUB))
    rep = zoo_ev.evaluate(EvalRequest(idx, detail="objectives"))
    ys_s = np.stack(
        [np.stack([rep.latency[s.prefill], rep.latency[s.decode], rep.area],
                  axis=1) for s in zoo_ev.scenarios], axis=1)
    ratio = (ys_s[:, :, :2].astype(np.float32)
             / eng.ref_points[None, :, :2].astype(np.float32))
    ys_r = np.concatenate([ratio.max(axis=1),
                           ys_s[:, 0, 2:3].astype(np.float32)], axis=1)
    front = pareto_front(ys_r)
    assert len(res.pareto_ids) == len(front)
    assert np.allclose(np.sort(res.pareto_y, axis=0),
                       np.sort(front, axis=0), rtol=1e-5)
    # robust superiority = designs beating the reference on EVERY scenario
    assert res.n_superior == int(dominates_ref(ys_r, res.ref_point).sum())


def test_portfolio_workers_and_resume_identical(zoo_ev, swept, tmp_path):
    eng, res = swept
    res2 = eng.run(0, SUB, workers=2)
    assert np.array_equal(res.pareto_ids, res2.pareto_ids)
    assert np.array_equal(res.topk_ids, res2.topk_ids)
    assert np.array_equal(
        res.scenario(res.scenario_names[0]).pareto_ids,
        res2.scenario(res.scenario_names[0]).pareto_ids)
    ck = str(tmp_path / "ck")
    eng.run(0, SUB // 2, checkpoint_path=ck)
    res3 = eng.run(0, SUB, resume_from=ck)
    assert np.array_equal(res.pareto_ids, res3.pareto_ids)
    for nm in res.scenario_names:
        assert np.allclose(res.scenario(nm).stall_topk_val,
                           res3.scenario(nm).stall_topk_val, rtol=1e-7)


def test_portfolio_geomean_and_validation(zoo_ev):
    engg = SweepEngine(zoo_ev, chunk_size=8_192, robust="geomean")
    resg = engg.run(0, 8_192)
    assert resg.robust == "geomean"
    assert len(resg.pareto_ids) > 0
    with pytest.raises(ValueError, match="robust"):
        SweepEngine(zoo_ev, robust="median")
    with pytest.raises(KeyError, match="scenario"):
        resg.stall_seeds(scenario="gpt5")
    with pytest.raises(ValueError, match="roofline"):
        SweepEngine(zoo_ev, backend="pallas")


def test_portfolio_stall_seeds_flatten(zoo_ev, swept):
    """stall_seeds() flattens to '<scenario>:<class>' campaign labels;
    scenario= selects one scenario's classes."""
    _, res = swept
    flat = res.stall_seeds()
    assert len(flat) == 4 * len(res.scenario_names)
    one = res.stall_seeds(scenario=res.scenario_names[0])
    assert set(one) == {"tensor_compute", "vector_compute", "memory_bw",
                        "interconnect"}
    for cls, arr in one.items():
        assert np.array_equal(
            flat[f"{res.scenario_names[0]}:{cls}"], arr)
        assert arr.ndim == 2 and arr.shape[1] == SPACE.n_params


# --------------------------------------------------- archive auto-capacity
def test_archive_auto_capacity_tracks_front_width():
    rng = np.random.default_rng(0)
    arch = ParetoArchive(3, capacity="auto", auto_floor=32)
    for _ in range(20):
        arch.insert(rng.uniform(1, 2, size=(256, 3)))
    assert not arch.truncated                       # auto never truncated it
    assert arch.capacity >= max(32, 2 * len(arch))  # bound trails the width
    # a fixed-capacity run at the auto-derived bound reproduces the front
    fixed = ParetoArchive(3, capacity=arch.capacity)
    rng = np.random.default_rng(0)
    for _ in range(20):
        fixed.insert(rng.uniform(1, 2, size=(256, 3)))
    assert np.array_equal(np.sort(fixed.y, axis=0), np.sort(arch.y, axis=0))


def test_sweep_accepts_auto_archive_capacity(zoo_ev):
    eng = SweepEngine(get_evaluator("proxy"), chunk_size=8_192,
                      archive_capacity="auto")
    res = eng.run(0, 20_000)
    assert not res.archive_truncated
    assert res.archive_capacity >= 2_048            # the default floor
    ref = SweepEngine(get_evaluator("proxy"), chunk_size=8_192,
                      archive_capacity=None).run(0, 20_000)
    assert np.array_equal(res.pareto_ids, ref.pareto_ids)
    with pytest.raises(ValueError, match="archive_capacity"):
        SweepEngine(get_evaluator("proxy"), archive_capacity="huge")


# --------------------------------------------------- scenario campaigns
def test_campaign_runner_per_scenario_class(zoo_ev, swept):
    """A scenario campaign: the runner optimizes ONE zoo scenario's
    (prefill, decode) pair, seeded from that scenario's sweep stall
    classes, at the usual ~B/K fused dispatch cost."""
    _, res = swept
    scen = zoo_ev.scenarios[0]
    runner = CampaignRunner(zoo_ev, proxy=zoo_ev, scenario=scen.name, seed=0)
    assert runner.ee.workload_pair == (scen.prefill, scen.decode)
    assert np.allclose(runner.ref_point,
                       res.scenario(scen.name).ref_point, rtol=1e-5)
    out = runner.run(budget=6, seeds=res.stall_seeds(scenario=scen.name))
    assert len(out.samples) == 6
    assert len({tuple(s.idx) for s in out.samples}) == 6
    # rounds stay fused: <= 1 dispatch/round + 1 per seed class + the ref
    k = len(out.per_campaign)
    assert out.dispatches <= out.rounds + k + 1
    with pytest.raises(KeyError, match="scenario"):
        CampaignRunner(zoo_ev, scenario="imaginary-arch")
