"""Design-space encode/decode round-trip properties (hypothesis)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # offline container: deterministic fallback
    from _hyp_compat import given, settings, st

from repro.perfmodel.designspace import SPACE, A100_REFERENCE, DESIGN_A


idx_strategy = st.tuples(*[st.integers(0, int(c) - 1)
                           for c in SPACE.cardinalities])


@given(idx_strategy)
@settings(max_examples=100, deadline=None)
def test_flat_roundtrip(idx):
    idx = np.array(idx, dtype=np.int32)
    flat = SPACE.idx_to_flat(idx)
    assert 0 <= flat < SPACE.size
    back = SPACE.flat_to_idx(flat)
    assert np.array_equal(back, idx)


@given(idx_strategy)
@settings(max_examples=50, deadline=None)
def test_decode_members(idx):
    idx = np.array(idx, dtype=np.int32)
    vals = SPACE.decode_np(idx)
    for i, name in enumerate(SPACE.names):
        assert float(vals[name]) in SPACE.choices[i]


def test_encode_decode_design_a():
    idx = SPACE.encode({**DESIGN_A, "gbuf_mb": 32})   # 40MB not in space
    vals = SPACE.decode_np(idx)
    assert int(vals["core_count"]) == 64
    assert int(vals["sa_dim"]) == 32


def test_encode_nearest_a100():
    idx = SPACE.encode_nearest(A100_REFERENCE)
    vals = SPACE.decode_np(idx)
    assert int(vals["core_count"]) == 108
    assert int(vals["gbuf_mb"]) == 32      # nearest member to 40 MB


def test_neighbors_validity():
    idx = SPACE.encode_nearest(A100_REFERENCE)
    nbrs = SPACE.neighbors(idx)
    assert len(nbrs) >= SPACE.n_params      # most params have both directions
    for n in nbrs:
        assert (n >= 0).all() and (n < SPACE.cardinalities).all()
        assert np.abs(n - idx).sum() == 1


def test_sample_shape_and_range():
    rng = np.random.default_rng(0)
    s = SPACE.sample(rng, 1000)
    assert s.shape == (1000, SPACE.n_params)
    assert (s >= 0).all() and (s < SPACE.cardinalities[None, :]).all()
