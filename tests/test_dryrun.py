"""Dry-run integration: one real (arch x shape x mesh) cell through the
512-host-device lower+compile path, in a subprocess (the XLA device-count
flag must be set before jax initializes).  Also unit-tests the HLO
collective parser and the roofline-term math."""
import json
import os
import subprocess
import sys

import pytest

from repro.launch.dryrun import parse_collectives, roofline_terms

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_parse_collectives():
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(%x), replica_groups={{0,1}}
  %ar.1 = f32[256]{0} all-reduce(%y), to_apply=%add
  %aa = (bf16[4,4]{1,0}) all-to-all(%z)
  %cp = u8[16]{0} collective-permute(%w)
  %dot = f32[8,8]{1,0} dot(%a, %b)
"""
    out = parse_collectives(hlo)
    assert out["all-gather"] == 8 * 128 * 2
    assert out["all-reduce"] == 256 * 4
    assert out["all-to-all"] == 4 * 4 * 2
    assert out["collective-permute"] == 16
    assert "dot" not in out


def test_roofline_terms_math():
    t = roofline_terms(197e12, 819e9, {"all-reduce": 50e9})
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(1.0)


@pytest.mark.slow
def test_one_cell_compiles(tmp_path):
    """llama3.2-1b x decode_32k x multi-pod: full 512-device lower+compile."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "llama3.2-1b",
         "--shape", "decode_32k", "--mesh", "multi", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=900, cwd=REPO)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rec = json.load(open(tmp_path / "llama3.2-1b__decode_32k__multi.json"))
    assert rec["status"] == "OK"
    assert rec["flops"] > 0
    assert rec["memory"]["temp_size_in_bytes"] < 16 * 2 ** 30   # fits v5e HBM
