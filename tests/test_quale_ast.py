"""repro.core.quale_ast is now a deprecation shim over
repro.analysis.influence; the original cross-validation contract must keep
holding through it."""
import importlib
import warnings

import pytest

from repro.core.quale import derive_influence_map
from repro.perfmodel import get_evaluator
from repro.perfmodel.designspace import PARAM_NAMES


def _import_shim():
    import repro.core.quale_ast as qa
    return importlib.reload(qa)


def test_shim_warns_deprecation():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        qa = _import_shim()
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert callable(qa.derive_influence_map_from_source)


def test_source_map_covers_probed_map():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.core.quale_ast import derive_influence_map_from_source
    src_map = derive_influence_map_from_source()
    probed = derive_influence_map(get_evaluator("proxy"), n_probes=6, seed=0)
    for p in PARAM_NAMES:
        # static reachability is an over-approximation of observed influence
        assert probed.metric_edges[p] <= src_map[p], (
            p, probed.metric_edges[p], src_map[p])


def test_source_map_structure():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        import repro.core.quale_ast as qa
    m = qa.derive_influence_map_from_source()
    for p in PARAM_NAMES:
        assert "area" in m[p], p          # every param has an area cost
    assert {"ttft", "tpot"} <= m["mem_channels"]
    assert {"ttft", "tpot"} <= m["link_count"]
    # legacy table access resolves through the extracted graph
    d2m = qa.DERIVED_TO_METRICS
    assert d2m["tensor_flops"] == {"ttft", "tpot"}
    assert d2m["area_mm2"] == {"area"}
    with pytest.raises(AttributeError):
        qa.not_an_attr
