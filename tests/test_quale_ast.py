"""QualE static-analysis path: the AST-derived influence map must agree
with the probing-derived map on metric edges (§3.2.1 cross-validation)."""
import pytest

from repro.core.quale import derive_influence_map
from repro.core.quale_ast import derive_influence_map_from_source
from repro.perfmodel import get_evaluator
from repro.perfmodel.designspace import PARAM_NAMES


def test_source_map_covers_probed_map():
    src_map = derive_influence_map_from_source()
    probed = derive_influence_map(get_evaluator("proxy"), n_probes=6, seed=0)
    for p in PARAM_NAMES:
        # static reachability is an over-approximation of observed influence
        assert probed.metric_edges[p] <= src_map[p], (
            p, probed.metric_edges[p], src_map[p])


def test_source_map_structure():
    m = derive_influence_map_from_source()
    for p in PARAM_NAMES:
        assert "area" in m[p], p          # every param has an area cost
    assert {"ttft", "tpot"} <= m["mem_channels"]
    assert {"ttft", "tpot"} <= m["link_count"]
