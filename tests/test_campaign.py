"""Multi-campaign orchestration + the exploration-engine bugfix regressions.

Covers: the latency-weighted dominant-stall merge (a TPOT-bound design is
attributed to the TPOT report's stall class even when TTFT is merely
large); bounded LRU report-cache eviction keeps the hot base design (the
one-dispatch-per-step invariant holds across an eviction boundary); empty
stall-seed classes are skipped, not crashed on; K campaigns at shared
budget B cost ~B/K fused dispatches; the merged archive's per-step regret
curve is monotonically non-increasing and its PHV fraction non-decreasing;
seed lists + step callbacks on the single-campaign loop.
"""
import json

import numpy as np
import pytest

import repro.core.explore as explore_mod
from repro.core.campaign import CampaignRunner, REFERENCE_CAMPAIGN
from repro.core.explore import ExplorationEngine
from repro.core.loop import LuminaDSE
from repro.perfmodel import (EvalRequest, ModelEvaluator, OracleEvaluator,
                             get_evaluator)
from repro.perfmodel.critical_path import StallReport
from repro.perfmodel.designspace import SPACE, A100_REFERENCE
from repro.perfmodel.sweep import SweepEngine

RNG = np.random.default_rng(7)


def _report(latency, dominant, fraction, area=800.0):
    stalls = {c: 0.0 for c in
              ("tensor_compute", "vector_compute", "memory_bw",
               "interconnect")}
    stalls[dominant] = fraction * latency
    return StallReport(stall_seconds=stalls, dominant=dominant,
                       dominant_fraction=fraction, top_ops=[],
                       latency=latency, area=area)


# ---------------------------------------------------- dominant-stall merge
def test_merge_is_latency_weighted():
    """The report whose dominant stall burns more time (on its objective's
    reference scale) wins — not the one with the higher fraction."""
    ee = ExplorationEngine(get_evaluator("proxy"))
    assert ee.ref_point is None                       # bare engine: raw time
    rep_t = _report(100.0, "memory_bw", 0.4)          # 40s absolute
    rep_p = _report(30.0, "tensor_compute", 0.9)      # 27s absolute
    assert ee._merge(rep_t, rep_p) is rep_t
    rep_p2 = _report(60.0, "tensor_compute", 0.9)     # 54s absolute
    assert ee._merge(rep_t, rep_p2) is rep_p2
    # with reference scales, each objective is weighted on its own latency
    # scale: a relatively-worse TPOT wins although its raw seconds are tiny
    ee.ref_point = np.array([100.0, 0.01, 800.0])
    rep_p3 = _report(0.02, "tensor_compute", 0.5)     # 1.0 ref-relative
    assert ee._merge(rep_t, rep_p3) is rep_p3         # 0.4 ref-relative ttft
    assert ee._merge(_report(100.0, "memory_bw", 1.0),
                     rep_p3) is not rep_p3            # 1.0 >= 1.0 -> ttft


def test_lumina_dse_sets_merge_scales():
    dse = LuminaDSE(ModelEvaluator(get_evaluator("proxy").models))
    assert np.array_equal(dse.ee.ref_point, dse.ref_point)


def test_tpot_bound_design_attributed_to_tpot_stall(monkeypatch):
    """Regression: a TPOT-bound design (decode stall dominates in absolute
    time) must NOT be attributed to the TTFT report just because TTFT
    latency is large — the old `latency >= 50 * tpot` bypass did exactly
    that."""
    ee = ExplorationEngine(get_evaluator("proxy"))
    # TTFT is 100x TPOT (the old bypass territory) but its dominant stall
    # is a sliver; TPOT's dominant stall is bigger in absolute seconds
    rep_t = _report(1.0, "memory_bw", 0.004)          # 0.004s absolute
    rep_p = _report(0.01, "interconnect", 0.9)        # 0.009s absolute
    monkeypatch.setattr(ee, "_report_pair", lambda idx: (rep_t, rep_p))
    sample = ee.evaluate(SPACE.sample(RNG, 1)[0], step=1)
    assert sample.dominant_stall == "interconnect"


# ---------------------------------------------------- LRU report cache
def test_report_cache_lru_keeps_hot_base(monkeypatch):
    """One dispatch per NEW design, even across the cache-eviction
    boundary: the `reports()` re-read of the hot base design must never
    re-dispatch (the old cache .clear() evicted it)."""
    monkeypatch.setattr(explore_mod, "_CACHE_CAP", 4)
    ev = ModelEvaluator(get_evaluator("proxy").models)
    ee = ExplorationEngine(ev)
    designs = SPACE.sample(RNG, 12)
    base = designs[0]
    d0 = ev.dispatches
    ee.evaluate(base, step=0)
    for step, d in enumerate(designs[1:], start=1):
        ee.reports(base)                 # the SE re-reading the base design
        ee.evaluate(d, step=step)
    # 12 unique designs -> exactly 12 dispatches despite capacity 4
    assert ev.dispatches - d0 == len(designs)
    assert len(ee._cache) <= 4


def test_prefetch_batches_into_one_dispatch():
    ev = ModelEvaluator(get_evaluator("proxy").models)
    ee = ExplorationEngine(ev)
    designs = SPACE.sample(RNG, 6)
    d0 = ev.dispatches
    assert ee.prefetch(designs) == 6     # one fused dispatch for all six
    assert ev.dispatches - d0 == 1
    for i, d in enumerate(designs):      # all cache-resident now
        ee.evaluate(d, step=i)
    assert ev.dispatches - d0 == 1
    assert ee.evals == 6                 # budget accounting still per design
    assert ee.prefetch(designs) == 0     # fully cached: no dispatch at all
    assert ev.dispatches - d0 == 1


# ---------------------------------------------------- empty seed classes
def test_stall_seeds_empty_class_returns_empty_array():
    """A sweep over a subrange where some stall class never dominates must
    yield an EMPTY (0, n_params) seed array for it — not crash."""
    eng = SweepEngine(get_evaluator("proxy"), chunk_size=8_192, stall_topk=4)
    res = eng.run(0, 20_000)
    seeds = res.stall_seeds()
    assert set(seeds) == {"tensor_compute", "vector_compute", "memory_bw",
                          "interconnect"}
    empty = [k for k, v in seeds.items() if v.shape[0] == 0]
    assert empty, "expected at least one absent stall class in this subrange"
    for arr in seeds.values():
        assert arr.ndim == 2 and arr.shape[1] == SPACE.n_params


def test_duplicate_seeds_never_burn_budget():
    """A stall-class seed equal to the reference start (or to another
    class's seed) must not be evaluated twice — every budget unit buys a
    UNIQUE design."""
    ev = ModelEvaluator(get_evaluator("proxy").models)
    runner = CampaignRunner(ev, proxy=get_evaluator("proxy"), seed=0)
    ref_idx = SPACE.encode_nearest(A100_REFERENCE)
    dup = SPACE.sample(RNG, 1)[0]
    res = runner.run(budget=6, seeds={
        "memory_bw": ref_idx[None, :],           # duplicates the a100 start
        "tensor_compute": np.stack([dup, dup]),  # internal duplicate
        "interconnect": dup[None, :],            # cross-class duplicate
    })
    assert len(res.samples) == 6
    assert len({tuple(s.idx) for s in res.samples}) == 6
    # the all-duplicate classes never became campaigns
    assert set(res.per_campaign) == {REFERENCE_CAMPAIGN, "tensor_compute"}


def test_campaign_runner_skips_empty_seed_classes():
    ev = ModelEvaluator(get_evaluator("proxy").models)
    runner = CampaignRunner(ev, proxy=get_evaluator("proxy"), seed=0)
    seeds = {
        "memory_bw": SPACE.sample(RNG, 2),
        "interconnect": np.zeros((0, SPACE.n_params), dtype=np.int32),
        "vector_compute": np.zeros((0,), dtype=np.int32),  # degenerate shape
    }
    res = runner.run(budget=8, seeds=seeds)
    assert set(res.per_campaign) == {REFERENCE_CAMPAIGN, "memory_bw"}
    assert len(res.samples) == 8
    with pytest.raises(ValueError, match="no campaigns"):
        CampaignRunner(ev, proxy=get_evaluator("proxy")).run(
            budget=4, seeds={"memory_bw": np.zeros((0, SPACE.n_params))},
            include_reference=False)


# ---------------------------------------------------- fused round batching
@pytest.fixture(scope="module")
def oracle():
    return OracleEvaluator(get_evaluator("proxy"),
                           sweep_kwargs=dict(chunk_size=8_192, stall_topk=8,
                                             stall_rank="ref"),
                           stop=60_000)


def test_k_campaigns_batch_to_one_dispatch_per_round(oracle):
    """Acceptance: K seeded campaigns at shared budget B issue ~B/K + O(1)
    fused dispatches (batched rounds), far below the B an unbatched runner
    would spend."""
    ev = ModelEvaluator(get_evaluator("proxy").models)
    runner = CampaignRunner(ev, proxy=get_evaluator("proxy"), seed=0)
    budget = 20
    res = runner.run(budget=budget, sweep=oracle.sweep_result())
    k = len(res.per_campaign)
    assert k >= 3                         # a100 + >= 2 non-empty stall classes
    assert len(res.samples) == budget
    assert res.rounds <= -(-budget // k) + 1
    # fused dispatches: <= 1 per round + 1 per seed class (minimax scoring),
    # certainly far below one per evaluation
    assert res.dispatches <= res.rounds + k + 1
    assert res.dispatches < budget


def test_regret_curve_monotone_and_json_roundtrip(oracle, tmp_path):
    """The merged archive's per-step regret never increases, its PHV
    fraction never decreases, and the telemetry series survives the JSON
    round trip."""
    ev = ModelEvaluator(get_evaluator("proxy").models)
    runner = CampaignRunner(ev, proxy=get_evaluator("proxy"),
                            oracle=oracle, seed=0)
    res = runner.run(budget=15, sweep=oracle.sweep_result())
    regret = res.regret_curve()
    phv_frac = res.phv_frac_curve()
    assert regret.shape == (15, 3) and not np.isnan(regret).any()
    assert (np.diff(regret, axis=0) <= 1e-12).all()
    assert (np.diff(phv_frac) >= -1e-12).all()
    path = tmp_path / "telemetry.json"
    res.save_telemetry(str(path))
    data = json.loads(path.read_text())
    assert len(data["records"]) == 15
    assert data["records"][0]["eval_i"] == 1
    got = np.array([r["regret"] for r in data["records"]])
    assert np.allclose(got, regret)
    # every record names a live campaign
    assert set(r["campaign"] for r in data["records"]) \
        <= set(data["campaigns"])


# ---------------------------------------------------- scheduling policies
def test_policy_validation():
    ev = ModelEvaluator(get_evaluator("proxy").models)
    with pytest.raises(ValueError, match="policy"):
        CampaignRunner(ev, policy="greedy")


def test_uniform_policy_never_early_stops():
    ev = ModelEvaluator(get_evaluator("proxy").models)
    runner = CampaignRunner(ev, proxy=get_evaluator("proxy"), seed=0)
    res = runner.run(budget=8, seeds={"memory_bw": SPACE.sample(RNG, 2)})
    assert res.policy == "uniform"
    assert res.early_stopped == {}
    assert res.budget_weights is None


def test_allocate_slots_weighted_deficit():
    """Deterministic shares: over N rounds each label is chosen ~N * its
    normalized weight times, ties break toward the front of `order`, and
    the carried credit guarantees even a floor-weight label is served."""
    from repro.core.campaign import allocate_slots
    credit = {"a": 0.0, "b": 0.0}
    weights = {"a": 1.05, "b": 0.05}
    counts = {"a": 0, "b": 0}
    for _ in range(22):                     # one full period of b's share
        for lb in allocate_slots(["a", "b"], credit, weights, 1):
            counts[lb] += 1
    assert counts == {"a": 21, "b": 1}      # 22 * (0.05 / 1.10) == 1
    # equal weights, 2 slots of 3: stable tie-break then deficit rotation
    credit = {}
    eq = {"x": 1.0, "y": 1.0, "z": 1.0}
    assert allocate_slots(["x", "y", "z"], credit, eq, 2) == ["x", "y"]
    assert allocate_slots(["x", "y", "z"], credit, eq, 2) == ["x", "z"]
    assert allocate_slots(["x", "y", "z"], credit, eq, 2) == ["y", "z"]
    # degenerate inputs
    assert allocate_slots([], {}, {}, 3) == []
    assert allocate_slots(["x"], {}, {"x": 1.0}, 0) == []
    with pytest.raises(ValueError, match="positive"):
        allocate_slots(["x"], {}, {"x": 0.0}, 1)


def test_adaptive_policy_continuous_budget_weights():
    """The continuous adaptive policy reallocates by regret slope without
    ever killing a campaign: the shared budget is spent exactly, every
    campaign keeps proposing (weight floor), no binary early-stop fires,
    and the final scheduling weights are reported + serialized."""
    from repro.core.campaign import ADAPTIVE_WEIGHT_FLOOR
    rng = np.random.default_rng(11)
    ev = ModelEvaluator(get_evaluator("proxy").models)
    runner = CampaignRunner(ev, proxy=get_evaluator("proxy"), seed=0,
                            policy="adaptive", patience=1)
    seeds = {"memory_bw": SPACE.sample(rng, 2),
             "interconnect": SPACE.sample(rng, 2)}
    res = runner.run(budget=18, seeds=seeds)
    assert res.policy == "adaptive"
    assert len(res.samples) == 18                # budget spent exactly
    assert len({tuple(s.idx) for s in res.samples}) == 18
    assert res.early_stopped == {}               # continuous, not binary
    # nobody is starved: every campaign observes at least one sample
    observed = {t.campaign for t in res.telemetry}
    assert observed == set(res.per_campaign)
    # final weights cover every campaign and respect the floor
    assert set(res.budget_weights) == set(res.per_campaign)
    assert all(w >= ADAPTIVE_WEIGHT_FLOOR - 1e-9
               for w in res.budget_weights.values())
    assert all(w <= 1.0 + ADAPTIVE_WEIGHT_FLOOR + 1e-9
               for w in res.budget_weights.values())
    # serialization carries the policy + continuous weights
    data = res.telemetry_dict()
    assert data["policy"] == "adaptive"
    assert data["early_stopped"] == {}
    assert data["budget_weights"] == res.budget_weights


def test_seeds_per_campaign_multi_seed_step0():
    """seeds_per_campaign > 1: the stall-class campaign drains its whole
    step-0 seed list before the trajectory moves on."""
    rng = np.random.default_rng(5)
    ev = ModelEvaluator(get_evaluator("proxy").models)
    runner = CampaignRunner(ev, proxy=get_evaluator("proxy"), seed=0,
                            seeds_per_campaign=2)
    res = runner.run(budget=8, seeds={"memory_bw": SPACE.sample(rng, 3)})
    camp = res.per_campaign["memory_bw"]
    steps = [s.step for s in camp.samples]
    assert steps[:2] == [0, 0]                   # both seeds evaluated first
    assert len(steps) < 3 or steps[2] == 1


# ---------------------------------------------------- seed lists + callback
def test_run_accepts_seed_list_and_step_callback():
    ev = ModelEvaluator(get_evaluator("proxy").models)
    seeds = np.stack([SPACE.encode_nearest(A100_REFERENCE),
                      SPACE.sample(RNG, 1)[0]])
    seen = []
    res = LuminaDSE(ev, proxy=get_evaluator("proxy"), seed=0).run(
        budget=6, init=seeds,
        step_callback=lambda campaign, sample: seen.append(sample.step))
    assert len(res.samples) == 6
    assert len(seen) == 6
    # both seeds were evaluated first (step 0), then the trajectory moved on
    assert [tuple(s.idx) for s in res.samples[:2]] == \
        [tuple(r) for r in seeds]
    assert res.samples[0].step == 0 and res.samples[1].step == 0
    assert res.samples[2].step == 1


def test_shared_engine_budget_across_campaigns():
    """Two LuminaDSE instances sharing one ExplorationEngine draw from one
    budget pool (the CampaignRunner contract)."""
    ev = ModelEvaluator(get_evaluator("proxy").models)
    ee = ExplorationEngine(ev)
    proxy = get_evaluator("proxy")
    a = LuminaDSE(ev, proxy=proxy, engine=ee, seed=0)
    b = LuminaDSE(ev, proxy=proxy, engine=ee, seed=1)
    a.run(budget=5)
    assert ee.evals == 5
    b.run(budget=5)                      # its OWN 5, on top of a's
    assert ee.evals == 10
