from repro.data.pipeline import SyntheticLMDataset, PrefetchIterator, make_batch_iter

__all__ = ["SyntheticLMDataset", "PrefetchIterator", "make_batch_iter"]
