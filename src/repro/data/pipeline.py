"""Deterministic synthetic LM data pipeline, shard-aware, double-buffered.

Tokens are a cheap stateless hash of (step, position) so (a) any worker can
produce its shard without coordination, (b) restarts resume bit-identically
from the step counter (fault-tolerance requirement: the data pipeline must
be replayable from a checkpointed step), and (c) the stream has enough
structure (a noisy periodic pattern) for the loss to actually fall.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

try:
    import jax
    from jax.sharding import NamedSharding, PartitionSpec
except Exception:                                    # pragma: no cover
    jax = None


class SyntheticLMDataset:
    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, structure: int = 97):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.structure = structure     # period of the learnable pattern

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Full global batch for `step` (deterministic)."""
        b, s = self.global_batch, self.seq_len
        rng = np.random.default_rng((self.seed * 1_000_003 + step) & 0x7FFFFFFF)
        base = rng.integers(0, self.structure, size=(b, 1))
        pos = np.arange(s + 1)[None, :]
        pattern = (base + pos) % self.structure
        noise = rng.integers(0, self.vocab, size=(b, s + 1))
        mask = rng.random((b, s + 1)) < 0.15
        toks = np.where(mask, noise, pattern % self.vocab).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class PrefetchIterator:
    """Background-thread prefetch (double buffering: compute step i while
    the host builds batch i+1)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = object()
        self._err: Optional[BaseException] = None

        def worker():
            try:
                for item in it:
                    self._q.put(item)
            except BaseException as e:          # propagate into consumer
                self._err = e
            finally:
                self._q.put(self._done)

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item


def make_batch_iter(ds: SyntheticLMDataset, start_step: int, num_steps: int,
                    mesh=None, dp_axes=("data",), prefetch: int = 2):
    """Yields device-placed (when mesh given) batches for steps
    [start_step, start_step+num_steps)."""

    def gen():
        for step in range(start_step, start_step + num_steps):
            host = ds.batch_at(step)
            if mesh is None:
                yield host
                continue
            spec = PartitionSpec(tuple(dp_axes), None)
            out = {}
            for k, v in host.items():
                sh = NamedSharding(mesh, spec)
                out[k] = jax.device_put(v, sh)
            yield out

    return PrefetchIterator(gen(), depth=prefetch)
