"""DSE-as-a-service: the cross-machine, multi-tenant serving layer.

Turns the in-process evaluation stack into an always-on service in
three layers, each riding an existing contract unchanged:

* **Transport** (:mod:`~repro.serve.wire`, :mod:`~repro.serve.codec`,
  :mod:`~repro.serve.worker`, :mod:`~repro.serve.pool`) — the PR 4
  pickled-spec + ``ShardPayload -> PPAReport`` exchange over
  length-prefixed TCP frames, carried by a schema-restricted binary
  codec with optional HMAC frame signing, replay rejection and TLS
  (legacy pickle only behind ``insecure=True``).  Run
  ``python -m repro.serve.worker --host H --port P --key id=secret`` on
  any machine; point a :class:`~repro.distributed.sharded.
  ShardedEvaluator` at the fleet with ``mode='socket'`` plus either a
  static ``addresses=[(H, P), ...]`` list or a live ``membership=``
  view workers announce to (:mod:`~repro.serve.membership`), and the
  retry / timeout / straggler / elastic / chaos machinery drives remote
  workers exactly as it drives local pools.  Workers enforce their own
  quotas (rows/dispatch, concurrency, deadline, per-peer rate) and the
  evaluator reroutes refusals instead of hammering.
* **QoS** — :meth:`EvalService.submit(..., tier=...)
  <repro.distributed.service.EvalService.submit>` with weighted-deficit
  tier drain and an anti-starvation floor (lives in
  :mod:`repro.distributed.service`; re-exported here).
* **Admission control** (:mod:`~repro.serve.gateway`) — per-tenant row
  budgets, queue-depth backpressure with drain-ETA retry hints, fleet
  telemetry down to membership leases.

See ``examples/serve_cluster.py`` for the authenticated two-worker
loopback cluster walkthrough and the README "DSE-as-a-service" section
(incl. the security model) for deployment.
"""

from repro.distributed.service import (DEFAULT_TIER_WEIGHTS, QOS_TIERS,
                                       EvalService)
from repro.serve.codec import (AuthError, Channel, CodecError, FrameTooLarge,
                               Keyring, restricted_loads, spec_digest)
from repro.serve.gateway import Gateway, RetryAfter, TenantAccount
from repro.serve.membership import MembershipView, Registrar
from repro.serve.pool import SocketPool, connect_evaluator
from repro.serve.wire import WIRE_VERSION, ConnectionClosed, WireError
from repro.serve.worker import (WorkerHandle, WorkerOptions, WorkerServer,
                                start_worker_process)

__all__ = ["EvalService", "QOS_TIERS", "DEFAULT_TIER_WEIGHTS",
           "Gateway", "RetryAfter", "TenantAccount",
           "SocketPool", "connect_evaluator",
           "WorkerServer", "WorkerHandle", "WorkerOptions",
           "start_worker_process",
           "Keyring", "Channel", "AuthError", "CodecError", "FrameTooLarge",
           "restricted_loads", "spec_digest",
           "MembershipView", "Registrar",
           "WIRE_VERSION", "WireError", "ConnectionClosed"]
