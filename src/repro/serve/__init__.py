"""DSE-as-a-service: the cross-machine, multi-tenant serving layer.

Turns the in-process evaluation stack into an always-on service in
three layers, each riding an existing contract unchanged:

* **Transport** (:mod:`~repro.serve.wire`, :mod:`~repro.serve.worker`,
  :mod:`~repro.serve.pool`) — the PR 4 pickled-spec + ``ShardPayload ->
  PPAReport`` wire format over length-prefixed TCP frames.  Run
  ``python -m repro.serve.worker --host H --port P`` on any machine;
  point a :class:`~repro.distributed.sharded.ShardedEvaluator` at the
  fleet with ``mode='socket', addresses=[(H, P), ...]`` (or
  :func:`~repro.serve.pool.connect_evaluator`) and the retry / timeout /
  straggler / elastic / chaos machinery drives remote workers exactly as
  it drives local pools.
* **QoS** — :meth:`EvalService.submit(..., tier=...)
  <repro.distributed.service.EvalService.submit>` with weighted-deficit
  tier drain and an anti-starvation floor (lives in
  :mod:`repro.distributed.service`; re-exported here).
* **Admission control** (:mod:`~repro.serve.gateway`) — per-tenant row
  budgets, queue-depth backpressure with drain-ETA retry hints, fleet
  telemetry.

See ``examples/serve_cluster.py`` for the two-worker loopback cluster
walkthrough and the README "DSE-as-a-service" section for deployment.
"""

from repro.distributed.service import (DEFAULT_TIER_WEIGHTS, QOS_TIERS,
                                       EvalService)
from repro.serve.gateway import Gateway, RetryAfter, TenantAccount
from repro.serve.pool import SocketPool, connect_evaluator
from repro.serve.wire import WIRE_VERSION, ConnectionClosed, WireError
from repro.serve.worker import (WorkerHandle, WorkerServer,
                                start_worker_process)

__all__ = ["EvalService", "QOS_TIERS", "DEFAULT_TIER_WEIGHTS",
           "Gateway", "RetryAfter", "TenantAccount",
           "SocketPool", "connect_evaluator",
           "WorkerServer", "WorkerHandle", "start_worker_process",
           "WIRE_VERSION", "WireError", "ConnectionClosed"]
