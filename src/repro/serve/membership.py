"""Dynamic worker membership: TTL leases instead of a static address list.

PR 7's ``SocketPool(addresses=[...])`` hard-codes the fleet at
construction — fine for a loopback bench, wrong for a real cluster where
workers come and go.  This module inverts the direction of discovery:
**workers dial the gateway**, announce ``(address, spec digests,
capacity)`` to a :class:`Registrar`, and hold a lease that lapses unless
renewed by heartbeat.  The pool consumes a :class:`MembershipView` — a
live, versioned set of worker addresses — so join/leave events drive
the existing elastic-resize path, and
:meth:`~repro.serve.gateway.Gateway.telemetry` can show *leases*, not
just sockets.

Lease semantics: an :class:`~repro.serve.wire.Announce` frame (re)news
the lease for ``ttl_s``; a :class:`~repro.serve.wire.Bye` removes it
immediately; a worker that crashes simply stops renewing and ages out
after ``ttl_s`` — no failure detector beyond the clock.  The view keeps
a monotonic **version** that bumps on every topology change (join,
leave, expiry — NOT renewals), which is what lets consumers sync in
O(1) on the common no-change path.

The registrar speaks the same framed codec as the dispatch plane
(:mod:`repro.serve.codec`): announcements are HMAC-signed under the
shared keyring, so an unauthenticated host cannot register itself into
the fleet (or unregister someone else).  All instruments live in the
injected :class:`~repro.obs.metrics.MetricsRegistry`:
``membership_joins`` / ``membership_renewals`` /
``membership_expirations`` / ``membership_leaves`` counters and the
``membership_live`` gauge.
"""
from __future__ import annotations

import socket
import threading
import time
from typing import Dict, List, Optional, Tuple

from repro.obs.metrics import Clock, MetricsRegistry
from repro.serve import codec as _codec
from repro.serve import wire

DEFAULT_TTL_S = 5.0

Address = Tuple[str, int]


class Lease:
    """One worker's claim on fleet membership."""

    __slots__ = ("address", "digests", "capacity", "expires_at", "joined_at",
                 "renewals")

    def __init__(self, address: Address, digests: Tuple[str, ...],
                 capacity: int, now: float, ttl_s: float):
        self.address = address
        self.digests = digests
        self.capacity = capacity
        self.joined_at = now
        self.expires_at = now + ttl_s
        self.renewals = 0


class MembershipView:
    """Thread-safe lease table with lazy expiry.

    Expiry is swept on every read (``live``/``version``/``snapshot``)
    against the injected clock, so tests drive it with a
    :class:`~repro.obs.metrics.ManualClock` and production needs no
    dedicated reaper thread — any consumer touching the view collects
    the garbage.
    """

    def __init__(self, *, ttl_s: float = DEFAULT_TTL_S,
                 clock: Optional[Clock] = None,
                 metrics: Optional[MetricsRegistry] = None):
        self.ttl_s = float(ttl_s)
        self.clock: Clock = clock if clock is not None else time.monotonic
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._c_joins = self.metrics.counter(
            "membership_joins", "workers granted a fresh lease")
        self._c_renewals = self.metrics.counter(
            "membership_renewals", "lease heartbeat renewals")
        self._c_expirations = self.metrics.counter(
            "membership_expirations", "leases lapsed past TTL")
        self._c_leaves = self.metrics.counter(
            "membership_leaves", "graceful lease withdrawals (Bye)")
        self._g_live = self.metrics.gauge(
            "membership_live", "workers currently holding a lease")
        self._lock = threading.Lock()
        self._leases: Dict[Address, Lease] = {}
        self._version = 0

    # -- writes ----------------------------------------------------------
    def announce(self, address: Address, digests: Tuple[str, ...] = (),
                 capacity: int = 1) -> float:
        """Grant or renew a lease; returns the TTL for the ack."""
        address = (str(address[0]), int(address[1]))
        now = self.clock()
        with self._lock:
            self._sweep(now)
            lease = self._leases.get(address)
            if lease is None:
                self._leases[address] = Lease(address, tuple(digests),
                                              int(capacity), now, self.ttl_s)
                self._version += 1
                self._c_joins.inc()
            else:
                lease.expires_at = now + self.ttl_s
                lease.digests = tuple(digests)
                lease.capacity = int(capacity)
                lease.renewals += 1
                self._c_renewals.inc()
            self._g_live.set(len(self._leases))
        return self.ttl_s

    def remove(self, address: Address) -> bool:
        """Graceful withdrawal (worker said Bye)."""
        address = (str(address[0]), int(address[1]))
        with self._lock:
            gone = self._leases.pop(address, None) is not None
            if gone:
                self._version += 1
                self._c_leaves.inc()
                self._g_live.set(len(self._leases))
        return gone

    def _sweep(self, now: float) -> None:
        # caller holds the lock
        dead = [a for a, l in self._leases.items() if l.expires_at <= now]
        for a in dead:
            del self._leases[a]
            self._version += 1
            self._c_expirations.inc()
        if dead:
            self._g_live.set(len(self._leases))

    # -- reads -----------------------------------------------------------
    def live(self) -> List[Address]:
        """Addresses currently under lease, sorted for deterministic slot
        assignment across consumers."""
        with self._lock:
            self._sweep(self.clock())
            return sorted(self._leases)

    def version(self) -> int:
        """Monotonic topology version: changes iff the live set changed."""
        with self._lock:
            self._sweep(self.clock())
            return self._version

    def __len__(self) -> int:
        return len(self.live())

    def snapshot(self) -> Dict[str, dict]:
        """Per-lease telemetry for the gateway fleet view."""
        with self._lock:
            now = self.clock()
            self._sweep(now)
            return {
                f"{a[0]}:{a[1]}": {
                    "capacity": l.capacity,
                    "digests": list(l.digests),
                    "renewals": l.renewals,
                    "ttl_remaining_s": max(0.0, l.expires_at - now),
                }
                for a, l in sorted(self._leases.items())
            }

    def wait_for(self, n: int, timeout_s: float = 10.0,
                 poll_s: float = 0.02) -> bool:
        """Block until at least ``n`` workers hold leases (real-clock
        convenience for construction paths and tests)."""
        deadline = time.monotonic() + timeout_s
        while time.monotonic() < deadline:
            if len(self.live()) >= n:
                return True
            time.sleep(poll_s)
        return len(self.live()) >= n


class Registrar:
    """The gateway-side TCP endpoint workers announce themselves to.

    Each worker holds one persistent connection; every
    :class:`~repro.serve.wire.Announce` on it renews the lease and is
    acked with :class:`~repro.serve.wire.LeaseAck`; a
    :class:`~repro.serve.wire.Bye` withdraws immediately; a dead
    connection just stops renewing — the TTL does the rest.  Frames are
    authenticated exactly like the dispatch plane: with a ``keyring``,
    unsigned/tampered/replayed announcements are rejected (and counted
    as ``registrar_auth_rejected``); the legacy pickle codec is only
    accepted under ``insecure=True``.
    """

    def __init__(self, view: Optional[MembershipView] = None, *,
                 host: str = "127.0.0.1", port: int = 0,
                 keyring: Optional[_codec.Keyring] = None,
                 insecure: bool = False,
                 ssl_context=None,
                 max_frame_bytes: int = 1 << 20,
                 metrics: Optional[MetricsRegistry] = None):
        self.view = view if view is not None else MembershipView(
            metrics=metrics)
        self.keyring = keyring
        self.insecure = bool(insecure)
        self.ssl_context = ssl_context
        self.max_frame_bytes = int(max_frame_bytes)
        self.metrics = (metrics if metrics is not None
                        else self.view.metrics)
        self._c_auth_rejected = self.metrics.counter(
            "registrar_auth_rejected",
            "announce frames rejected by authentication",
            labelnames=("reason",))
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(16)
        self.host, self.port = self._sock.getsockname()[:2]
        self.address: Address = (self.host, self.port)
        self._closed = threading.Event()
        self._accept_thread: Optional[threading.Thread] = None

    @property
    def auth_rejected(self) -> int:
        return int(self._c_auth_rejected.total())

    def start(self) -> "Registrar":
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="registrar-accept", daemon=True)
        self._accept_thread.start()
        return self

    def close(self) -> None:
        if not self._closed.is_set():
            self._closed.set()
            try:
                self._sock.close()
            except OSError:
                pass

    # -- internals -------------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._closed.is_set():
            try:
                conn, _addr = self._sock.accept()
            except OSError:
                break
            threading.Thread(target=self._serve_conn, args=(conn,),
                             name="registrar-conn", daemon=True).start()

    def _serve_conn(self, conn: socket.socket) -> None:
        announced: Optional[Address] = None
        try:
            if self.ssl_context is not None:
                conn = self.ssl_context.wrap_socket(conn, server_side=True)
            first = wire.recv_frame(conn, self.max_frame_bytes)
            mode = _codec.sniff_codec(first)
            if mode == _codec.CODEC_PICKLE and not self.insecure:
                self._c_auth_rejected.inc(reason="pickle_codec")
                return
            ch = _codec.Channel(
                conn, codec=mode,
                keyring=self.keyring if mode == _codec.CODEC_BINARY else None,
                max_frame_bytes=self.max_frame_bytes)
            if mode == _codec.CODEC_BINARY and _codec.is_nonce_frame(first):
                ch.server_handshake(first)
                msg = ch.recv()
            else:
                msg = ch.feed(first)
            while True:
                if isinstance(msg, wire.Announce):
                    announced = (str(msg.address[0]), int(msg.address[1]))
                    ttl = self.view.announce(announced, msg.digests,
                                             msg.capacity)
                    ch.send(wire.LeaseAck(ttl_s=ttl))
                elif isinstance(msg, wire.Bye):
                    if announced is not None:
                        self.view.remove(announced)
                        announced = None
                    break
                else:
                    raise wire.WireError(
                        f"unexpected {type(msg).__name__} on registrar")
                msg = ch.recv()
        except _codec.AuthError as exc:
            self._c_auth_rejected.inc(reason=exc.reason)
        except (wire.WireError, OSError):
            pass                  # dead connection: the TTL handles it
        finally:
            try:
                conn.close()
            except OSError:
                pass
