"""Trusted wire codec for ``repro.serve``: no pickle on the hot path.

The PR 7 transport pickled every frame — acceptable inside one trust
domain, arbitrary-code-execution-as-a-service outside it.  This module
replaces it with a **schema-restricted binary codec** plus optional
per-frame authentication, negotiated implicitly by the first frame of a
connection (codec frames open with a magic marker; pickle frames open
with the pickle opcode, accepted only when both sides opt into
``insecure=True``).

Three layers, all in this file so the trust boundary is one module:

* **Value encoding** — a tagged binary format for exactly the types the
  frame vocabulary needs: ``None``, bools, ints, floats, str, bytes,
  tuples/lists, str-keyed dicts, and numpy arrays from a dtype
  allowlist.  The decoder constructs *only* these types; there is no
  object/reduce/class machinery to smuggle code through.
* **Message schema** — :data:`MESSAGE_TYPES` maps the narrow frame
  vocabulary (``Hello``/``Ready``/``Dispatch``/``ResultMsg``/
  ``ErrorMsg``/``Ping``/``Pong``/``Bye`` plus the membership frames
  ``Announce``/``LeaseAck``) to explicit field schemas; payloads are
  limited to :class:`~repro.distributed.sharded.ShardPayload` and
  :class:`~repro.perfmodel.evaluator.PPAReport` structures, encoded
  field by field (bit-identical array round-trip: dtype + shape + raw
  C-order bytes).  Anything off-schema is a :class:`CodecError`, never
  an object.
* **Frame auth** — every codec frame can be HMAC-SHA256 signed with a
  shared-secret :class:`Keyring` (key id travels in the frame header,
  so keys rotate without downtime) and carries a monotonic
  per-connection, per-direction sequence number; keyed connections open
  with a session-nonce handshake whose pair of random nonces is folded
  into every frame MAC, so a recorded signed session cannot replay over
  a new connection.  A receiver with a keyring rejects unsigned frames,
  unknown key ids, bad MACs (``tamper``), out-of-order sequence numbers
  and signed frames outside a nonce-bound session (``replay``) — all as
  typed :class:`AuthError`\\ s, counted by the caller, **before** any
  payload decoding happens.

The evaluator *spec* (the PR 4 pickled constructor template) cannot ride
the restricted codec as-is.  Two defenses replace blind unpickling:
:func:`restricted_loads` deserializes it through an **allowlisted
constructor table** (only ``repro.*`` model/space classes, numpy array
reconstructors and a short list of builtins resolve; everything else
raises), and workers can additionally pin an out-of-band
``spec_digests`` allowlist so only pre-approved studies rebuild at all.
:func:`legacy_loads` is the *only* raw ``pickle.loads`` on the serve
surface (the ``pickle-outside-codec`` lint rule enforces this) and is
reachable only behind ``insecure=True``.
"""
from __future__ import annotations

import hashlib
import hmac
import io
import os
import pickle
import struct
import threading
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.serve import wire

MAGIC = b"RSC1"                     # codec frame marker (pickle starts 0x80)
FLAG_SIGNED = 0x01
FLAG_NONCE = 0x02                   # session-nonce handshake frame
_MAC = hashlib.sha256
_MAC_BYTES = 32
NONCE_BYTES = 16

# containers deeper than this are hostile, not ours: the frame schema
# nests ~4 levels (message dict -> report dict -> array dict -> array)
MAX_NESTING_DEPTH = 64

_U8 = struct.Struct(">B")
_U32 = struct.Struct(">I")
_U64 = struct.Struct(">Q")
_I64 = struct.Struct(">q")
_F64 = struct.Struct(">d")

# the only dtypes a frame may carry — everything the ShardPayload /
# PPAReport schema produces, nothing with object or void innards
ALLOWED_DTYPES = frozenset({
    "bool", "int8", "int16", "int32", "int64",
    "uint8", "uint16", "uint32", "uint64",
    "float16", "float32", "float64",
})


class CodecError(wire.WireError):
    """Off-schema traffic: unknown tag/type, bad dtype, truncated body."""


class AuthError(wire.WireError):
    """Frame authentication failed; ``reason`` is one of ``unsigned`` /
    ``unknown_key`` / ``tamper`` / ``replay``."""

    def __init__(self, reason: str, detail: str = ""):
        super().__init__(f"auth rejected ({reason})"
                         + (f": {detail}" if detail else ""))
        self.reason = reason


# ---------------------------------------------------------------------------
# restricted value encoding
# ---------------------------------------------------------------------------

# dtype-name caches keyed by the interned dtype object: v.dtype.name is
# a surprisingly expensive property, and this sits on the dispatch hot
# path for every array in every frame
_DTYPE_WIRE: Dict[object, bytes] = {}
_DTYPE_BY_NAME: Dict[str, np.dtype] = {n: np.dtype(n)
                                       for n in ALLOWED_DTYPES}


def _enc_value(v, out: List[bytes]) -> None:
    t = type(v)
    if t is str:
        b = v.encode("utf-8")
        out.append(b"S" + _U32.pack(len(b)) + b)
    elif v is None:
        out.append(b"N")
    elif v is True:
        out.append(b"T")
    elif v is False:
        out.append(b"F")
    elif t is int:
        if -(1 << 63) <= v < (1 << 63):
            out.append(b"I" + _I64.pack(v))
        else:
            s = str(v).encode("ascii")
            out.append(b"J" + _U32.pack(len(s)) + s)
    elif t is float:
        out.append(b"D" + _F64.pack(v))
    elif t is tuple:
        out.append(b"U" + _U32.pack(len(v)))
        for item in v:
            _enc_value(item, out)
    elif t is list:
        out.append(b"L" + _U32.pack(len(v)))
        for item in v:
            _enc_value(item, out)
    elif t is dict:
        out.append(b"M" + _U32.pack(len(v)))
        for k, item in v.items():
            if type(k) is not str:
                raise CodecError(f"dict keys must be str, got "
                                 f"{type(k).__name__}")
            kb = k.encode("utf-8")
            out.append(_U32.pack(len(kb)) + kb)
            _enc_value(item, out)
    elif isinstance(v, np.ndarray):
        dt = v.dtype
        header = _DTYPE_WIRE.get(dt)
        if header is None:
            name = dt.name
            if name not in ALLOWED_DTYPES:
                raise CodecError(f"dtype {name!r} is not wire-encodable")
            nb = name.encode("ascii")
            header = _U8.pack(len(nb)) + nb
            _DTYPE_WIRE[dt] = header
        arr = np.ascontiguousarray(v)
        out.append(b"A" + header + _U8.pack(arr.ndim))
        for d in arr.shape:
            out.append(_U64.pack(d))
        out.append(_U64.pack(arr.nbytes))
        out.append(arr.tobytes())
    elif isinstance(v, np.bool_):
        out.append(b"T" if bool(v) else b"F")
    elif isinstance(v, (int, np.integer)):
        v = int(v)
        if -(1 << 63) <= v < (1 << 63):
            out.append(b"I" + _I64.pack(v))
        else:
            s = str(v).encode("ascii")
            out.append(b"J" + _U32.pack(len(s)) + s)
    elif isinstance(v, (float, np.floating)):
        out.append(b"D" + _F64.pack(float(v)))
    elif isinstance(v, (bytes, bytearray, memoryview)):
        b = bytes(v)
        out.append(b"B" + _U32.pack(len(b)) + b)
    else:
        raise CodecError(f"type {type(v).__name__} is not wire-encodable")


# tag bytes as ints (data[i] indexes to int in py3) for the decode switch
_T_N, _T_T, _T_F = ord("N"), ord("T"), ord("F")
_T_I, _T_J, _T_D = ord("I"), ord("J"), ord("D")
_T_S, _T_B = ord("S"), ord("B")
_T_U, _T_L, _T_M, _T_A = ord("U"), ord("L"), ord("M"), ord("A")


def _truncated(pos: int, data: bytes) -> CodecError:
    return CodecError(f"truncated frame body at offset {pos} "
                      f"(have {len(data)})")


def _dec_value(data: bytes, pos: int, depth: int = 0):
    """Decode one value at ``pos``; returns ``(value, next_pos)``.

    Flat ``(data, pos)`` recursion instead of a cursor object: this runs
    once per field of every frame, so method-call and slice overhead here
    is codec overhead on every dispatch.  Nesting is bounded at
    :data:`MAX_NESTING_DEPTH` so a hostile frame of stacked container
    headers raises :class:`CodecError`, never ``RecursionError`` (which
    would escape the typed except clauses of reader threads).
    """
    try:
        tag = data[pos]
    except IndexError:
        raise _truncated(pos, data) from None
    pos += 1
    try:
        if tag == _T_S:
            (n,) = _U32.unpack_from(data, pos)
            pos += 4
            end = pos + n
            if end > len(data):
                raise _truncated(pos, data)
            return data[pos:end].decode("utf-8"), end
        if tag == _T_I:
            return _I64.unpack_from(data, pos)[0], pos + 8
        if tag == _T_D:
            return _F64.unpack_from(data, pos)[0], pos + 8
        if tag == _T_N:
            return None, pos
        if tag == _T_T:
            return True, pos
        if tag == _T_F:
            return False, pos
        if tag == _T_U or tag == _T_L:
            if depth >= MAX_NESTING_DEPTH:
                raise CodecError(f"nesting deeper than {MAX_NESTING_DEPTH} "
                                 "levels")
            (n,) = _U32.unpack_from(data, pos)
            pos += 4
            items = []
            append = items.append
            for _ in range(n):
                v, pos = _dec_value(data, pos, depth + 1)
                append(v)
            return (tuple(items), pos) if tag == _T_U else (items, pos)
        if tag == _T_M:
            if depth >= MAX_NESTING_DEPTH:
                raise CodecError(f"nesting deeper than {MAX_NESTING_DEPTH} "
                                 "levels")
            (n,) = _U32.unpack_from(data, pos)
            pos += 4
            out: Dict[str, object] = {}
            for _ in range(n):
                (kn,) = _U32.unpack_from(data, pos)
                pos += 4
                kend = pos + kn
                if kend > len(data):
                    raise _truncated(pos, data)
                key = data[pos:kend].decode("utf-8")
                out[key], pos = _dec_value(data, kend, depth + 1)
            return out, pos
        if tag == _T_A:
            (dn,) = _U8.unpack_from(data, pos)
            pos += 1
            name = data[pos:pos + dn].decode("ascii")
            pos += dn
            dtype = _DTYPE_BY_NAME.get(name)
            if dtype is None:
                raise CodecError(f"dtype {name!r} is not wire-decodable")
            (ndim,) = _U8.unpack_from(data, pos)
            pos += 1
            shape = []
            count = 1
            for _ in range(ndim):
                (d,) = _U64.unpack_from(data, pos)
                pos += 8
                shape.append(d)
                count *= d
            (nbytes,) = _U64.unpack_from(data, pos)
            pos += 8
            if nbytes != count * dtype.itemsize:
                raise CodecError(f"array byte count {nbytes} does not "
                                 f"match shape {tuple(shape)} dtype {name}")
            end = pos + nbytes
            if end > len(data):
                raise _truncated(pos, data)
            # frombuffer straight off the frame: ONE copy total (the
            # .copy() that detaches from the read-only frame bytes)
            arr = np.frombuffer(data, dtype=dtype, count=count,
                                offset=pos).reshape(shape).copy()
            return arr, end
        if tag == _T_J:
            (n,) = _U32.unpack_from(data, pos)
            pos += 4
            end = pos + n
            if end > len(data):
                raise _truncated(pos, data)
            return int(data[pos:end].decode("ascii")), end
        if tag == _T_B:
            (n,) = _U32.unpack_from(data, pos)
            pos += 4
            end = pos + n
            if end > len(data):
                raise _truncated(pos, data)
            return data[pos:end], end
    except struct.error:
        raise _truncated(pos, data) from None
    raise CodecError(f"unknown value tag {bytes([tag])!r}")


def encode_value(v) -> bytes:
    out: List[bytes] = []
    _enc_value(v, out)
    return b"".join(out)


def decode_value(data: bytes):
    v, pos = _dec_value(data, 0)
    if pos != len(data):
        raise CodecError(f"{len(data) - pos} trailing bytes after value")
    return v


# ---------------------------------------------------------------------------
# message schema
# ---------------------------------------------------------------------------

def _payload_to_wire(p) -> Dict[str, object]:
    """ShardPayload -> schema dict (duck-typed: the codec must not import
    repro.distributed at module load, the worker daemon imports lazily)."""
    return {"_t": "ShardPayload",
            "idx": np.asarray(p.idx),
            "detail": str(p.detail),
            "workloads": (None if p.workloads is None
                          else tuple(str(w) for w in p.workloads))}


def _payload_from_wire(d: Dict[str, object]):
    from repro.distributed.sharded import ShardPayload
    idx = _field(d, "idx", np.ndarray)
    wl = d.get("workloads")
    if wl is not None and not isinstance(wl, tuple):
        raise CodecError("ShardPayload.workloads must be a tuple or None")
    return ShardPayload(idx=idx, detail=_field(d, "detail", str),
                        workloads=wl)


def _report_to_wire(r) -> Dict[str, object]:
    def arrs(dct):
        return None if dct is None else {k: np.asarray(v)
                                         for k, v in dct.items()}
    return {"_t": "PPAReport",
            "workloads": tuple(r.workloads),
            "detail": str(r.detail),
            "area": np.asarray(r.area),
            "latency": arrs(r.latency),
            "stall": arrs(r.stall),
            "op_time": arrs(r.op_time),
            "op_class": arrs(r.op_class),
            "op_names": (None if r.op_names is None
                         else {k: tuple(v) for k, v in r.op_names.items()})}


def _report_from_wire(d: Dict[str, object]):
    from repro.perfmodel.evaluator import PPAReport

    def arrs(key):
        v = d.get(key)
        if v is None:
            return None
        if not isinstance(v, dict) or not all(
                isinstance(a, np.ndarray) for a in v.values()):
            raise CodecError(f"PPAReport.{key} must be a dict of arrays")
        return v

    return PPAReport(workloads=_field(d, "workloads", tuple),
                     detail=_field(d, "detail", str),
                     area=_field(d, "area", np.ndarray),
                     latency=arrs("latency") or {},
                     stall=arrs("stall"), op_time=arrs("op_time"),
                     op_class=arrs("op_class"), op_names=d.get("op_names"))


def _field(d: Dict[str, object], name: str, typ):
    v = d.get(name)
    if not isinstance(v, typ):
        raise CodecError(f"field {name!r} must be {typ.__name__}, got "
                         f"{type(v).__name__}")
    return v


def _spans_to_wire(spans) -> list:
    return [dict(s) for s in (spans or ())]


def _opt_body(v):
    """Payload slot of Dispatch/ResultMsg: structured types get their
    schema dict, plain values pass through the restricted encoder."""
    if v is None or isinstance(v, (str, bytes, int, float, bool)):
        return v
    if hasattr(v, "idx") and hasattr(v, "detail"):
        return _payload_to_wire(v)
    if hasattr(v, "area") and hasattr(v, "latency"):
        return _report_to_wire(v)
    raise CodecError(f"unsupported payload type {type(v).__name__}")


def _opt_unbody(v):
    if isinstance(v, dict) and v.get("_t") == "ShardPayload":
        return _payload_from_wire(v)
    if isinstance(v, dict) and v.get("_t") == "PPAReport":
        return _report_from_wire(v)
    return v


def encode_msg(msg) -> bytes:
    """One wire message -> restricted binary body."""
    t = type(msg).__name__
    if t == "Hello":
        d = {"_t": t, "spec": msg.spec, "wire_version": msg.wire_version}
    elif t == "Ready":
        d = {"_t": t, "digest": msg.digest, "workloads": tuple(msg.workloads)}
    elif t == "Dispatch":
        d = {"_t": t, "seq": msg.seq, "payload": _opt_body(msg.payload),
             "trace_ctx": (None if msg.trace_ctx is None
                           else tuple(msg.trace_ctx))}
    elif t == "ResultMsg":
        d = {"_t": t, "seq": msg.seq, "report": _opt_body(msg.report),
             "spans": _spans_to_wire(getattr(msg, "spans", ()))}
    elif t == "ErrorMsg":
        d = {"_t": t, "seq": msg.seq, "message": msg.message,
             "code": getattr(msg, "code", ""),
             "spans": _spans_to_wire(getattr(msg, "spans", ()))}
    elif t in ("Ping", "Pong"):
        d = {"_t": t, "seq": msg.seq}
    elif t == "Bye":
        d = {"_t": t, "reason": msg.reason}
    elif t == "Announce":
        d = {"_t": t, "address": tuple(msg.address),
             "digests": tuple(msg.digests), "capacity": msg.capacity}
    elif t == "LeaseAck":
        d = {"_t": t, "ttl_s": float(msg.ttl_s)}
    else:
        raise CodecError(f"{t} is not a wire message")
    return encode_value(d)


def decode_msg(body: bytes):
    """Restricted binary body -> wire message (allowlisted constructors
    only; anything off-schema raises :class:`CodecError`)."""
    d = decode_value(body)
    if not isinstance(d, dict) or "_t" not in d:
        raise CodecError("frame body is not a message")
    t = d["_t"]
    if t == "Hello":
        return wire.Hello(spec=_field(d, "spec", bytes),
                          wire_version=_field(d, "wire_version", int))
    if t == "Ready":
        return wire.Ready(digest=_field(d, "digest", str),
                          workloads=_field(d, "workloads", tuple))
    if t == "Dispatch":
        ctx = d.get("trace_ctx")
        return wire.Dispatch(seq=_field(d, "seq", int),
                             payload=_opt_unbody(d.get("payload")),
                             trace_ctx=None if ctx is None else tuple(ctx))
    if t == "ResultMsg":
        return wire.ResultMsg(seq=_field(d, "seq", int),
                              report=_opt_unbody(d.get("report")),
                              spans=tuple(d.get("spans") or ()))
    if t == "ErrorMsg":
        return wire.ErrorMsg(seq=_field(d, "seq", int),
                             message=_field(d, "message", str),
                             spans=tuple(d.get("spans") or ()),
                             code=str(d.get("code") or ""))
    if t == "Ping":
        return wire.Ping(seq=_field(d, "seq", int))
    if t == "Pong":
        return wire.Pong(seq=_field(d, "seq", int))
    if t == "Bye":
        return wire.Bye(reason=_field(d, "reason", str))
    if t == "Announce":
        return wire.Announce(address=tuple(_field(d, "address", tuple)),
                             digests=tuple(d.get("digests") or ()),
                             capacity=_field(d, "capacity", int))
    if t == "LeaseAck":
        return wire.LeaseAck(ttl_s=_field(d, "ttl_s", float))
    raise CodecError(f"unknown message type {t!r}")


MESSAGE_TYPES = ("Hello", "Ready", "Dispatch", "ResultMsg", "ErrorMsg",
                 "Ping", "Pong", "Bye", "Announce", "LeaseAck")


# ---------------------------------------------------------------------------
# frame authentication
# ---------------------------------------------------------------------------

class Keyring:
    """Shared-secret HMAC keys, id-addressable for rotation.

    ``keys`` maps key id -> secret (str secrets are encoded utf-8);
    ``active`` names the signing key (default: the first).  Verification
    accepts ANY key in the ring, so rotating means: add the new key to
    every ring, flip ``active`` on senders, drop the old key later.
    """

    def __init__(self, keys: Mapping[str, object],
                 active: Optional[str] = None):
        if not keys:
            raise ValueError("Keyring needs at least one key")
        self._keys: Dict[str, bytes] = {}
        for kid, secret in keys.items():
            if not isinstance(kid, str) or not kid or len(kid) > 255:
                raise ValueError(f"bad key id {kid!r}")
            self._keys[kid] = (secret.encode("utf-8")
                               if isinstance(secret, str) else bytes(secret))
        self.active = active if active is not None else next(iter(self._keys))
        if self.active not in self._keys:
            raise ValueError(f"active key {self.active!r} not in ring")

    def has(self, key_id: str) -> bool:
        return key_id in self._keys

    def sign(self, key_id: str, data: bytes) -> bytes:
        return hmac.new(self._keys[key_id], data, _MAC).digest()

    def verify(self, key_id: str, data: bytes, mac: bytes) -> bool:
        key = self._keys.get(key_id)
        if key is None:
            return False
        return hmac.compare_digest(
            hmac.new(key, data, _MAC).digest(), mac)


def make_nonce_frame() -> Tuple[bytes, bytes]:
    """A fresh session-nonce handshake frame; returns ``(nonce, frame)``.
    The nonce travels in the clear — it adds no secrecy, only freshness:
    once both sides fold the pair of nonces into every frame MAC, a
    recorded session cannot replay over a NEW connection (the responder's
    fresh nonce changes every MAC).  A man in the middle can corrupt the
    exchange, but that only yields a connection where nothing verifies."""
    nonce = os.urandom(NONCE_BYTES)
    return nonce, MAGIC + bytes([FLAG_NONCE]) + nonce


def is_nonce_frame(data: bytes) -> bool:
    return data[:4] == MAGIC and len(data) > 4 and bool(data[4] & FLAG_NONCE)


def nonce_of(frame: bytes) -> bytes:
    """The nonce carried by a handshake frame (typed error off-shape)."""
    if not is_nonce_frame(frame) or len(frame) != 5 + NONCE_BYTES:
        raise CodecError("malformed session nonce frame")
    return frame[5:]


def seal_frame(body: bytes, keyring: Optional[Keyring], seq: int,
               key_id: Optional[str] = None, *,
               binding: bytes = b"") -> bytes:
    """Wrap a message body in the codec frame header; signed when a
    keyring is given (the MAC covers magic, flags, key id, the
    per-direction sequence number and the session ``binding`` — the
    concatenated connection nonces — so none of them can be spliced and
    a frame from one connection never verifies on another)."""
    if keyring is None:
        return MAGIC + bytes([0]) + body
    kid = (key_id if key_id is not None else keyring.active).encode("utf-8")
    head = MAGIC + bytes([FLAG_SIGNED]) + _U8.pack(len(kid)) + kid \
        + _U64.pack(seq)
    return head + keyring.sign(kid.decode("utf-8"),
                               binding + head + body) + body


def open_frame(data: bytes, keyring: Optional[Keyring],
               expected_seq: int, *, binding: bytes = b"") -> bytes:
    """Validate + unwrap one codec frame; every failure is typed and
    happens BEFORE the body is decoded."""
    if data[:4] != MAGIC:
        raise CodecError("not a codec frame")
    if len(data) < 5:
        raise CodecError("truncated frame header")
    flags = data[4]
    if flags & FLAG_NONCE:
        raise CodecError("unexpected session nonce frame mid-stream")
    if not flags & FLAG_SIGNED:
        if keyring is not None:
            raise AuthError("unsigned", "this endpoint requires signed "
                            "frames")
        return data[5:]
    pos = 5
    if len(data) < pos + 1:
        raise CodecError("truncated frame header")
    kid_len = data[pos]
    pos += 1
    if len(data) < pos + kid_len + 8 + _MAC_BYTES:
        raise CodecError("truncated frame header")
    kid = data[pos:pos + kid_len].decode("utf-8", errors="replace")
    pos += kid_len
    (seq,) = _U64.unpack(data[pos:pos + 8])
    pos += 8
    mac = data[pos:pos + _MAC_BYTES]
    pos += _MAC_BYTES
    body = data[pos:]
    if keyring is None:
        raise AuthError("unknown_key", "signed frame but this endpoint has "
                        "no keyring")
    if not keyring.has(kid):
        raise AuthError("unknown_key", f"key id {kid!r}")
    head = data[:5 + 1 + kid_len + 8]
    if not keyring.verify(kid, binding + head + body, mac):
        raise AuthError("tamper", f"bad MAC under key {kid!r}")
    if seq != expected_seq:
        raise AuthError("replay", f"frame seq {seq}, expected "
                        f"{expected_seq}")
    return body


# ---------------------------------------------------------------------------
# the channel: framing + codec + auth + replay state for one socket
# ---------------------------------------------------------------------------

CODEC_BINARY = "binary"
CODEC_PICKLE = "pickle"


class Channel:
    """One side of a serve connection.

    ``codec='binary'`` speaks the restricted codec (optionally signed);
    ``codec='pickle'`` is the legacy single-trust-domain transport.
    ``send`` serializes + seals under an internal lock (the signing
    sequence number and the socket write must stay in lockstep — and the
    pickle path serializes the raw ``sendall`` too, so reader / eval /
    timer threads cannot interleave a frame stream);
    ``recv``/``feed`` verify and decode, maintaining the receive-side
    replay counter.  ``max_frame_bytes`` bounds BOTH directions: an
    outbound frame above it raises :class:`FrameTooLarge` before any
    byte hits the wire.

    **Session binding**: a keyed channel must run the nonce handshake
    before any signed traffic — the connecting side calls
    :meth:`client_handshake`, the accepting side feeds the peer's nonce
    frame to :meth:`server_handshake`.  Both nonces are folded into
    every frame MAC, so a recorded signed session replayed verbatim
    over a NEW connection fails verification (the fresh responder nonce
    changes every expected MAC).  Signed frames before the handshake
    are ``AuthError("replay")`` — the replay window they would reopen
    is exactly what the handshake closes.
    """

    def __init__(self, sock, *, codec: str = CODEC_BINARY,
                 keyring: Optional[Keyring] = None,
                 key_id: Optional[str] = None,
                 max_frame_bytes: int = wire.MAX_MESSAGE_BYTES):
        if codec not in (CODEC_BINARY, CODEC_PICKLE):
            raise ValueError(f"codec must be binary|pickle, got {codec!r}")
        if codec == CODEC_PICKLE and keyring is not None:
            raise ValueError("the legacy pickle codec cannot be signed; "
                             "use the binary codec for authenticated "
                             "traffic")
        self.sock = sock
        self.codec = codec
        self.keyring = keyring
        self.key_id = key_id
        self.max_frame_bytes = int(max_frame_bytes)
        self.binding = b""              # session nonces, folded into MACs
        self._handshaken = codec != CODEC_BINARY or keyring is None
        self._send_seq = 0
        self._recv_seq = 0
        self._send_lock = threading.Lock()

    def client_handshake(self) -> None:
        """Run the connecting side of the session-nonce exchange (no-op
        on unsigned or pickle channels): send our nonce, receive the
        peer's, bind both into every subsequent frame MAC."""
        if self.codec != CODEC_BINARY or self.keyring is None \
                or self._handshaken:
            return
        local, frame = make_nonce_frame()
        wire.send_frame(self.sock, frame)
        peer = nonce_of(wire.recv_frame(self.sock, self.max_frame_bytes))
        self.binding = local + peer     # initiator nonce first
        self._handshaken = True

    def server_handshake(self, peer_frame: bytes) -> None:
        """Run the accepting side: ``peer_frame`` is the connection's
        first frame (already sniffed as a nonce frame); answer with our
        own nonce and bind the pair."""
        peer = nonce_of(peer_frame)
        local, frame = make_nonce_frame()
        wire.send_frame(self.sock, frame)
        self.binding = peer + local     # initiator nonce first
        self._handshaken = True

    def send(self, msg) -> None:
        if self.codec == CODEC_PICKLE:
            frame = pickle.dumps(msg, protocol=pickle.HIGHEST_PROTOCOL)
            if len(frame) > self.max_frame_bytes:
                raise FrameTooLarge(len(frame), self.max_frame_bytes)
            with self._send_lock:
                wire.send_frame(self.sock, frame)
            return
        if not self._handshaken:
            raise AuthError("replay", "session nonce handshake required "
                            "before signed traffic")
        body = encode_msg(msg)
        with self._send_lock:
            frame = seal_frame(body, self.keyring, self._send_seq,
                               self.key_id, binding=self.binding)
            if len(frame) > self.max_frame_bytes:
                raise FrameTooLarge(len(frame), self.max_frame_bytes)
            self._send_seq += 1
            wire.send_frame(self.sock, frame)

    def recv(self):
        return self.feed(wire.recv_frame(self.sock, self.max_frame_bytes))

    def feed(self, raw: bytes):
        """Decode one already-received frame (the accept-side sniff path
        hands the first frame here after choosing the codec)."""
        if self.codec == CODEC_PICKLE:
            return legacy_loads(raw)
        if not self._handshaken and len(raw) > 4 \
                and raw[4] & FLAG_SIGNED:
            # a signed frame with no session handshake is indistinguishable
            # from a cross-connection replay of a recorded session — refuse
            raise AuthError("replay", "signed frame before the session "
                            "nonce handshake")
        body = open_frame(raw, self.keyring, self._recv_seq,
                          binding=self.binding)
        self._recv_seq += 1
        return decode_msg(body)


class FrameTooLarge(wire.WireError):
    """An OUTBOUND frame exceeds the configured bound — refused before
    sending (the receiver would drop the connection anyway)."""

    def __init__(self, size: int, bound: int):
        super().__init__(f"outbound frame of {size} bytes exceeds the "
                         f"{bound}-byte frame bound")
        self.size = size
        self.bound = bound


def sniff_codec(first_frame: bytes) -> str:
    """Which codec an incoming connection speaks, from its first frame:
    the codec magic, or pickle's protocol-2+ opcode (0x80)."""
    if first_frame[:4] == MAGIC:
        return CODEC_BINARY
    if first_frame[:1] == b"\x80":
        return CODEC_PICKLE
    raise CodecError(f"unrecognized first frame "
                     f"(starts {first_frame[:4]!r})")


# ---------------------------------------------------------------------------
# evaluator spec deserialization: the two sanctioned paths
# ---------------------------------------------------------------------------

# module prefixes the restricted spec loader may resolve constructors
# from: the repo's own model/space/workload classes plus numpy's array
# reconstruction machinery.  NOTHING else resolves — os/subprocess/
# builtins.eval style gadgets raise before construction.
_SPEC_MODULE_PREFIXES = ("repro.",)
_SPEC_MODULES = {"numpy", "numpy.core.multiarray", "numpy._core.multiarray",
                 "numpy.core.numeric", "numpy._core.numeric", "numpy.dtypes",
                 "collections"}
# NO builtins.getattr / builtins.object here: getattr turns ANY reachable
# module attribute (e.g. an `os` re-exported by some repro module) into
# an arbitrary-call gadget, which is exactly the traversal this loader
# exists to close.  Only value constructors resolve.
_SPEC_BUILTINS = {"dict", "list", "tuple", "set", "frozenset", "str",
                  "bytes", "bytearray", "int", "float", "bool", "complex"}
# the only non-class module attributes the spec format legitimately
# references: numpy's array/scalar reconstruction functions.  Everything
# else resolved from an allowlisted module must be a CLASS — modules
# (re-exported `os`/`pickle`), functions and bound callables raise.
_SPEC_FUNCTIONS = {
    ("numpy.core.multiarray", "_reconstruct"),
    ("numpy._core.multiarray", "_reconstruct"),
    ("numpy.core.multiarray", "scalar"),
    ("numpy._core.multiarray", "scalar"),
    ("numpy.core.numeric", "_frombuffer"),
    ("numpy._core.numeric", "_frombuffer"),
}


class _RestrictedUnpickler(pickle.Unpickler):
    def find_class(self, module: str, name: str):
        if module == "builtins":
            if name in _SPEC_BUILTINS:
                return super().find_class(module, name)
            raise CodecError(f"spec constructor builtins.{name} is not "
                             "allowlisted")
        if module in _SPEC_MODULES or module.startswith(
                _SPEC_MODULE_PREFIXES):
            obj = super().find_class(module, name)
            if isinstance(obj, type) or (module, name) in _SPEC_FUNCTIONS:
                return obj
            raise CodecError(f"spec constructor {module}.{name} resolves "
                             f"to a {type(obj).__name__}, not a class — "
                             "not allowlisted")
        raise CodecError(f"spec constructor {module}.{name} is not "
                         "allowlisted")


def restricted_loads(data: bytes):
    """Deserialize an evaluator spec through the allowlisted constructor
    table — the secure-mode replacement for ``pickle.loads`` on spec
    bytes (defense in depth under frame auth: even a signed spec cannot
    name constructors outside the evaluator schema)."""
    return _RestrictedUnpickler(io.BytesIO(data)).load()


def legacy_loads(data: bytes):
    """The legacy pickle shim — the ONLY raw ``pickle.loads`` permitted
    under ``serve/`` (enforced by the ``pickle-outside-codec`` lint
    rule).  Reachable only when both endpoints opted into
    ``insecure=True``: single trust domain, same machine-room rules as
    the PR 4 process pool."""
    return pickle.loads(data)


def spec_digest(spec: bytes) -> str:
    """The sha256 hex digest workers cache/allowlist specs by."""
    return hashlib.sha256(spec).hexdigest()
