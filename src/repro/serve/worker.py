"""The ``repro.serve`` worker daemon: a remote evaluator over TCP.

Run one per host/core budget::

    PYTHONPATH=src python -m repro.serve.worker --port 9707 \\
        --key prod=0123abcd... --max-rows-per-dispatch 200000 \\
        --registrar gateway-host:9700

A worker starts *evaluator-agnostic*.  Each client connection opens with
a :class:`~repro.serve.wire.Hello` carrying the pickled evaluator spec
(the PR 4 process-pool template, see :func:`~repro.distributed.sharded.
_worker_spec`); the worker rebuilds the evaluator — cached process-wide
by spec digest, so reconnects and sibling connections serving the same
study skip the rebuild — answers :class:`~repro.serve.wire.Ready`, then
serves ``Dispatch(ShardPayload) -> ResultMsg(PPAReport)`` until the
client hangs up.

**Trust boundary** (PR 10): the first frame of a connection picks the
codec — the schema-restricted binary codec (default, optionally
HMAC-signed under ``--key`` with replay-protected sequence numbers) or
legacy pickle, which is refused unless the worker runs ``--insecure``.
Secure-mode specs deserialize through the allowlisted constructor table
(:func:`repro.serve.codec.restricted_loads`), optionally further pinned
to an out-of-band ``spec_digests`` allowlist.  Auth rejects are counted
(``worker_auth_rejected{reason}``), answered with a typed
``ErrorMsg(code="auth.*")`` best-effort, and never evaluated.

**Quotas**: ``max_rows_per_dispatch`` (shard size), a worker-wide
``max_concurrent_evals`` admission semaphore, a per-dispatch wall-clock
``deadline_s``, and a per-peer-host token-bucket ``rate_limit`` — all
enforced BEFORE the evaluation thread sees the payload, rejected with
``ErrorMsg(code="quota.*")`` that the client treats as
non-retryable-at-this-worker (reroute, don't hammer), and counted as
``worker_quota_rejected{kind}``.

Evaluations run on a per-connection executor thread while the reader
thread keeps answering :class:`~repro.serve.wire.Ping` heartbeats — a
worker grinding through a big shard still proves liveness, which is what
lets the client side distinguish *slow* from *dead*.

With ``--registrar host:port`` the worker dials the gateway's
:class:`~repro.serve.membership.Registrar` and keeps a TTL lease alive
(announce → renew loop, Bye on shutdown) instead of waiting to be found
in a static address list.

:func:`start_worker_process` spawns a daemon in a child process (spawn
context, so no jax state is forked) and returns a handle with the bound
port — the test/bench/example harness for 2-worker loopback clusters,
and the thing to SIGKILL when proving fault tolerance.
"""
from __future__ import annotations

import argparse
import socket
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.obs.metrics import Clock, MetricsRegistry
from repro.obs.trace import Tracer
from repro.serve import codec as _codec
from repro.serve import wire

# evaluators by spec sha256 — shared across connections so a fleet
# serving one study builds once per process, not once per reconnect
_EVALUATORS: Dict[str, object] = {}
_EVALUATORS_LOCK = threading.Lock()


def _evaluator_for(spec: bytes, loads=None) -> Tuple[str, object]:
    digest = _codec.spec_digest(spec)
    with _EVALUATORS_LOCK:
        ev = _EVALUATORS.get(digest)
        if ev is None:
            from repro.distributed.sharded import evaluator_from_spec
            ev = evaluator_from_spec(spec, loads=loads)
            _EVALUATORS[digest] = ev
    return digest, ev


class _TokenBucket:
    """Per-peer dispatch rate limiter: ``rate`` tokens/s, ``burst`` cap."""

    def __init__(self, rate: float, burst: float, now: float):
        self.rate = float(rate)
        self.burst = float(burst)
        self.tokens = self.burst
        self.stamp = now

    def try_take(self, now: float) -> bool:
        self.tokens = min(self.burst,
                          self.tokens + (now - self.stamp) * self.rate)
        self.stamp = now
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False


@dataclass
class WorkerOptions:
    """Everything a hardened worker enforces, bundled so the spawn
    harness and the CLI share one surface.  All fields picklable (the
    keyring travels as its raw ``keys`` mapping)."""
    keys: Optional[Dict[str, bytes]] = None       # HMAC keyring (id->secret)
    active_key: Optional[str] = None
    insecure: bool = False                        # accept legacy pickle codec
    max_frame_bytes: int = wire.MAX_MESSAGE_BYTES
    spec_digests: Tuple[str, ...] = ()            # out-of-band spec allowlist
    max_rows_per_dispatch: int = 0                # 0 = unlimited
    max_concurrent_evals: int = 0                 # 0 = unlimited
    deadline_s: float = 0.0                       # 0 = no deadline
    rate_limit: float = 0.0                       # dispatches/s/peer; 0 = off
    rate_burst: float = 0.0                       # 0 = 2x rate
    registrar: Optional[Tuple[str, int]] = None   # membership endpoint
    announce_interval_s: float = 0.0              # 0 = ttl/3 from LeaseAck
    capacity: int = 1                             # advisory, for Announce
    certfile: Optional[str] = None                # TLS server cert (PEM)
    keyfile: Optional[str] = None                 # TLS private key (PEM)

    def keyring(self) -> Optional[_codec.Keyring]:
        if not self.keys:
            return None
        return _codec.Keyring(self.keys, active=self.active_key)


class WorkerServer:
    """Accepts connections on ``host:port`` (``port=0`` = ephemeral) and
    serves the wire protocol; one reader thread + one eval thread per
    connection, quotas enforced on the reader."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0, *,
                 options: Optional[WorkerOptions] = None,
                 max_message_bytes: Optional[int] = None,
                 registry: Optional[MetricsRegistry] = None,
                 clock: Optional[Clock] = None):
        self.options = options if options is not None else WorkerOptions()
        self.max_frame_bytes = int(
            max_message_bytes if max_message_bytes is not None
            else self.options.max_frame_bytes)
        self.keyring = self.options.keyring()
        self.insecure = bool(self.options.insecure)
        self._ssl_context = None
        if self.options.certfile:
            import ssl
            self._ssl_context = ssl.SSLContext(ssl.PROTOCOL_TLS_SERVER)
            self._ssl_context.load_cert_chain(self.options.certfile,
                                              self.options.keyfile)
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(16)
        self.host, self.port = self._sock.getsockname()[:2]
        self._closed = threading.Event()
        self._clock: Clock = clock if clock is not None else time.monotonic
        self.metrics = registry if registry is not None else MetricsRegistry()
        self._c_connections = self.metrics.counter(
            "worker_connections_served", "client connections accepted")
        self._c_dispatches = self.metrics.counter(
            "worker_dispatches_served", "shard dispatches answered OK")
        self._c_auth_rejected = self.metrics.counter(
            "worker_auth_rejected", "frames/connections rejected by "
            "authentication", labelnames=("reason",))
        self._c_quota_rejected = self.metrics.counter(
            "worker_quota_rejected", "dispatches rejected by quota",
            labelnames=("kind",))
        self._h_eval = self.metrics.histogram(
            "worker_eval_s", "per-dispatch evaluation wall time (s)")
        # worker-wide eval admission (across connections)
        self._eval_slots = (
            threading.BoundedSemaphore(self.options.max_concurrent_evals)
            if self.options.max_concurrent_evals > 0 else None)
        self._buckets: Dict[str, _TokenBucket] = {}
        self._buckets_lock = threading.Lock()
        self._announcer: Optional[_Announcer] = None
        # Perfetto process lane for spans minted on this worker
        self._proc = f"worker:{self.host}:{self.port}"

    @property
    def connections_served(self) -> int:
        return int(self._c_connections.value())

    @property
    def dispatches_served(self) -> int:
        return int(self._c_dispatches.value())

    def auth_rejected(self, reason: Optional[str] = None) -> int:
        c = self._c_auth_rejected
        return int(c.value(reason=reason) if reason is not None
                   else c.total())

    def quota_rejected(self, kind: Optional[str] = None) -> int:
        c = self._c_quota_rejected
        return int(c.value(kind=kind) if kind is not None else c.total())

    # -- accept loop ----------------------------------------------------
    def serve_forever(self) -> None:
        if self.options.registrar is not None:
            self._announcer = _Announcer(self)
            self._announcer.start()
        try:
            while not self._closed.is_set():
                try:
                    conn, _addr = self._sock.accept()
                except OSError:
                    break                        # listener closed
                self._c_connections.inc()
                t = threading.Thread(target=self._serve_conn, args=(conn,),
                                     name="serve-conn", daemon=True)
                t.start()
        finally:
            self.close()

    def start(self) -> threading.Thread:
        """Run the accept loop on a background thread (in-process use)."""
        t = threading.Thread(target=self.serve_forever,
                             name="serve-accept", daemon=True)
        t.start()
        return t

    def close(self) -> None:
        if not self._closed.is_set():
            self._closed.set()
            if self._announcer is not None:
                self._announcer.stop()
            try:
                self._sock.close()
            except OSError:
                pass

    # -- quota checks (reader thread, before the eval lane) --------------
    def _check_quota(self, msg: wire.Dispatch,
                     peer: str) -> Optional[Tuple[str, str]]:
        """None when admitted, else ``(kind, detail)`` for the reject."""
        o = self.options
        if o.rate_limit > 0:
            now = self._clock()
            with self._buckets_lock:
                # evict buckets idle long enough to have fully refilled —
                # indistinguishable from fresh ones, so dropping them is
                # lossless and the dict stays bounded by ACTIVE peers
                # instead of growing one entry per client IP forever
                stale = [p for p, b in self._buckets.items()
                         if p != peer and (now - b.stamp) * b.rate >= b.burst]
                for p in stale:
                    del self._buckets[p]
                bucket = self._buckets.get(peer)
                if bucket is None:
                    burst = o.rate_burst if o.rate_burst > 0 \
                        else max(1.0, 2.0 * o.rate_limit)
                    bucket = _TokenBucket(o.rate_limit, burst, now)
                    self._buckets[peer] = bucket
                admitted = bucket.try_take(now)
            if not admitted:
                return ("rate", f"peer {peer} above "
                        f"{o.rate_limit:g} dispatches/s")
        if o.max_rows_per_dispatch > 0:
            idx = getattr(msg.payload, "idx", None)
            rows = int(idx.shape[0]) if hasattr(idx, "shape") else 0
            if rows > o.max_rows_per_dispatch:
                return ("rows", f"shard of {rows} rows exceeds "
                        f"max_rows_per_dispatch={o.max_rows_per_dispatch}")
        if self._eval_slots is not None:
            if not self._eval_slots.acquire(blocking=False):
                return ("concurrency", f"worker at max_concurrent_evals="
                        f"{o.max_concurrent_evals}")
        return None

    # -- per-connection protocol ----------------------------------------
    def _serve_conn(self, conn: socket.socket) -> None:
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        try:
            peer = conn.getpeername()[0]
        except OSError:
            peer = "?"
        if self._ssl_context is not None:
            try:
                conn = self._ssl_context.wrap_socket(conn, server_side=True)
            except (OSError, ValueError):
                conn.close()                     # failed TLS handshake
                return
        ch: Optional[_codec.Channel] = None
        # one eval lane per connection: dispatches execute in order while
        # the reader loop stays free to answer heartbeats
        ex = ThreadPoolExecutor(max_workers=1,
                                thread_name_prefix="serve-eval")

        def reply(msg: object) -> None:
            ch.send(msg)

        def run_dispatch(evaluator, msg: wire.Dispatch,
                         holds_slot: bool) -> None:
            ctx = getattr(msg, "trace_ctx", None)
            tracer = (Tracer(clock=self._clock, proc=self._proc)
                      if ctx is not None else None)

            def shipped_spans() -> Tuple:
                if tracer is None:
                    return ()
                return tuple(s.as_dict() for s in tracer.drain())

            # exactly one answer per dispatch: the deadline timer and the
            # eval thread race for it under this lock
            answered = threading.Lock()
            done = [False]

            def answer(msg_out: object) -> bool:
                with answered:
                    if done[0]:
                        return False
                    done[0] = True
                try:
                    reply(msg_out)
                except (OSError, wire.WireError):
                    pass                    # client already gone
                return True

            timer: Optional[threading.Timer] = None
            if self.options.deadline_s > 0:
                def expire() -> None:
                    if answer(wire.ErrorMsg(
                            msg.seq, f"dispatch exceeded the "
                            f"{self.options.deadline_s:g}s deadline",
                            (), "quota.deadline")):
                        self._c_quota_rejected.inc(kind="deadline")
                timer = threading.Timer(self.options.deadline_s, expire)
                timer.daemon = True
                timer.start()
            try:
                from repro.distributed.sharded import _eval_payload
                t0 = self._clock()
                if tracer is not None:
                    idx = getattr(msg.payload, "idx", None)
                    rows = int(idx.shape[0]) if hasattr(idx, "shape") else 0
                    with tracer.span("worker.eval", parent=tuple(ctx),
                                     seq=msg.seq, rows=rows):
                        rep = _eval_payload(evaluator, msg.payload)
                else:
                    rep = _eval_payload(evaluator, msg.payload)
                self._h_eval.observe(self._clock() - t0)
                if answer(wire.ResultMsg(msg.seq, rep, shipped_spans())):
                    self._c_dispatches.inc()
            except Exception as exc:        # noqa: BLE001 — wire boundary
                answer(wire.ErrorMsg(msg.seq, f"{type(exc).__name__}: "
                                              f"{exc}", shipped_spans()))
            finally:
                if timer is not None:
                    timer.cancel()
                if holds_slot:
                    self._eval_slots.release()

        try:
            first = wire.recv_frame(conn, self.max_frame_bytes)
            mode = _codec.sniff_codec(first)
            if mode == _codec.CODEC_PICKLE and not self.insecure:
                # a legacy client dialed a hardened worker: typed refusal
                # over ITS codec (sending pickle is safe; loading is not)
                self._c_auth_rejected.inc(reason="pickle_codec")
                try:
                    wire.send_msg(conn, wire.ErrorMsg(
                        -1, "this worker requires the binary codec "
                        "(legacy pickle needs --insecure)", (),
                        "auth.codec"))
                except OSError:
                    pass
                return
            ch = _codec.Channel(
                conn, codec=mode,
                keyring=self.keyring if mode == _codec.CODEC_BINARY
                else None,
                max_frame_bytes=self.max_frame_bytes)
            if mode == _codec.CODEC_BINARY and _codec.is_nonce_frame(first):
                ch.server_handshake(first)
                hello = wire.check_hello(ch.recv())
            else:
                hello = wire.check_hello(ch.feed(first))
            digest = _codec.spec_digest(hello.spec)
            if self.options.spec_digests and \
                    digest not in self.options.spec_digests:
                self._c_auth_rejected.inc(reason="spec_digest")
                reply(wire.ErrorMsg(-1, f"spec digest {digest[:12]}… is "
                                    "not in this worker's allowlist", (),
                                    "auth.spec_digest"))
                return
            loads = (_codec.legacy_loads if self.insecure
                     else _codec.restricted_loads)
            digest, evaluator = _evaluator_for(hello.spec, loads)
            if self._announcer is not None:
                self._announcer.add_digest(digest)
            reply(wire.Ready(digest, tuple(evaluator.workloads)))
            while True:
                msg = ch.recv()
                if isinstance(msg, wire.Dispatch):
                    verdict = self._check_quota(msg, peer)
                    if verdict is not None:
                        kind, detail = verdict
                        self._c_quota_rejected.inc(kind=kind)
                        reply(wire.ErrorMsg(msg.seq, detail, (),
                                            f"quota.{kind}"))
                        continue
                    ex.submit(run_dispatch, evaluator, msg,
                              self._eval_slots is not None)
                elif isinstance(msg, wire.Ping):
                    reply(wire.Pong(msg.seq))
                elif isinstance(msg, wire.Bye):
                    break
                else:
                    raise wire.WireError(
                        f"unexpected message {type(msg).__name__}")
        except _codec.AuthError as exc:
            # tampered / replayed / unsigned / unknown-key traffic: count,
            # answer with a typed refusal, drop the connection — the frame
            # is NEVER decoded, let alone evaluated
            self._c_auth_rejected.inc(reason=exc.reason)
            if ch is not None:
                try:
                    ch.send(wire.ErrorMsg(-1, str(exc), (),
                                          f"auth.{exc.reason}"))
                except (OSError, wire.WireError):
                    pass
        except wire.ConnectionClosed:
            pass                                # normal client departure
        except (wire.WireError, OSError) as exc:
            if ch is not None:
                try:
                    ch.send(wire.ErrorMsg(-1, str(exc)))
                except (OSError, wire.WireError):
                    pass
        finally:
            ex.shutdown(wait=False)
            try:
                conn.close()
            except OSError:
                pass


class _Announcer(threading.Thread):
    """Keeps this worker's membership lease alive: dial the registrar,
    Announce, renew every ``interval`` (default TTL/3 from the ack),
    redial with backoff on failure, Bye on shutdown."""

    def __init__(self, server: WorkerServer):
        super().__init__(name="worker-announcer", daemon=True)
        self.server = server
        self._stop = threading.Event()
        self._digests: Tuple[str, ...] = tuple(server.options.spec_digests)
        self._lock = threading.Lock()
        self._ch: Optional[_codec.Channel] = None

    def add_digest(self, digest: str) -> None:
        with self._lock:
            if digest not in self._digests:
                self._digests = self._digests + (digest,)

    def stop(self) -> None:
        self._stop.set()
        ch = self._ch
        if ch is not None:
            try:
                ch.send(wire.Bye("worker shutdown"))
            except (OSError, wire.WireError):
                pass
            try:
                ch.sock.close()
            except OSError:
                pass

    def _announce_once(self) -> float:
        o = self.server.options
        if self._ch is None:
            sock = wire.connect(o.registrar, timeout_s=5.0)
            self._ch = _codec.Channel(sock, keyring=self.server.keyring,
                                      max_frame_bytes=1 << 20)
            self._ch.client_handshake()
        with self._lock:
            digests = self._digests
        self._ch.send(wire.Announce((self.server.host, self.server.port),
                                    digests, o.capacity))
        ack = self._ch.recv()
        if not isinstance(ack, wire.LeaseAck):
            raise wire.WireError(f"expected LeaseAck, got "
                                 f"{type(ack).__name__}")
        return float(ack.ttl_s)

    def run(self) -> None:
        o = self.server.options
        interval = o.announce_interval_s
        while not self._stop.is_set():
            try:
                ttl = self._announce_once()
                if o.announce_interval_s <= 0:
                    interval = max(0.05, ttl / 3.0)
            except (OSError, wire.WireError, _codec.AuthError):
                ch, self._ch = self._ch, None
                if ch is not None:
                    try:
                        ch.sock.close()
                    except OSError:
                        pass
                interval = max(0.1, interval or 0.5)
            self._stop.wait(interval or 0.5)


# ---------------------------------------------------------------------------
# process harness
# ---------------------------------------------------------------------------

def _spawned_main(host: str, port: int, port_conn,
                  options: Optional[WorkerOptions] = None) -> None:
    srv = WorkerServer(host, port, options=options)
    port_conn.send(srv.port)
    port_conn.close()
    srv.serve_forever()


@dataclass
class WorkerHandle:
    """A spawned worker daemon: its process and bound address."""
    process: object                 # multiprocessing.Process
    host: str
    port: int
    address: Tuple[str, int] = field(init=False)

    def __post_init__(self):
        self.address = (self.host, self.port)

    def kill(self) -> None:
        """SIGKILL — the fault-tolerance test hammer: no cleanup, no
        goodbye, in-flight dispatches die with the process."""
        self.process.kill()
        self.process.join()

    def terminate(self) -> None:
        self.process.terminate()
        self.process.join()

    def alive(self) -> bool:
        return self.process.is_alive()


def start_worker_process(host: str = "127.0.0.1", port: int = 0, *,
                         options: Optional[WorkerOptions] = None,
                         timeout_s: float = 120.0) -> WorkerHandle:
    """Spawn a worker daemon in a child process; returns once it is
    listening (the bound port travels back over a pipe, so ``port=0``
    works).  ``options`` configures auth/quotas/membership in the child."""
    import multiprocessing as mp
    ctx = mp.get_context("spawn")
    parent, child = ctx.Pipe()
    proc = ctx.Process(target=_spawned_main,
                       args=(host, port, child, options), daemon=True)
    proc.start()
    child.close()
    if not parent.poll(timeout_s):
        proc.kill()
        raise TimeoutError(f"worker did not bind within {timeout_s}s")
    bound_port = parent.recv()
    parent.close()
    return WorkerHandle(process=proc, host=host, port=bound_port)


def _parse_key(text: str) -> Tuple[str, bytes]:
    """``id=hex-or-text`` CLI key syntax."""
    if "=" not in text:
        raise argparse.ArgumentTypeError(
            f"--key wants id=secret, got {text!r}")
    kid, secret = text.split("=", 1)
    try:
        return kid, bytes.fromhex(secret)
    except ValueError:
        return kid, secret.encode("utf-8")


def _parse_addr(text: str) -> Tuple[str, int]:
    host, _, port = text.rpartition(":")
    return (host or "127.0.0.1", int(port))


def main(argv: Optional[list] = None) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve.worker",
        description="repro.serve evaluation worker daemon")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 picks an ephemeral port (printed on startup)")
    ap.add_argument("--key", type=_parse_key, action="append", default=[],
                    metavar="ID=SECRET",
                    help="HMAC keyring entry (hex or raw text secret); "
                         "repeatable — first is the signing key")
    ap.add_argument("--insecure", action="store_true",
                    help="accept the legacy pickle codec "
                         "(single-trust-domain deployments only)")
    ap.add_argument("--max-frame-bytes", type=int,
                    default=wire.MAX_MESSAGE_BYTES)
    ap.add_argument("--spec-digest", action="append", default=[],
                    metavar="SHA256",
                    help="only serve specs with these digests (repeatable)")
    ap.add_argument("--max-rows-per-dispatch", type=int, default=0,
                    help="reject shards above this many rows (0 = off)")
    ap.add_argument("--max-concurrent-evals", type=int, default=0,
                    help="worker-wide concurrent evaluation cap (0 = off)")
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="per-dispatch wall-clock deadline (0 = off)")
    ap.add_argument("--rate-limit", type=float, default=0.0,
                    help="per-peer dispatches/second token bucket (0 = off)")
    ap.add_argument("--registrar", type=_parse_addr, default=None,
                    metavar="HOST:PORT",
                    help="announce to this membership registrar")
    ap.add_argument("--capacity", type=int, default=1,
                    help="advisory concurrent-eval capacity for Announce")
    ap.add_argument("--certfile", default=None, help="TLS server cert PEM")
    ap.add_argument("--keyfile", default=None, help="TLS private key PEM")
    args = ap.parse_args(argv)
    options = WorkerOptions(
        keys=dict(args.key) or None,
        active_key=args.key[0][0] if args.key else None,
        insecure=args.insecure,
        max_frame_bytes=args.max_frame_bytes,
        spec_digests=tuple(args.spec_digest),
        max_rows_per_dispatch=args.max_rows_per_dispatch,
        max_concurrent_evals=args.max_concurrent_evals,
        deadline_s=args.deadline_s,
        rate_limit=args.rate_limit,
        registrar=args.registrar,
        capacity=args.capacity,
        certfile=args.certfile,
        keyfile=args.keyfile)
    srv = WorkerServer(args.host, args.port, options=options)
    print(f"repro-serve-worker listening on {srv.host}:{srv.port}"
          + (" [signed]" if srv.keyring else "")
          + (" [insecure]" if srv.insecure else ""), flush=True)
    try:
        srv.serve_forever()
    except KeyboardInterrupt:
        srv.close()


if __name__ == "__main__":
    main()
