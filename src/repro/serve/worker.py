"""The ``repro.serve`` worker daemon: a remote evaluator over TCP.

Run one per host/core budget::

    PYTHONPATH=src python -m repro.serve.worker --host 0.0.0.0 --port 9707

A worker starts *evaluator-agnostic*.  Each client connection opens with
a :class:`~repro.serve.wire.Hello` carrying the pickled evaluator spec
(the PR 4 process-pool template, see :func:`~repro.distributed.sharded.
_worker_spec`); the worker rebuilds the evaluator — cached process-wide
by spec digest, so reconnects and sibling connections serving the same
study skip the rebuild — answers :class:`~repro.serve.wire.Ready`, then
serves ``Dispatch(ShardPayload) -> ResultMsg(PPAReport)`` until the
client hangs up.

Evaluations run on a per-connection executor thread while the reader
thread keeps answering :class:`~repro.serve.wire.Ping` heartbeats — a
worker grinding through a big shard still proves liveness, which is what
lets the client side distinguish *slow* from *dead*.

:func:`start_worker_process` spawns a daemon in a child process (spawn
context, so no jax state is forked) and returns a handle with the bound
port — the test/bench/example harness for 2-worker loopback clusters,
and the thing to SIGKILL when proving fault tolerance.
"""
from __future__ import annotations

import argparse
import hashlib
import socket
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.obs.metrics import Clock, MetricsRegistry
from repro.obs.trace import Tracer
from repro.serve import wire

# evaluators by spec sha256 — shared across connections so a fleet
# serving one study builds once per process, not once per reconnect
_EVALUATORS: Dict[str, object] = {}
_EVALUATORS_LOCK = threading.Lock()


def _evaluator_for(spec: bytes) -> Tuple[str, object]:
    digest = hashlib.sha256(spec).hexdigest()
    with _EVALUATORS_LOCK:
        ev = _EVALUATORS.get(digest)
        if ev is None:
            from repro.distributed.sharded import evaluator_from_spec
            ev = evaluator_from_spec(spec)
            _EVALUATORS[digest] = ev
    return digest, ev


class WorkerServer:
    """Accepts connections on ``host:port`` (``port=0`` = ephemeral) and
    serves the wire protocol; one reader thread + one eval thread per
    connection."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0, *,
                 max_message_bytes: int = wire.MAX_MESSAGE_BYTES,
                 registry: Optional[MetricsRegistry] = None,
                 clock: Optional[Clock] = None):
        self.max_message_bytes = int(max_message_bytes)
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(16)
        self.host, self.port = self._sock.getsockname()[:2]
        self._closed = threading.Event()
        self._clock: Clock = clock if clock is not None else time.monotonic
        self.metrics = registry if registry is not None else MetricsRegistry()
        self._c_connections = self.metrics.counter(
            "worker_connections_served", "client connections accepted")
        self._c_dispatches = self.metrics.counter(
            "worker_dispatches_served", "shard dispatches answered OK")
        self._h_eval = self.metrics.histogram(
            "worker_eval_s", "per-dispatch evaluation wall time (s)")
        # Perfetto process lane for spans minted on this worker
        self._proc = f"worker:{self.host}:{self.port}"

    @property
    def connections_served(self) -> int:
        return int(self._c_connections.value())

    @property
    def dispatches_served(self) -> int:
        return int(self._c_dispatches.value())

    # -- accept loop ----------------------------------------------------
    def serve_forever(self) -> None:
        try:
            while not self._closed.is_set():
                try:
                    conn, _addr = self._sock.accept()
                except OSError:
                    break                        # listener closed
                self._c_connections.inc()
                t = threading.Thread(target=self._serve_conn, args=(conn,),
                                     name="serve-conn", daemon=True)
                t.start()
        finally:
            self.close()

    def start(self) -> threading.Thread:
        """Run the accept loop on a background thread (in-process use)."""
        t = threading.Thread(target=self.serve_forever,
                             name="serve-accept", daemon=True)
        t.start()
        return t

    def close(self) -> None:
        if not self._closed.is_set():
            self._closed.set()
            try:
                self._sock.close()
            except OSError:
                pass

    # -- per-connection protocol ----------------------------------------
    def _serve_conn(self, conn: socket.socket) -> None:
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        send_lock = threading.Lock()
        # one eval lane per connection: dispatches execute in order while
        # the reader loop stays free to answer heartbeats
        ex = ThreadPoolExecutor(max_workers=1,
                                thread_name_prefix="serve-eval")

        def reply(msg: object) -> None:
            with send_lock:
                wire.send_msg(conn, msg)

        def run_dispatch(evaluator, msg: wire.Dispatch) -> None:
            # old clients pickled Dispatch without trace_ctx
            ctx = getattr(msg, "trace_ctx", None)
            tracer = (Tracer(clock=self._clock, proc=self._proc)
                      if ctx is not None else None)

            def shipped_spans() -> Tuple:
                if tracer is None:
                    return ()
                return tuple(s.as_dict() for s in tracer.drain())

            try:
                from repro.distributed.sharded import _eval_payload
                t0 = self._clock()
                if tracer is not None:
                    idx = getattr(msg.payload, "idx", None)
                    rows = int(idx.shape[0]) if hasattr(idx, "shape") else 0
                    with tracer.span("worker.eval", parent=tuple(ctx),
                                     seq=msg.seq, rows=rows):
                        rep = _eval_payload(evaluator, msg.payload)
                else:
                    rep = _eval_payload(evaluator, msg.payload)
                self._h_eval.observe(self._clock() - t0)
                reply(wire.ResultMsg(msg.seq, rep, shipped_spans()))
            except Exception as exc:        # noqa: BLE001 — wire boundary
                try:
                    reply(wire.ErrorMsg(msg.seq, f"{type(exc).__name__}: "
                                                 f"{exc}", shipped_spans()))
                except OSError:
                    pass                    # client already gone
            else:
                self._c_dispatches.inc()

        try:
            hello = wire.check_hello(
                wire.recv_msg(conn, self.max_message_bytes))
            digest, evaluator = _evaluator_for(hello.spec)
            reply(wire.Ready(digest, tuple(evaluator.workloads)))
            while True:
                msg = wire.recv_msg(conn, self.max_message_bytes)
                if isinstance(msg, wire.Dispatch):
                    ex.submit(run_dispatch, evaluator, msg)
                elif isinstance(msg, wire.Ping):
                    reply(wire.Pong(msg.seq))
                elif isinstance(msg, wire.Bye):
                    break
                else:
                    raise wire.WireError(
                        f"unexpected message {type(msg).__name__}")
        except wire.ConnectionClosed:
            pass                                # normal client departure
        except (wire.WireError, OSError) as exc:
            try:
                reply(wire.ErrorMsg(-1, str(exc)))
            except OSError:
                pass
        finally:
            ex.shutdown(wait=False)
            try:
                conn.close()
            except OSError:
                pass


# ---------------------------------------------------------------------------
# process harness
# ---------------------------------------------------------------------------

def _spawned_main(host: str, port: int, port_conn) -> None:
    srv = WorkerServer(host, port)
    port_conn.send(srv.port)
    port_conn.close()
    srv.serve_forever()


@dataclass
class WorkerHandle:
    """A spawned worker daemon: its process and bound address."""
    process: object                 # multiprocessing.Process
    host: str
    port: int
    address: Tuple[str, int] = field(init=False)

    def __post_init__(self):
        self.address = (self.host, self.port)

    def kill(self) -> None:
        """SIGKILL — the fault-tolerance test hammer: no cleanup, no
        goodbye, in-flight dispatches die with the process."""
        self.process.kill()
        self.process.join()

    def terminate(self) -> None:
        self.process.terminate()
        self.process.join()

    def alive(self) -> bool:
        return self.process.is_alive()


def start_worker_process(host: str = "127.0.0.1", port: int = 0, *,
                         timeout_s: float = 120.0) -> WorkerHandle:
    """Spawn a worker daemon in a child process; returns once it is
    listening (the bound port travels back over a pipe, so ``port=0``
    works)."""
    import multiprocessing as mp
    ctx = mp.get_context("spawn")
    parent, child = ctx.Pipe()
    proc = ctx.Process(target=_spawned_main, args=(host, port, child),
                       daemon=True)
    proc.start()
    child.close()
    if not parent.poll(timeout_s):
        proc.kill()
        raise TimeoutError(f"worker did not bind within {timeout_s}s")
    bound_port = parent.recv()
    parent.close()
    return WorkerHandle(process=proc, host=host, port=bound_port)


def main(argv: Optional[list] = None) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve.worker",
        description="repro.serve evaluation worker daemon")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 picks an ephemeral port (printed on startup)")
    args = ap.parse_args(argv)
    srv = WorkerServer(args.host, args.port)
    print(f"repro-serve-worker listening on {srv.host}:{srv.port}",
          flush=True)
    try:
        srv.serve_forever()
    except KeyboardInterrupt:
        srv.close()


if __name__ == "__main__":
    main()
