"""The service front door: tenant accounting, admission control, telemetry.

:class:`Gateway` wraps an :class:`~repro.distributed.service.EvalService`
and is the only layer that knows about *tenants*.  Every
:meth:`Gateway.submit` passes two admission checks before reaching the
service queue:

* **Per-tenant token budgets** — each tenant may admit at most
  ``rows_per_window`` design rows per fixed ``window_s`` window
  (per-tenant overrides via ``tenants={name: rows}``).  Exhausted budget
  rejects with :class:`RetryAfter` carrying the time until the window
  rolls.
* **Queue-depth backpressure** — when the service backlog exceeds
  ``max_queued_rows``, the gateway rejects with a :class:`RetryAfter`
  whose hint is the backlog drain ETA at the observed service rate
  (:func:`~repro.runtime.elastic.admission_retry_after`) — reject early
  and cheap instead of queueing unboundedly and timing out expensively.

A rejected request costs the tenant nothing (no budget is consumed).
:meth:`telemetry` merges the service's QoS/degradation counters with
per-tenant accounting and the worker fleet state (the evaluator's
:class:`~repro.distributed.faults.WorkerRegistry` snapshot, when it has
one — a sharded/socket evaluator does).

The gateway also implements the synchronous ``Evaluator`` protocol
(``evaluate`` / ``objectives`` / ``workloads`` / ...), self-ticking like
the service, so a ``CampaignRunner`` or bench can be pointed at the
front door and inherit admission control + QoS unchanged.
"""
from __future__ import annotations

import json
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Dict, Mapping, Optional

import numpy as np

from repro.distributed.service import QOS_TIERS, EvalService
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NOOP
from repro.perfmodel.evaluator import EvalRequest, PPAReport
from repro.runtime.elastic import admission_retry_after


class RetryAfter(RuntimeError):
    """Admission rejected; retry after ``retry_after_s`` seconds."""

    def __init__(self, message: str, retry_after_s: float):
        super().__init__(message)
        self.retry_after_s = float(retry_after_s)


@dataclass
class TenantAccount:
    """Fixed-window admission state for one tenant (the traffic counts
    live in the gateway's metrics registry, labelled by tenant)."""
    rows_per_window: int
    window_start: float
    used_rows: int = 0


class Gateway:
    """Multi-tenant admission-controlled front door over an EvalService.

    Parameters
    ----------
    service:
        The :class:`~repro.distributed.service.EvalService` to guard —
        or anything ``EvalService`` accepts (a bare evaluator is wrapped
        in a fresh service).
    rows_per_window / window_s:
        Default per-tenant token budget: design rows admitted per fixed
        window.  The window is per tenant, opened at its first submit.
    tenants:
        Per-tenant ``rows_per_window`` overrides (``{tenant: rows}``).
        Unknown tenants get the default — this is quota config, not an
        allow-list.
    max_queued_rows:
        Queue-depth backpressure threshold: submits that would push the
        service backlog past this are rejected with a drain-ETA retry
        hint.  ``None`` disables backpressure.
    default_tier:
        QoS tier used when a submit names none.
    """

    def __init__(self, service, *, rows_per_window: int = 100_000,
                 window_s: float = 60.0,
                 tenants: Optional[Mapping[str, int]] = None,
                 max_queued_rows: Optional[int] = None,
                 default_tier: str = "batch",
                 now=time.monotonic,
                 registry: Optional[MetricsRegistry] = None,
                 tracer=None):
        if not isinstance(service, EvalService):
            service = EvalService(service, tracer=tracer, clock=now)
        if default_tier not in QOS_TIERS:
            raise ValueError(f"default_tier must be one of {QOS_TIERS}, "
                             f"got {default_tier!r}")
        self.service = service
        self.rows_per_window = int(rows_per_window)
        self.window_s = float(window_s)
        self.quotas = dict(tenants or {})
        self.max_queued_rows = (None if max_queued_rows is None
                                else int(max_queued_rows))
        self.default_tier = default_tier
        self._now = now
        self._lock = threading.Lock()
        self._accounts: Dict[str, TenantAccount] = {}
        # observed service rate (rows/s EWMA) feeding the drain-ETA hint
        self._rate_rows_per_s = 0.0
        self._rate_alpha = 0.3
        # default to the service's tracer so gateway.evaluate roots the
        # same causal tree the tick/dispatch/shard spans grow under
        self.tracer = (tracer if tracer is not None
                       else getattr(service, "tracer", NOOP))
        self.metrics = registry if registry is not None else MetricsRegistry()
        m = self.metrics
        self._c_admitted = m.counter(
            "gateway_admitted", "requests past both admission checks")
        self._c_rejected = m.counter(
            "gateway_rejected", "requests refused (budget or backpressure)")
        self._c_t_admitted = m.counter(
            "gateway_tenant_admitted", "admitted requests, by tenant",
            labelnames=("tenant",))
        self._c_t_admitted_rows = m.counter(
            "gateway_tenant_admitted_rows", "admitted design rows, by tenant",
            labelnames=("tenant",))
        self._c_t_rej_budget = m.counter(
            "gateway_tenant_rejected_budget",
            "budget-exhausted rejections, by tenant", labelnames=("tenant",))
        self._c_t_rej_bp = m.counter(
            "gateway_tenant_rejected_backpressure",
            "backpressure rejections, by tenant", labelnames=("tenant",))

    @property
    def admitted(self) -> int:
        return int(self._c_admitted.value())

    @property
    def rejected(self) -> int:
        return int(self._c_rejected.value())

    # -- admission ------------------------------------------------------
    def _account(self, tenant: str) -> TenantAccount:
        acct = self._accounts.get(tenant)
        if acct is None:
            acct = TenantAccount(
                rows_per_window=int(self.quotas.get(tenant,
                                                    self.rows_per_window)),
                window_start=self._now())
            self._accounts[tenant] = acct
            for c in (self._c_t_admitted, self._c_t_admitted_rows,
                      self._c_t_rej_budget, self._c_t_rej_bp):
                c.touch(tenant=tenant)
        return acct

    def submit(self, request: EvalRequest, *, tenant: str = "default",
               tier: Optional[str] = None,
               client: Optional[str] = None,
               deadline_s: Optional[float] = None) -> Future:
        """Admit + enqueue one request, or raise :class:`RetryAfter`.

        ``client`` defaults to the tenant name, so each tenant is a
        fairness lane inside its QoS tier unless it names finer lanes.
        """
        tier = self.default_tier if tier is None else tier
        idx = np.atleast_2d(np.asarray(request.idx, dtype=np.int32))
        n = int(idx.shape[0])
        with self._lock:
            acct = self._account(tenant)
            now = self._now()
            if now - acct.window_start >= self.window_s:
                acct.window_start = now
                acct.used_rows = 0
            if self.max_queued_rows is not None:
                backlog = self.service.queued_rows()
                if backlog + n > self.max_queued_rows:
                    self._c_t_rej_bp.inc(tenant=tenant)
                    self._c_rejected.inc()
                    hint = admission_retry_after(backlog,
                                                 self._rate_rows_per_s)
                    raise RetryAfter(
                        f"service backlog {backlog} rows "
                        f"(+{n} > {self.max_queued_rows} cap); "
                        f"retry in {hint:.2f}s", hint)
            if acct.used_rows + n > acct.rows_per_window:
                self._c_t_rej_budget.inc(tenant=tenant)
                self._c_rejected.inc()
                hint = max(0.0,
                           self.window_s - (now - acct.window_start))
                raise RetryAfter(
                    f"tenant {tenant!r} budget exhausted "
                    f"({acct.used_rows}+{n} > {acct.rows_per_window} "
                    f"rows/window); window rolls in {hint:.2f}s", hint)
            acct.used_rows += n
            self._c_t_admitted.inc(tenant=tenant)
            self._c_t_admitted_rows.inc(n, tenant=tenant)
            self._c_admitted.inc()
        return self.service.submit(request,
                                   client=tenant if client is None
                                   else client,
                                   tier=tier, deadline_s=deadline_s)

    def tick(self) -> int:
        """Drive the service batcher; feeds the drain-rate estimate the
        backpressure retry hints are computed from."""
        t0 = self._now()
        rows = self.service.tick()
        dt = self._now() - t0
        if rows and dt > 0:
            with self._lock:
                a = self._rate_alpha
                self._rate_rows_per_s = ((1 - a) * self._rate_rows_per_s
                                         + a * (rows / dt))
        return rows

    # -- telemetry ------------------------------------------------------
    def _tenant_dict(self, tenant: str, acct: TenantAccount) -> dict:
        return {
            "rows_per_window": acct.rows_per_window,
            "used_rows": acct.used_rows,
            "admitted": int(self._c_t_admitted.value(tenant=tenant)),
            "admitted_rows": int(self._c_t_admitted_rows.value(tenant=tenant)),
            "rejected_budget": int(self._c_t_rej_budget.value(tenant=tenant)),
            "rejected_backpressure": int(self._c_t_rej_bp.value(tenant=tenant)),
        }

    def telemetry(self) -> dict:
        """Service QoS counters + tenant ledgers + worker fleet state."""
        with self._lock:
            tenants = {t: self._tenant_dict(t, a)
                       for t, a in self._accounts.items()}
            out = {
                "service": self.service.telemetry(),
                "tenants": tenants,
                "admission": {
                    "admitted": self.admitted,
                    "rejected": self.rejected,
                    "max_queued_rows": self.max_queued_rows,
                    "rows_per_window": self.rows_per_window,
                    "window_s": self.window_s,
                    "observed_rows_per_s": round(self._rate_rows_per_s, 1),
                },
            }
        ev = self.service.evaluator
        registry = getattr(ev, "registry", None)
        if registry is not None:
            out["fleet"] = registry.snapshot()
            out["fleet"]["mode"] = getattr(ev, "mode", None)
            out["fleet"]["workers"] = getattr(ev, "workers", None)
            membership = getattr(ev, "membership", None)
            if membership is not None:
                # lease-level fleet view: who holds membership right now,
                # not just which sockets happen to be open
                out["fleet"]["leases"] = membership.snapshot()
            ev_metrics = getattr(ev, "metrics", None)
            if ev_metrics is not None:
                rtt = ev_metrics.get("heartbeat_rtt")
                if rtt is not None:
                    out["fleet"]["heartbeat_rtt"] = {
                        labels[0]: {
                            "count": s["count"],
                            "p50_ms": (round(s["p50"] * 1e3, 3)
                                       if s["p50"] is not None else None),
                            "p99_ms": (round(s["p99"] * 1e3, 3)
                                       if s["p99"] is not None else None),
                        }
                        for labels in rtt.series_keys()
                        for s in (rtt.stats(worker=labels[0]),)
                    }
        return out

    def snapshot(self) -> dict:
        """Everything the fleet dashboard wants in one JSON-able dict:
        the merged :meth:`telemetry` tree plus the raw metric registries
        of every layer that has one."""
        out = {"telemetry": self.telemetry(),
               "metrics": {"gateway": self.metrics.snapshot()}}
        svc_metrics = getattr(self.service, "metrics", None)
        if svc_metrics is not None:
            out["metrics"]["service"] = svc_metrics.snapshot()
        ev_metrics = getattr(self.service.evaluator, "metrics", None)
        if ev_metrics is not None:
            out["metrics"]["evaluator"] = ev_metrics.snapshot()
        return out

    def save_snapshot(self, path) -> None:
        """Write :meth:`snapshot` as JSON — the input format of
        ``python -m repro.obs.report``."""
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(self.snapshot(), fh, indent=2, default=str)

    # -- Evaluator facade ----------------------------------------------
    @property
    def workloads(self):
        return self.service.workloads

    @property
    def models(self):
        return self.service.models

    @property
    def scenarios(self):
        return self.service.scenarios

    @property
    def space(self):
        return self.service.space

    @property
    def tier(self):
        return self.service.tier

    @property
    def row_cache(self):
        return self.service.row_cache

    def evaluate(self, request: EvalRequest, *,
                 tenant: str = "default") -> PPAReport:
        with self.tracer.span("gateway.evaluate", tenant=tenant):
            fut = self.submit(request, tenant=tenant)
            while not fut.done() and self.service._batcher is None:
                self.tick()
            return fut.result()

    def objectives(self, idx: np.ndarray) -> np.ndarray:
        return self.evaluate(EvalRequest(idx, detail="objectives")).objectives

    def ppa(self, idx: np.ndarray) -> PPAReport:
        return self.evaluate(EvalRequest(idx, detail="ppa"))

    def stalls(self, idx: np.ndarray) -> PPAReport:
        return self.evaluate(EvalRequest(idx, detail="stalls"))

    def __call__(self, idx: np.ndarray) -> np.ndarray:
        return self.objectives(idx)

    def close(self) -> None:
        self.service.close()
