"""Length-prefixed pickle wire protocol for the cross-machine eval fabric.

The PR 4 process pool established the wire format: a worker is anything
that can rebuild an evaluator from a pickled spec and answer
:class:`~repro.distributed.sharded.ShardPayload` dispatches with
:class:`~repro.perfmodel.evaluator.PPAReport` payloads.  This module
carries exactly that contract over a TCP socket:

* **Framing** — every message is an 8-byte big-endian length prefix
  followed by a pickle (``pickle.HIGHEST_PROTOCOL``) of one of the
  dataclasses below.  :func:`send_msg` / :func:`recv_msg` are the entire
  codec; ``recv_msg`` rejects frames above ``max_bytes`` before reading
  them (a corrupt or hostile length prefix cannot OOM the receiver).
* **Messages** — ``Hello`` (the evaluator spec bytes: the handshake that
  turns a bare worker daemon into THIS evaluator's worker), ``Ready``
  (spec digest ack), ``Dispatch``/``ResultMsg``/``ErrorMsg`` (one shard
  request/response, correlated by ``seq`` so many dispatches ride one
  connection), ``Ping``/``Pong`` (heartbeats carried over the same wire,
  answered while evaluations are in flight), ``Bye`` (graceful close).

Trust model: pickle-over-socket assumes the same trust domain as the PR 4
process pool (your own fleet behind your own firewall) — it is a cluster
transport, not an internet-facing API.  :class:`~repro.serve.gateway.
Gateway` is where multi-tenant admission control lives.
"""
from __future__ import annotations

import dataclasses
import pickle
import socket
import struct
from typing import Optional, Tuple

WIRE_VERSION = 1

# 8-byte big-endian unsigned length prefix
_HEADER = struct.Struct(">Q")

# refuse frames above this before allocating (a flipped length bit cannot
# ask the receiver to materialize petabytes)
MAX_MESSAGE_BYTES = 1 << 31


class WireError(RuntimeError):
    """Malformed traffic: bad frame, oversized message, version mismatch."""


class ConnectionClosed(WireError):
    """The peer closed (or was killed) mid-conversation."""


# ---------------------------------------------------------------------------
# messages
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Hello:
    """Client handshake: the pickled evaluator spec this connection serves
    (the same bytes :func:`~repro.distributed.sharded._worker_spec`
    feeds the process pool's initializer)."""
    spec: bytes
    wire_version: int = WIRE_VERSION


@dataclasses.dataclass(frozen=True)
class Ready:
    """Worker ack: the sha256 digest of the spec it (re)built, plus the
    workload names of the evaluator it is now serving."""
    digest: str
    workloads: Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class Dispatch:
    """One shard request; ``seq`` correlates the eventual response.

    ``trace_ctx`` is an optional ``(trace_id, span_id)`` pair naming the
    client-side wire span: when present, the worker opens its evaluation
    span *under* it so the per-request causal tree crosses the machine
    boundary.  Old peers pickled this class without the field — always
    read it via ``getattr(msg, "trace_ctx", None)``.
    """
    seq: int
    payload: object                # ShardPayload (kept loose: wire is generic)
    trace_ctx: Optional[Tuple[str, str]] = None


@dataclasses.dataclass(frozen=True)
class ResultMsg:
    """One shard response.  ``spans`` carries the worker-side span dicts
    (empty when the dispatch was untraced); read via
    ``getattr(msg, "spans", ())`` for old-peer compatibility."""
    seq: int
    report: object                 # PPAReport
    spans: Tuple = ()


@dataclasses.dataclass(frozen=True)
class ErrorMsg:
    seq: int
    message: str
    spans: Tuple = ()


@dataclasses.dataclass(frozen=True)
class Ping:
    seq: int


@dataclasses.dataclass(frozen=True)
class Pong:
    seq: int


@dataclasses.dataclass(frozen=True)
class Bye:
    reason: str = ""


# ---------------------------------------------------------------------------
# codec
# ---------------------------------------------------------------------------

def send_msg(sock: socket.socket, msg: object) -> None:
    """Frame + send one message (callers serialize access per socket)."""
    payload = pickle.dumps(msg, protocol=pickle.HIGHEST_PROTOCOL)
    sock.sendall(_HEADER.pack(len(payload)) + payload)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(min(1 << 20, n - len(buf)))
        if not chunk:
            raise ConnectionClosed(f"peer closed after {len(buf)}/{n} bytes")
        buf += chunk
    return bytes(buf)


def recv_msg(sock: socket.socket,
             max_bytes: int = MAX_MESSAGE_BYTES) -> object:
    """Receive one framed message (blocking; raises ConnectionClosed on
    EOF, WireError on an oversized frame)."""
    (n,) = _HEADER.unpack(_recv_exact(sock, _HEADER.size))
    if n > max_bytes:
        raise WireError(f"frame of {n} bytes exceeds the {max_bytes}-byte "
                        "message bound")
    return pickle.loads(_recv_exact(sock, n))


def check_hello(msg: object) -> Hello:
    """Validate the opening message of a connection."""
    if not isinstance(msg, Hello):
        raise WireError(f"expected Hello, got {type(msg).__name__}")
    if msg.wire_version != WIRE_VERSION:
        raise WireError(f"wire version mismatch: peer speaks "
                        f"v{msg.wire_version}, this build v{WIRE_VERSION}")
    return msg


def connect(address: Tuple[str, int], *,
            timeout_s: Optional[float] = 10.0) -> socket.socket:
    """TCP connect with TCP_NODELAY (small request/response frames should
    not wait on Nagle) and the timeout cleared after establishment."""
    sock = socket.create_connection(address, timeout=timeout_s)
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    sock.settimeout(None)
    return sock
