"""Length-prefixed wire framing + message vocabulary for ``repro.serve``.

The PR 4 process pool established the contract: a worker is anything
that can rebuild an evaluator from a pickled spec and answer
:class:`~repro.distributed.sharded.ShardPayload` dispatches with
:class:`~repro.perfmodel.evaluator.PPAReport` payloads.  This module
carries that contract over a TCP socket:

* **Framing** — every frame is an 8-byte big-endian length prefix
  followed by the frame bytes.  :func:`send_frame` / :func:`recv_frame`
  are the transport; ``recv_frame`` rejects frames above ``max_bytes``
  before reading them (a corrupt or hostile length prefix cannot OOM
  the receiver).  What's INSIDE the frame is the codec's business:
  :mod:`repro.serve.codec` provides the default schema-restricted
  binary codec (optionally HMAC-signed, replay-protected) and the
  legacy pickle shim behind ``insecure=True``.
* **Messages** — ``Hello`` (the evaluator spec bytes: the handshake
  that turns a bare worker daemon into THIS evaluator's worker),
  ``Ready`` (spec digest ack), ``Dispatch``/``ResultMsg``/``ErrorMsg``
  (one shard request/response, correlated by ``seq`` so many dispatches
  ride one connection; ``ErrorMsg.code`` carries typed reject hints
  like ``quota.rows``), ``Ping``/``Pong`` (heartbeats answered while
  evaluations are in flight), ``Bye`` (graceful close), and the
  membership pair ``Announce``/``LeaseAck`` (workers leasing a slot in
  the gateway's registrar, see :mod:`repro.serve.membership`).

Trust model: the binary codec + keyring makes the fabric safe to expose
beyond one trust domain (see README "Security model"); the legacy
pickle mode assumes the same trust domain as the PR 4 process pool and
stays available only behind an explicit ``insecure=True``.
"""
from __future__ import annotations

import dataclasses
import socket
import ssl as _ssl
import struct
from typing import Optional, Tuple

WIRE_VERSION = 1

# 8-byte big-endian unsigned length prefix
_HEADER = struct.Struct(">Q")

# refuse frames above this before allocating (a flipped length bit cannot
# ask the receiver to materialize petabytes); endpoints can tighten it
# per-connection via ``max_frame_bytes``
MAX_MESSAGE_BYTES = 1 << 31


class WireError(RuntimeError):
    """Malformed traffic: bad frame, oversized message, version mismatch."""


class ConnectionClosed(WireError):
    """The peer closed (or was killed) mid-conversation."""


# ---------------------------------------------------------------------------
# messages
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Hello:
    """Client handshake: the pickled evaluator spec this connection serves
    (the same bytes :func:`~repro.distributed.sharded._worker_spec`
    feeds the process pool's initializer).  Secure-mode workers
    deserialize it through the allowlisted constructor table
    (:func:`repro.serve.codec.restricted_loads`) and may additionally
    require its digest to be pre-approved."""
    spec: bytes
    wire_version: int = WIRE_VERSION


@dataclasses.dataclass(frozen=True)
class Ready:
    """Worker ack: the sha256 digest of the spec it (re)built, plus the
    workload names of the evaluator it is now serving."""
    digest: str
    workloads: Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class Dispatch:
    """One shard request; ``seq`` correlates the eventual response.

    ``trace_ctx`` is an optional ``(trace_id, span_id)`` pair naming the
    client-side wire span: when present, the worker opens its evaluation
    span *under* it so the per-request causal tree crosses the machine
    boundary.  Old peers pickled this class without the field — always
    read it via ``getattr(msg, "trace_ctx", None)``.
    """
    seq: int
    payload: object                # ShardPayload (kept loose: wire is generic)
    trace_ctx: Optional[Tuple[str, str]] = None


@dataclasses.dataclass(frozen=True)
class ResultMsg:
    """One shard response.  ``spans`` carries the worker-side span dicts
    (empty when the dispatch was untraced); read via
    ``getattr(msg, "spans", ())`` for old-peer compatibility."""
    seq: int
    report: object                 # PPAReport
    spans: Tuple = ()


@dataclasses.dataclass(frozen=True)
class ErrorMsg:
    """One failed request (``seq >= 0``) or a connection-fatal protocol
    error (``seq < 0``).  ``code`` is a typed machine hint: empty for
    plain evaluation failures, ``quota.*`` for worker-side quota rejects
    (the client reroutes instead of retrying the same worker), ``auth.*``
    for authentication rejects.  Read via ``getattr(msg, "code", "")``
    for old-peer compatibility."""
    seq: int
    message: str
    spans: Tuple = ()
    code: str = ""


@dataclasses.dataclass(frozen=True)
class Ping:
    seq: int


@dataclasses.dataclass(frozen=True)
class Pong:
    seq: int


@dataclasses.dataclass(frozen=True)
class Bye:
    reason: str = ""


@dataclasses.dataclass(frozen=True)
class Announce:
    """Worker -> registrar: lease (or renew) a membership slot.

    ``address`` is where the worker's dispatch port listens, ``digests``
    the spec digests it already serves (empty = will build anything its
    own allowlist accepts), ``capacity`` an advisory concurrent-eval
    count for placement."""
    address: Tuple[str, int]
    digests: Tuple[str, ...] = ()
    capacity: int = 1


@dataclasses.dataclass(frozen=True)
class LeaseAck:
    """Registrar -> worker: the lease is held for ``ttl_s`` more seconds;
    renew (re-Announce) before it lapses or the membership view drops
    the worker."""
    ttl_s: float


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------

def send_frame(sock: socket.socket, frame: bytes) -> None:
    """Length-prefix + send one raw frame (callers serialize per socket)."""
    sock.sendall(_HEADER.pack(len(frame)) + frame)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(min(1 << 20, n - len(buf)))
        if not chunk:
            raise ConnectionClosed(f"peer closed after {len(buf)}/{n} bytes")
        buf += chunk
    return bytes(buf)


def recv_frame(sock: socket.socket,
               max_bytes: int = MAX_MESSAGE_BYTES) -> bytes:
    """Receive one raw frame (blocking; raises ConnectionClosed on EOF,
    WireError on an oversized frame)."""
    (n,) = _HEADER.unpack(_recv_exact(sock, _HEADER.size))
    if n > max_bytes:
        raise WireError(f"frame of {n} bytes exceeds the {max_bytes}-byte "
                        "message bound")
    return _recv_exact(sock, n)


def send_msg(sock: socket.socket, msg: object) -> None:
    """LEGACY single-trust-domain path: frame + send one pickled message
    (callers serialize access per socket).  New code should speak through
    :class:`repro.serve.codec.Channel` instead."""
    import pickle
    send_frame(sock, pickle.dumps(msg, protocol=pickle.HIGHEST_PROTOCOL))


def recv_msg(sock: socket.socket,
             max_bytes: int = MAX_MESSAGE_BYTES) -> object:
    """LEGACY single-trust-domain path: receive one pickled message
    (deserialized through the codec module's sanctioned shim)."""
    from repro.serve import codec
    return codec.legacy_loads(recv_frame(sock, max_bytes))


def check_hello(msg: object) -> Hello:
    """Validate the opening message of a connection."""
    if not isinstance(msg, Hello):
        raise WireError(f"expected Hello, got {type(msg).__name__}")
    if msg.wire_version != WIRE_VERSION:
        raise WireError(f"wire version mismatch: peer speaks "
                        f"v{msg.wire_version}, this build v{WIRE_VERSION}")
    return msg


def connect(address: Tuple[str, int], *,
            timeout_s: Optional[float] = 10.0,
            ssl_context: Optional[_ssl.SSLContext] = None) -> socket.socket:
    """TCP connect with TCP_NODELAY (small request/response frames should
    not wait on Nagle) and the timeout cleared after establishment.
    With ``ssl_context`` the socket is TLS-wrapped (the handshake runs
    under the connect timeout)."""
    sock = socket.create_connection(address, timeout=timeout_s)
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    if ssl_context is not None:
        sock = ssl_context.wrap_socket(sock, server_hostname=address[0])
    sock.settimeout(None)
    return sock
