"""`SocketPool`: remote ``repro.serve`` workers behind the pool protocol.

Implements exactly the ``submit(ShardPayload) -> Future`` / ``resize`` /
``close`` surface of the local pools in :mod:`repro.distributed.sharded`,
so :class:`~repro.distributed.sharded.ShardedEvaluator` — retry budgets,
shard timeouts, straggler speculation, elastic resize, ``ChaosPool``
wrapping — drives a cross-machine fleet *unchanged*.

One :class:`_Connection` per worker address: a Hello/Ready handshake
ships the pickled evaluator spec, then dispatches multiplex over the
connection keyed by ``seq`` (a reader thread resolves the matching
futures as results land, out of order is fine).  Traffic rides a
:class:`~repro.serve.codec.Channel` — the schema-restricted binary
codec by default, HMAC-signed + replay-protected when a ``keyring`` is
given, TLS-wrapped when an ``ssl_context`` is given; the legacy pickle
transport needs an explicit ``insecure=True``.  A frame the channel
refuses (tampered, replayed, unsigned) is counted
(``pool_auth_rejected{reason}``) and kills the connection without ever
being decoded.

Liveness is the pool's own :class:`~repro.distributed.faults.
WorkerRegistry`: a heartbeat thread pings every worker each
``heartbeat_s``; pongs and results beat the registry; a connection that
dies (EOF, send failure, silent past ``heartbeat_timeout_s``) fails all
its in-flight futures with :class:`~repro.distributed.faults.
WorkerFault` — which lands in the ShardedEvaluator retry path — and is
marked dead + evicted.  A worker-side quota reject
(``ErrorMsg(code="quota.*")``) instead resolves the future with
:class:`~repro.distributed.faults.QuotaExceeded`: the worker is fine,
the dispatch must go elsewhere.  Submits round-robin over live
connections and lazily reconnect dead addresses (under a cooldown),
re-registering the slot on success.

Topology comes from either a static ``addresses=[...]`` list (PR 7) or
a live :class:`~repro.serve.membership.MembershipView` (``membership=``):
the pool syncs against the view's version counter on every submit and
heartbeat tick — new leases append worker slots (slot ids are stable:
the address list only grows), lapsed leases disable their slot and fail
its in-flight work into the retry path, and a rejoin re-enables the
slot with a cleared redial cooldown.
"""
from __future__ import annotations

import itertools
import math
import threading
import time
from concurrent.futures import Future, InvalidStateError
from typing import Dict, List, Optional, Sequence, Tuple

from repro.distributed.faults import (QuotaExceeded, WorkerFault,
                                      WorkerRegistry)
from repro.obs.metrics import Clock, MetricsRegistry
from repro.obs.trace import NOOP, Span
from repro.serve import codec as _codec
from repro.serve import wire


class _Connection:
    """One live worker link: handshake, seq-keyed in-flight futures, a
    reader thread, and a fail-everything death path."""

    def __init__(self, pool: "SocketPool", slot: int,
                 address: Tuple[str, int]):
        self.pool = pool
        self.slot = slot
        self.address = address
        self.sock = wire.connect(address, timeout_s=pool.connect_timeout_s,
                                 ssl_context=pool.ssl_context)
        # handshake under a deadline: a worker that accepts but never
        # answers Ready must not wedge pool construction
        self.sock.settimeout(pool.handshake_timeout_s)
        self.ch = _codec.Channel(
            self.sock,
            codec=_codec.CODEC_PICKLE if pool.insecure
            else _codec.CODEC_BINARY,
            keyring=None if pool.insecure else pool.keyring,
            key_id=pool.key_id,
            max_frame_bytes=pool.max_frame_bytes)
        # keyed channels bind the session nonces into every MAC before
        # any signed traffic (no-op unsigned/pickle); runs under the
        # handshake timeout like the Hello/Ready exchange
        self.ch.client_handshake()
        self.ch.send(wire.Hello(pool.spec))
        ready = self.ch.recv()
        if isinstance(ready, wire.ErrorMsg):
            self.sock.close()
            code = getattr(ready, "code", "")
            if code.startswith("auth."):
                pool._c_auth_rejected.inc(reason=code[5:])
            raise WorkerFault(f"worker {address} refused: {ready.message}")
        if not isinstance(ready, wire.Ready):
            self.sock.close()
            raise wire.WireError(f"expected Ready from {address}, got "
                                 f"{type(ready).__name__}")
        self.sock.settimeout(None)
        self.digest = ready.digest
        self.alive = True
        self.last_activity = pool.clock()
        self._send_lock = threading.Lock()
        self._lock = threading.Lock()
        # seq -> (future, wire span or None)
        self._pending: Dict[int, Tuple[Future, Optional[Span]]] = {}
        # seq -> heartbeat send time (for RTT; heartbeats are ~1/s so
        # this stays tiny — cleared on death)
        self._pings: Dict[int, float] = {}
        self._seq = itertools.count()
        self._reader = threading.Thread(
            target=self._read_loop, daemon=True,
            name=f"socket-pool-reader-{slot}")
        self._reader.start()

    # -- client side -----------------------------------------------------
    def submit(self, payload) -> Future:
        fut: Future = Future()
        tr = self.pool.tracer
        span: Optional[Span] = None
        ctx: Optional[Tuple[str, str]] = None
        if tr.enabled:
            # detached: resolved out of order by the reader thread
            span = tr.start("wire.dispatch", detached=True, slot=self.slot,
                            addr=f"{self.address[0]}:{self.address[1]}")
            ctx = span.ctx
        with self._lock:
            if not self.alive:
                if span is not None:
                    tr.lose(span, "worker down at submit")
                raise WorkerFault(f"worker {self.address} is down")
            seq = next(self._seq)
            self._pending[seq] = (fut, span)
        try:
            self._send(wire.Dispatch(seq, payload, ctx))
        except _codec.FrameTooLarge:
            # the frame never left this process: the connection is fine,
            # the DISPATCH is impossible — surface it to the caller
            # without tearing anything down
            with self._lock:
                self._pending.pop(seq, None)
            if span is not None:
                tr.lose(span, "dispatch frame over the size bound")
            raise
        except (OSError, wire.WireError) as exc:
            self.die(f"send failed: {exc}")
            raise WorkerFault(
                f"dispatch to {self.address} failed: {exc}") from exc
        return fut

    def ping(self) -> None:
        seq = next(self._seq)
        with self._lock:
            self._pings[seq] = self.pool.clock()
        try:
            self._send(wire.Ping(seq))
        except (OSError, wire.WireError) as exc:
            self.die(f"ping failed: {exc}")

    def _send(self, msg: object) -> None:
        with self._send_lock:
            self.ch.send(msg)

    # -- reader ----------------------------------------------------------
    def _read_loop(self) -> None:
        try:
            while True:
                msg = self.ch.recv()
                if isinstance(msg, wire.ResultMsg):
                    fut, span = self._pop(msg.seq)
                    self.pool._on_activity(self)
                    # worker-side spans re-parent under `span` client-side
                    self.pool.tracer.adopt(getattr(msg, "spans", ()))
                    if span is not None:
                        self.pool.tracer.finish(span)
                    if fut is not None and not fut.cancelled():
                        try:
                            fut.set_result(msg.report)
                        except InvalidStateError:
                            pass               # receiver abandoned the twin
                elif isinstance(msg, wire.ErrorMsg):
                    code = getattr(msg, "code", "")
                    if msg.seq < 0:
                        if code.startswith("auth."):
                            self.pool._c_auth_rejected.inc(reason=code[5:])
                        raise wire.WireError(f"protocol error from "
                                             f"{self.address}: {msg.message}")
                    # the WORKER is alive — the evaluation failed or was
                    # refused; surface it without tearing the wire down
                    fut, span = self._pop(msg.seq)
                    self.pool._on_activity(self)
                    self.pool.tracer.adopt(getattr(msg, "spans", ()))
                    if span is not None:
                        span.attrs["error"] = msg.message
                        self.pool.tracer.finish(span, status="error")
                    if code.startswith("quota."):
                        self.pool._c_quota_rejected.inc(kind=code[6:])
                        exc: WorkerFault = QuotaExceeded(
                            f"worker {self.address} refused the dispatch: "
                            f"{msg.message}", code)
                    else:
                        exc = WorkerFault(
                            f"remote evaluation on {self.address} "
                            f"failed: {msg.message}")
                    if fut is not None and not fut.cancelled():
                        try:
                            fut.set_exception(exc)
                        except InvalidStateError:
                            pass
                elif isinstance(msg, wire.Pong):
                    with self._lock:
                        sent = self._pings.pop(msg.seq, None)
                    if sent is not None:
                        self.pool._observe_rtt(self.slot,
                                               self.pool.clock() - sent)
                    self.pool._on_activity(self)
                else:
                    raise wire.WireError(f"unexpected "
                                         f"{type(msg).__name__} "
                                         f"from {self.address}")
        except _codec.AuthError as exc:
            # a frame that fails MAC/replay/signing checks is counted and
            # the connection dropped — its contents are never decoded
            self.pool._c_auth_rejected.inc(reason=exc.reason)
            self.die(str(exc))
        except (wire.WireError, OSError) as exc:
            self.die(str(exc))

    def _pop(self, seq: int) -> Tuple[Optional[Future], Optional[Span]]:
        with self._lock:
            return self._pending.pop(seq, (None, None))

    # -- death -----------------------------------------------------------
    def die(self, reason: str) -> None:
        """Fail every in-flight future and report the slot dead; safe to
        call from any thread, idempotent."""
        with self._lock:
            if not self.alive:
                return
            self.alive = False
            doomed = list(self._pending.values())
            self._pending.clear()
            self._pings.clear()
        try:
            self.sock.close()
        except OSError:
            pass
        exc = WorkerFault(f"worker {self.address} died: {reason}")
        for fut, span in doomed:
            if span is not None:
                # the worker will never answer: the span is orphaned
                self.pool.tracer.lose(span, f"connection died: {reason}")
            if not fut.done():
                try:
                    fut.set_exception(exc)
                except InvalidStateError:
                    pass
        self.pool._on_conn_dead(self)

    def close(self) -> None:
        """Graceful goodbye (best effort), then the death path."""
        if self.alive:
            try:
                self._send(wire.Bye())
            except (OSError, wire.WireError):
                pass
        self.die("closed")


class SocketPool:
    """Round-robin dispatch over remote worker daemons (pool protocol)."""

    mode = "socket"

    def __init__(self, base, workers: Optional[int] = None, *,
                 addresses: Optional[Sequence[Tuple[str, int]]] = None,
                 membership=None,
                 membership_wait_s: float = 10.0,
                 spec: Optional[bytes] = None,
                 insecure: bool = False,
                 keyring: Optional[_codec.Keyring] = None,
                 key_id: Optional[str] = None,
                 ssl_context=None,
                 connect_timeout_s: float = 10.0,
                 handshake_timeout_s: float = 300.0,
                 heartbeat_s: float = 1.0,
                 heartbeat_timeout_s: float = 30.0,
                 reconnect_cooldown_s: float = 0.25,
                 max_frame_bytes: Optional[int] = None,
                 max_message_bytes: int = wire.MAX_MESSAGE_BYTES,
                 metrics: Optional[MetricsRegistry] = None,
                 tracer=None,
                 clock: Optional[Clock] = None):
        self.membership = membership
        self.insecure = bool(insecure)
        self.keyring = keyring
        self.key_id = key_id
        self.ssl_context = ssl_context
        self.max_frame_bytes = int(max_frame_bytes if max_frame_bytes
                                   is not None else max_message_bytes)
        # legacy alias (PR 7 name) so old call sites keep working
        self.max_message_bytes = self.max_frame_bytes
        if membership is not None:
            if addresses:
                raise ValueError("pass addresses= OR membership=, not both")
            membership.wait_for(1, timeout_s=membership_wait_s)
            addresses = membership.live()
            if not addresses:
                raise RuntimeError(
                    f"no worker leased membership within "
                    f"{membership_wait_s}s")
        self.addresses: List[Tuple[str, int]] = [
            (str(h), int(p)) for h, p in (addresses or ())]
        if not self.addresses:
            raise ValueError("SocketPool needs at least one address")
        if spec is None:
            from repro.distributed.sharded import _worker_spec
            spec = _worker_spec(base)
        self.spec = spec
        self.workers = max(1, min(int(workers) if workers is not None
                                  else len(self.addresses),
                                  len(self.addresses)))
        self.connect_timeout_s = float(connect_timeout_s)
        self.handshake_timeout_s = float(handshake_timeout_s)
        self.heartbeat_s = float(heartbeat_s)
        self.heartbeat_timeout_s = float(heartbeat_timeout_s)
        self.reconnect_cooldown_s = float(reconnect_cooldown_s)
        self.clock: Clock = clock if clock is not None else time.monotonic
        self.tracer = tracer if tracer is not None else NOOP
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._c_reconnects = self.metrics.counter(
            "pool_reconnects", "worker connections re-established")
        self._c_auth_rejected = self.metrics.counter(
            "pool_auth_rejected",
            "worker frames rejected by client-side authentication",
            labelnames=("reason",))
        self._c_quota_rejected = self.metrics.counter(
            "pool_quota_rejected",
            "dispatches refused by worker quotas", labelnames=("kind",))
        self._h_rtt = self.metrics.histogram(
            "heartbeat_rtt", "Ping->Pong round-trip (s) per worker slot",
            labelnames=("worker",))
        self.registry = WorkerRegistry(timeout_s=self.heartbeat_timeout_s,
                                       now=self.clock)
        self._conns: Dict[int, _Connection] = {}
        self._topology_lock = threading.Lock()
        self._slot_locks = [threading.Lock() for _ in self.addresses]
        self._last_attempt = [-math.inf] * len(self.addresses)
        self._addr_slot: Dict[Tuple[str, int], int] = {
            a: s for s, a in enumerate(self.addresses)}
        self._disabled: set = set()
        self._mver = -1                # force a sync on first submit
        self._rr = itertools.count()
        self._closed = False
        errors: List[str] = []
        for slot in range(self.workers):
            self._ensure(slot, errors)
        if not any(c.alive for c in self._conns.values()):
            raise RuntimeError("no repro.serve worker reachable: "
                               + "; ".join(errors))
        self._hb = threading.Thread(target=self._heartbeat_loop,
                                    name="socket-pool-heartbeat",
                                    daemon=True)
        self._hb.start()

    @property
    def reconnects(self) -> int:
        return int(self._c_reconnects.value())

    @property
    def auth_rejected(self) -> int:
        return int(self._c_auth_rejected.total())

    @property
    def quota_rejected(self) -> int:
        return int(self._c_quota_rejected.total())

    def _observe_rtt(self, slot: int, rtt_s: float) -> None:
        self._h_rtt.observe(rtt_s, worker=slot)

    # -- membership sync --------------------------------------------------
    def _sync_membership(self) -> None:
        """Reconcile slots against the live lease set; O(1) when the
        view's version has not moved.  Slot ids are stable — the address
        list only grows; lapsed leases disable their slot (failing its
        in-flight work into the retry path), rejoins re-enable it with
        the redial cooldown cleared."""
        if self.membership is None:
            return
        v = self.membership.version()
        if v == self._mver:
            return
        to_close: List[_Connection] = []
        with self._topology_lock:
            v = self.membership.version()
            if v == self._mver:
                return
            live = set(self.membership.live())
            for addr in sorted(live):
                if addr not in self._addr_slot:
                    self._addr_slot[addr] = len(self.addresses)
                    self.addresses.append(addr)
                    self._slot_locks.append(threading.Lock())
                    self._last_attempt.append(-math.inf)
            enabled = 0
            for addr, slot in self._addr_slot.items():
                if addr in live:
                    if slot in self._disabled:
                        self._disabled.discard(slot)
                        self._last_attempt[slot] = -math.inf
                    enabled += 1
                elif slot not in self._disabled:
                    self._disabled.add(slot)
                    conn = self._conns.pop(slot, None)
                    if conn is not None:
                        to_close.append(conn)
            self.workers = max(1, enabled)
            self._mver = v
        for conn in to_close:      # outside the lock: die() fans out
            conn.close()

    def _enabled_slots(self) -> List[int]:
        if self.membership is None:
            return list(range(max(1, self.workers)))
        with self._topology_lock:
            return [s for s in range(len(self.addresses))
                    if s not in self._disabled]

    # -- pool protocol ----------------------------------------------------
    def submit(self, payload) -> Future:
        if self._closed:
            fut: Future = Future()
            fut.set_exception(WorkerFault("pool is closed"))
            return fut
        self._sync_membership()
        slots = self._enabled_slots()
        start = next(self._rr)
        for off in range(len(slots)):
            slot = slots[(start + off) % len(slots)]
            conn = self._ensure(slot)
            if conn is None:
                continue
            try:
                return conn.submit(payload)
            except _codec.FrameTooLarge:
                raise                          # caller error, fail loud
            except WorkerFault:
                continue                       # slot died mid-submit
        fut = Future()
        fut.set_exception(WorkerFault(
            f"no live worker among {len(slots)} socket slots"))
        return fut

    def resize(self, workers: int) -> None:
        """Static topology: clamp to the address list; shrinking closes
        the trailing connections, growing clears their reconnect cooldown
        so the next submit redials immediately.  Under membership the
        lease set IS the topology, so resize is a no-op."""
        if self.membership is not None:
            return
        workers = max(1, min(int(workers), len(self.addresses)))
        if workers == self.workers:
            return
        old, self.workers = self.workers, workers
        for slot in range(workers, old):
            conn = self._conns.pop(slot, None)
            if conn is not None:
                conn.close()
        for slot in range(old, workers):
            self._last_attempt[slot] = -math.inf

    def close(self) -> None:
        self._closed = True
        for conn in list(self._conns.values()):
            conn.close()
        self._conns.clear()

    def live_workers(self) -> int:
        return sum(1 for c in self._conns.values() if c.alive)

    # -- liveness plumbing ------------------------------------------------
    def _ensure(self, slot: int,
                errors: Optional[List[str]] = None) -> Optional[_Connection]:
        """The slot's live connection, redialing if dead and out of
        cooldown; None while the slot stays down (or its lease lapsed)."""
        if slot in self._disabled:
            return None
        with self._slot_locks[slot]:
            conn = self._conns.get(slot)
            if conn is not None and conn.alive:
                return conn
            now = self.clock()
            if now - self._last_attempt[slot] < self.reconnect_cooldown_s:
                return None
            self._last_attempt[slot] = now
            try:
                fresh = _Connection(self, slot, self.addresses[slot])
            except (OSError, wire.WireError, WorkerFault) as exc:
                if errors is not None:
                    errors.append(f"{self.addresses[slot]}: {exc}")
                return None
            if conn is not None:
                self._c_reconnects.inc()
            self._conns[slot] = fresh
            self.registry.register(slot)
            return fresh

    def _on_activity(self, conn: _Connection) -> None:
        conn.last_activity = self.clock()
        self.registry.beat(conn.slot)
        if not self.registry.alive(conn.slot):
            # the slot was (possibly mis-)evicted while the wire kept
            # working — the pong is proof of life, so re-register
            self.registry.register(conn.slot)

    def _on_conn_dead(self, conn: _Connection) -> None:
        self.registry.mark_dead(conn.slot)
        self.registry.evict_dead()

    def _heartbeat_loop(self) -> None:
        period = max(0.05, min(self.heartbeat_s,
                               self.heartbeat_timeout_s / 3.0))
        while not self._closed:
            time.sleep(period)
            self._sync_membership()
            now = self.clock()
            for conn in list(self._conns.values()):
                if not conn.alive:
                    continue
                if now - conn.last_activity > self.heartbeat_timeout_s:
                    # silent too long: pings went unanswered — the worker
                    # is hung or the wire is black-holed; declare it dead
                    conn.die(f"heartbeat timeout "
                             f"({self.heartbeat_timeout_s}s silent)")
                    continue
                conn.ping()


def connect_evaluator(base, addresses: Sequence[Tuple[str, int]], **kwargs):
    """Convenience: a ShardedEvaluator fanned over remote workers, one
    shard lane per address (``workers=len(addresses)``) unless told
    otherwise."""
    from repro.distributed.sharded import ShardedEvaluator
    kwargs.setdefault("workers", len(tuple(addresses)))
    return ShardedEvaluator(base, mode="socket",
                            addresses=list(addresses), **kwargs)
