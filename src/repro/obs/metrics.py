"""Typed metrics instruments behind a process-wide registry.

Three instrument kinds, all label-aware and all locked per instrument:

- :class:`Counter` — monotonically increasing floats.
- :class:`Gauge` — last-write-wins floats.
- :class:`Histogram` — count/sum/min/max plus a bounded reservoir
  (``deque(maxlen=...)``) from which exact p50/p95/p99 are computed.

A *label set* turns one instrument into a family of series: an
instrument declared with ``labelnames=("tier",)`` keeps an independent
series per observed ``tier=...`` value.  :class:`CounterView` wraps a
single-label counter in a read-only ``Mapping`` so legacy call sites
that did ``svc.degraded["narrow"]`` or ``dict(svc.tier_served)`` keep
working bit-for-bit after the registry migration.

Timing everywhere in this package goes through an injectable ``Clock``
(any zero-arg callable returning float seconds); :class:`ManualClock`
makes span timing and latency histograms deterministic under test.
"""

from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import (
    Callable,
    Deque,
    Dict,
    Iterator,
    List,
    Mapping,
    Optional,
    Tuple,
)

import numpy as np

# A clock is any zero-arg callable returning seconds as float.
Clock = Callable[[], float]

MONOTONIC: Clock = time.monotonic

DEFAULT_RESERVOIR = 4096


class ManualClock:
    """Deterministic clock for tests: starts at ``start``, moves only
    when :meth:`advance` is called."""

    def __init__(self, start: float = 0.0) -> None:
        self._t = float(start)

    def __call__(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        self._t += float(dt)
        return self._t


class _Instrument:
    """Base: name, label schema, and the per-instrument write lock."""

    kind = "instrument"

    def __init__(self, name: str, description: str = "", labelnames: Tuple[str, ...] = ()) -> None:
        self.name = str(name)
        self.description = str(description)
        self.labelnames = tuple(labelnames)
        self._lock = threading.Lock()

    def _key(self, labels: Mapping[str, object]) -> Tuple[str, ...]:
        if len(labels) != len(self.labelnames) or any(n not in labels for n in self.labelnames):
            raise ValueError(
                f"instrument {self.name!r} takes labels {self.labelnames}, got {tuple(labels)}"
            )
        return tuple(str(labels[n]) for n in self.labelnames)

    def _label_dict(self, key: Tuple[str, ...]) -> Dict[str, str]:
        return dict(zip(self.labelnames, key))


class Counter(_Instrument):
    """Monotonic counter.  ``inc()`` rejects negative deltas."""

    kind = "counter"

    def __init__(self, name: str, description: str = "", labelnames: Tuple[str, ...] = ()) -> None:
        super().__init__(name, description, labelnames)
        self._values: Dict[Tuple[str, ...], float] = {}

    def touch(self, **labels: object) -> None:
        """Ensure a series exists at 0 (so views expose stable key sets)."""
        key = self._key(labels)
        with self._lock:
            self._values.setdefault(key, 0.0)

    def inc(self, amount: float = 1, **labels: object) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease (amount={amount})")
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels: object) -> float:
        key = self._key(labels)
        with self._lock:
            return self._values.get(key, 0.0)

    def series(self) -> Dict[Tuple[str, ...], float]:
        with self._lock:
            return dict(self._values)

    def total(self) -> float:
        with self._lock:
            return sum(self._values.values())


class Gauge(_Instrument):
    """Last-write-wins value (queue depths, rates, fleet sizes)."""

    kind = "gauge"

    def __init__(self, name: str, description: str = "", labelnames: Tuple[str, ...] = ()) -> None:
        super().__init__(name, description, labelnames)
        self._values: Dict[Tuple[str, ...], float] = {}

    def set(self, value: float, **labels: object) -> None:
        key = self._key(labels)
        with self._lock:
            self._values[key] = float(value)

    def value(self, default: float = 0.0, **labels: object) -> float:
        key = self._key(labels)
        with self._lock:
            return self._values.get(key, default)

    def series(self) -> Dict[Tuple[str, ...], float]:
        with self._lock:
            return dict(self._values)


class _HistSeries:
    __slots__ = ("count", "total", "vmin", "vmax", "reservoir")

    def __init__(self, maxlen: int) -> None:
        self.count = 0
        self.total = 0.0
        self.vmin: Optional[float] = None
        self.vmax: Optional[float] = None
        self.reservoir: Deque[float] = deque(maxlen=maxlen)


class Histogram(_Instrument):
    """Exact-stats histogram over a bounded reservoir.

    Keeps exact ``count``/``sum``/``min``/``max`` for the full stream
    and a ``deque(maxlen=reservoir)`` of recent samples from which
    percentiles are computed (exact while the stream fits, sliding
    window after) — the same semantics the old ad-hoc
    ``Deque[float]`` tier-latency buffers had.
    """

    kind = "histogram"

    def __init__(
        self,
        name: str,
        description: str = "",
        labelnames: Tuple[str, ...] = (),
        reservoir: int = DEFAULT_RESERVOIR,
    ) -> None:
        super().__init__(name, description, labelnames)
        self.reservoir_size = int(reservoir)
        self._series: Dict[Tuple[str, ...], _HistSeries] = {}

    def touch(self, **labels: object) -> None:
        key = self._key(labels)
        with self._lock:
            self._series.setdefault(key, _HistSeries(self.reservoir_size))

    def observe(self, value: float, **labels: object) -> None:
        v = float(value)
        key = self._key(labels)
        with self._lock:
            s = self._series.get(key)
            if s is None:
                s = self._series[key] = _HistSeries(self.reservoir_size)
            s.count += 1
            s.total += v
            s.vmin = v if s.vmin is None else min(s.vmin, v)
            s.vmax = v if s.vmax is None else max(s.vmax, v)
            s.reservoir.append(v)

    def count(self, **labels: object) -> int:
        key = self._key(labels)
        with self._lock:
            s = self._series.get(key)
            return s.count if s is not None else 0

    def percentile(self, q: float, **labels: object) -> Optional[float]:
        """Exact percentile over the reservoir; None when empty."""
        key = self._key(labels)
        with self._lock:
            s = self._series.get(key)
            samples = list(s.reservoir) if s is not None else []
        if not samples:
            return None
        return float(np.percentile(samples, q))

    def stats(self, **labels: object) -> Dict[str, Optional[float]]:
        key = self._key(labels)
        with self._lock:
            s = self._series.get(key)
            if s is None or s.count == 0:
                return {
                    "count": 0,
                    "sum": 0.0,
                    "min": None,
                    "max": None,
                    "p50": None,
                    "p95": None,
                    "p99": None,
                }
            samples = list(s.reservoir)
            count, total, vmin, vmax = s.count, s.total, s.vmin, s.vmax
        p50, p95, p99 = (float(np.percentile(samples, q)) for q in (50, 95, 99))
        return {
            "count": count,
            "sum": total,
            "min": vmin,
            "max": vmax,
            "p50": p50,
            "p95": p95,
            "p99": p99,
        }

    def series_keys(self) -> List[Tuple[str, ...]]:
        with self._lock:
            return list(self._series)


class CounterView(Mapping):
    """Read-only ``Mapping`` facade over a single-label :class:`Counter`.

    Back-compat for the pre-registry telemetry dicts: supports
    ``view["narrow"]``, ``dict(view)``, ``sum(view.values())`` with the
    label values as keys.  Counts surface as ``int`` (the old dicts
    held ints).
    """

    def __init__(self, counter: Counter) -> None:
        if len(counter.labelnames) != 1:
            raise ValueError(
                f"CounterView needs a single-label counter, {counter.name!r} has {counter.labelnames}"
            )
        self._counter = counter

    def __getitem__(self, key: str) -> int:
        series = self._counter.series()
        k = (str(key),)
        if k not in series:
            raise KeyError(key)
        return int(series[k])

    def __iter__(self) -> Iterator[str]:
        return iter(k[0] for k in self._counter.series())

    def __len__(self) -> int:
        return len(self._counter.series())

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"CounterView({dict(self)!r})"


class MetricsRegistry:
    """Get-or-create home for instruments, with snapshot/flat exports.

    Each major component (``EvalService``, ``ShardedEvaluator``+pool,
    ``Gateway``, ``SweepEngine``, ``CampaignRunner``, ``WorkerServer``)
    owns a registry; the ``Gateway`` merges component snapshots into
    one fleet view.  Re-registering a name with a different kind or
    label schema is an error — same kind/schema returns the existing
    instrument.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._instruments: Dict[str, _Instrument] = {}

    def _get_or_create(self, cls, name: str, description: str, labelnames: Tuple[str, ...], **kw):
        labelnames = tuple(labelnames)
        with self._lock:
            inst = self._instruments.get(name)
            if inst is not None:
                if not isinstance(inst, cls) or inst.labelnames != labelnames:
                    raise ValueError(
                        f"instrument {name!r} already registered as {inst.kind} with "
                        f"labels {inst.labelnames}, requested {cls.kind} with {labelnames}"
                    )
                return inst
            inst = cls(name, description, labelnames, **kw)
            self._instruments[name] = inst
            return inst

    def counter(self, name: str, description: str = "", labelnames: Tuple[str, ...] = ()) -> Counter:
        return self._get_or_create(Counter, name, description, labelnames)

    def gauge(self, name: str, description: str = "", labelnames: Tuple[str, ...] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, description, labelnames)

    def histogram(
        self,
        name: str,
        description: str = "",
        labelnames: Tuple[str, ...] = (),
        reservoir: int = DEFAULT_RESERVOIR,
    ) -> Histogram:
        return self._get_or_create(Histogram, name, description, labelnames, reservoir=reservoir)

    def get(self, name: str) -> Optional[_Instrument]:
        with self._lock:
            return self._instruments.get(name)

    def names(self) -> List[str]:
        with self._lock:
            return list(self._instruments)

    def snapshot(self) -> Dict[str, Dict]:
        """Structured dump: ``{name: {type, description, labels, series}}``."""
        with self._lock:
            instruments = list(self._instruments.values())
        out: Dict[str, Dict] = {}
        for inst in instruments:
            entry: Dict[str, object] = {
                "type": inst.kind,
                "description": inst.description,
                "labels": list(inst.labelnames),
            }
            if isinstance(inst, (Counter, Gauge)):
                entry["series"] = [
                    {"labels": inst._label_dict(k), "value": v}
                    for k, v in sorted(inst.series().items())
                ]
            elif isinstance(inst, Histogram):
                entry["series"] = [
                    {"labels": inst._label_dict(k), **inst.stats(**inst._label_dict(k))}
                    for k in sorted(inst.series_keys())
                ]
            out[inst.name] = entry
        return out

    def flat(self) -> Dict[str, float]:
        """Flat ``{series_name: value}`` map (histograms expand to
        ``name_count``/``name_sum``/``name_p50``/...)."""
        out: Dict[str, float] = {}
        for name, entry in self.snapshot().items():
            for s in entry["series"]:
                labels = s["labels"]
                suffix = (
                    "{" + ",".join(f"{k}={v}" for k, v in sorted(labels.items())) + "}"
                    if labels
                    else ""
                )
                if entry["type"] in ("counter", "gauge"):
                    out[f"{name}{suffix}"] = float(s["value"])
                else:
                    for stat in ("count", "sum", "min", "max", "p50", "p95", "p99"):
                        v = s[stat]
                        if v is not None:
                            out[f"{name}_{stat}{suffix}"] = float(v)
        return out

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    def csv_lines(self) -> List[str]:
        """Flat snapshot as ``metric,value`` CSV lines (header first)."""
        lines = ["metric,value"]
        for key, value in sorted(self.flat().items()):
            lines.append(f"{key},{value:.9g}")
        return lines
