"""Causal tracing: spans, per-thread context, cross-machine adoption.

Model (deliberately small — this rides the service tick hot path):

- A :class:`Span` is one timed operation with a ``trace_id`` shared by
  the whole causal tree and a ``parent_id`` linking it to its cause.
- A :class:`Tracer` keeps a *per-thread* stack of open spans so nested
  calls on one thread parent automatically, plus one bounded buffer of
  finished spans.  Cross-thread / cross-machine causality is explicit:
  pass ``parent=(trace_id, span_id)`` (the tuple the ``Dispatch`` wire
  frame carries as ``trace_ctx``) and the remote side's spans re-parent
  under the client span; :meth:`Tracer.adopt` merges their dicts back.
- Spans end with ``status`` ``"ok"``, ``"error"`` (the attempt failed
  and was observed failing), or ``"lost"`` (orphaned — shard timeout,
  abandoned straggler twin, worker SIGKILL / connection death).

``NOOP`` (a :class:`NoopTracer`) is the default everywhere; every
method is a constant-time no-op so instrumentation left in place costs
effectively nothing when tracing is off.
"""

from __future__ import annotations

import itertools
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, Iterable, List, Optional, Tuple, Union

from repro.obs.metrics import Clock, MONOTONIC

SPAN_STATUSES = ("ok", "error", "lost")

#: (trace_id, span_id) — the wire-portable causal context.
TraceContext = Tuple[str, str]

DEFAULT_MAX_SPANS = 65536


@dataclass
class Span:
    name: str
    trace_id: str
    span_id: str
    parent_id: Optional[str]
    proc: str
    thread: str
    t_start: float
    t_end: Optional[float] = None
    status: str = "ok"
    attrs: Dict[str, object] = field(default_factory=dict)

    @property
    def duration_s(self) -> Optional[float]:
        if self.t_end is None:
            return None
        return self.t_end - self.t_start

    @property
    def ctx(self) -> TraceContext:
        return (self.trace_id, self.span_id)

    def as_dict(self) -> Dict[str, object]:
        return {
            "name": self.name,
            "trace_id": self.trace_id,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "proc": self.proc,
            "thread": self.thread,
            "t_start": self.t_start,
            "t_end": self.t_end,
            "status": self.status,
            "attrs": dict(self.attrs),
        }

    @staticmethod
    def from_dict(d: Dict[str, object]) -> "Span":
        return Span(
            name=str(d["name"]),
            trace_id=str(d["trace_id"]),
            span_id=str(d["span_id"]),
            parent_id=(None if d.get("parent_id") is None else str(d["parent_id"])),
            proc=str(d.get("proc", "?")),
            thread=str(d.get("thread", "?")),
            t_start=float(d["t_start"]),
            t_end=(None if d.get("t_end") is None else float(d["t_end"])),
            status=str(d.get("status", "ok")),
            attrs=dict(d.get("attrs", {}) or {}),
        )


class _SpanHandle:
    """Context manager returned by ``Tracer.span(...)``."""

    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", span: Span) -> None:
        self._tracer = tracer
        self._span = span

    def __enter__(self) -> Span:
        return self._span

    def __exit__(self, exc_type, exc, tb) -> bool:
        self._tracer.finish(self._span, status="error" if exc_type is not None else None)
        if exc_type is not None:
            self._span.attrs.setdefault("error", f"{exc_type.__name__}: {exc}")
        return False


class _Activation:
    """Context manager that makes a detached span *current* on this
    thread for the duration of the block, without finishing it."""

    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", span: Span) -> None:
        self._tracer = tracer
        self._span = span

    def __enter__(self) -> Span:
        self._tracer._stack().append(self._span)
        return self._span

    def __exit__(self, exc_type, exc, tb) -> bool:
        stack = self._tracer._stack()
        if stack and stack[-1] is self._span:
            stack.pop()
        return False


_PARENT_INHERIT = "inherit"


class Tracer:
    """Span factory with per-thread open-span stacks.

    ``proc`` names the process for span-id minting and the Perfetto
    process lane (e.g. ``"client"`` or ``"worker:127.0.0.1:9001"``).
    Finished spans land in one bounded deque (oldest dropped first);
    ``drain()`` empties it, ``spans()`` copies it.
    """

    enabled = True

    def __init__(
        self,
        *,
        clock: Clock = MONOTONIC,
        proc: str = "main",
        max_spans: int = DEFAULT_MAX_SPANS,
    ) -> None:
        self.clock = clock
        self.proc = str(proc)
        self._local = threading.local()
        self._lock = threading.Lock()
        self._finished: Deque[Span] = deque(maxlen=int(max_spans))
        self._ids = itertools.count(1)

    # -- thread-local stack ------------------------------------------------

    def _stack(self) -> List[Span]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def current(self) -> Optional[Span]:
        stack = getattr(self._local, "stack", None)
        return stack[-1] if stack else None

    def current_ctx(self) -> Optional[TraceContext]:
        cur = self.current()
        return cur.ctx if cur is not None else None

    # -- span lifecycle ----------------------------------------------------

    def _new_id(self) -> str:
        return f"{self.proc}/{next(self._ids)}"

    def start(
        self,
        name: str,
        *,
        parent: Union[str, None, TraceContext, Span] = _PARENT_INHERIT,
        detached: bool = False,
        **attrs: object,
    ) -> Span:
        """Open a span.

        ``parent`` is the current thread's open span by default; pass an
        explicit ``(trace_id, span_id)`` tuple (e.g. a wire ``trace_ctx``)
        or a ``Span``, or ``None`` to force a new root.  ``detached=True``
        keeps the span off the thread-local stack — required when the
        span will be finished from another thread or out of order
        (shard fan-out, wire futures).
        """
        if isinstance(parent, Span):
            parent = parent.ctx
        if parent == _PARENT_INHERIT:
            parent = self.current_ctx()
        span_id = self._new_id()
        if parent is None:
            trace_id, parent_id = span_id, None
        else:
            trace_id, parent_id = str(parent[0]), str(parent[1])
        span = Span(
            name=name,
            trace_id=trace_id,
            span_id=span_id,
            parent_id=parent_id,
            proc=self.proc,
            thread=threading.current_thread().name,
            t_start=self.clock(),
            attrs=dict(attrs) if attrs else {},
        )
        if not detached:
            self._stack().append(span)
        return span

    def finish(self, span: Span, status: Optional[str] = None) -> None:
        if span.t_end is not None:
            return
        span.t_end = self.clock()
        if status is not None:
            span.status = status
        stack = getattr(self._local, "stack", None)
        if stack and span in stack:
            stack.remove(span)
        with self._lock:
            self._finished.append(span)

    def lose(self, span: Span, reason: str = "") -> None:
        """Close an orphaned span with ``status="lost"``."""
        if reason:
            span.attrs.setdefault("lost_reason", reason)
        self.finish(span, status="lost")

    def span(self, name: str, *, parent=_PARENT_INHERIT, **attrs: object) -> _SpanHandle:
        return _SpanHandle(self, self.start(name, parent=parent, **attrs))

    def activate(self, span: Span) -> _Activation:
        return _Activation(self, span)

    # -- cross-machine -----------------------------------------------------

    def adopt(self, span_dicts: Iterable[Dict[str, object]]) -> int:
        """Merge spans serialized by a remote tracer into this buffer."""
        n = 0
        adopted = [Span.from_dict(d) for d in span_dicts or ()]
        with self._lock:
            for s in adopted:
                self._finished.append(s)
                n += 1
        return n

    # -- buffer access -----------------------------------------------------

    def spans(self) -> List[Span]:
        with self._lock:
            return list(self._finished)

    def drain(self) -> List[Span]:
        with self._lock:
            out = list(self._finished)
            self._finished.clear()
        return out


class _NoopSpanHandle:
    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return _NOOP_SPAN

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


class _NoopSpan:
    """Inert span stand-in; mutating it is harmless and unrecorded."""

    __slots__ = ("attrs",)
    name = trace_id = span_id = proc = thread = ""
    parent_id = None
    t_start = 0.0
    t_end: Optional[float] = None
    status = "ok"

    def __init__(self) -> None:
        self.attrs: Dict[str, object] = {}

    @property
    def ctx(self) -> TraceContext:
        return ("", "")

    def as_dict(self) -> Dict[str, object]:
        return {}


_NOOP_SPAN = _NoopSpan()
_NOOP_HANDLE = _NoopSpanHandle()


class NoopTracer:
    """Disabled tracer: every operation is a constant-time no-op."""

    enabled = False
    proc = "noop"

    def current(self) -> None:
        return None

    def current_ctx(self) -> None:
        return None

    def start(self, name: str, **kw: object) -> _NoopSpan:
        return _NOOP_SPAN

    def finish(self, span: object, status: Optional[str] = None) -> None:
        pass

    def lose(self, span: object, reason: str = "") -> None:
        pass

    def span(self, name: str, **kw: object) -> _NoopSpanHandle:
        return _NOOP_HANDLE

    def activate(self, span: object) -> _NoopSpanHandle:
        return _NOOP_HANDLE

    def adopt(self, span_dicts: Iterable[Dict[str, object]]) -> int:
        return 0

    def spans(self) -> List[Span]:
        return []

    def drain(self) -> List[Span]:
        return []


NOOP = NoopTracer()
