"""repro.obs — unified metrics registry + cross-machine causal tracing.

The observability layer for the whole dispatch path:

- :mod:`repro.obs.metrics` — typed ``Counter``/``Gauge``/``Histogram``
  instruments behind a process-wide :class:`MetricsRegistry`.  Every
  ad-hoc telemetry dict in ``distributed/`` and ``serve/`` is a view
  over these instruments; writes take a lock **per instrument** so the
  ``unlocked-shared-write`` lint rule passes by construction.
- :mod:`repro.obs.trace` — ``Span``/``Tracer`` with per-thread buffers
  and context propagation across the socket boundary (the ``Dispatch``
  wire frame carries a ``trace_ctx``; worker-side spans re-parent under
  the client span).  ``NOOP`` is the always-on-cheap default.
- :mod:`repro.obs.export` — Perfetto/Chrome ``trace_event`` JSON, flat
  metrics JSON/CSV snapshots, span-tree validation and ASCII rendering.
- :mod:`repro.obs.report` — ``python -m repro.obs.report`` fleet
  dashboard from a live ``Gateway`` or a saved snapshot.

This package is a leaf: it imports nothing from the rest of ``repro``.
"""

from repro.obs.metrics import (
    Clock,
    Counter,
    CounterView,
    Gauge,
    Histogram,
    ManualClock,
    MetricsRegistry,
)
from repro.obs.trace import NOOP, NoopTracer, Span, Tracer

from repro.obs.export import (
    completeness_errors,
    metrics_csv_lines,
    render_tree,
    trace_events,
    validate_trace_events,
    write_trace,
)

__all__ = [
    "Clock",
    "Counter",
    "CounterView",
    "Gauge",
    "Histogram",
    "ManualClock",
    "MetricsRegistry",
    "NOOP",
    "NoopTracer",
    "Span",
    "Tracer",
    "completeness_errors",
    "metrics_csv_lines",
    "render_tree",
    "trace_events",
    "validate_trace_events",
    "write_trace",
]
