"""Exporters: Perfetto/Chrome trace JSON, span-tree checks, CSV metrics.

The trace format is the Chrome ``trace_event`` JSON (object form with a
``traceEvents`` list), loadable by Perfetto / ``chrome://tracing``:
one ``"X"`` (complete) event per finished span with microsecond
``ts``/``dur``, plus ``"M"`` metadata events naming each process lane.
Span identity/causality ride in ``args`` (``trace_id``/``span_id``/
``parent_id``/``status`` + user attrs).
"""

from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

from repro.obs.trace import SPAN_STATUSES, Span

SpanLike = Union[Span, Dict[str, object]]

TRACE_SCHEMA_VERSION = 1


def _as_spans(spans: Iterable[SpanLike]) -> List[Span]:
    out: List[Span] = []
    for s in spans:
        out.append(s if isinstance(s, Span) else Span.from_dict(s))
    return out


def trace_events(spans: Iterable[SpanLike]) -> Dict[str, object]:
    """Render spans as a Chrome/Perfetto ``trace_event`` JSON object."""
    sp = _as_spans(spans)
    procs = sorted({s.proc for s in sp})
    pid_of = {p: i + 1 for i, p in enumerate(procs)}
    tids: Dict[Tuple[str, str], int] = {}
    events: List[Dict[str, object]] = []
    for p in procs:
        events.append(
            {"ph": "M", "name": "process_name", "pid": pid_of[p], "tid": 0, "args": {"name": p}}
        )
    for s in sp:
        key = (s.proc, s.thread)
        if key not in tids:
            tids[key] = len([k for k in tids if k[0] == s.proc]) + 1
            events.append(
                {
                    "ph": "M",
                    "name": "thread_name",
                    "pid": pid_of[s.proc],
                    "tid": tids[key],
                    "args": {"name": s.thread},
                }
            )
        t_end = s.t_end if s.t_end is not None else s.t_start
        args: Dict[str, object] = {
            "trace_id": s.trace_id,
            "span_id": s.span_id,
            "parent_id": s.parent_id,
            "status": s.status,
        }
        args.update(s.attrs)
        events.append(
            {
                "ph": "X",
                "name": s.name,
                "cat": "repro",
                "ts": s.t_start * 1e6,
                "dur": max(0.0, (t_end - s.t_start) * 1e6),
                "pid": pid_of[s.proc],
                "tid": tids[key],
                "args": args,
            }
        )
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"exporter": "repro.obs", "schema_version": TRACE_SCHEMA_VERSION},
    }


def write_trace(path: str, spans: Iterable[SpanLike]) -> str:
    obj = trace_events(spans)
    with open(path, "w") as fh:
        json.dump(obj, fh, indent=1, default=str)
    return path


def validate_trace_events(obj: object) -> List[str]:
    """Schema-check an exported trace object; returns a list of problems
    (empty = valid)."""
    errors: List[str] = []
    if not isinstance(obj, dict):
        return ["top level is not an object"]
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    for i, ev in enumerate(events):
        where = f"event[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "M"):
            errors.append(f"{where}: unknown ph {ph!r}")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            errors.append(f"{where}: missing name")
        if not isinstance(ev.get("pid"), int) or not isinstance(ev.get("tid"), int):
            errors.append(f"{where}: pid/tid must be ints")
        if ph == "X":
            if not isinstance(ev.get("ts"), (int, float)):
                errors.append(f"{where}: X event missing numeric ts")
            if not isinstance(ev.get("dur"), (int, float)) or ev.get("dur", -1) < 0:
                errors.append(f"{where}: X event needs dur >= 0")
            args = ev.get("args")
            if not isinstance(args, dict) or "span_id" not in args or "trace_id" not in args:
                errors.append(f"{where}: args must carry span_id/trace_id")
            elif args.get("status") not in SPAN_STATUSES:
                errors.append(f"{where}: bad status {args.get('status')!r}")
    return errors


# -- span-tree structure ---------------------------------------------------


def build_tree(spans: Iterable[SpanLike]) -> Tuple[List[Span], Dict[str, List[Span]]]:
    """Return (roots, children-by-parent-span-id), children time-sorted."""
    sp = _as_spans(spans)
    children: Dict[str, List[Span]] = {}
    ids = {s.span_id for s in sp}
    roots: List[Span] = []
    for s in sp:
        if s.parent_id is None or s.parent_id not in ids:
            roots.append(s)
        else:
            children.setdefault(s.parent_id, []).append(s)
    for lst in children.values():
        lst.sort(key=lambda s: (s.t_start, s.span_id))
    roots.sort(key=lambda s: (s.t_start, s.span_id))
    return roots, children


def completeness_errors(
    spans: Iterable[SpanLike], trace_id: Optional[str] = None
) -> List[str]:
    """Structural checks for a causal tree: one root per trace, no
    dangling parents, no open (unfinished) spans, statuses legal."""
    sp = _as_spans(spans)
    if trace_id is not None:
        sp = [s for s in sp if s.trace_id == trace_id]
    errors: List[str] = []
    if not sp:
        return ["no spans"]
    ids = {s.span_id for s in sp}
    by_trace: Dict[str, List[Span]] = {}
    for s in sp:
        by_trace.setdefault(s.trace_id, []).append(s)
        if s.parent_id is not None and s.parent_id not in ids:
            errors.append(f"span {s.span_id} ({s.name}) has dangling parent {s.parent_id}")
        if s.t_end is None:
            errors.append(f"span {s.span_id} ({s.name}) never finished")
        if s.status not in SPAN_STATUSES:
            errors.append(f"span {s.span_id} ({s.name}) has bad status {s.status!r}")
    for tid, members in sorted(by_trace.items()):
        roots = [s for s in members if s.parent_id is None]
        if len(roots) != 1:
            errors.append(
                f"trace {tid} has {len(roots)} roots ({[s.name for s in roots]}), expected 1"
            )
    return errors


def render_tree(spans: Iterable[SpanLike], trace_id: Optional[str] = None) -> str:
    """ASCII causal tree with durations, statuses, and process identity."""
    sp = _as_spans(spans)
    if trace_id is not None:
        sp = [s for s in sp if s.trace_id == trace_id]
    roots, children = build_tree(sp)
    lines: List[str] = []

    def _fmt(s: Span) -> str:
        dur = s.duration_s
        dur_txt = f"{dur * 1e3:8.3f}ms" if dur is not None else "    open"
        mark = {"ok": " ", "error": "!", "lost": "?"}.get(s.status, "?")
        attrs = ""
        if s.attrs:
            parts = [f"{k}={v}" for k, v in sorted(s.attrs.items())]
            attrs = "  [" + " ".join(parts) + "]"
        return f"{mark} {s.name}  {dur_txt}  ({s.proc}/{s.thread}) {s.status}{attrs}"

    def _walk(s: Span, prefix: str, is_last: bool) -> None:
        connector = "`-- " if is_last else "|-- "
        lines.append(prefix + connector + _fmt(s))
        kids = children.get(s.span_id, [])
        child_prefix = prefix + ("    " if is_last else "|   ")
        for i, kid in enumerate(kids):
            _walk(kid, child_prefix, i == len(kids) - 1)

    for root in roots:
        lines.append(_fmt(root))
        kids = children.get(root.span_id, [])
        for i, kid in enumerate(kids):
            _walk(kid, "", i == len(kids) - 1)
    return "\n".join(lines)


# -- metrics ---------------------------------------------------------------


def metrics_csv_lines(flat: Dict[str, float]) -> List[str]:
    """Flat metrics map -> ``metric,value`` CSV lines (header first)."""
    lines = ["metric,value"]
    for key, value in sorted(flat.items()):
        lines.append(f"{key},{value:.9g}")
    return lines


def write_metrics_json(path: str, snapshot: Dict[str, object]) -> str:
    with open(path, "w") as fh:
        json.dump(snapshot, fh, indent=2, sort_keys=True, default=str)
    return path
