"""Fleet dashboard: render a Gateway observability snapshot as text.

Usage::

    python -m repro.obs.report snapshot.json          # saved snapshot
    report.fleet_report(gateway)                      # live Gateway

The snapshot shape is what ``Gateway.snapshot()`` produces::

    {"telemetry": <Gateway.telemetry()>,
     "metrics": {"gateway": ..., "service": ..., "evaluator": ...}}

Sections: per-tier queue-latency percentiles, per-tenant admission,
per-worker heartbeat RTT + shard timings, degradation-rung hit rates,
and raw traffic counters.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional


def _table(headers: List[str], rows: List[List[object]]) -> List[str]:
    cells = [[str(c) for c in row] for row in rows]
    widths = [len(h) for h in headers]
    for row in cells:
        for i, c in enumerate(row):
            widths[i] = max(widths[i], len(c))
    out = ["  ".join(h.ljust(w) for h, w in zip(headers, widths)).rstrip()]
    out.append("  ".join("-" * w for w in widths))
    for row in cells:
        out.append("  ".join(c.ljust(w) for c, w in zip(row, widths)).rstrip())
    return out


def _fmt(v: object) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.3f}"
    return str(v)


def _hist_series(metrics: Dict, registry: str, name: str) -> List[Dict]:
    entry = (metrics or {}).get(registry, {}).get(name)
    if not entry:
        return []
    return entry.get("series", [])


def fleet_report(source) -> str:
    """Render the dashboard.  ``source`` is a snapshot dict or any
    object with a ``snapshot()`` method (a live ``Gateway``)."""
    snap = source if isinstance(source, dict) else source.snapshot()
    tel = snap.get("telemetry", snap)
    metrics = snap.get("metrics", {})
    svc = tel.get("service", {})
    lines: List[str] = ["== repro.obs fleet report =="]

    # -- traffic ----------------------------------------------------------
    lines.append("")
    lines.append("-- traffic --")
    lines += _table(
        ["submits", "cache_hits", "fused", "coalesced", "admitted", "rejected"],
        [
            [
                _fmt(svc.get("submits")),
                _fmt(svc.get("cache_hits")),
                _fmt(svc.get("fused_dispatches")),
                _fmt(svc.get("coalesced_requests")),
                _fmt(tel.get("admission", {}).get("admitted")),
                _fmt(tel.get("admission", {}).get("rejected")),
            ]
        ],
    )

    # -- tiers ------------------------------------------------------------
    tiers = svc.get("tiers", {})
    if tiers:
        lines.append("")
        lines.append("-- qos tiers (queue latency) --")
        rows = [
            [t, d.get("weight"), d.get("served"), d.get("queued"), _fmt(d.get("p50_ms")), _fmt(d.get("p99_ms"))]
            for t, d in sorted(tiers.items())
        ]
        lines += _table(["tier", "weight", "served", "queued", "p50_ms", "p99_ms"], rows)

    # -- degradation ladder ----------------------------------------------
    degraded = svc.get("degraded", {})
    if degraded:
        submits = max(1, int(svc.get("submits") or 1))
        lines.append("")
        lines.append("-- degradation rungs --")
        rows = [
            [rung, int(n), f"{100.0 * int(n) / submits:.2f}%"]
            for rung, n in sorted(degraded.items())
        ]
        lines += _table(["rung", "hits", "rate/submit"], rows)

    # -- tenants ----------------------------------------------------------
    tenants = tel.get("tenants", {})
    if tenants:
        lines.append("")
        lines.append("-- tenants (admission) --")
        rows = [
            [
                t,
                d.get("admitted"),
                d.get("admitted_rows"),
                d.get("used_rows"),
                d.get("rows_per_window"),
                d.get("rejected_budget"),
                d.get("rejected_backpressure"),
            ]
            for t, d in sorted(tenants.items())
        ]
        lines += _table(
            ["tenant", "admitted", "rows", "used", "budget", "rej_budget", "rej_bp"], rows
        )

    # -- fleet ------------------------------------------------------------
    fleet = tel.get("fleet")
    if fleet:
        lines.append("")
        lines.append("-- fleet --")
        lines += _table(
            ["mode", "workers", "live", "known", "evictions"],
            [
                [
                    fleet.get("mode"),
                    fleet.get("workers"),
                    fleet.get("live"),
                    fleet.get("known"),
                    fleet.get("evictions"),
                ]
            ],
        )
        rtt = fleet.get("heartbeat_rtt") or {}
        if rtt:
            lines.append("")
            lines.append("-- heartbeat rtt (per worker) --")
            rows = [
                [w, d.get("count"), _fmt(d.get("p50_ms")), _fmt(d.get("p99_ms"))]
                for w, d in sorted(rtt.items())
            ]
            lines += _table(["worker", "pings", "p50_ms", "p99_ms"], rows)

    # -- per-worker shard timings ----------------------------------------
    shard = _hist_series(metrics, "evaluator", "sharded_shard_s")
    if shard:
        lines.append("")
        lines.append("-- shard timings (per worker slot) --")
        rows = []
        for s in shard:
            slot = s.get("labels", {}).get("slot", "?")
            p50 = s.get("p50")
            p99 = s.get("p99")
            rows.append(
                [
                    slot,
                    s.get("count"),
                    _fmt(None if p50 is None else p50 * 1e3),
                    _fmt(None if p99 is None else p99 * 1e3),
                ]
            )
        lines += _table(["slot", "shards", "p50_ms", "p99_ms"], rows)

    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.obs.report", description="Render a fleet dashboard"
    )
    parser.add_argument("snapshot", help="path to a Gateway.save_snapshot() JSON file")
    args = parser.parse_args(argv)
    with open(args.snapshot) as fh:
        snap = json.load(fh)
    print(fleet_report(snap))
    return 0


if __name__ == "__main__":
    sys.exit(main())
