"""Llama-3.2-1B — small llama3, GQA kv=8, tied embeddings.
[hf:meta-llama/Llama-3.2-1B]"""
from repro.configs.base import ArchConfig, FULL_ATTENTION_SKIP

CONFIG = ArchConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab=128256,
    gated_mlp=True,
    tie_embeddings=True,
    rope_theta=5e5,
    skip_shapes=FULL_ATTENTION_SKIP,
)
