"""Architecture config schema + shape suite (the assigned 10x4 grid)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | hybrid | vlm | audio | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    qkv_bias: bool = False
    gated_mlp: bool = True
    tie_embeddings: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    expert_ff: int = 0
    dense_residual: bool = False   # Arctic: dense FFN residual alongside MoE
    # zero-traffic padding experts so the expert dim divides the TP axis
    # (perf iteration, EXPERIMENTS.md §Perf: EP beats intra-expert TP for
    # the dispatch collectives; the router never selects a padding expert)
    expert_pad: int = 0

    # hybrid (Jamba): one attention layer per `attn_every`; MoE every 2nd layer
    attn_every: int = 0
    d_state: int = 16
    d_conv: int = 4

    # encoder-decoder (Whisper): encoder depth + fixed encoder context
    enc_layers: int = 0
    enc_ctx: int = 0

    # modality frontend (STUB per assignment): input is precomputed embeddings
    frontend: str = "none"      # none | patch | conv

    # RWKV6
    rwkv_head_size: int = 64

    # which shapes this arch supports (see DESIGN.md §Shape-applicability)
    skip_shapes: Tuple[str, ...] = ()

    # ------------------------------------------------------------------
    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 2 if not self.attn_every else self.attn_every),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab=256,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            expert_ff=64 if self.expert_ff else 0,
            enc_layers=min(self.enc_layers, 2),
            enc_ctx=min(self.enc_ctx, 16) if self.enc_ctx else 0,
            d_state=min(self.d_state, 8),
            rwkv_head_size=16,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                   # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# full-attention archs skip long_500k (quadratic-history decode; see DESIGN.md)
FULL_ATTENTION_SKIP = ("long_500k",)
