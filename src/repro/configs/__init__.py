"""Assigned architecture configs (exact hyperparameters from the assignment).

Every architecture is selectable via ``--arch <id>`` in the launchers and is
simultaneously a DSE workload for the Lumina core
(``repro.perfmodel.workload.from_arch``).
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ArchConfig, ShapeConfig, SHAPES, FULL_ATTENTION_SKIP

from repro.configs.codeqwen15_7b import CONFIG as codeqwen15_7b
from repro.configs.mistral_nemo_12b import CONFIG as mistral_nemo_12b
from repro.configs.qwen25_14b import CONFIG as qwen25_14b
from repro.configs.llama32_1b import CONFIG as llama32_1b
from repro.configs.qwen2_moe_a27b import CONFIG as qwen2_moe_a27b
from repro.configs.arctic_480b import CONFIG as arctic_480b
from repro.configs.jamba15_large_398b import CONFIG as jamba15_large_398b
from repro.configs.internvl2_2b import CONFIG as internvl2_2b
from repro.configs.whisper_medium import CONFIG as whisper_medium
from repro.configs.rwkv6_7b import CONFIG as rwkv6_7b

ARCHS: Dict[str, ArchConfig] = {
    c.name: c for c in (
        codeqwen15_7b, mistral_nemo_12b, qwen25_14b, llama32_1b,
        qwen2_moe_a27b, arctic_480b, jamba15_large_398b, internvl2_2b,
        whisper_medium, rwkv6_7b,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def cells():
    """All (arch, shape) grid cells, with skip annotations."""
    out = []
    for a in ARCHS.values():
        for s in SHAPES.values():
            skip = s.name in a.skip_shapes
            out.append((a, s, skip))
    return out


__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "ARCHS", "get_arch",
           "cells", "FULL_ATTENTION_SKIP"]
