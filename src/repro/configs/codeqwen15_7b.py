"""CodeQwen1.5-7B — dense, Qwen1.5 architecture (QKV bias, MHA: kv == heads).
[hf:Qwen/CodeQwen1.5-7B]"""
from repro.configs.base import ArchConfig, FULL_ATTENTION_SKIP

CONFIG = ArchConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab=92416,
    qkv_bias=True,
    gated_mlp=True,
    rope_theta=1e6,
    skip_shapes=FULL_ATTENTION_SKIP,
)
