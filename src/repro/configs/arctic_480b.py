"""Snowflake Arctic-480B — dense-MoE hybrid: every layer has a dense FFN
residual in parallel with a 128-expert top-2 MoE. [hf:Snowflake/snowflake-arctic-base]"""
from repro.configs.base import ArchConfig, FULL_ATTENTION_SKIP

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,                  # the dense residual FFN
    vocab=32000,
    gated_mlp=True,
    n_experts=128,
    top_k=2,
    n_shared_experts=0,
    expert_ff=4864,
    dense_residual=True,
    skip_shapes=FULL_ATTENTION_SKIP,
)
