"""Mistral-Nemo-12B — dense GQA (kv=8), head_dim 128, 128k context.
[hf:mistralai/Mistral-Nemo-Base-2407]"""
from repro.configs.base import ArchConfig, FULL_ATTENTION_SKIP

CONFIG = ArchConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,               # explicit: NOT d_model // n_heads (=160)
    d_ff=14336,
    vocab=131072,
    gated_mlp=True,
    rope_theta=1e6,
    skip_shapes=FULL_ATTENTION_SKIP,
)
