"""RWKV6-7B ("Finch") — attention-free RNN with data-dependent decay.
[arXiv:2404.05892]

O(1) decode state — runs long_500k natively.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,                 # d_model / rwkv_head_size
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab=65536,
    gated_mlp=False,
    rwkv_head_size=64,
    skip_shapes=(),             # all four shapes run
)
