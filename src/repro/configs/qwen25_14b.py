"""Qwen2.5-14B — dense GQA (kv=8) with QKV bias. [hf:Qwen/Qwen2.5-14B]"""
from repro.configs.base import ArchConfig, FULL_ATTENTION_SKIP

CONFIG = ArchConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab=152064,
    qkv_bias=True,
    gated_mlp=True,
    rope_theta=1e6,
    skip_shapes=FULL_ATTENTION_SKIP,
)
