"""Qwen2-MoE-A2.7B (Qwen1.5-MoE) — 60 routed experts top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B]"""
from repro.configs.base import ArchConfig, FULL_ATTENTION_SKIP

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=5632,                  # shared-expert intermediate (4 x 1408)
    vocab=151936,
    qkv_bias=True,
    gated_mlp=True,
    n_experts=60,
    top_k=4,
    n_shared_experts=4,
    expert_ff=1408,
    expert_pad=4,               # 60 -> 64 zero-traffic experts: EP | 16-way TP

    skip_shapes=FULL_ATTENTION_SKIP,
)
