"""Whisper-medium — encoder-decoder; conv audio frontend is a STUB
(``input_specs()`` provides precomputed 1500-frame embeddings).
[arXiv:2212.04356]

seq_len maps to the DECODER side (teacher-forced for train/prefill); the
encoder context is the fixed 1500-frame conv output.  long_500k skipped
(full attention).
"""
from repro.configs.base import ArchConfig, FULL_ATTENTION_SKIP

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,                # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=51865,
    qkv_bias=True,
    gated_mlp=False,            # plain GELU MLP
    enc_layers=24,
    enc_ctx=1500,
    frontend="conv",
    skip_shapes=FULL_ATTENTION_SKIP,
)
