"""InternVL2-2B — InternViT patch frontend (STUB) + InternLM2-1.8B backbone.
[arXiv:2404.16821]

The vision tower is a stub per the assignment: ``input_specs()`` provides
precomputed patch embeddings of shape (batch, seq, d_model) prepended to the
text stream; only the LM backbone is modeled.
"""
from repro.configs.base import ArchConfig, FULL_ATTENTION_SKIP

CONFIG = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=92553,
    gated_mlp=True,
    frontend="patch",
    skip_shapes=FULL_ATTENTION_SKIP,
)
