"""Jamba-1.5-Large-398B — hybrid Mamba+attention (1:7 interleave) with
16-expert top-2 MoE every other layer. [arXiv:2403.19887]

Sub-quadratic: runs the long_500k shape (Mamba layers O(1) state; the 1-in-8
attention layers keep a seq-sharded KV cache).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=65536,
    gated_mlp=True,
    n_experts=16,
    top_k=2,
    expert_ff=24576,
    attn_every=8,               # 1 attention layer per 8 (1:7 Mamba:attn)
    d_state=16,
    d_conv=4,
    skip_shapes=(),             # all four shapes run
)
