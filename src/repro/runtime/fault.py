"""Fault-tolerance runtime: step retries, straggler detection, heartbeats.

On a real multi-host deployment the coordinator drives these through the
cluster scheduler; here the policies are host-local but the interfaces (and
tests) are the production ones:

* ``run_with_retries`` — execute a step function; on failure restore the
  last checkpoint and replay (the data pipeline is deterministic-by-step, so
  replay is bit-exact).
* ``StragglerMonitor`` — rolling per-step latency stats; flags steps slower
  than median * threshold.  At scale the flagged host is drained and the
  elastic re-mesh path (repro.runtime.elastic) kicks in.
* ``Heartbeat`` — liveness file a watchdog can poll.
"""
from __future__ import annotations

import dataclasses
import os
import time
from collections import deque
from typing import Callable, Optional


@dataclasses.dataclass
class RetryPolicy:
    max_retries: int = 3
    backoff_s: float = 0.0          # 0 in tests; seconds in production
    retryable: tuple = (RuntimeError, ValueError)


def run_with_retries(step_fn: Callable, restore_fn: Callable,
                     policy: RetryPolicy = RetryPolicy()):
    """step_fn() -> result; restore_fn(attempt) resets state before retry."""
    last = None
    for attempt in range(policy.max_retries + 1):
        try:
            return step_fn()
        except policy.retryable as e:        # noqa: PERF203
            last = e
            if attempt == policy.max_retries:
                break
            if policy.backoff_s:
                time.sleep(policy.backoff_s * (2 ** attempt))
            restore_fn(attempt)
    raise RuntimeError(
        f"step failed after {policy.max_retries} retries") from last


class StragglerMonitor:
    def __init__(self, window: int = 32, threshold: float = 2.0):
        self.window = window
        self.threshold = threshold
        self._times: deque = deque(maxlen=window)
        self.flagged: list = []

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        self._times.append(seconds)
        if len(self._times) < 8:
            return False
        med = sorted(self._times)[len(self._times) // 2]
        if seconds > med * self.threshold:
            self.flagged.append((step, seconds, med))
            return True
        return False


class Heartbeat:
    def __init__(self, path: str, interval_s: float = 10.0):
        self.path = path
        self.interval_s = interval_s
        self._last = 0.0

    def beat(self, step: int) -> None:
        now = time.time()
        if now - self._last >= self.interval_s:
            tmp = self.path + ".tmp"
            with open(tmp, "w") as f:
                f.write(f"{step} {now}\n")
            os.replace(tmp, self.path)
            self._last = now

    @staticmethod
    def is_alive(path: str, timeout_s: float) -> bool:
        try:
            with open(path) as f:
                _, ts = f.read().split()
            return time.time() - float(ts) < timeout_s
        except (OSError, ValueError):
            return False
