"""Fault-tolerance runtime: retry policies, straggler detection, heartbeats.

These are LIVE production policies, not seed stubs: the distributed
evaluation stack drives them directly —

* :class:`RetryPolicy` — retry budget + jittered exponential backoff.
  :func:`run_with_retries` executes a step function under one (the
  training-loop replay path), and :class:`~repro.distributed.sharded.
  ShardedEvaluator` uses the same policy object for its per-shard retry /
  timeout backoff, while :class:`~repro.perfmodel.sweep.SweepEngine`
  replays crashed worker spans through :func:`run_with_retries` itself.
* :class:`StragglerMonitor` — rolling per-step latency stats; flags steps
  slower than median * threshold.  At scale the flagged host is drained
  and the elastic re-plan path (:mod:`repro.runtime.elastic`) kicks in.
* :class:`Heartbeat` — liveness file a watchdog can poll across process
  boundaries.  :class:`~repro.distributed.faults.WorkerRegistry` is the
  in-process registry built on the same expiry semantics (beat / timeout /
  evict / re-register).
"""
from __future__ import annotations

import dataclasses
import os
import random
import time
from collections import deque
from typing import Callable, Optional, Tuple, Type


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Retry budget with jittered exponential backoff.

    ``delay(attempt)`` is ``backoff_s * 2^attempt`` capped at
    ``max_backoff_s``, optionally spread by ``jitter`` (a symmetric
    +/- fraction, de-synchronizing retry storms across workers).  Frozen:
    a policy is shared freely across call sites without aliasing state.
    """
    max_retries: int = 3
    backoff_s: float = 0.0          # 0 in tests; seconds in production
    max_backoff_s: float = 30.0
    jitter: float = 0.0             # +/- fraction of the delay randomized
    retryable: Tuple[Type[BaseException], ...] = (RuntimeError, ValueError)

    def delay(self, attempt: int,
              rng: Optional[random.Random] = None) -> float:
        """Backoff before retry number `attempt` (0-based), jittered."""
        base = min(self.backoff_s * (2 ** attempt), self.max_backoff_s)
        if base and self.jitter:
            u = (rng.random() if rng is not None else random.random())
            base *= 1.0 + self.jitter * (2.0 * u - 1.0)
        return max(0.0, base)


def run_with_retries(step_fn: Callable, restore_fn: Callable,
                     policy: Optional[RetryPolicy] = None):
    """step_fn() -> result; restore_fn(attempt) resets state before retry.

    ``policy=None`` builds a fresh default :class:`RetryPolicy` per call
    (the old module-level default instance was evaluated once at import
    and shared by every caller — a mutable-default footgun).
    """
    policy = RetryPolicy() if policy is None else policy
    last = None
    for attempt in range(policy.max_retries + 1):
        try:
            return step_fn()
        except policy.retryable as e:        # noqa: PERF203
            last = e
            if attempt == policy.max_retries:
                break
            d = policy.delay(attempt)
            if d:
                time.sleep(d)
            restore_fn(attempt)
    raise RuntimeError(
        f"step failed after {policy.max_retries} retries") from last


class StragglerMonitor:
    def __init__(self, window: int = 32, threshold: float = 2.0):
        self.window = window
        self.threshold = threshold
        self._times: deque = deque(maxlen=window)
        self.flagged: list = []

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        self._times.append(seconds)
        if len(self._times) < 8:
            return False
        med = sorted(self._times)[len(self._times) // 2]
        if seconds > med * self.threshold:
            self.flagged.append((step, seconds, med))
            return True
        return False


class Heartbeat:
    def __init__(self, path: str, interval_s: float = 10.0):
        self.path = path
        self.interval_s = interval_s
        self._last = 0.0

    def beat(self, step: int) -> None:
        now = time.time()
        if now - self._last >= self.interval_s:
            tmp = self.path + ".tmp"
            with open(tmp, "w") as f:
                f.write(f"{step} {now}\n")
            os.replace(tmp, self.path)
            self._last = now

    @staticmethod
    def is_alive(path: str, timeout_s: float) -> bool:
        try:
            with open(path) as f:
                _, ts = f.read().split()
            return time.time() - float(ts) < timeout_s
        except (OSError, ValueError):
            return False
