"""Elastic re-meshing: recompute the best mesh when devices are lost.

Policy: keep the `model` axis intact (TP degree is tied to weight sharding
and head counts), shrink the data axes to the largest multiple that fits the
surviving device count, then restore from the last checkpoint with the new
shardings (repro.checkpoint supports restore-time resharding).  The
deterministic-by-step data pipeline replays the remainder of the epoch with
the new DP degree by re-chunking the global batch.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass
class ElasticPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    devices_used: int
    dp_degree: int
    tp_degree: int
    note: str


def plan_elastic_mesh(available_devices: int, model_axis: int = 16,
                      prefer_pods: bool = True) -> Optional[ElasticPlan]:
    """Largest (pod, data, model) grid that fits `available_devices` with the
    model axis fixed.  Returns None if even one model group doesn't fit."""
    if available_devices < model_axis:
        return None
    groups = available_devices // model_axis        # surviving TP groups
    # prefer two balanced pods when there are enough groups and it divides
    if prefer_pods and groups >= 4 and groups % 2 == 0:
        return ElasticPlan(
            shape=(2, groups // 2, model_axis),
            axes=("pod", "data", "model"),
            devices_used=groups * model_axis,
            dp_degree=groups,
            tp_degree=model_axis,
            note=f"2 pods x {groups // 2} DP x {model_axis} TP",
        )
    return ElasticPlan(
        shape=(groups, model_axis),
        axes=("data", "model"),
        devices_used=groups * model_axis,
        dp_degree=groups,
        tp_degree=model_axis,
        note=f"single pod {groups} DP x {model_axis} TP",
    )
