"""Elastic re-planning: meshes when devices are lost, pools under load.

This module is LIVE, not a seed stub — two consumers drive it:

* :func:`plan_elastic_mesh` — recompute the best device mesh when hosts
  are lost.  Policy: keep the `model` axis intact (TP degree is tied to
  weight sharding and head counts), shrink the data axes to the largest
  multiple that fits the surviving device count, then restore from the
  last checkpoint with the new shardings (repro.checkpoint supports
  restore-time resharding).  The deterministic-by-step data pipeline
  replays the remainder of the epoch with the new DP degree.
* :func:`plan_elastic_pool` — the same policy shape adapted to evaluation
  worker pools: given the surviving worker count and the pending-shard
  backlog, pick the pool size that keeps the backlog under
  ``target_queue`` shards per worker, bounded by ``[min_workers,
  max_workers]``.  :class:`~repro.distributed.sharded.ShardedEvaluator`
  calls this after dead-worker eviction (shrink to the survivors instead
  of oversubscribing dead slots) and under sustained queue pressure
  (grow toward the cap).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass
class ElasticPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    devices_used: int
    dp_degree: int
    tp_degree: int
    note: str


def plan_elastic_mesh(available_devices: int, model_axis: int = 16,
                      prefer_pods: bool = True) -> Optional[ElasticPlan]:
    """Largest (pod, data, model) grid that fits `available_devices` with the
    model axis fixed.  Returns None if even one model group doesn't fit."""
    if available_devices < model_axis:
        return None
    groups = available_devices // model_axis        # surviving TP groups
    # prefer two balanced pods when there are enough groups and it divides
    if prefer_pods and groups >= 4 and groups % 2 == 0:
        return ElasticPlan(
            shape=(2, groups // 2, model_axis),
            axes=("pod", "data", "model"),
            devices_used=groups * model_axis,
            dp_degree=groups,
            tp_degree=model_axis,
            note=f"2 pods x {groups // 2} DP x {model_axis} TP",
        )
    return ElasticPlan(
        shape=(groups, model_axis),
        axes=("data", "model"),
        devices_used=groups * model_axis,
        dp_degree=groups,
        tp_degree=model_axis,
        note=f"single pod {groups} DP x {model_axis} TP",
    )


@dataclasses.dataclass(frozen=True)
class PoolPlan:
    """Target size for an elastic evaluation worker pool."""
    workers: int
    grow: bool                    # True when the plan adds workers
    note: str


def plan_elastic_pool(live_workers: int, queued: int, *,
                      min_workers: int = 1, max_workers: int = 16,
                      target_queue: float = 2.0) -> PoolPlan:
    """Pool analogue of :func:`plan_elastic_mesh`.

    Keep enough workers that the pending backlog stays under
    ``target_queue`` items per worker; after worker loss with no backlog
    pressure, shrink to the surviving count instead of oversubscribing
    dead slots.  The result is always clamped to
    ``[min_workers, max_workers]``.
    """
    if min_workers < 1:
        raise ValueError(f"min_workers must be >= 1, got {min_workers}")
    if max_workers < min_workers:
        raise ValueError(f"max_workers ({max_workers}) < min_workers "
                         f"({min_workers})")
    live = max(0, int(live_workers))
    queued = max(0, int(queued))
    want = math.ceil(queued / max(target_queue, 1e-9)) if queued else live
    want = min(max(want, min_workers), max_workers)
    if want > live:
        note = f"grow {live} -> {want} ({queued} queued)"
    elif want < live:
        note = f"shrink {live} -> {want} ({queued} queued)"
    else:
        note = f"hold {want} ({queued} queued)"
    return PoolPlan(workers=want, grow=want > live, note=note)


def admission_retry_after(queued_rows: int, rows_per_s: float, *,
                          floor_s: float = 0.05,
                          cap_s: float = 60.0) -> float:
    """Backpressure hint for admission control: seconds until the current
    backlog drains at the observed service rate.

    The :class:`~repro.serve.gateway.Gateway` attaches this to its
    reject-with-retry-after responses so a well-behaved client backs off
    exactly as long as the queue needs, instead of hammering a saturated
    service.  With no rate estimate yet (``rows_per_s <= 0``) the hint is
    one second — optimistic but bounded.  Always clamped to
    ``[floor_s, cap_s]``.
    """
    if cap_s < floor_s:
        raise ValueError(f"cap_s ({cap_s}) < floor_s ({floor_s})")
    queued_rows = max(0, int(queued_rows))
    eta = (queued_rows / rows_per_s) if rows_per_s > 0 else 1.0
    return float(min(max(eta, floor_s), cap_s))
