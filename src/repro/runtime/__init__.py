from repro.runtime.fault import (Heartbeat, RetryPolicy, StragglerMonitor,
                                 run_with_retries)
from repro.runtime.elastic import (ElasticPlan, PoolPlan, plan_elastic_mesh,
                                   plan_elastic_pool)

__all__ = ["RetryPolicy", "run_with_retries", "StragglerMonitor",
           "Heartbeat", "ElasticPlan", "PoolPlan", "plan_elastic_mesh",
           "plan_elastic_pool"]
