from repro.runtime.fault import RetryPolicy, run_with_retries, StragglerMonitor
from repro.runtime.elastic import plan_elastic_mesh

__all__ = ["RetryPolicy", "run_with_retries", "StragglerMonitor",
           "plan_elastic_mesh"]
