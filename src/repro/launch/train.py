"""End-to-end training driver.

Runs on whatever devices exist (1 CPU here; a 256/512-chip mesh in
production — same code path, the mesh shape adapts).  Features exercised:
sharded train step, deterministic replayable data pipeline with prefetch,
async checkpointing, step retries with checkpoint restore, straggler
monitoring, optional int8 gradient compression, elastic restart.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --smoke --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data import SyntheticLMDataset, make_batch_iter
from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.launch import steps as ST
from repro.launch.mesh import make_mesh, data_axes, activate_mesh
from repro.optim import AdamWConfig, adamw_init
from repro.models import build_model
from repro.runtime import StragglerMonitor


def choose_mesh():
    n = len(jax.devices())
    # largest (data, model) grid on the available devices, model <= 16
    model = 1
    for m in (16, 8, 4, 2, 1):
        if n % m == 0 and n >= m:
            model = m
            break
    return make_mesh((n // model, model), ("data", "model"))


def train(arch: str, steps: int, batch: int, seq: int, smoke: bool,
          ckpt_dir: Optional[str], ckpt_every: int = 50,
          lr: float = 3e-4, log_every: int = 10, resume: bool = True,
          dtype=jnp.float32, compress_grads: bool = False):
    cfg = get_arch(arch)
    if smoke:
        cfg = cfg.smoke()
    mesh = choose_mesh()
    model = build_model(cfg, dtype=dtype, remat=not smoke)
    opt_cfg = AdamWConfig(lr=lr, total_steps=steps,
                          warmup_steps=max(10, steps // 20))

    from repro.configs.base import ShapeConfig
    shape = ShapeConfig("cli", seq, batch, "train")
    sh = ST.shardings_for(mesh, model, cfg, shape, zero1=True)
    model.hidden_pspec = sh["hidden"]
    model.hidden_divisors = sh["divisors"]

    with activate_mesh(mesh):
        params = jax.jit(model.init)(jax.random.key(0))
        opt_state = adamw_init(params)
        start = 0
        ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
        if ckpt_dir and resume:
            s = latest_step(ckpt_dir)
            if s is not None:
                state = restore_checkpoint(ckpt_dir, s,
                                           {"params": params, "opt": opt_state})
                params, opt_state = state["params"], state["opt"]
                start = s
                print(f"resumed from step {s}")

        if compress_grads:
            # int8 + error feedback on the DP gradient exchange
            from repro.optim import (compress_grads as cg,
                                     decompress_grads as dg, ef_init)
            from repro.optim import adamw_update

            def step_with_compression(params, opt_state, ef, batch):
                loss, grads = jax.value_and_grad(model.loss)(params, batch)
                comp, ef = cg(grads, ef)
                grads = dg(comp, grads)
                params, opt_state, metrics = adamw_update(
                    opt_cfg, grads, opt_state, params)
                return params, opt_state, ef, {"loss": loss, **metrics}

            ef_state = ef_init(params)
            raw_fn = jax.jit(step_with_compression, donate_argnums=(0, 1, 2))

            def step_fn(params, opt_state, batch, _ef=[ef_state]):
                params, opt_state, _ef[0], metrics = raw_fn(
                    params, opt_state, _ef[0], batch)
                return params, opt_state, metrics
        else:
            step_fn = jax.jit(ST.make_train_step(model, opt_cfg),
                              donate_argnums=(0, 1))
        ds = SyntheticLMDataset(cfg.vocab, seq, batch)
        it = make_batch_iter(ds, start, steps - start, mesh=mesh,
                             dp_axes=data_axes(mesh))
        mon = StragglerMonitor()
        losses = []
        for i, host_batch in zip(range(start, steps), it):
            t0 = time.time()
            params, opt_state, metrics = step_fn(params, opt_state, host_batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.time() - t0
            mon.record(i, dt)
            if i % log_every == 0 or i == steps - 1:
                print(f"step {i:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} {dt * 1e3:.0f}ms",
                      flush=True)
            if ckpt and (i + 1) % ckpt_every == 0:
                ckpt.save(i + 1, {"params": params, "opt": opt_state})
        if ckpt:
            ckpt.save(steps, {"params": params, "opt": opt_state})
            ckpt.wait()
        if mon.flagged:
            print(f"straggler steps flagged: {len(mon.flagged)}")
        return losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8 gradient compression with error feedback")
    args = ap.parse_args()
    losses = train(args.arch, args.steps, args.batch, args.seq, args.smoke,
                   args.ckpt_dir, args.ckpt_every, args.lr,
                   compress_grads=args.compress_grads)
    first = np.mean(losses[:10])
    last = np.mean(losses[-10:])
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
