"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips.  Multi-pod: (pod=2, data=16,
model=16) = 512 chips, with the `pod` axis carrying pure data parallelism
across the inter-pod (DCN) boundary.

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS before calling.
"""
from __future__ import annotations

from typing import Tuple

import jax


def _auto(n: int):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (elastic re-mesh path, tests)."""
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def data_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel axes of a mesh (('pod','data') when multi-pod)."""
    names = mesh.axis_names
    return tuple(n for n in names if n in ("pod", "data"))


def mesh_devices(mesh) -> int:
    return int(mesh.size)
