"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips.  Multi-pod: (pod=2, data=16,
model=16) = 512 chips, with the `pod` axis carrying pure data parallelism
across the inter-pod (DCN) boundary.

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS before calling.
"""
from __future__ import annotations

from typing import Tuple

import jax


def _make(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    # jax >= 0.5 exposes jax.sharding.AxisType and make_mesh(axis_types=...);
    # older releases (e.g. 0.4.x) have neither — everything is Auto there, so
    # plain make_mesh is equivalent.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (elastic re-mesh path, tests)."""
    return _make(shape, axes)


def activate_mesh(mesh):
    """Context manager installing `mesh` as the ambient mesh.

    jax >= 0.5: jax.sharding.set_mesh / use_mesh.  jax 0.4.x: the Mesh object
    itself is the context manager (legacy ambient-mesh mechanism).
    """
    setter = (getattr(jax.sharding, "set_mesh", None)
              or getattr(jax.sharding, "use_mesh", None))
    if setter is not None:
        return setter(mesh)
    return mesh


def data_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel axes of a mesh (('pod','data') when multi-pod)."""
    names = mesh.axis_names
    return tuple(n for n in names if n in ("pod", "data"))


def mesh_devices(mesh) -> int:
    return int(mesh.size)
