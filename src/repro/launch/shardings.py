"""Partition specs for parameters, optimizer state, batches and caches.

Strategy (see DESIGN.md §5):
  * DP over ('pod','data') on batch dims;
  * Megatron TP over 'model' on attention heads / d_ff / vocab / RWKV and
    Mamba channel dims;
  * EP over 'model' for MoE expert stacks (falling back to TP on the expert
    FF dim when n_experts doesn't divide the axis, e.g. qwen2-moe's 60);
  * SP (sequence sharding) for long_500k KV caches, for GQA caches whose
    kv-head count doesn't divide the model axis (flash-decode layout), and,
    via activation constraints, for residual streams (Megatron sequence
    parallelism).

jax requires argument shardings to divide dims exactly, so every rule is
divisibility-checked against the actual leaf shape and falls back to the
next-best layout (documented inline) instead of relying on GSPMD padding.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig


def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


# ----------------------------------------------------------------- params
def _base_spec(keys, shape: Tuple[int, ...], model_size: int) -> Tuple:
    """Spec for a param leaf, by path rules + divisibility checks.

    `shape` is the full (possibly layer-stacked) leaf shape; rules address
    trailing dims and the result is left-padded with None by the caller.
    """
    last = keys[-1]

    def has(*names):
        return any(n in keys for n in names)

    def m(dim_from_end: int):
        """'model' if that trailing dim divides the axis, else None."""
        d = shape[len(shape) - dim_from_end]
        return "model" if _div(d, model_size) else None

    # shared-expert MLP inside MoE blocks: ordinary TP rules (check first —
    # its leaves are also named w_gate/w_up/w_down)
    if has("shared"):
        if last in ("w_gate", "w_up"):
            return (None, m(1))
        if last == "w_down":
            return (m(2), None)
        return (None,) * min(len(shape), 1)

    # MoE expert stacks: (E, d, f) / (E, f, d) -> EP on E when divisible,
    # else TP on the expert FF dim
    if has("moe") and last in ("w_gate", "w_up", "w_down"):
        e_dim = shape[-3]
        if _div(e_dim, model_size):
            return ("model", None, None)
        if last == "w_down":
            return (None, m(2), None)
        return (None, None, m(1))
    if last == "router":
        return (None, None)

    # attention / rwkv / mamba linears
    if has("q", "k", "v", "g", "r", "w_proj", "cm_k", "in_proj") and last == "w":
        return (None, m(1))
    if has("q", "k", "v", "g", "r", "w_proj", "cm_k", "in_proj") and last == "b":
        return (m(1),)
    if has("o", "out", "cm_v", "out_proj", "x_proj") and last == "w":
        return (m(2), None)
    if has("o", "out", "cm_v", "out_proj", "x_proj") and last == "b":
        return (None,)
    if last == "conv_w":
        return (None, m(1))
    if last in ("conv_b", "dt_bias", "D"):
        return (m(1),)
    if last == "A_log":
        return (m(2), None)
    if last == "u":                       # rwkv bonus (H, hd)
        return (m(2), None)

    # MLP
    if last in ("w_gate", "w_up"):
        return (None, m(1))
    if last == "b_up":
        return (m(1),)
    if last == "w_down":
        return (m(2), None)
    if last == "b_down":
        return (None,)

    # embeddings / head: vocab-sharded when divisible, else d_model-sharded
    if last == "embed":
        v, d = shape[-2], shape[-1]
        if _div(v, model_size):
            return ("model", None)
        return (None, m(1))
    if has("lm_head") and last == "w":
        d, v = shape[-2], shape[-1]
        if _div(v, model_size):
            return (None, "model")
        return (m(2), None)
    if has("lm_head") and last == "b":
        return (m(1),)

    # norms, mixes, scalars
    return tuple([None] * len(shape))


def param_spec(path, leaf, model_size: int = 16) -> P:
    keys = [str(getattr(k, "key", k)) for k in path]
    shape = tuple(getattr(leaf, "shape", ()))
    ndim = len(shape)
    tail = _base_spec(keys, shape, model_size)
    tail = tuple(tail[-ndim:]) if len(tail) > ndim else tail
    pad = ndim - len(tail)
    return P(*([None] * pad + list(tail)))


def param_specs(params: Any, model_size: int = 16):
    """Pytree of PartitionSpec matching `params` (works on abstract trees)."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: param_spec(p, l, model_size), params)


# ----------------------------------------------------------------- batch
def batch_spec(cfg: ArchConfig, shape: ShapeConfig, dp, dp_size: int) -> Any:
    """Input-batch PartitionSpecs.  dp = data axes, dp_size = their product."""
    dp = tuple(dp)
    bdim = dp if _div(shape.global_batch, dp_size) and shape.global_batch > 1 \
        else None
    if shape.mode == "decode":
        tok = P(bdim)                     # (B,) one token per sequence
    else:
        tok = P(bdim, None)               # (B, S)
    out = {"tokens": tok, "labels": P(bdim, None)}
    if cfg.family == "vlm":
        out["embeds"] = P(bdim, None, "model")
    if cfg.family == "audio":
        out["frames"] = P(bdim, None, "model")
    return out


# ----------------------------------------------------------------- cache
def cache_spec(cfg: ArchConfig, shape: ShapeConfig, dp, dp_size: int,
               model_size: int) -> Any:
    """Decode-cache PartitionSpecs.

    KV layout decision tree:
      * kv-heads divide the model axis -> shard heads (classic TP serving);
      * else -> shard the KV sequence over 'model' (flash-decode layout);
      * batch==1 (long_500k) -> the data axes also land on the sequence dim.
    """
    dp = tuple(dp)
    seq_sharded = shape.global_batch == 1
    b_ax = None if seq_sharded else (dp if _div(shape.global_batch, dp_size)
                                     else None)
    heads_ok = _div(cfg.n_kv_heads, model_size)
    s_parts = []
    if seq_sharded:
        s_parts.extend(dp)
    if not heads_ok:
        s_parts.append("model")
    s_ax = tuple(s_parts) if s_parts else None
    h_ax = "model" if heads_ok else None

    kv = P(None, b_ax, s_ax, h_ax, None)          # (L, B, S, kvH, hd)
    d_ax = "model" if _div(cfg.d_model, model_size) else None
    if cfg.family == "ssm":
        return {
            "layers": {
                "tm": {"wkv": P(None, b_ax, "model" if _div(
                            cfg.d_model // cfg.rwkv_head_size, model_size)
                            else None, None, None),
                       "shift": P(None, b_ax, None, d_ax)},
                "cm": {"shift": P(None, b_ax, None, d_ax)},
            },
            "len": P(),
        }
    if cfg.family == "hybrid":
        din_ax = "model" if _div(2 * cfg.d_model, model_size) else None
        return {
            "k": kv, "v": kv,
            "mamba": {"h": P(None, None, b_ax, din_ax, None),
                      "conv": P(None, None, b_ax, None, din_ax)},
            "len": P(),
        }
    out = {"k": kv, "v": kv, "len": P()}
    if cfg.family == "audio":
        out["enc"] = P(b_ax, None, d_ax)
    return out


def hidden_spec(dp) -> P:
    """Residual-stream constraint: Megatron sequence parallelism — batch
    over data axes AND sequence over model between blocks."""
    return P(tuple(dp), "model", None)


# ----------------------------------------------------------------- FSDP
def fsdp_param_spec(path, leaf, axes: Tuple[str, ...], size: int) -> P:
    """ZeRO-3/FSDP layout: shard the largest dim divisible by the FULL
    device count over all mesh axes; XLA all-gathers params at each use.

    Beats TP for small-dense models where per-token TP collectives dwarf
    the per-step parameter traffic (see EXPERIMENTS.md §Perf iteration 1).
    """
    shape = tuple(getattr(leaf, "shape", ()))
    if not shape:
        return P()
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if shape[i] % size == 0 and shape[i] >= size:
            parts: list = [None] * len(shape)
            parts[i] = tuple(axes)
            return P(*parts)
    return P(*([None] * len(shape)))


def fsdp_param_specs(params: Any, axes: Tuple[str, ...], size: int):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: fsdp_param_spec(p, l, axes, size), params)
