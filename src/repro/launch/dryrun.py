import os
if __name__ == "__main__":
    # MUST run before any jax import (device count locks at first init).
    # Guarded so importing this module (tests, tooling) never mutates the
    # process' device topology — the dry-run is its own process by design.
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell and extract the roofline terms from the compiled artifact.

MUST be invoked as its own process (the XLA flag above is read at first jax
init).  Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out experiments/dryrun

Per cell it records: per-device memory analysis, HLO FLOPs/bytes
(cost_analysis), per-collective byte totals (parsed from the compiled HLO),
and derived roofline terms for the TPU-v5e-like target
(197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).
"""

import argparse
import json
import re
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, SHAPES
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh, data_axes, activate_mesh
from repro.models import build_model
from repro.optim import AdamWConfig

# ---- hardware constants (assignment: TPU v5e-like target) ----
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (use 1 link per collective hop)

COLLECTIVE_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^)]*?\)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3": 1,
               "f8e5m2": 1, "s16": 2, "u16": 2}


def parse_collectives(hlo_text: str) -> Dict[str, float]:
    """Sum output bytes of every collective op in the (SPMD, per-device) HLO."""
    out: Dict[str, float] = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        nbytes = DTYPE_BYTES.get(dtype, 4)
        if dims:
            for d in dims.split(","):
                nbytes *= int(d)
        out[kind] = out.get(kind, 0.0) + nbytes
    return out


def roofline_terms(flops: float, bytes_acc: float, coll: Dict[str, float]):
    """The three roofline terms, in seconds per step per chip."""
    comm_bytes = sum(coll.values())
    return {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_acc / HBM_BW,
        "collective_s": comm_bytes / ICI_BW,
        "collective_bytes": comm_bytes,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             zero1: bool = True, extra: Optional[dict] = None,
             layers: Optional[int] = None, policy: str = "tp") -> dict:
    """layers: override the scan depth (in scan units: layers for most
    archs, Jamba periods x attn_every for hybrid, both enc+dec for audio).
    Used by the roofline tool to extrapolate per-layer FLOPs/bytes — XLA's
    cost_analysis counts while-loop bodies once, so full-depth numbers come
    from two shallow compiles + linear extrapolation."""
    import dataclasses as _dc
    cfg = ARCHS[arch]
    if layers is not None:
        nl = layers * cfg.attn_every if cfg.attn_every else layers
        cfg = _dc.replace(cfg, n_layers=nl,
                          enc_layers=layers if cfg.enc_layers else 0)
    shape = SHAPES[shape_name]
    rec: dict = {"arch": arch, "shape": shape_name,
                 "mesh": "multi" if multi_pod else "single",
                 "layers_override": layers}
    if shape.name in cfg.skip_shapes:
        rec["status"] = "SKIP"
        rec["reason"] = ("full-attention arch: quadratic-history 500k decode"
                        if shape.name == "long_500k" else "n/a")
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = data_axes(mesh)
    model = build_model(cfg, remat=(shape.mode == "train"))
    if layers is not None:
        model.scan_unroll = True    # cost_analysis must see every layer
    sh = ST.shardings_for(mesh, model, cfg, shape, zero1=zero1, policy=policy)
    model.hidden_pspec = sh["hidden"]
    model.hidden_divisors = sh["divisors"]
    rec["policy"] = policy
    # grouped MoE dispatch aligned with the data axes (EP over 'model')
    if cfg.n_experts:
        from jax.sharding import PartitionSpec as P
        e_tot = cfg.n_experts + cfg.expert_pad
        model_size = int(mesh.shape["model"]) if "model" in mesh.axis_names else 1
        if e_tot % max(model_size, 1) == 0:
            model.moe_groups = sh["divisors"][0]
            model.moe_buf_pspec = P(tuple(dp), "model", None, None)
            if shape.mode != "decode":
                # manual-collective EP (shard_map) for train/prefill
                model.moe_impl = "shard_map"
                model.moe_mesh = mesh
                model.moe_dp_axes = tuple(dp)
    batch_abs = ST.input_specs(cfg, shape)
    params_abs = ST.abstract_params(model)

    with activate_mesh(mesh):
        if shape.mode == "train":
            opt_abs = jax.eval_shape(lambda p: __import__(
                "repro.optim", fromlist=["adamw_init"]).adamw_init(p), params_abs)
            step = ST.make_train_step(model, AdamWConfig())
            jitted = jax.jit(
                step,
                in_shardings=(ST.named(mesh, sh["params"]),
                              ST.named(mesh, sh["opt"]),
                              ST.named(mesh, {k: sh["batch"][k] for k in batch_abs})),
                donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
        elif shape.mode == "prefill":
            step = ST.make_prefill_step(model)
            jitted = jax.jit(
                step,
                in_shardings=(ST.named(mesh, sh["params"]),
                              ST.named(mesh, {k: sh["batch"][k] for k in batch_abs})))
            lowered = jitted.lower(params_abs, batch_abs)
        else:  # decode
            cache_abs = ST.abstract_cache(model, cfg, shape)
            step = ST.make_serve_step(model)
            jitted = jax.jit(
                step,
                in_shardings=(ST.named(mesh, sh["params"]),
                              ST.named(mesh, sh["cache"]),
                              ST.named(mesh, sh["batch"]["tokens"])),
                donate_argnums=(1,))
            lowered = jitted.lower(params_abs, cache_abs,
                                   batch_abs["tokens"])

        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

    mem = compiled.memory_analysis()
    rec["memory"] = {
        k: int(getattr(mem, k))
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes")
        if hasattr(mem, k)
    }
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    rec["flops"] = float(cost.get("flops", 0.0))
    rec["bytes_accessed"] = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    rec["collectives"] = parse_collectives(hlo)
    rec["roofline"] = roofline_terms(rec["flops"], rec["bytes_accessed"],
                                     rec["collectives"])
    rec["status"] = "OK"
    if extra:
        rec.update(extra)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--layers", type=int, default=None,
                    help="scan-depth override for per-layer cost extraction")
    ap.add_argument("--policy", default="tp", choices=("tp", "fsdp", "dp"))
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                if args.layers is not None:
                    tag += f"__L{args.layers}"
                if args.policy != "tp":
                    tag += f"__{args.policy}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    rec = json.load(open(path))
                    if rec.get("status") in ("OK", "SKIP"):
                        print(f"[cached] {tag}: {rec['status']}")
                        continue
                try:
                    rec = run_cell(arch, shape, mp, zero1=not args.no_zero1,
                                   layers=args.layers, policy=args.policy)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    n_fail += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if rec["status"] == "OK":
                    r = rec["roofline"]
                    print(f"{tag}: OK lower={rec['lower_s']}s "
                          f"compile={rec['compile_s']}s "
                          f"compute={r['compute_s']:.4f}s "
                          f"mem={r['memory_s']:.4f}s "
                          f"coll={r['collective_s']:.4f}s "
                          f"temp={rec['memory'].get('temp_size_in_bytes', 0) / 2**30:.2f}GiB",
                          flush=True)
                else:
                    print(f"{tag}: {rec['status']} {rec.get('error', rec.get('reason', ''))}",
                          flush=True)
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
