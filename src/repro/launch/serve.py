"""Batched serving driver: prefill + decode with a KV/state cache.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.launch.mesh import activate_mesh
from repro.launch.train import choose_mesh
from repro.models import build_model


def serve(arch: str, batch: int, prompt_len: int, gen: int, smoke: bool,
          dtype=jnp.float32, greedy: bool = True, seed: int = 0):
    cfg = get_arch(arch)
    if smoke:
        cfg = cfg.smoke()
    mesh = choose_mesh()
    model = build_model(cfg, dtype=dtype, remat=False)

    with activate_mesh(mesh):
        params = jax.jit(model.init)(jax.random.key(seed))
        rng = np.random.default_rng(seed)
        prompts = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)),
                              jnp.int32)

        enc = None
        if cfg.family == "audio":
            frames = jnp.asarray(
                rng.standard_normal((batch, cfg.enc_ctx, cfg.d_model)), dtype)
            enc = model._encoder_stack(params, frames)

        max_len = prompt_len + gen + 1
        cache = model.init_cache(batch, max_len, enc_out=enc)

        step = jax.jit(model.decode_step, donate_argnums=(1,))
        # prefill via repeated decode steps for cache-correctness (a fused
        # prefill kernel is the production path; see repro.kernels)
        t0 = time.time()
        logits = None
        for t in range(prompt_len):
            logits, cache = step(params, cache, prompts[:, t])
        ttft = time.time() - t0

        toks = []
        t0 = time.time()
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for _ in range(gen):
            toks.append(tok)        # stays on device: no per-token sync
            logits, cache = step(params, cache, tok)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(logits)
        tpot = (time.time() - t0) / max(gen, 1)
        out = np.asarray(jnp.stack(toks, axis=1))
        return {"tokens": out, "ttft_s": ttft, "tpot_s": tpot}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    r = serve(args.arch, args.batch, args.prompt_len, args.gen, args.smoke)
    print(f"generated {r['tokens'].shape} tokens; "
          f"TTFT {r['ttft_s'] * 1e3:.1f}ms TPOT {r['tpot_s'] * 1e3:.2f}ms")
    print("first row:", r["tokens"][0][:16])


if __name__ == "__main__":
    main()
