"""Step factories + abstract input specs for every (arch x shape) cell.

Everything the dry-run, the trainer and the server share lives here:

* :func:`input_specs`  — ShapeDtypeStruct stand-ins for every model input
  (weak-type-correct, shardable, no device allocation);
* :func:`abstract_state` — eval_shape'd params / optimizer / cache trees;
* :func:`make_train_step` / :func:`make_prefill_step` /
  :func:`make_serve_step` — the jittable step functions;
* :func:`shardings_for` — the full (params, opt, batch, cache) sharding
  bundle for a mesh.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.launch import shardings as SH
from repro.launch.mesh import data_axes
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init, adamw_update

PyTree = Any


# ------------------------------------------------------------------ specs
def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract inputs for one cell (the dry-run's batch stand-in)."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.mode == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b,), i32)}
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.family == "vlm":
        out["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
    elif cfg.family == "audio":
        out["frames"] = jax.ShapeDtypeStruct((b, cfg.enc_ctx, cfg.d_model),
                                             jnp.bfloat16)
        out["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
    if shape.mode == "train":
        out["labels"] = jax.ShapeDtypeStruct((b, s), i32)
    return out


def abstract_params(model) -> PyTree:
    return jax.eval_shape(model.init, jax.random.key(0))


def abstract_cache(model, cfg: ArchConfig, shape: ShapeConfig) -> PyTree:
    fn = functools.partial(model.init_cache, shape.global_batch, shape.seq_len)
    if cfg.family == "audio":
        enc = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.enc_ctx, cfg.d_model), jnp.bfloat16)
        return jax.eval_shape(lambda e: fn(enc_out=e), enc)
    return jax.eval_shape(fn)


# ------------------------------------------------------------------ steps
def make_train_step(model, opt_cfg: AdamWConfig):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state, metrics = adamw_update(opt_cfg, grads, opt_state,
                                                  params)
        return params, opt_state, {"loss": loss, **metrics}
    return train_step


def make_prefill_step(model):
    def prefill_step(params, batch):
        return model.forward(params, batch)
    return prefill_step


def make_serve_step(model):
    def serve_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)
    return serve_step


# ------------------------------------------------------------------ shardings
def _zero1_checked(spec: P, dp: Tuple[str, ...], dp_size: int,
                   shape: Tuple[int, ...], axis_sizes=None) -> P:
    """ZeRO-1 moment sharding: put the (still unused) data axes on the first
    unsharded dim whose size divides them (jax requires exact divisibility
    and forbids axis reuse within one spec)."""
    used = set()
    for ax in spec:
        if ax is None:
            continue
        used.update((ax,) if isinstance(ax, str) else tuple(ax))
    avail = tuple(a for a in dp if a not in used)
    if not avail:
        return spec
    axis_sizes = axis_sizes or {"pod": 2, "data": 16, "model": 16}
    size = 1
    for a in avail:
        size *= axis_sizes.get(a, 1)
    parts = list(spec)
    while len(parts) < len(shape):
        parts.append(None)
    for i, ax in enumerate(parts):
        if ax is None and shape[i] % max(size, 1) == 0 and shape[i] >= size:
            parts[i] = avail if len(avail) > 1 else avail[0]
            return P(*parts)
    return spec


def _axis_size(mesh, name: str) -> int:
    return int(mesh.shape[name]) if name in mesh.axis_names else 1


def shardings_for(mesh, model, cfg: ArchConfig, shape: ShapeConfig,
                  zero1: bool = True, policy: str = "tp") -> Dict[str, PyTree]:
    """PartitionSpec trees for params / optimizer / batch / cache.

    policy:
      "tp"   — Megatron TP over 'model' + DP over data axes (default);
      "fsdp" — ZeRO-3 parameter sharding over ALL axes, batch over all axes
               (wins for small dense models; see EXPERIMENTS.md §Perf).
    """
    dp = data_axes(mesh)
    dp_size = int(np.prod([_axis_size(mesh, a) for a in dp]))
    model_size = _axis_size(mesh, "model")
    p_abs = abstract_params(model)
    if policy == "fsdp":
        all_axes = tuple(mesh.axis_names)
        total = int(mesh.size)
        p_spec = SH.fsdp_param_specs(p_abs, all_axes, total)
        opt_spec = {"m": p_spec, "v": p_spec, "step": P()}
        bspec = SH.batch_spec(cfg, shape, all_axes, total)
        return {"params": p_spec, "opt": opt_spec, "batch": bspec,
                "hidden": None, "divisors": (total, 1)}
    if policy == "dp":
        # (MoE-aware) data parallelism: dense params replicated, expert
        # stacks EP-sharded over 'model' when divisible, batch over ALL
        # axes, ZeRO-sharded moments so fp32 optimizer state fits HBM
        all_axes = tuple(mesh.axis_names)
        total = int(mesh.size)
        sizes = {a: _axis_size(mesh, a) for a in all_axes}

        def pick(path, leaf):
            keys = [str(getattr(k, "key", k)) for k in path]
            if "moe" in keys and keys[-1] in ("w_gate", "w_up", "w_down") \
                    and "shared" not in keys and leaf.ndim >= 3 \
                    and leaf.shape[-3] % max(model_size, 1) == 0 \
                    and leaf.shape[-3] >= model_size:
                parts = [None] * leaf.ndim
                parts[leaf.ndim - 3] = "model"
                return P(*parts)
            return P(*([None] * leaf.ndim))

        p_spec = jax.tree_util.tree_map_with_path(pick, p_abs)
        z = lambda s, l: _zero1_checked(s, all_axes, total, l.shape, sizes)
        opt_spec = {"m": jax.tree.map(z, p_spec, p_abs),
                    "v": jax.tree.map(z, p_spec, p_abs),
                    "step": P()}
        # MoE archs keep the model axis for EP, so the batch shards over the
        # data axes only; dense archs spread the batch over everything
        if cfg.n_experts:
            bspec = SH.batch_spec(cfg, shape, dp, dp_size)
            return {"params": p_spec, "opt": opt_spec, "batch": bspec,
                    "hidden": None, "divisors": (dp_size, 1)}
        bspec = SH.batch_spec(cfg, shape, all_axes, total)
        return {"params": p_spec, "opt": opt_spec, "batch": bspec,
                "hidden": None, "divisors": (total, 1)}
    p_spec = SH.param_specs(p_abs, model_size)

    def z1(spec, leaf):
        if not zero1:
            return spec
        return _zero1_checked(spec, dp, dp_size, leaf.shape)

    opt_spec = {
        "m": jax.tree.map(z1, p_spec, p_abs),
        "v": jax.tree.map(z1, p_spec, p_abs),
        "step": P(),
    }
    out = {
        "params": p_spec,
        "opt": opt_spec,
        "batch": SH.batch_spec(cfg, shape, dp, dp_size),
        "hidden": SH.hidden_spec(dp),
        "divisors": (dp_size, model_size),
    }
    if shape.mode == "decode":
        out["cache"] = SH.cache_spec(cfg, shape, dp, dp_size, model_size)
    return out


def named(mesh, spec_tree: PyTree) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
