"""Shared AST/dataflow core for `repro.analysis`.

Builds assignment-level dataflow facts from *parsed source files* (never
``inspect.getsource`` of a single function — whole-file parsing keeps AST
line numbers equal to real file lines, which is what gives every extracted
edge honest ``file:line`` provenance).

This module supersedes the `_DepVisitor` in the deprecated
``repro.core.quale_ast`` and fixes its two known gaps:

* ``AugAssign`` / ``AnnAssign`` (and ``for``-loop / ``with``-as) targets are
  recorded, not silently dropped;
* string *constants* are never treated as name reads (the old visitor
  recorded every ``ast.Constant`` string in an expression as a dataflow
  source, so ``hw["sa_dim"]`` polluted the dep set with both ``hw`` and a
  phantom name ``sa_dim``).  Here a subscript with a constant-string key on
  a named base becomes a typed *key read* ``base[key]`` instead.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from types import ModuleType
from typing import Dict, Iterator, List, Optional, Sequence, Tuple


class AnalysisError(RuntimeError):
    """Raised when analyzed source does not match an anticipated shape.

    Extraction fails loudly (CI's ``extract --check`` turns red) instead of
    silently emitting a wrong influence graph after a perfmodel refactor.
    """


def repo_relative(path: str) -> str:
    """Render an absolute source path repo-relative (from the last ``src/``
    component) so provenance strings are stable across checkouts."""
    parts = Path(path).parts
    if "src" in parts:
        i = len(parts) - 1 - tuple(reversed(parts)).index("src")
        return "/".join(parts[i:])
    return Path(path).name


@dataclasses.dataclass(frozen=True)
class Site:
    """A ``file:line`` provenance anchor (file is repo-relative)."""

    file: str
    line: int

    def __str__(self) -> str:
        return f"{self.file}:{self.line}"


@dataclasses.dataclass(frozen=True)
class Read:
    """One dataflow source inside an expression.

    kind:
      * ``"name"`` — a plain identifier read;
      * ``"key"``  — ``base[name]`` with a constant-string key;
      * ``"attr"`` — ``base.name`` attribute read.
    """

    kind: str
    name: str
    base: Optional[str]
    site: Site


def expr_reads(node: ast.AST, file: str) -> List[Read]:
    """All reads in an expression, typed.  Subscript/attribute *bases* are
    folded into the typed read instead of leaking as extra plain names, and
    string constants are data, never names."""
    out: List[Read] = []
    skip: set = set()

    for sub in ast.walk(node):
        if id(sub) in skip:
            continue
        if isinstance(sub, ast.Subscript) and isinstance(sub.value, ast.Name):
            key = sub.slice
            if isinstance(key, ast.Constant) and isinstance(key.value, str):
                out.append(Read("key", key.value, sub.value.id,
                                Site(file, sub.lineno)))
                skip.add(id(sub.value))
                skip.add(id(key))
        elif isinstance(sub, ast.Attribute) and isinstance(sub.value, ast.Name):
            out.append(Read("attr", sub.attr, sub.value.id,
                            Site(file, sub.lineno)))
            skip.add(id(sub.value))

    for sub in ast.walk(node):
        if id(sub) in skip:
            continue
        if isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Load):
            out.append(Read("name", sub.id, None, Site(file, sub.lineno)))
    return out


# --------------------------------------------------------------------------
# per-function facts
# --------------------------------------------------------------------------

@dataclasses.dataclass
class FunctionInfo:
    """Assignment-level dataflow facts for one function."""

    module: str
    qualname: str                  # "fn" or "Class.fn"
    name: str
    cls: Optional[str]
    params: Tuple[str, ...]        # excludes a leading self/cls
    node: ast.AST
    file: str                      # repo-relative
    # local name -> every RHS expression ever assigned to it (Assign,
    # AugAssign, AnnAssign, for-targets, with-as), with its site
    assigns: Dict[str, List[Tuple[ast.expr, Site]]] = \
        dataclasses.field(default_factory=dict)
    returns: List[Tuple[ast.expr, Site]] = dataclasses.field(default_factory=list)
    # constant-string-keyed dict-literal returns: key -> (value expr, site)
    dict_returns: Dict[str, Tuple[ast.expr, Site]] = \
        dataclasses.field(default_factory=dict)

    def local_exprs(self, name: str) -> List[Tuple[ast.expr, Site]]:
        return self.assigns.get(name, [])


def _record_target(info: FunctionInfo, target: ast.expr, value: ast.expr,
                   site: Site) -> None:
    if isinstance(target, ast.Name):
        info.assigns.setdefault(target.id, []).append((value, site))
    elif isinstance(target, (ast.Tuple, ast.List)):
        elts = target.elts
        if isinstance(value, (ast.Tuple, ast.List)) and \
                len(value.elts) == len(elts):
            for t, v in zip(elts, value.elts):
                _record_target(info, t, v, site)
        else:
            for t in elts:
                _record_target(info, t, value, site)
    # attribute/subscript targets (self.x = ..) are object state, not locals


def _build_function(module: str, qualname: str, cls: Optional[str],
                    node: ast.AST, file: str) -> FunctionInfo:
    args = node.args
    params = [a.arg for a in
              (args.posonlyargs + args.args + args.kwonlyargs)]
    if cls is not None and params and params[0] in ("self", "cls"):
        params = params[1:]
    info = FunctionInfo(module=module, qualname=qualname, name=node.name,
                        cls=cls, params=tuple(params), node=node, file=file)

    for sub in ast.walk(node):
        site = Site(file, getattr(sub, "lineno", node.lineno))
        if isinstance(sub, ast.Assign):
            for t in sub.targets:
                _record_target(info, t, sub.value, site)
        elif isinstance(sub, ast.AugAssign):
            # target reads both its prior value and the RHS; record the RHS
            # (prior assignments are already in the list for this name)
            _record_target(info, sub.target, sub.value, site)
        elif isinstance(sub, ast.AnnAssign) and sub.value is not None:
            _record_target(info, sub.target, sub.value, site)
        elif isinstance(sub, ast.For):
            _record_target(info, sub.target, sub.iter, site)
        elif isinstance(sub, ast.With):
            for item in sub.items:
                if item.optional_vars is not None:
                    _record_target(info, item.optional_vars,
                                   item.context_expr, site)
        elif isinstance(sub, ast.Return) and sub.value is not None:
            info.returns.append((sub.value, site))
            if isinstance(sub.value, ast.Dict):
                for k, v in zip(sub.value.keys, sub.value.values):
                    if isinstance(k, ast.Constant) and isinstance(k.value, str):
                        info.dict_returns[k.value] = (v, Site(file, v.lineno))
    return info


# --------------------------------------------------------------------------
# per-module / cross-module index
# --------------------------------------------------------------------------

def iter_functions(tree: ast.Module) -> Iterator[Tuple[str, Optional[str], ast.AST]]:
    """Yield (qualname, class_name, node) for every def in a module AST,
    including methods (one class level deep — the repo's code shape)."""
    for stmt in tree.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield stmt.name, None, stmt
        elif isinstance(stmt, ast.ClassDef):
            for sub in stmt.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    yield f"{stmt.name}.{sub.name}", stmt.name, sub


@dataclasses.dataclass
class ModuleInfo:
    name: str                       # full module name
    file: str                       # repo-relative
    tree: ast.Module
    functions: Dict[str, FunctionInfo]          # by qualname AND bare name
    constants: Dict[str, Tuple[object, Site]]   # module-level literal consts
    imports: Dict[str, Tuple[str, Optional[str]]]
    # local alias -> (module name, original symbol or None for module imports)


def _module_constants(tree: ast.Module, file: str) -> Dict[str, Tuple[object, Site]]:
    out: Dict[str, Tuple[object, Site]] = {}
    for stmt in tree.body:
        if not isinstance(stmt, ast.Assign):
            continue
        site = Site(file, stmt.lineno)
        for target in stmt.targets:
            if isinstance(target, ast.Name) and \
                    isinstance(stmt.value, ast.Constant):
                out[target.id] = (stmt.value.value, site)
            elif isinstance(target, ast.Name) and \
                    isinstance(stmt.value, ast.Tuple) and \
                    all(isinstance(e, ast.Constant) for e in stmt.value.elts):
                out[target.id] = (
                    tuple(e.value for e in stmt.value.elts), site)
            elif isinstance(target, ast.Tuple) and \
                    isinstance(stmt.value, ast.Tuple) and \
                    len(target.elts) == len(stmt.value.elts):
                for t, v in zip(target.elts, stmt.value.elts):
                    if isinstance(t, ast.Name) and isinstance(v, ast.Constant):
                        out[t.id] = (v.value, site)
    return out


def _module_imports(tree: ast.Module) -> Dict[str, Tuple[str, Optional[str]]]:
    out: Dict[str, Tuple[str, Optional[str]]] = {}
    for stmt in ast.walk(tree):
        if isinstance(stmt, ast.Import):
            for alias in stmt.names:
                local = alias.asname or alias.name.split(".")[0]
                out[local] = (alias.name, None)
        elif isinstance(stmt, ast.ImportFrom) and stmt.module:
            for alias in stmt.names:
                local = alias.asname or alias.name
                out[local] = (stmt.module, alias.name)
    return out


class ModuleIndex:
    """Parsed-source index over a set of modules with interprocedural
    function/constant resolution."""

    def __init__(self) -> None:
        self.modules: Dict[str, ModuleInfo] = {}

    @classmethod
    def build(cls, modules: Sequence[ModuleType]) -> "ModuleIndex":
        idx = cls()
        for mod in modules:
            path = getattr(mod, "__file__", None)
            if path is None:
                raise AnalysisError(f"module {mod!r} has no source file")
            src = Path(path).read_text()
            tree = ast.parse(src)
            file = repo_relative(path)
            functions: Dict[str, FunctionInfo] = {}
            for qualname, cls_name, node in iter_functions(tree):
                info = _build_function(mod.__name__, qualname, cls_name,
                                       node, file)
                functions[qualname] = info
                # bare-name alias for methods, when unambiguous
                if cls_name is not None and node.name not in functions:
                    functions.setdefault(node.name, info)
            idx.modules[mod.__name__] = ModuleInfo(
                name=mod.__name__, file=file, tree=tree, functions=functions,
                constants=_module_constants(tree, file),
                imports=_module_imports(tree))
        return idx

    # -- resolution --------------------------------------------------------

    def module_of(self, info: FunctionInfo) -> ModuleInfo:
        return self.modules[info.module]

    def _imported_module(self, minfo: ModuleInfo,
                         local: str) -> Optional[ModuleInfo]:
        tgt = minfo.imports.get(local)
        if tgt is None:
            return None
        mod_name, orig = tgt
        if orig is not None:
            # "from pkg import workload as W" arrives as (pkg, workload)
            full = f"{mod_name}.{orig}"
            if full in self.modules:
                return self.modules[full]
        return self.modules.get(mod_name)

    def resolve_function(self, ctx: FunctionInfo, base: Optional[str],
                         name: str) -> Optional[FunctionInfo]:
        """Resolve a callee seen from inside ``ctx``: a plain name, an
        imported name, ``self.method``, or ``module_alias.fn``."""
        minfo = self.module_of(ctx)
        if base in ("self", "cls") and ctx.cls is not None:
            return minfo.functions.get(f"{ctx.cls}.{name}")
        if base is not None:
            target = self._imported_module(minfo, base)
            return target.functions.get(name) if target else None
        if name in minfo.functions:
            return minfo.functions[name]
        tgt = minfo.imports.get(name)
        if tgt is not None:
            mod_name, orig = tgt
            target = self.modules.get(mod_name)
            if target is not None and orig is not None:
                return target.functions.get(orig)
        return None

    def resolve_constant(self, ctx: FunctionInfo, base: Optional[str],
                         name: str) -> Optional[Tuple[object, Site]]:
        """Resolve ``name`` / ``alias.name`` to a module-level constant."""
        minfo = self.module_of(ctx)
        if base is not None:
            target = self._imported_module(minfo, base)
            return target.constants.get(name) if target else None
        if name in minfo.constants:
            return minfo.constants[name]
        tgt = minfo.imports.get(name)
        if tgt is not None:
            mod_name, orig = tgt
            target = self.modules.get(mod_name)
            if target is not None and orig is not None:
                return target.constants.get(orig)
        return None


# --------------------------------------------------------------------------
# call-site helpers
# --------------------------------------------------------------------------

def callee_parts(call: ast.Call) -> Tuple[Optional[str], Optional[str]]:
    """(base, name) of a call's target when it is a simple name or a
    one-level attribute; (None, None) otherwise."""
    f = call.func
    if isinstance(f, ast.Name):
        return None, f.id
    if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name):
        return f.value.id, f.attr
    return None, None


def bind_args(callee: FunctionInfo, call: ast.Call) -> Dict[str, ast.expr]:
    """Map callee formal names -> actual argument expressions (positional +
    keyword; *args/**kwargs ignored — not used in the analyzed surface)."""
    binding: Dict[str, ast.expr] = {}
    for i, arg in enumerate(call.args):
        if i < len(callee.params):
            binding[callee.params[i]] = arg
    for kw in call.keywords:
        if kw.arg is not None:
            binding[kw.arg] = kw.value
    return binding
