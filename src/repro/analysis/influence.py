"""Interprocedural influence-graph extraction from the perfmodel SOURCE.

The literal reproduction of the paper's §3.2.1 ("the LLM statically
analyses the simulator codebase and emits architectural heuristic
knowledge"): an assignment-level, guard-aware, interprocedural dataflow
analysis over ``repro.perfmodel.{hardware,roofline,workload,designspace,
critical_path}`` that emits a typed :class:`InfluenceGraph`

    design parameter -> derived hardware quantity -> roofline op-term
                     -> stall class -> PPA metric

with ``file:line`` provenance on every edge.  Nothing architectural is
hand-coded here: the analysis anchors only on *where the model lives*
(function names listed in ``_ANCHORS``) and derives *what it says* —
which guards split op kinds, which term each stall class attributes to,
which derived key is each class's peak throughput, and therefore which
parameter is the AHK "primary relief" for each stall class:

* **term discovery** — the op-time terms are exactly the non-guard keys
  `_dominant_class` reads off the `_op_terms` output dict;
* **class attribution** — `_dominant_class`'s nested ``where`` tree is
  decomposed into (guard-chain -> class-constant) leaves; a class's term
  is the common left operand of its positive dominance comparisons
  (MEMORY falls out by elimination), and its ``is_*`` guards become
  branch constraints;
* **primary resource** — a class's *peak key* is the first derived-hw key
  found in division-denominator position walking its term's compatible
  branches outward (breadth-first through locals and callees: the
  shallowest thing the term is divided by IS the throughput being
  saturated); the primary parameter is the unique parameter that reaches
  the peak key while influencing no other stall class.

`RuleOracle` / `StrategyEngine` consume :func:`primary_resources`;
:func:`cross_validate` checks the graph against the probe-based QualE map
(`repro.core.quale.derive_influence_map`) and classifies disagreements
for the rule auto-correction telemetry.  Any unanticipated source shape
raises :class:`~repro.analysis.dataflow.AnalysisError` so CI's
``python -m repro.analysis.extract --check`` fails loudly instead of
shipping a silently wrong graph.
"""
from __future__ import annotations

import ast
import dataclasses
import json
from functools import lru_cache
from pathlib import Path
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from repro.analysis.dataflow import (AnalysisError, FunctionInfo, ModuleIndex,
                                     Site, bind_args, callee_parts, expr_reads)

GuardAtom = Tuple[str, bool]
Guards = FrozenSet[GuardAtom]

ARTIFACT_PATH = Path(__file__).with_name("influence_graph.json")

# Where the model lives (not what it says): the only hand-maintained part.
_ANCHORS = {
    "hardware": ("repro.perfmodel.hardware", "derive_hardware"),
    "terms": ("repro.perfmodel.roofline", "RooflineModel._op_terms"),
    "dominant": ("repro.perfmodel.roofline", "_dominant_class"),
    "batch": ("repro.perfmodel.roofline", "RooflineModel._workload_batch"),
    "suite": ("repro.perfmodel.workload", "paper_suite"),
}
_AREA_KEY = "area_mm2"
_AREA_METRIC = "area"


def _perfmodel_modules():
    from repro.perfmodel import (critical_path, designspace, hardware,
                                 roofline, workload)
    return (hardware, roofline, workload, designspace, critical_path)


def _fn(idx: ModuleIndex, anchor: Tuple[str, str]) -> FunctionInfo:
    mod, qual = anchor
    minfo = idx.modules.get(mod)
    if minfo is None or qual not in minfo.functions:
        raise AnalysisError(f"anchor {mod}.{qual} not found in parsed source")
    return minfo.functions[qual]


# --------------------------------------------------------------------------
# guard-aware interprocedural key-read closure
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KeyUse:
    """One read of ``hw_dict["key"]`` reachable from an expression, with the
    guard chain (``jnp.where`` conditions) under which it is live."""

    key: str
    guards: Guards
    site: Site


def _guard_atom(cond: ast.expr) -> Optional[str]:
    if isinstance(cond, ast.Name):
        return cond.id
    if isinstance(cond, ast.Subscript) and \
            isinstance(cond.slice, ast.Constant) and \
            isinstance(cond.slice.value, str):
        return cond.slice.value
    return None


def _key_uses(idx: ModuleIndex, fn: FunctionInfo, expr: ast.expr,
              hw: FrozenSet[str], guards: Guards, seen: set) -> List[KeyUse]:
    """All hw-dict key reads reachable from ``expr``, through local
    assignments and into called functions whose arguments carry the dict."""
    out: List[KeyUse] = []

    def walk(e: ast.AST, g: Guards) -> None:
        if isinstance(e, ast.Call):
            base, name = callee_parts(e)
            if name == "where" and len(e.args) == 3:
                cond, a, b = e.args
                walk(cond, g)
                atom = _guard_atom(cond)
                ga = g | {(atom, True)} if atom else g
                gb = g | {(atom, False)} if atom else g
                walk(a, frozenset(ga))
                walk(b, frozenset(gb))
                return
            for arg in list(e.args) + [kw.value for kw in e.keywords]:
                walk(arg, g)
            if isinstance(e.func, ast.Attribute) and \
                    not isinstance(e.func.value, ast.Name):
                walk(e.func.value, g)
            callee = idx.resolve_function(fn, base, name) if name else None
            if callee is not None:
                binding = bind_args(callee, e)
                hwf = frozenset(f for f, a in binding.items()
                                if isinstance(a, ast.Name) and a.id in hw)
                tok = ("fn", callee.module, callee.qualname, hwf, g)
                if hwf and tok not in seen:
                    seen.add(tok)
                    for rexpr, _ in callee.returns:
                        out.extend(_key_uses(idx, callee, rexpr, hwf, g, seen))
            return
        if isinstance(e, ast.IfExp):
            walk(e.test, g)
            atom = _guard_atom(e.test)
            walk(e.body, frozenset(g | {(atom, True)}) if atom else g)
            walk(e.orelse, frozenset(g | {(atom, False)}) if atom else g)
            return
        if isinstance(e, ast.Subscript) and isinstance(e.value, ast.Name):
            sl = e.slice
            if isinstance(sl, ast.Constant) and isinstance(sl.value, str):
                if e.value.id in hw:
                    out.append(KeyUse(sl.value, g, Site(fn.file, e.lineno)))
                else:
                    walk(e.value, g)
                return
            walk(e.value, g)
            walk(sl, g)
            return
        if isinstance(e, ast.Name):
            if e.id in hw:
                return
            tok = ("local", fn.module, fn.qualname, e.id, g)
            if e.id in fn.assigns and tok not in seen:
                seen.add(tok)
                for aexpr, _ in fn.assigns[e.id]:
                    walk(aexpr, g)
            return
        if isinstance(e, ast.Attribute):
            if not isinstance(e.value, ast.Name):
                walk(e.value, g)
            return
        for child in ast.iter_child_nodes(e):
            walk(child, g)

    walk(expr, guards)
    return out


# --------------------------------------------------------------------------
# where-tree decomposition (branches / leaves with guard chains)
# --------------------------------------------------------------------------

def _branches(idx: ModuleIndex, fn: FunctionInfo, expr: ast.expr,
              guards: Guards = frozenset(), expand_locals: bool = False,
              _depth: int = 0) -> List[Tuple[Guards, ast.expr]]:
    """Peel nested ``where(cond, a, b)`` calls into (guards, leaf) pairs.
    With ``expand_locals``, a leaf that is a plain local name is expanded
    through its assignment (used on `_dominant_class`)."""
    if _depth > 16:
        raise AnalysisError(f"where-tree too deep in {fn.qualname}")
    if isinstance(expr, ast.Call):
        _, name = callee_parts(expr)
        if name == "where" and len(expr.args) == 3:
            cond, a, b = expr.args
            atom = _guard_atom(cond)
            ga = frozenset(guards | {(atom, True)}) if atom else guards
            gb = frozenset(guards | {(atom, False)}) if atom else guards
            return (_branches(idx, fn, a, ga, expand_locals, _depth + 1) +
                    _branches(idx, fn, b, gb, expand_locals, _depth + 1))
    if expand_locals and isinstance(expr, ast.Name) and \
            expr.id in fn.assigns:
        exprs = fn.assigns[expr.id]
        if len(exprs) != 1:
            raise AnalysisError(
                f"{fn.qualname}: local {expr.id} assigned {len(exprs)} times;"
                " cannot decompose unambiguously")
        return _branches(idx, fn, exprs[0][0], guards, expand_locals,
                         _depth + 1)
    return [(guards, expr)]


def _contradicts(guards: Guards, constraint: Guards) -> bool:
    return any((n, not p) in guards for n, p in constraint)


def _compatible(guards: Guards, leaf_constraints: Sequence[Guards]) -> bool:
    """A branch is live for a class if its guards don't contradict the kind
    constraints of at least one of the class's attribution leaves."""
    if not leaf_constraints:
        return True
    return any(not _contradicts(guards, c) for c in leaf_constraints)


# --------------------------------------------------------------------------
# peak-key search: first denominator hw-key outward from a term branch
# --------------------------------------------------------------------------

def _peak_keys(idx: ModuleIndex,
               items: List[Tuple[FunctionInfo, ast.expr, FrozenSet[str], bool]],
               max_depth: int = 8) -> List[Tuple[str, Site]]:
    """Breadth-first search for hw-dict keys in division-denominator
    position, by levels of indirection (locals / callee returns).  The
    first level with any hit wins: the shallowest quantity a time term is
    divided by is the peak throughput that term saturates."""
    seen: set = set()
    for _ in range(max_depth):
        found: List[Tuple[str, Site]] = []
        nxt: List[Tuple[FunctionInfo, ast.expr, FrozenSet[str], bool]] = []

        def scan(fn: FunctionInfo, e: ast.AST, hw: FrozenSet[str],
                 den: bool) -> None:
            if isinstance(e, ast.BinOp) and isinstance(e.op, ast.Div):
                scan(fn, e.left, hw, den)
                scan(fn, e.right, hw, True)
                return
            if isinstance(e, ast.Subscript) and \
                    isinstance(e.value, ast.Name) and \
                    isinstance(e.slice, ast.Constant) and \
                    isinstance(e.slice.value, str):
                if e.value.id in hw and den:
                    found.append((e.slice.value, Site(fn.file, e.lineno)))
                return
            if isinstance(e, ast.Name):
                tok = (fn.module, fn.qualname, e.id, den)
                if e.id in fn.assigns and tok not in seen:
                    seen.add(tok)
                    for aexpr, _ in fn.assigns[e.id]:
                        nxt.append((fn, aexpr, hw, den))
                return
            if isinstance(e, ast.Call):
                base, name = callee_parts(e)
                for arg in list(e.args) + [kw.value for kw in e.keywords]:
                    scan(fn, arg, hw, den)
                callee = idx.resolve_function(fn, base, name) if name else None
                if callee is not None:
                    binding = bind_args(callee, e)
                    hwf = frozenset(f for f, a in binding.items()
                                    if isinstance(a, ast.Name) and a.id in hw)
                    tok = (callee.module, callee.qualname, hwf, den)
                    if hwf and tok not in seen:
                        seen.add(tok)
                        for rexpr, _ in callee.returns:
                            nxt.append((callee, rexpr, hwf, den))
                return
            if isinstance(e, ast.Attribute):
                if not isinstance(e.value, ast.Name):
                    scan(fn, e.value, hw, den)
                return
            for child in ast.iter_child_nodes(e):
                scan(fn, child, hw, den)

        for fn, e, hw, den in items:
            scan(fn, e, hw, den)
        if found:
            return found
        if not nxt:
            break
        items = nxt
    return []


# --------------------------------------------------------------------------
# typed graph
# --------------------------------------------------------------------------

# edge kinds, in pipeline order
EK_PARAM_DERIVED = "param->derived"
EK_DERIVED_TERM = "derived->term"
EK_TERM_STALL = "term->stall"
EK_DERIVED_STALL = "derived->stall"
EK_TERM_METRIC = "term->metric"
EK_DERIVED_METRIC = "derived->metric"
EK_STALL_PRIMARY = "stall->primary"


@dataclasses.dataclass(frozen=True)
class Edge:
    kind: str
    src: str
    dst: str
    guards: Tuple[str, ...] = ()
    sites: Tuple[str, ...] = ()

    def as_dict(self) -> dict:
        return {"kind": self.kind, "src": self.src, "dst": self.dst,
                "guards": list(self.guards), "sites": list(self.sites)}


def _guard_strs(guards: Guards) -> Tuple[str, ...]:
    return tuple(sorted(n if p else f"!{n}" for n, p in guards))


@dataclasses.dataclass
class InfluenceGraph:
    """The extracted param -> derived -> term -> stall -> metric graph."""

    params: Tuple[str, ...]
    derived: Tuple[str, ...]
    terms: Tuple[str, ...]
    stalls: Tuple[str, ...]
    metrics: Tuple[str, ...]
    edges: Tuple[Edge, ...]
    guard_kinds: Dict[str, str]     # guard local -> workload op-kind name
    primary: Dict[str, str]         # stall class -> primary relief param

    # -- queries -----------------------------------------------------------

    def edges_of(self, kind: str) -> List[Edge]:
        return [e for e in self.edges if e.kind == kind]

    def param_derived(self) -> Dict[str, Set[str]]:
        out: Dict[str, Set[str]] = {p: set() for p in self.params}
        for e in self.edges_of(EK_PARAM_DERIVED):
            out[e.src].add(e.dst)
        return out

    def derived_stalls(self) -> Dict[str, Set[str]]:
        out: Dict[str, Set[str]] = {d: set() for d in self.derived}
        for e in self.edges_of(EK_DERIVED_STALL):
            out[e.src].add(e.dst)
        return out

    def stall_params(self) -> Dict[str, Set[str]]:
        """stall class -> every parameter with a structural path into it."""
        ds = self.derived_stalls()
        out: Dict[str, Set[str]] = {c: set() for c in self.stalls}
        for p, dkeys in self.param_derived().items():
            for d in dkeys:
                for c in ds.get(d, ()):
                    out[c].add(p)
        return out

    def params_for_stall(self, stall: str) -> List[str]:
        return sorted(self.stall_params().get(stall, ()))

    def derived_to_metrics(self) -> Dict[str, Set[str]]:
        """derived quantity -> PPA metrics it feeds (the extracted
        replacement for the old hand-coded ``DERIVED_TO_METRICS``)."""
        latency_metrics = {e.dst for e in self.edges_of(EK_TERM_METRIC)}
        out: Dict[str, Set[str]] = {}
        for e in self.edges_of(EK_DERIVED_TERM):
            out.setdefault(e.src, set()).update(latency_metrics)
        for e in self.edges_of(EK_DERIVED_METRIC):
            out.setdefault(e.src, set()).add(e.dst)
        return out

    def param_metrics(self) -> Dict[str, Set[str]]:
        """param -> PPA metrics, via param->derived composed with
        derived->metrics (the full-surface source-derived influence map)."""
        d2m = self.derived_to_metrics()
        out: Dict[str, Set[str]] = {p: set() for p in self.params}
        for p, dkeys in self.param_derived().items():
            for d in dkeys:
                out[p].update(d2m.get(d, ()))
        return out

    def primary_resources(self) -> Dict[str, str]:
        return dict(self.primary)

    def provenance(self, kind: str, src: str, dst: str) -> Tuple[str, ...]:
        for e in self.edges:
            if (e.kind, e.src, e.dst) == (kind, src, dst):
                return e.sites
        return ()

    # -- rendering / serialization ----------------------------------------

    def render_param(self, param: str) -> str:
        """Human-readable influence chain for one parameter (README/CLI)."""
        if param not in self.params:
            raise KeyError(param)
        lines = [f"{param}"]
        dterm: Dict[str, List[Edge]] = {}
        for e in self.edges_of(EK_DERIVED_TERM):
            dterm.setdefault(e.src, []).append(e)
        dstall = self.derived_stalls()
        lat = sorted({e.dst for e in self.edges_of(EK_TERM_METRIC)})
        for e in self.edges_of(EK_PARAM_DERIVED):
            if e.src != param:
                continue
            lines.append(f"  -> {e.dst}  @ {e.sites[0]}")
            for te in dterm.get(e.dst, ()):
                g = f" [{','.join(te.guards)}]" if te.guards else ""
                cls = sorted(dstall.get(e.dst, ()))
                lines.append(f"     -> {te.dst}{g}  @ {te.sites[0]}"
                             f"  -> {'/'.join(cls)} -> {','.join(lat)}")
            for me in self.edges_of(EK_DERIVED_METRIC):
                if me.src == e.dst:
                    lines.append(f"     -> metric {me.dst}  @ {me.sites[0]}")
        prim = [c for c, p in sorted(self.primary.items()) if p == param]
        if prim:
            lines.append(f"  primary relief for: {', '.join(prim)}")
        return "\n".join(lines)

    def as_json(self) -> dict:
        return {
            "version": 1,
            "params": list(self.params),
            "derived": list(self.derived),
            "terms": list(self.terms),
            "stalls": list(self.stalls),
            "metrics": list(self.metrics),
            "guard_kinds": dict(sorted(self.guard_kinds.items())),
            "primary": dict(sorted(self.primary.items())),
            "edges": [e.as_dict() for e in self.edges],
        }

    def signature(self) -> dict:
        """Everything architectural, nothing positional: the structure CI
        guards (``extract --check``).  Provenance lines may drift with
        formatting-only refactors without failing the build."""
        d = self.as_json()
        d["edges"] = sorted([e["kind"], e["src"], e["dst"], e["guards"]]
                            for e in d["edges"])
        return d

    @classmethod
    def from_json(cls, d: dict) -> "InfluenceGraph":
        return cls(
            params=tuple(d["params"]), derived=tuple(d["derived"]),
            terms=tuple(d["terms"]), stalls=tuple(d["stalls"]),
            metrics=tuple(d["metrics"]),
            edges=tuple(Edge(e["kind"], e["src"], e["dst"],
                             tuple(e["guards"]), tuple(e["sites"]))
                        for e in d["edges"]),
            guard_kinds=dict(d["guard_kinds"]),
            primary=dict(d["primary"]))


# --------------------------------------------------------------------------
# extraction
# --------------------------------------------------------------------------

def _add_edge(acc: Dict[tuple, Set[str]], kind: str, src: str, dst: str,
              guards: Tuple[str, ...], sites: Sequence[Site]) -> None:
    acc.setdefault((kind, src, dst, guards), set()).update(
        str(s) for s in sites)


def _extract(idx: ModuleIndex) -> InfluenceGraph:
    from repro.perfmodel.critical_path import STALL_CLASSES
    from repro.perfmodel.designspace import PARAM_NAMES

    hw_fn = _fn(idx, _ANCHORS["hardware"])
    terms_fn = _fn(idx, _ANCHORS["terms"])
    dom_fn = _fn(idx, _ANCHORS["dominant"])
    batch_fn = _fn(idx, _ANCHORS["batch"])
    suite_fn = _fn(idx, _ANCHORS["suite"])
    acc: Dict[tuple, Set[str]] = {}

    # ---- param -> derived: derive_hardware's dict-literal return ---------
    if len(hw_fn.params) != 1:
        raise AnalysisError(f"{hw_fn.qualname}: expected 1 formal")
    vname = hw_fn.params[0]
    if not hw_fn.dict_returns:
        raise AnalysisError(f"{hw_fn.qualname}: no dict-literal return")
    derived = tuple(hw_fn.dict_returns)
    params = tuple(PARAM_NAMES)
    for dkey, (vexpr, _) in hw_fn.dict_returns.items():
        uses = _key_uses(idx, hw_fn, vexpr, frozenset([vname]),
                         frozenset(), set())
        if not uses:
            raise AnalysisError(
                f"derived key {dkey!r} reads no design parameter")
        for u in uses:
            if u.key not in params:
                raise AnalysisError(
                    f"derived key {dkey!r} reads unknown parameter {u.key!r}")
            _add_edge(acc, EK_PARAM_DERIVED, u.key, dkey, (), [u.site])

    # ---- guards: is_* locals comparing the op kind to workload constants -
    guard_kinds: Dict[str, str] = {}
    for gname, exprs in terms_fn.assigns.items():
        for gexpr, _ in exprs:
            if isinstance(gexpr, ast.Compare) and len(gexpr.ops) == 1 and \
                    isinstance(gexpr.ops[0], ast.Eq) and \
                    isinstance(gexpr.comparators[0], ast.Attribute) and \
                    isinstance(gexpr.comparators[0].value, ast.Name):
                attr = gexpr.comparators[0]
                const = idx.resolve_constant(terms_fn, attr.value.id,
                                             attr.attr)
                if const is not None:
                    guard_kinds[gname] = attr.attr
    if not guard_kinds:
        raise AnalysisError("no op-kind guards found in _op_terms")

    # ---- terms: the non-guard keys _dominant_class reads off _op_terms ---
    if not dom_fn.params:
        raise AnalysisError(f"{dom_fn.qualname}: expected a terms-dict formal")
    tname = dom_fn.params[0]
    dom_keys = {r.name for r in expr_reads(dom_fn.node, dom_fn.file)
                if r.kind == "key" and r.base == tname}
    # keys read via the unpacking locals too (t_compute = t["t_compute"])
    terms = tuple(k for k in terms_fn.dict_returns
                  if k in dom_keys and k not in guard_kinds)
    if not terms:
        raise AnalysisError("no op-time terms discovered from _dominant_class")

    # map term key -> the _op_terms local holding it
    term_local: Dict[str, str] = {}
    for tkey in terms:
        vexpr, _ = terms_fn.dict_returns[tkey]
        if not isinstance(vexpr, ast.Name):
            raise AnalysisError(f"term {tkey!r} is not a plain local")
        term_local[tkey] = vexpr.id

    # ---- _dominant_class: (guards -> class) leaves -----------------------
    stall_classes = tuple(STALL_CLASSES)
    if len(dom_fn.returns) != 1:
        raise AnalysisError(f"{dom_fn.qualname}: expected a single return")
    ret_expr, _ = dom_fn.returns[0]
    leaves = _branches(idx, dom_fn, ret_expr, expand_locals=True)

    # dominance locals: Compare-structured; their subject is a term key
    def _dominance_subject(local: str) -> Optional[str]:
        exprs = dom_fn.assigns.get(local)
        if not exprs:
            return None
        subjects = set()
        for node in ast.walk(exprs[0][0]):
            if isinstance(node, ast.Compare) and \
                    isinstance(node.left, ast.Name) and \
                    any(isinstance(op, (ast.Gt, ast.GtE)) for op in node.ops):
                subjects.add(node.left.id)
        if len(subjects) != 1:
            return None
        subj = next(iter(subjects))
        # subject local -> t["<key>"] -> term key
        for sexpr, _site in dom_fn.assigns.get(subj, ()):
            for r in expr_reads(sexpr, dom_fn.file):
                if r.kind == "key" and r.base == tname and r.name in terms:
                    return r.name
        return None

    class_term: Dict[str, str] = {}
    class_constraints: Dict[str, List[Guards]] = {}
    class_sites: Dict[str, List[Site]] = {c: [] for c in stall_classes}
    for guards, leaf in leaves:
        if not isinstance(leaf, ast.Name):
            raise AnalysisError(
                f"{dom_fn.qualname}: non-constant attribution leaf at "
                f"line {getattr(leaf, 'lineno', '?')}")
        const = idx.resolve_constant(dom_fn, None, leaf.id)
        if const is None or not isinstance(const[0], int):
            raise AnalysisError(
                f"{dom_fn.qualname}: leaf {leaf.id!r} is not an int constant")
        cval, csite = const
        if not 0 <= cval < len(stall_classes):
            raise AnalysisError(f"class constant {leaf.id}={cval} out of "
                                f"range for STALL_CLASSES")
        cname = stall_classes[cval]
        class_sites[cname].append(Site(dom_fn.file, leaf.lineno))
        class_sites[cname].append(csite)
        kind_atoms = frozenset((n, p) for n, p in guards if n in guard_kinds)
        class_constraints.setdefault(cname, []).append(kind_atoms)
        for n, p in guards:
            if n in guard_kinds or not p:
                continue
            subj = _dominance_subject(n)
            if subj is None:
                raise AnalysisError(
                    f"{dom_fn.qualname}: cannot find dominance subject of "
                    f"guard {n!r}")
            if class_term.get(cname, subj) != subj:
                raise AnalysisError(f"class {cname}: conflicting terms")
            class_term[cname] = subj

    # classes with no positive dominance guard get the leftover term
    unclaimed = [c for c in class_constraints if c not in class_term]
    leftover = [t for t in terms if t not in class_term.values()]
    if len(unclaimed) == 1 and len(leftover) == 1:
        class_term[unclaimed[0]] = leftover[0]
    elif unclaimed:
        raise AnalysisError(
            f"cannot attribute terms by elimination: classes {unclaimed} "
            f"vs leftover terms {leftover}")
    stalls = tuple(c for c in stall_classes if c in class_term)
    if set(stalls) != set(stall_classes):
        raise AnalysisError(
            f"attribution covers {stalls}, expected {stall_classes}")

    # ---- derived -> term (guarded key uses of each term's dataflow) ------
    if not terms_fn.params:
        raise AnalysisError(f"{terms_fn.qualname}: expected a hw-dict formal")
    hwb = frozenset([terms_fn.params[0]])
    term_uses: Dict[str, List[KeyUse]] = {}
    for tkey in terms:
        uses: List[KeyUse] = []
        for aexpr, _ in terms_fn.assigns.get(term_local[tkey], ()):
            uses.extend(_key_uses(idx, terms_fn, aexpr, hwb,
                                  frozenset(), set()))
        if not uses:
            raise AnalysisError(f"term {tkey!r} reads no derived hw key")
        term_uses[tkey] = uses
        for u in uses:
            if u.key not in derived:
                raise AnalysisError(
                    f"term {tkey!r} reads {u.key!r}, not a derived key")
            _add_edge(acc, EK_DERIVED_TERM, u.key, tkey,
                      _guard_strs(u.guards), [u.site])

    # ---- term -> stall + derived -> stall (constraint-compatible) --------
    for cname in stalls:
        tkey = class_term[cname]
        constraints = class_constraints[cname]
        _add_edge(acc, EK_TERM_STALL, tkey, cname,
                  tuple(sorted({s for c in constraints
                                for s in _guard_strs(c)})),
                  class_sites[cname])
        for u in term_uses[tkey]:
            if _compatible(u.guards, constraints):
                _add_edge(acc, EK_DERIVED_STALL, u.key, cname,
                          _guard_strs(u.guards), [u.site])

    # ---- term -> metric: latency reduction + the suite's metric names ----
    # the latency local is the one reducing a key of the op-terms dict
    lat_local, lat_site = None, None
    tdict_locals = {n for n, exprs in batch_fn.assigns.items()
                    for aexpr, _ in exprs
                    if isinstance(aexpr, ast.Call) and
                    callee_parts(aexpr)[1] == terms_fn.name}
    if not tdict_locals:
        raise AnalysisError(
            f"{batch_fn.qualname}: no call to {terms_fn.name} found")
    for lname, exprs in batch_fn.assigns.items():
        for aexpr, asite in exprs:
            for r in expr_reads(aexpr, batch_fn.file):
                if r.kind == "key" and r.base in tdict_locals and \
                        r.name in terms_fn.dict_returns and \
                        r.name not in guard_kinds:
                    # chase the op-terms key back to the time terms
                    start, _ = terms_fn.dict_returns[r.name]
                    hits = _name_closure(terms_fn, start,
                                         set(term_local.values()))
                    if set(hits) == set(term_local.values()):
                        lat_local, lat_site = lname, asite
                        term_hits = hits
                        break
            if lat_local:
                break
        if lat_local:
            break
    if lat_local is None:
        raise AnalysisError(
            f"{batch_fn.qualname}: no local reduces all op-time terms")

    metric_names, suite_site = _suite_metrics(suite_fn)
    for tkey in terms:
        hsite = term_hits[term_local[tkey]]
        for m in metric_names:
            _add_edge(acc, EK_TERM_METRIC, tkey, m,
                      (), [hsite, lat_site, suite_site])

    # ---- derived -> metric: the area key feeds the area metric -----------
    if _AREA_KEY not in derived:
        raise AnalysisError(f"derived key {_AREA_KEY!r} missing")
    _add_edge(acc, EK_DERIVED_METRIC, _AREA_KEY, _AREA_METRIC,
              (), [hw_fn.dict_returns[_AREA_KEY][1]])
    metrics = tuple(metric_names) + (_AREA_METRIC,)

    # ---- primary resources: peak key + class exclusivity -----------------
    edges = tuple(Edge(k, s, d, g, tuple(sorted(sites)))
                  for (k, s, d, g), sites in sorted(acc.items()))
    graph = InfluenceGraph(params=params, derived=derived, terms=terms,
                           stalls=stalls, metrics=metrics, edges=edges,
                           guard_kinds=guard_kinds, primary={})
    stall_params = graph.stall_params()
    param_stalls: Dict[str, Set[str]] = {p: set() for p in params}
    for c, ps in stall_params.items():
        for p in ps:
            param_stalls[p].add(c)
    pderived = graph.param_derived()

    primary: Dict[str, str] = {}
    prim_edges: Dict[tuple, Set[str]] = {}
    for cname in stalls:
        tkey = class_term[cname]
        constraints = class_constraints[cname]
        items = []
        for aexpr, _ in terms_fn.assigns.get(term_local[tkey], ()):
            for guards, leaf in _branches(idx, terms_fn, aexpr):
                if _compatible(guards, constraints):
                    items.append((terms_fn, leaf, hwb, False))
        peaks = _peak_keys(idx, items)
        if not peaks:
            raise AnalysisError(f"class {cname}: no peak (denominator) key "
                                f"found in term {tkey!r}")
        peak_keys = {k for k, _ in peaks}
        cands = sorted(p for p in params
                       if pderived[p] & peak_keys and
                       param_stalls[p] == {cname})
        if len(cands) != 1:
            raise AnalysisError(
                f"class {cname}: primary parameter not unique: {cands} "
                f"(peak keys {sorted(peak_keys)})")
        primary[cname] = cands[0]
        sites = {str(s) for _, s in peaks}
        for e in graph.edges_of(EK_PARAM_DERIVED):
            if e.src == cands[0] and e.dst in peak_keys:
                sites.update(e.sites)
        prim_edges[(EK_STALL_PRIMARY, cname, cands[0], ())] = sites

    graph.primary = primary
    graph.edges = graph.edges + tuple(
        Edge(k, s, d, g, tuple(sorted(sites)))
        for (k, s, d, g), sites in sorted(prim_edges.items()))
    return graph


def _name_closure(fn: FunctionInfo, start: ast.expr,
                  targets: Set[str]) -> Dict[str, Site]:
    """Which of ``targets`` (locals of fn) are read, transitively through
    local assignments, starting from ``start``; with the site of the first
    read found."""
    hits: Dict[str, Site] = {}
    seen: Set[str] = set()
    work: List[ast.expr] = [start]
    while work:
        e = work.pop()
        for r in expr_reads(e, fn.file):
            if r.kind != "name":
                continue
            if r.name in targets:
                hits.setdefault(r.name, r.site)
            elif r.name in fn.assigns and r.name not in seen:
                seen.add(r.name)
                work.extend(ae for ae, _ in fn.assigns[r.name])
    return hits


def _suite_metrics(suite_fn: FunctionInfo) -> Tuple[Tuple[str, ...], Site]:
    """The latency metric names: the keys of the workload-dict literal the
    paper suite builds (``{"ttft": ..., "tpot": ...}``)."""
    for _, exprs in suite_fn.assigns.items():
        for aexpr, asite in exprs:
            if isinstance(aexpr, ast.Dict) and aexpr.keys and all(
                    isinstance(k, ast.Constant) and isinstance(k.value, str)
                    for k in aexpr.keys):
                return tuple(k.value for k in aexpr.keys), asite
    raise AnalysisError(
        f"{suite_fn.qualname}: no workload-dict literal found")


@lru_cache(maxsize=1)
def extract_influence_graph() -> InfluenceGraph:
    """Extract (and cache) the influence graph from the perfmodel source."""
    idx = ModuleIndex.build(_perfmodel_modules())
    return _extract(idx)


@lru_cache(maxsize=1)
def _primary_cached() -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted(extract_influence_graph().primary.items()))


def primary_resources() -> Dict[str, str]:
    """stall class -> the parameter that most directly relieves it, derived
    from the perfmodel source (replaces the hand-coded AHK tables that
    lived in ``core/llm.py`` / ``core/strategy.py``)."""
    return dict(_primary_cached())


def derived_to_metrics() -> Dict[str, Set[str]]:
    """Extracted replacement for ``repro.core.quale_ast.DERIVED_TO_METRICS``.

    Differs from the old hand table in one honest way: the passthrough key
    ``vector_width`` is NOT read by any op-time term (only
    ``vector_flops`` is), so it maps to no latency metric here; the old
    table's entry was redundant for the param-level map."""
    return extract_influence_graph().derived_to_metrics()


def derive_influence_map_from_source() -> Dict[str, Set[str]]:
    """param -> set of PPA metrics, from source over the FULL perfmodel
    surface (signature-compatible with the deprecated quale_ast version,
    which only analyzed two hardware functions)."""
    return extract_influence_graph().param_metrics()


# --------------------------------------------------------------------------
# cross-validation against the probe-based QualE map
# --------------------------------------------------------------------------

@dataclasses.dataclass
class RuleAudit:
    """Source-vs-probe disagreement report (the measurable half of the
    paper's rule auto-correction loop).

    * ``metric_probe_only`` non-empty means the extraction MISSED real
      dataflow — an extractor bug worth failing on.
    * ``metric_source_only`` is benign over-approximation (the probes did
      not excite that edge at the sampled designs).
    * ``stall_probe_only`` is *attribution coupling*: perturbing a param
      moves which ops dominate another class without structurally feeding
      it (e.g. growing ``sa_dim`` shifts memory-bound attribution).
    * ``stall_source_only`` is a structural path the probes never saw.
    """

    metric_agree: Dict[str, List[str]]
    metric_probe_only: Dict[str, List[str]]
    metric_source_only: Dict[str, List[str]]
    stall_agree: Dict[str, List[str]]
    stall_probe_only: Dict[str, List[str]]
    stall_source_only: Dict[str, List[str]]

    def counts(self) -> Dict[str, int]:
        return {f: sum(len(v) for v in getattr(self, f).values())
                for f in ("metric_agree", "metric_probe_only",
                          "metric_source_only", "stall_agree",
                          "stall_probe_only", "stall_source_only")}

    def corrections(self) -> List[str]:
        """Telemetry lines for the rule auto-correction loop."""
        out = []
        for p, ms in sorted(self.metric_probe_only.items()):
            if ms:
                out.append(f"EXTRACTION-GAP {p}: probes move {ms} but no "
                           f"source path found")
        for p, cs in sorted(self.stall_probe_only.items()):
            if cs:
                out.append(f"attribution-coupling {p}: probes move stall "
                           f"{cs} without a structural path")
        for p, cs in sorted(self.stall_source_only.items()):
            if cs:
                out.append(f"unexercised {p}: structural path to stall "
                           f"{cs} not excited by probes")
        return out

    def as_dict(self) -> dict:
        d = {f: {k: list(v) for k, v in getattr(self, f).items() if v}
             for f in ("metric_agree", "metric_probe_only",
                       "metric_source_only", "stall_agree",
                       "stall_probe_only", "stall_source_only")}
        d["counts"] = self.counts()
        return d


def _diff(src: Dict[str, Set[str]], probed: Dict[str, Set[str]],
          params) -> Tuple[Dict[str, List[str]], Dict[str, List[str]],
                           Dict[str, List[str]]]:
    agree, ponly, sonly = {}, {}, {}
    for p in params:
        s, pr = src.get(p, set()), probed.get(p, set())
        agree[p] = sorted(s & pr)
        ponly[p] = sorted(pr - s)
        sonly[p] = sorted(s - pr)
    return agree, ponly, sonly


def cross_validate(graph: InfluenceGraph, probed) -> RuleAudit:
    """Compare the source-extracted graph against a probe-based
    :class:`repro.core.quale.InfluenceMap`."""
    src_m = graph.param_metrics()
    src_s_by_stall = graph.stall_params()
    src_s: Dict[str, Set[str]] = {p: set() for p in graph.params}
    for c, ps in src_s_by_stall.items():
        for p in ps:
            src_s[p].add(c)
    ma, mp, ms = _diff(src_m, probed.metric_edges, graph.params)
    sa, sp, ss = _diff(src_s, probed.stall_edges, graph.params)
    return RuleAudit(metric_agree=ma, metric_probe_only=mp,
                     metric_source_only=ms, stall_agree=sa,
                     stall_probe_only=sp, stall_source_only=ss)


def load_artifact(path: Optional[Path] = None) -> InfluenceGraph:
    p = path or ARTIFACT_PATH
    return InfluenceGraph.from_json(json.loads(p.read_text()))
