"""Invariant linter for the repo's jit/concurrency stack.

AST checks tuned to THIS codebase (run as ``python -m repro.analysis.lint
[paths] --baseline .lint-baseline.json``).  CI fails only on findings not
recorded in the checked-in baseline, so intentional patterns are accepted
once — with a one-line justification — and every new occurrence is a
build failure.

Rules:

=====================  =====================================================
``mutable-default``    A function parameter default is a mutable literal or
                       constructor (the PR-6 ``RetryPolicy`` footgun).
``unlocked-shared-write``  In ``distributed/``/``serve/``: a class that owns
                       a lock mutates a container attribute outside any
                       ``with <lock>`` block (methods documented as
                       "caller holds the lock" are exempt).
``future-swallow``     A function that creates ``Future``\\ s has an
                       ``except`` handler that neither re-raises nor
                       resolves/cancels a future nor delegates to a
                       die/fail path — in-flight futures can hang forever.
``thread-not-daemon``  ``threading.Thread``/``Timer`` created without
                       ``daemon=True`` (kwarg or attribute before start):
                       leaked helpers block interpreter shutdown.
``executor-leak``      A ``ThreadPoolExecutor``/``ProcessPoolExecutor``
                       constructed outside ``with`` whose owner has no
                       visible ``.shutdown(`` path.
``jit-static-mutable`` ``jax.jit(..., static_argnums=[...])`` with a
                       mutable literal spec (unhashable-static hazard).
``jit-traced-branch``  A ``@jax.jit``-decorated function branches with
                       Python ``if``/``while`` on a traced parameter
                       (shape/isinstance/None checks are fine).
``host-sync-hot-loop`` Inside a loop, a value produced by jnp/jitted calls
                       in that same loop is pulled to host
                       (``float()``/``np.asarray``/``block_until_ready``)
                       — a per-iteration device sync in a hot path.
``raw-telemetry-dict`` In ``distributed/``/``serve/``: a public ``self``
                       attribute zero-initialized in ``__init__`` (``= 0``
                       or a dict of zeros) is ``+=``-incremented — an
                       ad-hoc telemetry counter that should be a
                       :class:`repro.obs.metrics.Counter` (typed, locked,
                       exported).  Underscore-prefixed attributes are
                       internal state, not telemetry, and are exempt.
=====================  =====================================================
"""
from __future__ import annotations

import ast
import dataclasses
import json
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from repro.analysis.dataflow import repo_relative

CONCURRENCY_SCOPES = ("distributed/", "serve/")

_MUTABLE_CTORS = {"list", "dict", "set", "deque", "defaultdict",
                  "OrderedDict", "bytearray", "Counter"}
_LOCK_CTORS = {"Lock", "RLock", "Condition", "Semaphore", "BoundedSemaphore"}
_CONTAINER_CTORS = {"list", "dict", "set", "deque", "defaultdict",
                    "OrderedDict", "Counter"}
_MUTATORS = {"append", "add", "update", "pop", "popitem", "popleft",
             "appendleft", "remove", "discard", "clear", "setdefault",
             "extend", "insert"}
_RESOLVERS = {"set_exception", "set_result", "cancel"}
_EXECUTORS = {"ThreadPoolExecutor", "ProcessPoolExecutor"}
_HOST_PULLS = {"float", "int", "asarray", "array", "item"}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    file: str          # repo-relative
    line: int
    symbol: str        # enclosing qualname
    message: str

    @property
    def key(self) -> Tuple[str, str, str]:
        # line-free so refactors that shift code don't churn the baseline
        return (self.rule, self.file, self.symbol)

    def __str__(self) -> str:
        return f"{self.file}:{self.line}: [{self.rule}] {self.symbol}: " \
               f"{self.message}"


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def _call_name(call: ast.Call) -> Optional[str]:
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None


def _is_jit_expr(e: ast.expr) -> bool:
    """jax.jit / jit / functools.partial(jax.jit, ...)"""
    if isinstance(e, ast.Attribute) and e.attr == "jit":
        return True
    if isinstance(e, ast.Name) and e.id == "jit":
        return True
    if isinstance(e, ast.Call):
        if _call_name(e) in ("jit",):
            return True
        if _call_name(e) == "partial" and e.args and _is_jit_expr(e.args[0]):
            return True
        return _is_jit_expr(e.func)
    return False


def _iter_scopes(tree: ast.Module) -> Iterator[Tuple[str, ast.AST]]:
    """(qualname, node) for every function at any nesting depth."""
    def rec(node: ast.AST, prefix: str) -> Iterator[Tuple[str, ast.AST]]:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}{child.name}"
                yield q, child
                yield from rec(child, f"{q}.")
            elif isinstance(child, ast.ClassDef):
                yield from rec(child, f"{prefix}{child.name}.")
    yield from rec(tree, "")


def _docstring(node: ast.AST) -> str:
    try:
        return ast.get_docstring(node) or ""
    except TypeError:
        return ""


# --------------------------------------------------------------------------
# rules
# --------------------------------------------------------------------------

def _rule_mutable_default(tree: ast.Module, file: str) -> Iterator[Finding]:
    for qual, fn in _iter_scopes(tree):
        defaults = list(fn.args.defaults) + \
            [d for d in fn.args.kw_defaults if d is not None]
        for d in defaults:
            bad = isinstance(d, (ast.List, ast.Dict, ast.Set)) or (
                isinstance(d, ast.Call) and _call_name(d) in _MUTABLE_CTORS)
            if bad:
                yield Finding("mutable-default", file, d.lineno, qual,
                              "mutable default argument is shared across "
                              "calls")


def _self_attr(e: ast.expr) -> Optional[str]:
    if isinstance(e, ast.Attribute) and isinstance(e.value, ast.Name) and \
            e.value.id == "self":
        return e.attr
    return None


def _mentions_lock(e: ast.expr, locks: Set[str]) -> bool:
    return any(isinstance(n, ast.Attribute) and n.attr in locks and
               isinstance(n.value, ast.Name) and n.value.id == "self"
               for n in ast.walk(e))


def _rule_unlocked_shared_write(tree: ast.Module,
                                file: str) -> Iterator[Finding]:
    if not any(s in file for s in CONCURRENCY_SCOPES):
        return
    for cls in tree.body:
        if not isinstance(cls, ast.ClassDef):
            continue
        methods = {m.name: m for m in cls.body
                   if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef))}
        init = methods.get("__init__")
        if init is None:
            continue
        locks: Set[str] = set()
        containers: Set[str] = set()
        for stmt in ast.walk(init):
            if not isinstance(stmt, ast.Assign):
                continue
            for tgt in stmt.targets:
                attr = _self_attr(tgt)
                if attr is None:
                    continue
                rhs_calls = {_call_name(n) for n in ast.walk(stmt.value)
                             if isinstance(n, ast.Call)}
                if rhs_calls & _LOCK_CTORS:
                    locks.add(attr)
                elif isinstance(stmt.value, (ast.Dict, ast.List, ast.Set)) \
                        or rhs_calls & _CONTAINER_CTORS:
                    containers.add(attr)
        if not locks or not containers:
            continue

        for mname, m in methods.items():
            if mname == "__init__":
                continue
            doc = _docstring(m).lower()
            if "holds the lock" in doc or "caller holds" in doc or \
                    "lock held" in doc:
                continue

            def scan(node: ast.AST, locked: bool) -> Iterator[Finding]:
                for child in ast.iter_child_nodes(node):
                    if isinstance(child, ast.With):
                        l2 = locked or any(
                            _mentions_lock(item.context_expr, locks)
                            for item in child.items)
                        yield from scan(child, l2)
                        continue
                    if isinstance(child,
                                  (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.Lambda)):
                        continue    # nested callbacks judged on their own
                    if not locked:
                        w = _container_write(child, containers)
                        if w is not None:
                            attr, verb = w
                            yield Finding(
                                "unlocked-shared-write", file, child.lineno,
                                f"{cls.name}.{mname}",
                                f"self.{attr} {verb} outside a held lock "
                                f"(class owns {sorted(locks)})")
                    yield from scan(child, locked)

            yield from scan(m, locked=False)


def _container_write(node: ast.AST,
                     containers: Set[str]) -> Optional[Tuple[str, str]]:
    if isinstance(node, (ast.Assign, ast.AugAssign)):
        targets = node.targets if isinstance(node, ast.Assign) \
            else [node.target]
        for tgt in targets:
            if isinstance(tgt, ast.Subscript):
                attr = _self_attr(tgt.value)
                if attr in containers:
                    return attr, "item-assigned"
            attr = _self_attr(tgt)
            if attr in containers:
                return attr, "rebound"
    if isinstance(node, ast.Delete):
        for tgt in node.targets:
            if isinstance(tgt, ast.Subscript):
                attr = _self_attr(tgt.value)
                if attr in containers:
                    return attr, "item-deleted"
    if isinstance(node, ast.Expr) and isinstance(node.value, ast.Call):
        f = node.value.func
        if isinstance(f, ast.Attribute) and f.attr in _MUTATORS:
            attr = _self_attr(f.value)
            if attr in containers:
                return attr, f".{f.attr}()"
    return None


def _rule_future_swallow(tree: ast.Module, file: str) -> Iterator[Finding]:
    for qual, fn in _iter_scopes(tree):
        makes_future = any(
            isinstance(n, ast.Call) and _call_name(n) == "Future"
            for n in ast.walk(fn))
        if not makes_future:
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.ExceptHandler):
                continue
            ok = False
            for sub in ast.walk(node):
                if isinstance(sub, ast.Raise):
                    ok = True
                elif isinstance(sub, ast.Call):
                    name = _call_name(sub)
                    if name in _RESOLVERS or (
                            name and ("die" in name or "fail" in name)):
                        ok = True
            if not ok:
                yield Finding(
                    "future-swallow", file, node.lineno, qual,
                    "except path neither re-raises nor resolves/fails the "
                    "pending future(s) created in this function")


def _rule_thread_not_daemon(tree: ast.Module, file: str) -> Iterator[Finding]:
    for qual, fn in _iter_scopes(tree):
        body = list(ast.walk(fn))
        # names whose .daemon is assigned True anywhere in this function
        daemonized: Set[str] = set()
        for node in body:
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Constant) and \
                    node.value.value is True:
                for tgt in node.targets:
                    if isinstance(tgt, ast.Attribute) and \
                            tgt.attr == "daemon":
                        daemonized.add(ast.unparse(tgt.value))
        for node in body:
            if not (isinstance(node, ast.Assign) and
                    isinstance(node.value, ast.Call) and
                    _call_name(node.value) in ("Thread", "Timer")):
                continue
            call = node.value
            if any(kw.arg == "daemon" for kw in call.keywords):
                continue
            tgt_names = {ast.unparse(t) for t in node.targets}
            if tgt_names & daemonized:
                continue
            yield Finding(
                "thread-not-daemon", file, node.lineno, qual,
                f"{_call_name(call)} created without daemon=True; a leaked "
                "helper blocks interpreter shutdown")


def _rule_executor_leak(tree: ast.Module, file: str) -> Iterator[Finding]:
    src_has_shutdown = any(
        isinstance(n, ast.Attribute) and n.attr == "shutdown"
        for n in ast.walk(tree))
    for qual, fn in _iter_scopes(tree):
        with_ctx: Set[int] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.With):
                for item in node.items:
                    for sub in ast.walk(item.context_expr):
                        with_ctx.add(id(sub))
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Call) and
                    _call_name(node) in _EXECUTORS):
                continue
            if id(node) in with_ctx:
                continue
            if src_has_shutdown:
                # an explicit lifecycle exists somewhere in this file;
                # pairing construction to shutdown is the baseline's job
                continue
            yield Finding(
                "executor-leak", file, node.lineno, qual,
                f"{_call_name(node)} constructed outside `with` and no "
                ".shutdown( anywhere in this file")


def _rule_jit_static_mutable(tree: ast.Module, file: str) -> Iterator[Finding]:
    for qual, fn in _iter_scopes(tree):
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Call) and _is_jit_expr(node.func)
                    or isinstance(node, ast.Call) and
                    _is_jit_expr(node)):
                continue
            for kw in getattr(node, "keywords", ()):
                if kw.arg in ("static_argnums", "static_argnames") and \
                        isinstance(kw.value, (ast.List, ast.Dict, ast.Set)):
                    yield Finding(
                        "jit-static-mutable", file, kw.value.lineno, qual,
                        f"{kw.arg} given as a mutable literal; use a tuple "
                        "(static specs are hashed into the jit cache key)")


def _rule_jit_traced_branch(tree: ast.Module, file: str) -> Iterator[Finding]:
    for qual, fn in _iter_scopes(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        jitted = any(_is_jit_expr(d) for d in fn.decorator_list)
        if not jitted:
            continue
        static: Set[str] = set()
        for d in fn.decorator_list:
            if isinstance(d, ast.Call):
                for kw in d.keywords:
                    if kw.arg == "static_argnames":
                        for n in ast.walk(kw.value):
                            if isinstance(n, ast.Constant) and \
                                    isinstance(n.value, str):
                                static.add(n.value)
        params = {a.arg for a in fn.args.args + fn.args.kwonlyargs} - static
        for node in ast.walk(fn):
            if not isinstance(node, (ast.If, ast.While)):
                continue
            test = node.test
            reads = {n.id for n in ast.walk(test)
                     if isinstance(n, ast.Name)}
            if not reads & params:
                continue
            benign = any(
                (isinstance(n, ast.Call) and
                 _call_name(n) in ("isinstance", "len", "hasattr")) or
                (isinstance(n, ast.Attribute) and
                 n.attr in ("shape", "ndim", "dtype", "size")) or
                (isinstance(n, ast.Constant) and n.value is None)
                for n in ast.walk(test))
            if benign:
                continue
            yield Finding(
                "jit-traced-branch", file, node.lineno, qual,
                "Python branch on a traced argument inside a jitted "
                "function (TracerBoolConversionError / silent retrace)")


def _rule_host_sync_hot_loop(tree: ast.Module, file: str) -> Iterator[Finding]:
    for qual, fn in _iter_scopes(tree):
        for loop in ast.walk(fn):
            if not isinstance(loop, (ast.For, ast.While)):
                continue
            device_locals: Set[str] = set()
            for node in ast.walk(loop):
                if isinstance(node, ast.Assign) and \
                        isinstance(node.value, ast.Call):
                    fsrc = ast.unparse(node.value.func)
                    if fsrc.startswith("jnp.") or "jit" in fsrc.lower():
                        for tgt in node.targets:
                            if isinstance(tgt, ast.Name):
                                device_locals.add(tgt.id)
            if not device_locals:
                continue
            for node in ast.walk(loop):
                if not isinstance(node, ast.Call):
                    continue
                name = _call_name(node)
                pulled = None
                if name in _HOST_PULLS and node.args and \
                        isinstance(node.args[0], ast.Name) and \
                        node.args[0].id in device_locals:
                    pulled = node.args[0].id
                elif name == "block_until_ready" and \
                        isinstance(node.func, ast.Attribute) and \
                        isinstance(node.func.value, ast.Name) and \
                        node.func.value.id in device_locals:
                    pulled = node.func.value.id
                if pulled:
                    yield Finding(
                        "host-sync-hot-loop", file, node.lineno, qual,
                        f"`{pulled}` is computed on device and pulled to "
                        "host every iteration of this loop")


def _is_zero_counter_init(value: ast.expr) -> bool:
    """`= 0`, `= {...: 0}` or `= {k: 0 for ...}` — the ad-hoc counter
    initialization shapes the registry replaces."""
    if isinstance(value, ast.Constant):
        return value.value == 0 and not isinstance(value.value, bool)
    if isinstance(value, ast.Dict):
        return bool(value.values) and all(
            isinstance(v, ast.Constant) and v.value == 0
            for v in value.values)
    if isinstance(value, ast.DictComp):
        return isinstance(value.value, ast.Constant) and \
            value.value.value == 0
    return False


def _rule_raw_telemetry_dict(tree: ast.Module,
                             file: str) -> Iterator[Finding]:
    if not any(s in file for s in CONCURRENCY_SCOPES):
        return
    for cls in tree.body:
        if not isinstance(cls, ast.ClassDef):
            continue
        methods = {m.name: m for m in cls.body
                   if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef))}
        init = methods.get("__init__")
        if init is None:
            continue
        counters: Set[str] = set()
        for stmt in ast.walk(init):
            if not isinstance(stmt, ast.Assign):
                continue
            for tgt in stmt.targets:
                attr = _self_attr(tgt)
                if attr is None or attr.startswith("_"):
                    continue
                if _is_zero_counter_init(stmt.value):
                    counters.add(attr)
        if not counters:
            continue
        for mname, m in methods.items():
            if mname == "__init__":
                continue
            for node in ast.walk(m):
                if not isinstance(node, ast.AugAssign):
                    continue
                tgt = node.target
                attr = _self_attr(tgt)
                if attr is None and isinstance(tgt, ast.Subscript):
                    attr = _self_attr(tgt.value)
                if attr in counters:
                    yield Finding(
                        "raw-telemetry-dict", file, node.lineno,
                        f"{cls.name}.{mname}",
                        f"self.{attr} is an ad-hoc telemetry counter "
                        "(zero-initialized in __init__, incremented here); "
                        "register a repro.obs.metrics Counter instead")


_PICKLE_LOADERS = {"loads", "load", "Unpickler"}


def _rule_pickle_outside_codec(tree: ast.Module,
                               file: str) -> Iterator[Finding]:
    """Pickle DESERIALIZATION on the serve/distributed surface is remote
    code execution for whoever owns the bytes; the only sanctioned sites
    are ``serve/codec.py``'s shims (the legacy ``insecure=True`` path and
    the allowlist-restricted unpickler) — everything else must route
    through them or carry a baseline entry for an intentional
    single-trust-domain use."""
    if not any(s in file for s in CONCURRENCY_SCOPES):
        return
    if file.replace("\\", "/").endswith("serve/codec.py"):
        return                          # the sanctioned shim module
    aliases = {"pickle"}
    bare: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "pickle":
                    aliases.add(a.asname or "pickle")
        elif isinstance(node, ast.ImportFrom) and node.module == "pickle":
            for a in node.names:
                if a.name in _PICKLE_LOADERS:
                    bare.add(a.asname or a.name)

    def hit(call: ast.Call) -> Optional[str]:
        f = call.func
        if isinstance(f, ast.Attribute) and f.attr in _PICKLE_LOADERS and \
                isinstance(f.value, ast.Name) and f.value.id in aliases:
            return f"pickle.{f.attr}"
        if isinstance(f, ast.Name) and f.id in bare:
            return f.id
        return None

    def visit(node: ast.AST, qual: str) -> Iterator[Finding]:
        for child in ast.iter_child_nodes(node):
            if isinstance(child,
                          (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.ClassDef)):
                inner = (child.name if qual == "<module>"
                         else f"{qual}.{child.name}")
                yield from visit(child, inner)
                continue
            for n in ast.walk(child):
                if isinstance(n, ast.Call):
                    name = hit(n)
                    if name is not None:
                        yield Finding(
                            "pickle-outside-codec", file, n.lineno, qual,
                            f"{name} deserializes attacker-controlled "
                            "bytes into arbitrary objects; route through "
                            "repro.serve.codec (restricted_loads / "
                            "legacy_loads) instead")

    yield from visit(tree, "<module>")


_RULES = (
    _rule_mutable_default,
    _rule_unlocked_shared_write,
    _rule_future_swallow,
    _rule_thread_not_daemon,
    _rule_executor_leak,
    _rule_jit_static_mutable,
    _rule_jit_traced_branch,
    _rule_host_sync_hot_loop,
    _rule_raw_telemetry_dict,
    _rule_pickle_outside_codec,
)

RULE_NAMES = ("mutable-default", "unlocked-shared-write", "future-swallow",
              "thread-not-daemon", "executor-leak", "jit-static-mutable",
              "jit-traced-branch", "host-sync-hot-loop",
              "raw-telemetry-dict", "pickle-outside-codec")


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------

def lint_file(path: Path) -> List[Finding]:
    try:
        tree = ast.parse(path.read_text())
    except SyntaxError as exc:
        return [Finding("syntax-error", repo_relative(str(path)),
                        exc.lineno or 0, "<module>", str(exc))]
    file = repo_relative(str(path))
    out: List[Finding] = []
    for rule in _RULES:
        out.extend(rule(tree, file))
    return sorted(out, key=lambda f: (f.file, f.line, f.rule))


def lint_paths(paths: Sequence[Path]) -> List[Finding]:
    files: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    out: List[Finding] = []
    for f in files:
        out.extend(lint_file(f))
    return out


def load_baseline(path: Path) -> Dict[Tuple[str, str, str], str]:
    """(rule, file, symbol) -> justification."""
    if not Path(path).exists():
        return {}
    data = json.loads(Path(path).read_text())
    return {(f["rule"], f["file"], f["symbol"]): f.get("justification", "")
            for f in data.get("findings", [])}


def write_baseline(path: Path, findings: Sequence[Finding],
                   old: Optional[Dict[Tuple[str, str, str], str]] = None
                   ) -> None:
    old = old or {}
    seen = set()
    rows = []
    for f in findings:
        if f.key in seen:
            continue
        seen.add(f.key)
        rows.append({"rule": f.rule, "file": f.file, "symbol": f.symbol,
                     "justification": old.get(f.key, "TODO: justify")})
    Path(path).write_text(json.dumps(
        {"version": 1, "findings": rows}, indent=2) + "\n")


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="repo-tuned jit/concurrency invariant linter")
    ap.add_argument("paths", nargs="*", default=["src/repro"],
                    help="files/dirs to lint (default: src/repro)")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="accepted-findings file; only NEW findings fail")
    ap.add_argument("--write-baseline", type=Path, default=None,
                    help="write current findings as the new baseline")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON")
    args = ap.parse_args(argv)

    findings = lint_paths([Path(p) for p in args.paths])

    if args.write_baseline is not None:
        old = load_baseline(args.baseline) if args.baseline else {}
        write_baseline(args.write_baseline, findings, old)
        print(f"wrote {args.write_baseline} "
              f"({len({f.key for f in findings})} accepted keys)")
        return 0

    baseline = load_baseline(args.baseline) if args.baseline else {}
    new = [f for f in findings if f.key not in baseline]
    accepted = [f for f in findings if f.key in baseline]
    stale = set(baseline) - {f.key for f in findings}

    if args.json:
        print(json.dumps({
            "new": [dataclasses.asdict(f) for f in new],
            "accepted": [dataclasses.asdict(f) for f in accepted],
            "stale_baseline_keys": sorted(map(list, stale)),
        }, indent=2))
    else:
        for f in new:
            print(f"NEW  {f}")
        if accepted:
            print(f"({len(accepted)} accepted finding(s) in baseline)")
        for key in sorted(stale):
            print(f"stale baseline entry (no longer fires): {key}")
        print(f"{len(new)} new finding(s), {len(findings)} total")
    return 1 if new else 0


if __name__ == "__main__":
    raise SystemExit(main())
