"""CLI: extract the influence graph and guard the checked-in artifact.

* ``python -m repro.analysis.extract``            — human-readable summary
* ``python -m repro.analysis.extract --check``    — fail (exit 1) if the
  freshly extracted graph's *signature* (nodes/edges/guards/primaries, not
  line numbers) differs from ``influence_graph.json`` — the CI tripwire
  for perfmodel refactors that silently change influence edges
* ``python -m repro.analysis.extract --write``    — refresh the artifact
* ``python -m repro.analysis.extract --param P``  — render one parameter's
  influence chain (the README example is generated this way)
* ``python -m repro.analysis.extract --probe``    — cross-validate against
  the probe-based QualE map and print the rule-audit telemetry
"""
from __future__ import annotations

import json
import sys
from pathlib import Path
from typing import Optional, Sequence

from repro.analysis.influence import (ARTIFACT_PATH, cross_validate,
                                      extract_influence_graph, load_artifact)


def _diff_signatures(old: dict, new: dict) -> list:
    lines = []
    for field in ("params", "derived", "terms", "stalls", "metrics",
                  "guard_kinds", "primary"):
        if old.get(field) != new.get(field):
            lines.append(f"  {field}: {old.get(field)!r} -> "
                         f"{new.get(field)!r}")
    o_edges = {tuple(map(str, e[:3])) + (tuple(e[3]),)
               for e in old.get("edges", [])}
    n_edges = {tuple(map(str, e[:3])) + (tuple(e[3]),)
               for e in new.get("edges", [])}
    for e in sorted(o_edges - n_edges):
        lines.append(f"  - edge gone: {e}")
    for e in sorted(n_edges - o_edges):
        lines.append(f"  + edge new:  {e}")
    return lines


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.extract",
        description="influence-graph extraction from the perfmodel source")
    ap.add_argument("--check", action="store_true",
                    help="compare against the checked-in artifact")
    ap.add_argument("--write", action="store_true",
                    help="write the checked-in artifact")
    ap.add_argument("--json", action="store_true",
                    help="dump the full graph (with provenance) as JSON")
    ap.add_argument("--param", default=None,
                    help="render one parameter's influence chain")
    ap.add_argument("--probe", action="store_true",
                    help="cross-validate against the probe-based QualE map")
    ap.add_argument("--artifact", type=Path, default=ARTIFACT_PATH)
    args = ap.parse_args(argv)

    graph = extract_influence_graph()

    if args.write:
        args.artifact.write_text(
            json.dumps(graph.as_json(), indent=2) + "\n")
        print(f"wrote {args.artifact} ({len(graph.edges)} edges)")
        return 0

    if args.check:
        if not args.artifact.exists():
            print(f"FAIL: artifact {args.artifact} missing "
                  f"(run --write and commit it)")
            return 1
        old = load_artifact(args.artifact)
        diff = _diff_signatures(old.signature(), graph.signature())
        if diff:
            print("FAIL: extracted influence graph differs from the "
                  "checked-in artifact — a perfmodel change moved "
                  "influence edges.  Review, then refresh with --write:")
            print("\n".join(diff))
            return 1
        print(f"OK: influence graph matches {args.artifact} "
              f"({len(graph.edges)} edges, "
              f"primaries {graph.primary_resources()})")
        return 0

    if args.json:
        print(json.dumps(graph.as_json(), indent=2))
        return 0

    if args.param:
        print(graph.render_param(args.param))
        return 0

    if args.probe:
        from repro.core.quale import derive_influence_map
        from repro.perfmodel.evaluator import get_evaluator
        audit = cross_validate(graph, derive_influence_map(
            get_evaluator("proxy")))
        print(json.dumps(audit.as_dict(), indent=2))
        for line in audit.corrections():
            print(line)
        return 0

    print(f"params:  {', '.join(graph.params)}")
    print(f"derived: {', '.join(graph.derived)}")
    print(f"terms:   {', '.join(graph.terms)}  "
          f"(guards: {graph.guard_kinds})")
    print(f"stalls:  {', '.join(graph.stalls)}")
    print(f"metrics: {', '.join(graph.metrics)}")
    print(f"edges:   {len(graph.edges)}")
    print("primary relief (extracted AHK):")
    for c, p in sorted(graph.primary_resources().items()):
        sites = graph.provenance("stall->primary", c, p)
        print(f"  {c:16s} -> {p:14s}  [{'; '.join(sites)}]")
    return 0


if __name__ == "__main__":
    sys.exit(main())
