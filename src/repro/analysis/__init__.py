"""`repro.analysis`: static analysis over the repo's own source code.

Two complementary passes share one AST/dataflow core (:mod:`.dataflow`):

* **Knowledge extraction** (:mod:`.influence`) — an interprocedural,
  assignment-level dataflow analysis of the performance-model source
  (``perfmodel/hardware.py``, ``roofline.py``, ``workload.py``,
  ``designspace.py``, ``critical_path.py``) that emits a typed
  :class:`~repro.analysis.influence.InfluenceGraph`: design parameter →
  derived hardware quantity → roofline op-term → stall class → PPA
  metric, every edge carrying ``file:line`` provenance.  The AHK primary
  stall→parameter edges consumed by :class:`~repro.core.llm.RuleOracle`
  and :class:`~repro.core.strategy.StrategyEngine` are *derived* from
  this graph instead of hand-coded (the literal reading of the paper's
  §3.2.1 "the LLM statically analyses the simulator codebase").
  ``python -m repro.analysis.extract --check`` guards the checked-in
  graph artifact in CI.

* **Invariant linter** (:mod:`.lint`) — AST checks tuned to this
  codebase's jit/concurrency stack (shared mutables written outside a
  held lock in ``distributed/``/``serve/``, futures swallowed on
  exception paths, thread/timer/executor leaks, mutable default args,
  jit hazards).  ``python -m repro.analysis.lint --baseline
  .lint-baseline.json`` fails CI only on *new* findings.
"""
from repro.analysis.influence import (InfluenceGraph, RuleAudit,
                                      cross_validate,
                                      derive_influence_map_from_source,
                                      derived_to_metrics,
                                      extract_influence_graph,
                                      primary_resources)

__all__ = [
    "InfluenceGraph", "RuleAudit", "cross_validate",
    "derive_influence_map_from_source", "derived_to_metrics",
    "extract_influence_graph", "primary_resources",
    "Finding", "lint_paths", "load_baseline",
]

_LINT_NAMES = ("Finding", "lint_paths", "load_baseline")


def __getattr__(name):
    # lazy so `python -m repro.analysis.lint` doesn't double-import lint
    if name in _LINT_NAMES:
        from repro.analysis import lint
        return getattr(lint, name)
    raise AttributeError(name)
