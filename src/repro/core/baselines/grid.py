"""Grid Search: stride-stratified lattice enumeration of the design space.

Visits a low-discrepancy sequence of flat ids (golden-ratio stride over the
mixed-radix space) so any prefix of the sequence spreads across the lattice —
the classic budgeted variant of exhaustive grid search.
"""
from __future__ import annotations

import numpy as np

from repro.core.baselines.common import BaseOptimizer


class GridSearch(BaseOptimizer):
    def __init__(self, space=None, seed: int = 0, **kw):
        super().__init__(space=space, seed=seed, **kw)
        size = self.space.size
        phi = (np.sqrt(5) - 1) / 2
        self._stride = max(1, int(size * phi) | 1)   # odd stride, ~coprime
        self._pos = int(self.rng.integers(size))

    def ask(self, n: int) -> np.ndarray:
        out = []
        for _ in range(n):
            out.append(self._pos)
            self._pos = (self._pos + self._stride) % self.space.size
        return self.space.flat_to_idx(np.asarray(out))
