"""Ant Colony Optimization: per-(parameter, choice) pheromone trails.

Ants sample each categorical choice proportionally to pheromone^alpha;
nondominated ants deposit pheromone on their choices; trails evaporate.
Exhibits the paper's observed far-to-near behaviour: early exploration is
near-uniform until trails accumulate.
"""
from __future__ import annotations

import numpy as np

from repro.core.baselines.common import BaseOptimizer
from repro.core.pareto import pareto_mask


class AntColony(BaseOptimizer):
    def __init__(self, space=None, seed: int = 0, alpha: float = 1.2,
                 rho: float = 0.08, deposit: float = 1.0, **kw):
        super().__init__(space=space, seed=seed, **kw)
        self.alpha, self.rho, self.deposit = alpha, rho, deposit
        self.tau = [np.ones(c, dtype=np.float64) for c in self.space.cardinalities]

    def ask(self, n: int) -> np.ndarray:
        out = np.zeros((n, self.space.n_params), dtype=np.int32)
        for pi in range(self.space.n_params):
            p = self.tau[pi] ** self.alpha
            p /= p.sum()
            out[:, pi] = self.rng.choice(len(p), size=n, p=p)
        return out

    def tell(self, X: np.ndarray, Y: np.ndarray) -> None:
        super().tell(X, Y)
        # evaporate, then deposit on the current nondominated set
        Yall = np.stack(self.Y)
        Xall = np.stack(self.X)
        mask = pareto_mask(Yall)
        for pi in range(self.space.n_params):
            self.tau[pi] *= (1.0 - self.rho)
            np.add.at(self.tau[pi], Xall[mask, pi], self.deposit * self.rho)
            self.tau[pi] = np.maximum(self.tau[pi], 1e-3)
