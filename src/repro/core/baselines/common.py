"""Ask/tell interface shared by all black-box DSE baselines (Table 2)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Type

import numpy as np

from repro.core.pareto import dominates_ref, ParetoArchive
from repro.perfmodel.designspace import DesignSpace, SPACE


class BaseOptimizer:
    """Black-box multi-objective optimizer over the index-coded space.

    ask(n) -> (n, n_params) candidate designs;
    tell(X, Y) -> observe objectives (minimize, shape (n, 3)).
    """

    def __init__(self, space: DesignSpace = SPACE, seed: int = 0):
        self.space = space
        self.rng = np.random.default_rng(seed)
        self.X: List[np.ndarray] = []
        self.Y: List[np.ndarray] = []

    def ask(self, n: int) -> np.ndarray:
        raise NotImplementedError

    def tell(self, X: np.ndarray, Y: np.ndarray) -> None:
        for x, y in zip(np.atleast_2d(X), np.atleast_2d(Y)):
            self.X.append(np.asarray(x, dtype=np.int32))
            self.Y.append(np.asarray(y, dtype=np.float64))

    # -------- helpers shared by subclasses --------
    def _norm_y(self) -> np.ndarray:
        y = np.stack(self.Y)
        lo, hi = y.min(axis=0), y.max(axis=0)
        return (y - lo) / np.maximum(hi - lo, 1e-12)

    def _norm_x(self, X: np.ndarray) -> np.ndarray:
        return np.asarray(X, dtype=np.float64) / (self.space.cardinalities - 1)


@dataclasses.dataclass
class MethodResult:
    name: str
    X: np.ndarray
    Y: np.ndarray
    phv: float
    sample_efficiency: float
    superior_count: int
    phv_curve: np.ndarray          # PHV after each evaluation


def run_method(opt_cls: Type[BaseOptimizer], evaluator, budget: int,
               ref_point: np.ndarray, space: DesignSpace = SPACE,
               seed: int = 0, batch: int = 1, curve_stride: int = 25,
               name: Optional[str] = None, **kw) -> MethodResult:
    """Drive one baseline for `budget` evaluations.

    `evaluator` is either an :class:`~repro.perfmodel.evaluator.Evaluator`
    (its fused ``objectives`` dispatch is used — one device call per ask
    batch) or a legacy callable ``X: (n, n_params) int -> (n, 3)``
    objectives ``[ttft, tpot, area]``.
    """
    if hasattr(evaluator, "evaluate") and hasattr(evaluator, "objectives"):
        evaluator = evaluator.objectives
    opt = opt_cls(space=space, seed=seed, **kw)
    ref = np.asarray(ref_point, dtype=np.float64)
    # Streaming Pareto archive: PHV is a function of the front alone, so each
    # curve point costs O(front) insertion + O(front^2) sweep instead of
    # recomputing dominance over the whole history (O(budget^2) total).
    archive = ParetoArchive(n_obj=ref.shape[0])
    n_superior = 0
    phv_curve = []
    next_record = curve_stride
    while len(opt.X) < budget:
        n = min(batch, budget - len(opt.X))
        X = np.atleast_2d(opt.ask(n))[:n]
        Y = np.atleast_2d(evaluator(X))
        opt.tell(X, Y)
        archive.insert(Y)
        n_superior += int(dominates_ref(Y, ref).sum())
        # record once per stride crossing (batch-aware) and at the end
        if len(opt.X) >= next_record or len(opt.X) >= budget:
            phv_curve.append(archive.hypervolume(ref))
            next_record = (len(opt.X) // curve_stride + 1) * curve_stride
    X = np.stack(opt.X)
    Y = np.stack(opt.Y)
    return MethodResult(
        name=name or opt_cls.__name__, X=X, Y=Y,
        phv=phv_curve[-1] if phv_curve else archive.hypervolume(ref),
        sample_efficiency=n_superior / max(len(opt.X), 1),
        superior_count=n_superior,
        phv_curve=np.asarray(phv_curve),
    )
