"""Multi-objective Bayesian Optimization (ParEGO-style).

GP surrogate (RBF kernel, numpy Cholesky) over normalized index coordinates;
each iteration draws a random weight vector, scalarizes the normalized
objectives with the augmented Tchebycheff function, fits the GP, and
maximizes Expected Improvement over a candidate pool (random + neighbors of
the incumbent).  O(n^3) in observed samples — the scalability limit the
paper cites for BO [22].
"""
from __future__ import annotations

import numpy as np

from repro.core.baselines.common import BaseOptimizer


def _rbf(A: np.ndarray, B: np.ndarray, ls: float) -> np.ndarray:
    d2 = ((A[:, None, :] - B[None, :, :]) ** 2).sum(-1)
    return np.exp(-0.5 * d2 / ls ** 2)


class BayesianOptimization(BaseOptimizer):
    def __init__(self, space=None, seed: int = 0, n_init: int = 8,
                 lengthscale: float = 0.35, noise: float = 1e-6,
                 pool: int = 512, **kw):
        super().__init__(space=space, seed=seed, **kw)
        self.n_init = n_init
        self.ls = lengthscale
        self.noise = noise
        self.pool = pool

    def ask(self, n: int) -> np.ndarray:
        out = []
        for _ in range(n):
            if len(self.X) < self.n_init:
                out.append(self.space.sample(self.rng, 1)[0])
                continue
            out.append(self._propose())
        return np.stack(out)

    # ------------------------------------------------------------------
    def _propose(self) -> np.ndarray:
        Xn = self._norm_x(np.stack(self.X))
        Yn = self._norm_y()
        # augmented Tchebycheff scalarization with random weights
        w = self.rng.dirichlet(np.ones(Yn.shape[1]))
        s = np.max(Yn * w, axis=1) + 0.05 * (Yn * w).sum(axis=1)
        mu, std = s.mean(), s.std() + 1e-12
        z = (s - mu) / std

        K = _rbf(Xn, Xn, self.ls) + self.noise * np.eye(len(Xn))
        L = np.linalg.cholesky(K)
        alpha = np.linalg.solve(L.T, np.linalg.solve(L, z))

        cands = self._candidates()
        Cn = self._norm_x(cands)
        Ks = _rbf(Cn, Xn, self.ls)
        mean = Ks @ alpha
        v = np.linalg.solve(L, Ks.T)
        var = np.clip(1.0 - (v ** 2).sum(axis=0), 1e-12, None)
        sd = np.sqrt(var)

        best = z.min()
        imp = best - mean
        zz = imp / sd
        ei = imp * _ncdf(zz) + sd * _npdf(zz)
        return cands[int(np.argmax(ei))]

    def _candidates(self) -> np.ndarray:
        cands = [self.space.sample(self.rng, self.pool)]
        # densify around the current scalarized incumbent
        Yn = self._norm_y()
        inc = self.X[int(np.argmin(Yn.sum(axis=1)))]
        cands.append(self.space.neighbors(inc))
        seen = {tuple(x) for x in self.X}
        allc = np.concatenate(cands, axis=0)
        mask = [tuple(c) not in seen for c in allc]
        out = allc[np.asarray(mask, dtype=bool)]
        return out if len(out) else allc


def _npdf(x):
    return np.exp(-0.5 * x ** 2) / np.sqrt(2 * np.pi)


def _ncdf(x):
    # Abramowitz-Stegun erf approximation (no scipy in this container)
    t = 1.0 / (1.0 + 0.2316419 * np.abs(x))
    poly = t * (0.319381530 + t * (-0.356563782 + t * (1.781477937
              + t * (-1.821255978 + t * 1.330274429))))
    nd = 1.0 - _npdf(np.abs(x)) * poly
    return np.where(x >= 0, nd, 1.0 - nd)
