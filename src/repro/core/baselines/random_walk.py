"""Random Walker: unbiased random walk over the design lattice."""
from __future__ import annotations

import numpy as np

from repro.core.baselines.common import BaseOptimizer


class RandomWalker(BaseOptimizer):
    def __init__(self, space=None, seed: int = 0, restart_p: float = 0.05, **kw):
        super().__init__(space=space, seed=seed, **kw)
        self._cur = None
        self._restart_p = restart_p

    def ask(self, n: int) -> np.ndarray:
        out = []
        for _ in range(n):
            if self._cur is None or self.rng.random() < self._restart_p:
                self._cur = self.space.sample(self.rng, 1)[0]
            else:
                nbrs = self.space.neighbors(self._cur)
                self._cur = nbrs[int(self.rng.integers(len(nbrs)))]
            out.append(self._cur.copy())
        return np.stack(out)
