from repro.core.baselines.common import BaseOptimizer, run_method, MethodResult
from repro.core.baselines.grid import GridSearch
from repro.core.baselines.random_walk import RandomWalker
from repro.core.baselines.bo import BayesianOptimization
from repro.core.baselines.ga import GeneticAlgorithm
from repro.core.baselines.aco import AntColony

METHODS = {
    "GS": GridSearch,
    "RW": RandomWalker,
    "BO": BayesianOptimization,
    "GA": GeneticAlgorithm,
    "ACO": AntColony,
}

__all__ = ["BaseOptimizer", "run_method", "MethodResult", "GridSearch",
           "RandomWalker", "BayesianOptimization", "GeneticAlgorithm",
           "AntColony", "METHODS"]
