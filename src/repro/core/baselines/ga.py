"""Genetic Algorithm: NSGA-II-lite (nondominated sort + crowding distance,
binary tournament, uniform crossover, per-gene mutation)."""
from __future__ import annotations

import numpy as np

from repro.core.baselines.common import BaseOptimizer
from repro.core.pareto import pareto_mask


def _nondominated_rank(Y: np.ndarray) -> np.ndarray:
    rank = np.full(len(Y), -1)
    r, remaining = 0, np.arange(len(Y))
    while len(remaining):
        mask = pareto_mask(Y[remaining])
        rank[remaining[mask]] = r
        remaining = remaining[~mask]
        r += 1
    return rank


def _crowding(Y: np.ndarray) -> np.ndarray:
    n, m = Y.shape
    if n <= 2:
        return np.full(n, np.inf)
    d = np.zeros(n)
    for j in range(m):
        order = np.argsort(Y[:, j])
        span = Y[order[-1], j] - Y[order[0], j] or 1.0
        d[order[0]] = d[order[-1]] = np.inf
        d[order[1:-1]] += (Y[order[2:], j] - Y[order[:-2], j]) / span
    return d


class GeneticAlgorithm(BaseOptimizer):
    def __init__(self, space=None, seed: int = 0, pop: int = 24,
                 p_mut: float = 0.15, **kw):
        super().__init__(space=space, seed=seed, **kw)
        self.pop_size = pop
        self.p_mut = p_mut

    def ask(self, n: int) -> np.ndarray:
        if len(self.X) < self.pop_size:
            return self.space.sample(self.rng, n)
        return np.stack([self._offspring() for _ in range(n)])

    def _offspring(self) -> np.ndarray:
        X = np.stack(self.X)
        Y = self._norm_y()
        rank = _nondominated_rank(Y)
        crowd = _crowding(Y)

        def tournament():
            i, j = self.rng.integers(len(X), size=2)
            if rank[i] != rank[j]:
                return i if rank[i] < rank[j] else j
            return i if crowd[i] > crowd[j] else j

        a, b = X[tournament()], X[tournament()]
        mask = self.rng.random(self.space.n_params) < 0.5
        child = np.where(mask, a, b).astype(np.int32)
        for pi in range(self.space.n_params):
            if self.rng.random() < self.p_mut:
                child[pi] = self.rng.integers(self.space.cardinalities[pi])
        return child
