"""Strategy Engine (SE): bottleneck -> constrained design-parameter moves.

Implements §3.3.1 plus the three corrective rules of §5.2:
  * focus ONLY on the dominant stall's most-correlated resource;
  * compute predicted deltas against the sensitivity reference;
  * trade area away from the LEAST-critical resource.

The SE formulates each decision as the SAME multiple-choice query format the
DSE Benchmark uses (task=parameter_tuning) and delegates the choice to the
configured LLM backend — the benchmark and the live loop exercise one code
path, which is how the benchmark "ensures consistent architectural
reasoning" inside the framework.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.llm import LLMBackend, MCQuery, TASK_TUNING
from repro.core.memory import TrajectoryMemory
from repro.core.quale import InfluenceMap
from repro.core.quane import Sensitivity
from repro.perfmodel.critical_path import StallReport
from repro.perfmodel.designspace import DesignSpace, SPACE
from repro.perfmodel.roofline import SRAM_FEED_WORDS_PER_KB

Move = Tuple[str, int]          # (param name, +1/-1 index step)


@dataclasses.dataclass
class Directive:
    moves: List[Move]
    new_idx: np.ndarray
    predicted: Dict[str, float]          # predicted metric deltas
    rationale: str

    def as_dict(self) -> dict:
        return {"moves": list(self.moves), "predicted": dict(self.predicted),
                "rationale": self.rationale}


class StrategyEngine:
    """``primary_map`` (stall class -> the single most-correlated resource,
    the AHK primary edges) defaults to the edges EXTRACTED from the
    perfmodel source by :mod:`repro.analysis.influence`; inject a mapping
    for ablations (e.g. the frozen legacy hand-coded table)."""

    def __init__(self, llm: LLMBackend, imap: InfluenceMap,
                 space: DesignSpace = SPACE, max_aggressiveness: int = 3,
                 primary_map: Optional[Dict[str, str]] = None):
        self.llm = llm
        self.imap = imap
        self.space = space
        self.max_aggressiveness = max_aggressiveness
        if primary_map is None:
            from repro.analysis.influence import primary_resources
            primary_map = primary_resources()
        self.primary_map = dict(primary_map)

    # ------------------------------------------------------------------
    def propose(self, idx: np.ndarray, report: StallReport, sens: Sensitivity,
                tm: TrajectoryMemory, focus: str,
                area_budget: Optional[float] = None,
                visited: Optional[set] = None) -> Directive:
        """One bottleneck-mitigation step.

        focus in {"ttft","tpot","area"}: the objective this iteration pushes;
        area_budget: if set and current area exceeds it, area-recovery
        trade-offs are mandatory (aggressiveness >= 2).
        """
        idx = np.asarray(idx, dtype=np.int32)
        vals = self.space.decode_np(idx)
        dominant = report.dominant

        relieve = self._relieve_moves(idx, vals, dominant, tm)
        tradeoff = self._tradeoff_moves(idx, sens, focus, tm, dominant)

        over_budget = area_budget is not None and report.area > area_budget
        aggressiveness = self._aggressiveness(report, over_budget)

        options = self._compose_options(relieve, tradeoff, aggressiveness,
                                        focus, over_budget)
        # never propose a design that was already evaluated (budget is precious)
        if visited:
            options = [o for o in options
                       if tuple(self._apply(idx, o)) not in visited]
        if not options:
            options = [self._fallback(idx, tm, visited)]

        crit = sens.criticality(focus if focus != "area" else "ttft")
        q = MCQuery(
            task=TASK_TUNING,
            prompt=(f"Current design {dict((k, int(v)) for k, v in vals.items())}.\n"
                    f"{report.as_prompt()}\n"
                    f"{sens.as_prompt()}\n"
                    f"Objective: minimize {focus}"
                    + (f" under area budget {area_budget:.0f}mm2" if area_budget else "")
                    + ". Pick the best single adjustment set."),
            options=[self._fmt_moves(m) for m in options],
            payload={
                "dominant_stall": dominant,
                "option_params": options,
                "criticality": crit,
                "sa_headroom": self._sa_headroom(vals),
                "constraints_ok": [True] * len(options),
            },
        )
        chosen = options[self.llm.choose(q)]
        new_idx = self._apply(idx, chosen)
        predicted = {
            m: float(sum(sens.delta[p][m] * d for p, d in chosen))
            for m in ("ttft", "tpot", "area")
        }
        return Directive(
            moves=list(chosen), new_idx=new_idx, predicted=predicted,
            rationale=(f"dominant={dominant} focus={focus} "
                       f"aggr={aggressiveness} moves={self._fmt_moves(chosen)}"))

    # ------------------------------------------------------------------
    def _apply(self, idx: np.ndarray, moves: Sequence[Move]) -> np.ndarray:
        new_idx = np.asarray(idx, dtype=np.int32).copy()
        for p, d in moves:
            pi = self.space.names.index(p)
            new_idx[pi] = np.clip(new_idx[pi] + d, 0,
                                  self.space.cardinalities[pi] - 1)
        return new_idx

    def _sa_headroom(self, vals: Dict[str, np.ndarray]) -> bool:
        """Would a one-step larger systolic array still be fed by SRAM?"""
        names = list(self.space.names)
        sa_choices = self.space.choices[names.index("sa_dim")]
        sa = float(vals["sa_dim"])
        bigger = next((c for c in sa_choices if c > sa), sa)
        feed = (SRAM_FEED_WORDS_PER_KB * float(vals["sram_kb"])
                / (bigger * float(vals["sublane_count"])))
        return feed >= 0.5

    def _relieve_moves(self, idx, vals, dominant, tm) -> List[List[Move]]:
        """Candidate move-sets that grow capacity for the dominant stall."""
        out: List[List[Move]] = []
        primary = self.primary_map[dominant]
        candidates = [primary] + [p for p in self.imap.params_for_stall(dominant)
                                  if p != primary]
        for p in candidates:
            pi = self.space.names.index(p)
            if idx[pi] + 1 >= self.space.cardinalities[pi]:
                continue
            if tm.denied(p, +1, dominant):
                continue
            moves = [(p, +1)]
            if p == "sa_dim" and not self._sa_headroom(vals):
                # utilization guard: pair the array growth with SRAM growth
                si = self.space.names.index("sram_kb")
                if idx[si] + 1 < self.space.cardinalities[si]:
                    moves.append(("sram_kb", +1))
                else:
                    continue
            out.append(moves)
        return out

    def _tradeoff_moves(self, idx, sens, focus, tm, dominant) -> List[Move]:
        """Area-recovery candidates: shrink the least-critical resources."""
        crit = sens.criticality(focus if focus != "area" else "ttft")
        area_gain = {p: -sens.delta[p]["area"] for p in crit}   # area saved per -1
        ranked = sorted(crit, key=lambda p: (crit[p], -abs(area_gain[p])))
        out: List[Move] = []
        for p in ranked:
            pi = self.space.names.index(p)
            if idx[pi] == 0:
                continue
            if tm.denied(p, -1, dominant):
                continue
            if sens.delta[p]["area"] <= 0:
                continue  # shrinking must actually save area
            out.append((p, -1))
            if len(out) >= 3:
                break
        return out

    def _aggressiveness(self, report: StallReport, over_budget: bool) -> int:
        a = 1
        if report.dominant_fraction > 0.5:
            a += 1
        if over_budget:
            a += 1
        return min(a, self.max_aggressiveness)

    def _compose_options(self, relieve, tradeoff, aggressiveness, focus,
                         over_budget) -> List[List[Move]]:
        options: List[List[Move]] = []
        if focus == "area" or over_budget:
            # area iterations: pure shrink options first
            for t in tradeoff:
                options.append([t])
            if len(tradeoff) >= 2:
                options.append(tradeoff[:2])
        for r in relieve[:3]:
            touched = {p for p, _ in r}
            compat = [t for t in tradeoff if t[0] not in touched]
            options.append(list(r))
            if aggressiveness >= 2 and compat:
                options.append(list(r) + [compat[0]])
            if aggressiveness >= 3 and len(compat) >= 2:
                options.append(list(r) + compat[:2])
        # dedupe, preserve order
        seen, uniq = set(), []
        for o in options:
            key = tuple(sorted(o))
            if key not in seen:
                seen.add(key)
                uniq.append(o)
        return uniq[:6]

    def _fallback(self, idx, tm, visited=None) -> List[Move]:
        """No admissible informed move: take a random legal (and unvisited)
        step — keeps the loop alive; the refinement pass learns from it."""
        rng = np.random.default_rng(len(tm.samples))
        for _ in range(64):
            pi = int(rng.integers(self.space.n_params))
            d = int(rng.choice([-1, 1]))
            if not (0 <= idx[pi] + d < self.space.cardinalities[pi]):
                continue
            moves = [(self.space.names[pi], d)]
            if visited and tuple(self._apply(idx, moves)) in visited:
                continue
            return moves
        # escape: random 2-param jump
        pis = rng.choice(self.space.n_params, size=2, replace=False)
        return [(self.space.names[int(p)], int(rng.choice([-1, 1]))) for p in pis]

    @staticmethod
    def _fmt_moves(moves: Sequence[Move]) -> str:
        return ", ".join(f"{p}{'+' if d > 0 else '-'}1" for p, d in moves) or "no-op"
