"""Quantitative Engine (QuanE): sensitivity-derived influence magnitudes.

Executes the paper's automated preliminary sensitivity analysis: +-1-step
perturbations of every parameter around a reference design, fully vectorized
(one batched model call evaluates all neighbors at once — the LLM-scripted
micro-benchmark orchestration of §3.2.2 collapses into a single vmap).

The result (per-parameter, per-metric deltas *per index step*) initializes
the AHK's quantitative influence factors; the Refinement Loop later
recalibrates them with observed samples.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.perfmodel.designspace import DesignSpace
from repro.perfmodel.evaluator import as_evaluator

METRICS = ("ttft", "tpot", "area")


@dataclasses.dataclass
class Sensitivity:
    """Per-parameter signed deltas for a +1 index step at the reference."""
    reference: np.ndarray                      # the sensitivity reference design
    ref_metrics: Dict[str, float]
    delta: Dict[str, Dict[str, float]]         # param -> metric -> d(metric)/d(step)

    def criticality(self, metric: str = "ttft") -> Dict[str, float]:
        """Normalized |influence| of each param on `metric` in [0, 1] —
        the 'least critical resource' ranking used by corrective rule 3."""
        mags = {p: abs(d.get(metric, 0.0)) for p, d in self.delta.items()}
        hi = max(mags.values()) or 1.0
        return {p: v / hi for p, v in mags.items()}

    def as_prompt(self) -> str:
        lines = ["Sensitivity (per +1 step, vs reference):"]
        for p, d in sorted(self.delta.items()):
            lines.append("  " + p + ": " + " ".join(
                f"d{m}={d[m]:+.3e}" for m in METRICS))
        return "\n".join(lines)


def sensitivity_analysis(evaluator, idx: np.ndarray,
                         space: Optional[DesignSpace] = None) -> Sensitivity:
    """Finite-difference sensitivities around design `idx`.

    Uses a central difference where both neighbors exist, one-sided at the
    choice-range boundaries.  ONE fused batched dispatch covers all
    neighbors across every workload.
    """
    ev = as_evaluator(evaluator)
    space = space or ev.space
    idx = np.asarray(idx, dtype=np.int32)
    rows = [idx]
    slots = []  # (param_i, direction, row_index)
    for pi in range(space.n_params):
        for d in (-1, +1):
            j = idx.copy()
            j[pi] += d
            if 0 <= j[pi] < space.cardinalities[pi]:
                slots.append((pi, d, len(rows)))
                rows.append(j)
    batch = np.stack(rows, axis=0)

    if len(ev.workloads) < 2:
        raise ValueError("sensitivity_analysis needs a two-workload "
                         "evaluator (ttft + tpot)")
    rep = ev.objectives(batch)                      # one fused dispatch
    vals = {
        "ttft": rep[:, 0],
        "tpot": rep[:, 1],
        "area": rep[:, -1],
    }
    ref = {m: float(v[0]) for m, v in vals.items()}

    delta: Dict[str, Dict[str, float]] = {}
    for pi, pname in enumerate(space.names):
        ups = [r for (q, d, r) in slots if q == pi and d > 0]
        downs = [r for (q, d, r) in slots if q == pi and d < 0]
        delta[pname] = {}
        for m, v in vals.items():
            if ups and downs:
                delta[pname][m] = float((v[ups[0]] - v[downs[0]]) / 2.0)
            elif ups:
                delta[pname][m] = float(v[ups[0]] - v[0])
            elif downs:
                delta[pname][m] = float(v[0] - v[downs[0]])
            else:
                delta[pname][m] = 0.0
    return Sensitivity(reference=idx.copy(), ref_metrics=ref, delta=delta)
