"""Refinement Loop (§3.4): data-driven correction of the AHK.

After each observed sample, the quantitative influence factors are
recalibrated toward the observed per-move deltas (EMA), and failed attempts
are reflected into the Trajectory Memory's deny-list.  Periodically the
sensitivity reference is re-anchored at the current best design so the
"delta vs sensitivity reference" rule stays locally valid.
"""
from __future__ import annotations

import numpy as np

from repro.core.memory import Sample, TrajectoryMemory
from repro.core.quane import Sensitivity, sensitivity_analysis


class RefinementLoop:
    def __init__(self, alpha: float = 0.5, reanchor_every: int = 5):
        self.alpha = alpha
        self.reanchor_every = reanchor_every

    def update(self, sens: Sensitivity, tm: TrajectoryMemory,
               sample: Sample) -> str:
        """EMA-correct influence factors with the observed move outcome."""
        note = tm.reflect(sample)
        if sample.directive is None or len(tm.samples) < 2:
            return note
        prev = tm.samples[-2]
        observed = {
            "ttft": sample.ttft - prev.ttft,
            "tpot": sample.tpot - prev.tpot,
            "area": sample.area - prev.area,
        }
        moves = sample.directive.get("moves", [])
        if not moves:
            return note
        # distribute the observed delta over the moves proportionally to the
        # current factors, then EMA each factor toward its share
        for metric, obs in observed.items():
            cur = {p: sens.delta[p][metric] * d for p, d in moves}
            total = sum(cur.values())
            for (p, d) in moves:
                share = cur[p] / total if abs(total) > 1e-30 else obs / len(moves)
                target = (obs * share / d) if abs(total) > 1e-30 else obs / (len(moves) * d)
                sens.delta[p][metric] = ((1 - self.alpha) * sens.delta[p][metric]
                                         + self.alpha * target)
        return note

    def maybe_reanchor(self, sens: Sensitivity, tm: TrajectoryMemory,
                       evaluator, step: int) -> Sensitivity:
        """Re-anchor the sensitivity reference at the current best design.

        `evaluator` is the proxy-tier :class:`~repro.perfmodel.evaluator.
        Evaluator`.
        """
        if step % self.reanchor_every != 0 or not tm.samples:
            return sens
        best = tm.best()
        if best is None or np.array_equal(best.idx, sens.reference):
            return sens
        return sensitivity_analysis(evaluator, best.idx)
