"""Pareto-front utilities: dominance, exact hypervolume (2D/3D), metrics.

Conventions: ALL objectives are minimized.  The Pareto Hypervolume (PHV,
paper Definition 3) is the m-dimensional volume of the region dominated by
the front and bounded above by the reference point; points not strictly
better than the reference in every objective contribute nothing.
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import numpy as np


def pareto_mask(y: np.ndarray, block_size: int = 512) -> np.ndarray:
    """Boolean mask of nondominated rows of y (n, m), minimization.

    Blockwise vectorized dominance with objective-sum pruning: a dominator of
    x must have all objectives <= and at least one < — hence a strictly
    smaller objective sum — so after a stable sort by sum, only *earlier*
    still-alive rows can dominate a block.  Duplicate rows never dominate
    each other (no strict inequality) and are all kept, matching the
    historical O(n^2) Python-loop semantics.
    """
    y = np.asarray(y, dtype=np.float64)
    n = y.shape[0]
    if n == 0:
        return np.zeros(0, dtype=bool)
    order = np.argsort(y.sum(axis=1), kind="stable")
    ys = y[order]
    alive = np.ones(n, dtype=bool)
    # Survivors of earlier blocks can never be dominated by later rows (their
    # sums are >=), so the running `front` only grows and is the complete
    # dominator set for every later block.
    front = np.empty((0, ys.shape[1]))
    for s in range(0, n, block_size):
        e = min(s + block_size, n)
        blk = ys[s:e]                                   # (b, m)
        balive = ~_dominated_by(front, blk)
        idx = np.flatnonzero(balive)
        if idx.size > 1:                                # within-block pass
            sub = blk[idx]
            dom = _dominated_by(sub, sub)
            if dom.any():
                balive[idx[dom]] = False
                idx = idx[~dom]
        alive[s:e] = balive
        front = np.concatenate([front, blk[idx]], axis=0)
    mask = np.zeros(n, dtype=bool)
    mask[order] = alive
    return mask


def _dominated_by(front: np.ndarray, blk: np.ndarray,
                  prefilter: int = 64) -> np.ndarray:
    """Rows of blk (b, m) dominated by some row of front (f, m).

    Two-tier: screen against the `prefilter` strongest (lowest objective-sum)
    front rows first — they kill most of the block cheaply — then run the
    full front only on the survivors.  Comparisons are per-objective 2D ops
    (much faster in NumPy than 3D broadcast + axis reduction).
    """
    b, m = blk.shape
    if front.shape[0] == 0 or b == 0:
        return np.zeros(b, dtype=bool)
    if front.shape[0] > 2 * prefilter:
        dead = _dominated_by(front[:prefilter], blk, prefilter)
        idx = np.flatnonzero(~dead)
        if idx.size:
            dead2 = _dominated_by(front[prefilter:], blk[idx], front.shape[0])
            dead[idx[dead2]] = True
        return dead
    all_le = np.ones((b, front.shape[0]), dtype=bool)
    any_lt = np.zeros((b, front.shape[0]), dtype=bool)
    for j in range(m):
        fj = front[:, j][None, :]
        bj = blk[:, j][:, None]
        all_le &= fj <= bj
        any_lt |= fj < bj
    return (all_le & any_lt).any(axis=1)


def pareto_front(y: np.ndarray) -> np.ndarray:
    return np.asarray(y)[pareto_mask(y)]


class ParetoArchive:
    """Streaming nondominated archive (minimization).

    Insertion is O(batch x front): newcomers are screened against the current
    front, surviving newcomers prune dominated incumbents, and the invariant
    "self.y == pareto_front(everything ever inserted)" holds exactly while
    the archive stays under ``capacity``.  With a capacity set, overflow is
    resolved by dropping the most crowded points (extreme points per
    objective are always kept), which bounds memory for full-space sweeps.

    Optionally carries one integer id per point (e.g. the flat design id) so
    sweep results remain traceable back to design vectors.

    ``capacity="auto"`` sizes the bound from the observed front width
    instead of a user guess: after every insert the cap is raised to
    ``auto_headroom`` x the widest (post-dominance) front seen so far
    (never below ``auto_floor``), BEFORE any pruning could fire — auto
    never truncates, memory stays proportional to the true front width,
    and the final ``capacity`` is the data-derived bound a fixed-capacity
    run of the same stream should use.
    """

    def __init__(self, n_obj: int, capacity: Union[int, str, None] = None, *,
                 auto_floor: int = 2_048, auto_headroom: float = 2.0):
        self.n_obj = int(n_obj)
        self.auto = capacity == "auto"
        self.auto_floor = int(auto_floor)
        self.auto_headroom = float(auto_headroom)
        self._peak = 0               # widest front observed (auto sizing)
        self.capacity = self.auto_floor if self.auto else capacity
        self.y = np.empty((0, self.n_obj), dtype=np.float64)
        self.ids = np.empty((0,), dtype=np.int64)
        self.n_seen = 0
        self.truncated = False       # True once capacity pruning ever fired

    def __len__(self) -> int:
        return self.y.shape[0]

    def insert(self, y: np.ndarray, ids: Optional[np.ndarray] = None) -> int:
        """Insert a batch of points; returns how many entered the front."""
        y = np.atleast_2d(np.asarray(y, dtype=np.float64))
        if y.shape[0] == 0:
            return 0
        if y.shape[1] != self.n_obj:
            raise ValueError(f"expected {self.n_obj} objectives, got {y.shape[1]}")
        ids = (np.full(y.shape[0], -1, dtype=np.int64) if ids is None
               else np.asarray(ids, dtype=np.int64).reshape(-1))
        self.n_seen += y.shape[0]

        # newcomers must be mutually nondominated first
        keep_new = pareto_mask(y)
        y, ids = y[keep_new], ids[keep_new]
        if self.y.shape[0]:
            # drop newcomers dominated by the current front (duplicates of
            # incumbents are NOT dominated and accumulate, matching
            # pareto_front on the concatenated history)
            dominated = _dominated_by(self.y, y)
            y, ids = y[~dominated], ids[~dominated]
            if y.shape[0]:
                # prune incumbents dominated by surviving newcomers
                dead = _dominated_by(y, self.y)
                if dead.any():
                    self.y, self.ids = self.y[~dead], self.ids[~dead]
        if y.shape[0] == 0:
            return 0
        self.y = np.concatenate([self.y, y], axis=0)
        self.ids = np.concatenate([self.ids, ids], axis=0)
        if self.auto:
            # raise the cap from the observed (post-dominance) width FIRST
            # so auto never prunes — not even on a first insert wider than
            # the floor; the cap is the data-derived recommendation
            self._peak = max(self._peak, len(self))
            self.capacity = max(self.auto_floor,
                                int(self.auto_headroom * self._peak))
        elif self.capacity is not None and len(self) > self.capacity:
            self._prune_to(self.capacity)
        return y.shape[0]

    def _prune_to(self, cap: int) -> None:
        """Keep the `cap` least-crowded points (NSGA-II crowding distance)."""
        self.truncated = True
        d = self._crowding(self.y)
        keep = np.argsort(-d, kind="stable")[:cap]
        keep.sort()
        self.y, self.ids = self.y[keep], self.ids[keep]

    @staticmethod
    def _crowding(y: np.ndarray) -> np.ndarray:
        n, m = y.shape
        d = np.zeros(n)
        for j in range(m):
            o = np.argsort(y[:, j], kind="stable")
            span = max(y[o[-1], j] - y[o[0], j], 1e-300)
            d[o[0]] = d[o[-1]] = np.inf        # always keep the extremes
            d[o[1:-1]] += (y[o[2:], j] - y[o[:-2], j]) / span
        return d

    def hypervolume(self, ref: Sequence[float]) -> float:
        return hypervolume(self.y, ref)

    def dominating(self, ref: Sequence[float]) -> np.ndarray:
        """Archive points strictly better than `ref` in every objective."""
        if not len(self):
            return np.zeros(0, dtype=bool)
        return dominates_ref(self.y, np.asarray(ref, dtype=np.float64))


def dominates_ref(y: np.ndarray, ref: np.ndarray) -> np.ndarray:
    """Mask of points strictly better than the reference in ALL objectives."""
    return np.all(np.asarray(y) < np.asarray(ref)[None, :], axis=1)


def _hv2d(pts: np.ndarray, ref: np.ndarray) -> float:
    """Exact 2D hypervolume (minimization)."""
    pts = pts[np.all(pts < ref[None, :], axis=1)]
    if len(pts) == 0:
        return 0.0
    order = np.argsort(pts[:, 0])
    pts = pts[order]
    hv, y_best = 0.0, ref[1]
    for x, y in pts:
        if y < y_best:
            hv += (ref[0] - x) * (y_best - y)
            y_best = y
    return float(hv)


def _hv3d(pts: np.ndarray, ref: np.ndarray) -> float:
    """Exact 3D hypervolume via z-sweep over 2D slabs (minimization).

    Sort by z; between consecutive z levels the dominated xy-area is the 2D
    hypervolume of all points at or below the slab.  O(n^2 log n) — the
    fronts here are <= a few hundred points.
    """
    pts = pts[np.all(pts < ref[None, :], axis=1)]
    if len(pts) == 0:
        return 0.0
    order = np.argsort(pts[:, 2])
    pts = pts[order]
    zs = np.concatenate([pts[:, 2], [ref[2]]])
    hv = 0.0
    for i in range(len(pts)):
        dz = zs[i + 1] - zs[i]
        if dz <= 0:
            continue
        hv += _hv2d(pts[: i + 1, :2], ref[:2]) * dz
    return float(hv)


def hypervolume(points: np.ndarray, ref: Sequence[float]) -> float:
    """Exact hypervolume for 2 or 3 objectives (minimization)."""
    points = np.asarray(points, dtype=np.float64)
    ref = np.asarray(ref, dtype=np.float64)
    if points.ndim != 2 or points.shape[0] == 0:
        return 0.0
    points = points[pareto_mask(points)]
    m = points.shape[1]
    if m == 2:
        return _hv2d(points, ref)
    if m == 3:
        return _hv3d(points, ref)
    raise NotImplementedError(f"hypervolume for m={m}")


def hypervolume_mc(points: np.ndarray, ref: Sequence[float], lo: Sequence[float],
                   n: int = 200_000, seed: int = 0) -> float:
    """Monte-Carlo hypervolume estimate (oracle for property tests)."""
    rng = np.random.default_rng(seed)
    ref = np.asarray(ref, dtype=np.float64)
    lo = np.asarray(lo, dtype=np.float64)
    pts = np.asarray(points, dtype=np.float64)
    samples = rng.uniform(lo, ref, size=(n, len(ref)))
    dominated = np.zeros(n, dtype=bool)
    for p in pts:
        dominated |= np.all(samples >= p[None, :], axis=1)
    return float(dominated.mean() * np.prod(ref - lo))


def sample_efficiency(y: np.ndarray, ref: np.ndarray) -> float:
    """Paper metric: fraction of evaluated designs strictly better than the
    reference point in all objectives."""
    y = np.asarray(y)
    if len(y) == 0:
        return 0.0
    return float(dominates_ref(y, ref).mean())
