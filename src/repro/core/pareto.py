"""Pareto-front utilities: dominance, exact hypervolume (2D/3D), metrics.

Conventions: ALL objectives are minimized.  The Pareto Hypervolume (PHV,
paper Definition 3) is the m-dimensional volume of the region dominated by
the front and bounded above by the reference point; points not strictly
better than the reference in every objective contribute nothing.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np


def pareto_mask(y: np.ndarray) -> np.ndarray:
    """Boolean mask of nondominated rows of y (n, m), minimization."""
    y = np.asarray(y, dtype=np.float64)
    n = y.shape[0]
    mask = np.ones(n, dtype=bool)
    for i in range(n):
        if not mask[i]:
            continue
        dominated_by_i = np.all(y >= y[i], axis=1) & np.any(y > y[i], axis=1)
        mask &= ~dominated_by_i
        mask[i] = True
        # anything that dominates i kills i
        dominates_i = np.all(y <= y[i], axis=1) & np.any(y < y[i], axis=1)
        if dominates_i.any():
            mask[i] = False
    return mask


def pareto_front(y: np.ndarray) -> np.ndarray:
    return np.asarray(y)[pareto_mask(y)]


def dominates_ref(y: np.ndarray, ref: np.ndarray) -> np.ndarray:
    """Mask of points strictly better than the reference in ALL objectives."""
    return np.all(np.asarray(y) < np.asarray(ref)[None, :], axis=1)


def _hv2d(pts: np.ndarray, ref: np.ndarray) -> float:
    """Exact 2D hypervolume (minimization)."""
    pts = pts[np.all(pts < ref[None, :], axis=1)]
    if len(pts) == 0:
        return 0.0
    order = np.argsort(pts[:, 0])
    pts = pts[order]
    hv, y_best = 0.0, ref[1]
    for x, y in pts:
        if y < y_best:
            hv += (ref[0] - x) * (y_best - y)
            y_best = y
    return float(hv)


def _hv3d(pts: np.ndarray, ref: np.ndarray) -> float:
    """Exact 3D hypervolume via z-sweep over 2D slabs (minimization).

    Sort by z; between consecutive z levels the dominated xy-area is the 2D
    hypervolume of all points at or below the slab.  O(n^2 log n) — the
    fronts here are <= a few hundred points.
    """
    pts = pts[np.all(pts < ref[None, :], axis=1)]
    if len(pts) == 0:
        return 0.0
    order = np.argsort(pts[:, 2])
    pts = pts[order]
    zs = np.concatenate([pts[:, 2], [ref[2]]])
    hv = 0.0
    for i in range(len(pts)):
        dz = zs[i + 1] - zs[i]
        if dz <= 0:
            continue
        hv += _hv2d(pts[: i + 1, :2], ref[:2]) * dz
    return float(hv)


def hypervolume(points: np.ndarray, ref: Sequence[float]) -> float:
    """Exact hypervolume for 2 or 3 objectives (minimization)."""
    points = np.asarray(points, dtype=np.float64)
    ref = np.asarray(ref, dtype=np.float64)
    if points.ndim != 2 or points.shape[0] == 0:
        return 0.0
    points = points[pareto_mask(points)]
    m = points.shape[1]
    if m == 2:
        return _hv2d(points, ref)
    if m == 3:
        return _hv3d(points, ref)
    raise NotImplementedError(f"hypervolume for m={m}")


def hypervolume_mc(points: np.ndarray, ref: Sequence[float], lo: Sequence[float],
                   n: int = 200_000, seed: int = 0) -> float:
    """Monte-Carlo hypervolume estimate (oracle for property tests)."""
    rng = np.random.default_rng(seed)
    ref = np.asarray(ref, dtype=np.float64)
    lo = np.asarray(lo, dtype=np.float64)
    pts = np.asarray(points, dtype=np.float64)
    samples = rng.uniform(lo, ref, size=(n, len(ref)))
    dominated = np.zeros(n, dtype=bool)
    for p in pts:
        dominated |= np.all(samples >= p[None, :], axis=1)
    return float(dominated.mean() * np.prod(ref - lo))


def sample_efficiency(y: np.ndarray, ref: np.ndarray) -> float:
    """Paper metric: fraction of evaluated designs strictly better than the
    reference point in all objectives."""
    y = np.asarray(y)
    if len(y) == 0:
        return 0.0
    return float(dominates_ref(y, ref).mean())
