"""Exploration Engine (EE): directive -> simulator evaluation -> sample.

The EE is the integration layer (§3.3.2): it serializes the SE's directive
into the simulator's design format (choice-index vector), issues the
evaluation through the unified :class:`~repro.perfmodel.evaluator.Evaluator`
contract, and returns the structured sample for the Trajectory Memory.

One DSE step costs exactly ONE fused jitted dispatch: the evaluator computes
both latency objectives and stall attribution together, and each design's
:class:`~repro.perfmodel.evaluator.PPAReport` row lands in a
:class:`~repro.perfmodel.evaluator.RowCache` so follow-up ``reports()``
reads (the SE re-reading the current base design) are free.
:meth:`ExplorationEngine.prefetch` extends the same contract to many designs
at once: the candidate sets of K parallel campaigns are fused into ONE
batched dispatch per round, which is what makes
:class:`~repro.core.campaign.CampaignRunner` cost ~1 dispatch/round instead
of K.

There is ONE cache design, not two: when the engine's evaluator is an
:class:`~repro.distributed.service.EvalService`, the engine reads the
SERVICE's shared cross-client row cache directly (rows the service already
evaluated for any client resolve here without a dispatch, and vice versa);
otherwise it keeps a private bounded ``RowCache`` with the same
eviction-aware LRU semantics.

``workloads=`` selects which (prefill, decode) pair of a multi-workload
evaluator drives this engine — the hook that points a DSE campaign at ONE
scenario of a zoo-suite evaluator (``get_evaluator(suite="zoo")``).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core.memory import Sample
from repro.core.strategy import Directive
from repro.perfmodel.critical_path import StallReport
from repro.perfmodel.evaluator import (EvalRequest, Evaluator, PPAReport,
                                       RowCache, as_evaluator)

_CACHE_CAP = 4096        # evaluated-design report rows kept per engine (LRU)

ReportPair = Tuple[StallReport, StallReport]


class ExplorationEngine:
    """Wraps an :class:`~repro.perfmodel.evaluator.Evaluator` as the
    evaluation backend of one or many DSE campaigns.

    ``evals`` counts simulator invocations — the sampling budget shared by
    every campaign driving this engine.
    """

    def __init__(self, evaluator: Evaluator,
                 workloads: Optional[Tuple[str, str]] = None,
                 cache: Optional[RowCache] = None):
        self.evaluator = as_evaluator(evaluator)
        if workloads is None:
            if len(self.evaluator.workloads) < 2:
                raise ValueError("the DSE loop needs a two-workload "
                                 "evaluator (prefill + decode)")
            workloads = tuple(self.evaluator.workloads[:2])
        else:
            workloads = tuple(workloads)
            if len(workloads) != 2:
                raise ValueError("workloads must be a (prefill, decode) pair")
            unknown = set(workloads) - set(self.evaluator.workloads)
            if unknown:
                raise KeyError(f"unknown workloads {sorted(unknown)}; "
                               f"have {self.evaluator.workloads}")
        self._wt, self._wp = workloads
        self.evals = 0        # simulator invocations (the sampling budget)
        # dominant-stall histogram over budgeted observations: which AHK
        # rules the SE will have fired; campaign telemetry snapshots it
        self.stall_counts: dict = {}
        # ONE cache: the service's shared cross-client row cache when the
        # evaluator is a service, a private same-semantics one otherwise
        self._cache: RowCache = (
            cache if cache is not None
            else getattr(self.evaluator, "row_cache", None)
            or RowCache(_CACHE_CAP))
        # per-objective latency scales for the dominant-stall merge; the DSE
        # loop sets this to its reference point so TTFT (whole prefill, ms)
        # and TPOT (per token, us) stalls compare on their own scales
        self.ref_point: Optional[np.ndarray] = None

    # legacy attribute access (a few benches/teardowns poke the models)
    @property
    def ttft_model(self):
        return self.evaluator.models[self._wt]

    @property
    def tpot_model(self):
        return self.evaluator.models[self._wp]

    @property
    def workload_pair(self) -> Tuple[str, str]:
        return (self._wt, self._wp)

    # -- shared row cache ----------------------------------------------
    def _cached_row(self, key: bytes) -> Optional[PPAReport]:
        return self._cache.get(key, "stalls", (self._wt, self._wp))

    def _report_pair(self, idx: np.ndarray) -> ReportPair:
        """Both workloads' critical-path reports from one fused dispatch."""
        idx = np.asarray(idx, dtype=np.int32)
        key = RowCache.key(idx)
        row = self._cached_row(key)
        if row is None:
            rep = self.evaluator.evaluate(
                EvalRequest(idx, detail="stalls",
                            workloads=self._request_names()))
            row = rep.row(0)
            self._cache.put(key, "stalls", row)
        return (row.stall_report(self._wt), row.stall_report(self._wp))

    def _request_names(self) -> Optional[Tuple[str, ...]]:
        """A service evaluates (and caches) its FULL workload set per tick
        anyway — request it all so the shared rows serve every client; a
        plain evaluator only pays for this engine's pair."""
        if getattr(self.evaluator, "row_cache", None) is self._cache \
                and self._cache is not None:
            return None
        return (self._wt, self._wp)

    def prefetch(self, idx_batch: np.ndarray) -> int:
        """Evaluate many designs in ONE fused batched dispatch.

        Fills the row cache so the follow-up per-design
        :meth:`evaluate`/:meth:`reports` calls are dispatch-free — the
        batched multi-design path behind multi-campaign rounds.  Designs
        already cached are not re-evaluated.  Returns the number of designs
        actually dispatched.
        """
        batch = np.atleast_2d(np.asarray(idx_batch, dtype=np.int32))
        fresh_keys: List[bytes] = []
        fresh_rows: List[np.ndarray] = []
        seen = set()
        for row in batch:
            key = RowCache.key(row)
            if key in seen or self._cached_row(key) is not None:
                continue
            seen.add(key)
            fresh_keys.append(key)
            fresh_rows.append(row)
        if not fresh_rows:
            return 0
        rep = self.evaluator.evaluate(
            EvalRequest(np.stack(fresh_rows), detail="stalls",
                        workloads=self._request_names()))
        for i, key in enumerate(fresh_keys):
            self._cache.put(key, "stalls", rep.row(i))
        return len(fresh_rows)

    # ------------------------------------------------------------------
    def evaluate(self, idx: np.ndarray, step: int,
                 directive: Optional[Directive] = None) -> Sample:
        idx = np.asarray(idx, dtype=np.int32)
        rep_t, rep_p = self._report_pair(idx)
        self.evals += 1
        # the design's dominant stall = the larger ABSOLUTE stall across the
        # two latency objectives (what the SE will attack next)
        dom = self._merge(rep_t, rep_p)
        self.stall_counts[dom.dominant] = \
            self.stall_counts.get(dom.dominant, 0) + 1
        return Sample(
            step=step, idx=idx.copy(),
            ttft=rep_t.latency, tpot=rep_p.latency, area=rep_t.area,
            dominant_stall=dom.dominant,
            directive=directive.as_dict() if directive else None,
        )

    def reports(self, idx: np.ndarray) -> ReportPair:
        """Critical-path reports for both latency objectives (cached)."""
        return self._report_pair(idx)

    def _merge(self, rep_t: StallReport, rep_p: StallReport) -> StallReport:
        """Latency-weighted dominant-stall merge: the report whose dominant
        stall burns more time — each objective measured on its OWN latency
        scale (``ref_point`` when the loop provides one) — wins.

        Comparing bare ``dominant_fraction``s (or short-circuiting on a raw
        latency ratio, as the old ``ttft >= 50 * tpot`` bypass did)
        misattributes TPOT-bound designs whenever TTFT is merely large;
        comparing raw seconds would bury the per-token TPOT objective under
        the whole-prefill TTFT for good — the reference scales make the two
        commensurable."""
        st, sp = ((float(self.ref_point[0]), float(self.ref_point[1]))
                  if self.ref_point is not None else (1.0, 1.0))
        w_t = rep_t.dominant_fraction * rep_t.latency / st
        w_p = rep_p.dominant_fraction * rep_p.latency / sp
        return rep_t if w_t >= w_p else rep_p
