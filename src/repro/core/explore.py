"""Exploration Engine (EE): directive -> simulator evaluation -> sample.

The EE is the integration layer (§3.3.2): it serializes the SE's directive
into the simulator's design format (choice-index vector), issues the
evaluation through the unified :class:`~repro.perfmodel.evaluator.Evaluator`
contract, and returns the structured sample for the Trajectory Memory.

One DSE step costs exactly ONE fused jitted dispatch: the evaluator computes
TTFT, TPOT and stall attribution together, and the resulting
:class:`~repro.perfmodel.evaluator.PPAReport` is cached per design so
follow-up ``reports()`` reads (the SE re-reading the current base design)
are free.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.memory import Sample
from repro.core.strategy import Directive
from repro.perfmodel.critical_path import StallReport
from repro.perfmodel.evaluator import EvalRequest, Evaluator, as_evaluator

_CACHE_CAP = 4096        # evaluated-design reports kept per engine


class ExplorationEngine:
    """Wraps an Evaluator as the evaluation backend.

    Construct from an :class:`~repro.perfmodel.evaluator.Evaluator`, or from
    a legacy ``(ttft_model, tpot_model)`` pair (deprecated shim).
    """

    def __init__(self, evaluator: Evaluator, tpot_model=None):
        self.evaluator = as_evaluator(evaluator, tpot_model)
        if len(self.evaluator.workloads) < 2:
            raise ValueError("the DSE loop needs a two-workload evaluator "
                             "(ttft + tpot)")
        self._wt, self._wp = self.evaluator.workloads[:2]
        self.evals = 0        # simulator invocations (the sampling budget)
        self._reports: Dict[tuple, Tuple[StallReport, StallReport]] = {}

    # legacy attribute access (a few benches/teardowns poke the models)
    @property
    def ttft_model(self):
        return self.evaluator.models[self._wt]

    @property
    def tpot_model(self):
        return self.evaluator.models[self._wp]

    def _report_pair(self, idx: np.ndarray) -> Tuple[StallReport, StallReport]:
        """Both workloads' critical-path reports from one fused dispatch."""
        idx = np.asarray(idx, dtype=np.int32)
        key = idx.tobytes()
        pair = self._reports.get(key)
        if pair is None:
            rep = self.evaluator.evaluate(EvalRequest(idx, detail="stalls"))
            pair = (rep.stall_report(self._wt), rep.stall_report(self._wp))
            if len(self._reports) >= _CACHE_CAP:
                self._reports.clear()
            self._reports[key] = pair
        return pair

    def evaluate(self, idx: np.ndarray, step: int,
                 directive: Optional[Directive] = None) -> Sample:
        idx = np.asarray(idx, dtype=np.int32)
        rep_t, rep_p = self._report_pair(idx)
        self.evals += 1
        # the design's dominant stall = the larger absolute stall across the
        # two latency objectives (what the SE will attack next)
        dom = rep_t if rep_t.latency >= rep_p.latency * 50 else self._merge(rep_t, rep_p)
        return Sample(
            step=step, idx=idx.copy(),
            ttft=rep_t.latency, tpot=rep_p.latency, area=rep_t.area,
            dominant_stall=dom.dominant,
            directive=directive.as_dict() if directive else None,
        )

    def reports(self, idx: np.ndarray):
        """Critical-path reports for both latency objectives (cached)."""
        return self._report_pair(idx)

    @staticmethod
    def _merge(rep_t: StallReport, rep_p: StallReport) -> StallReport:
        return rep_t if rep_t.dominant_fraction >= rep_p.dominant_fraction else rep_p
