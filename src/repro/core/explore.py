"""Exploration Engine (EE): directive -> simulator evaluation -> sample.

The EE is the integration layer (§3.3.2): it serializes the SE's directive
into the simulator's design format (choice-index vector), issues the
evaluation through the unified :class:`~repro.perfmodel.evaluator.Evaluator`
contract, and returns the structured sample for the Trajectory Memory.

One DSE step costs exactly ONE fused jitted dispatch: the evaluator computes
TTFT, TPOT and stall attribution together, and the resulting
:class:`~repro.perfmodel.evaluator.PPAReport` is cached per design (bounded
LRU) so follow-up ``reports()`` reads (the SE re-reading the current base
design) are free.  :meth:`ExplorationEngine.prefetch` extends the same
contract to many designs at once: the candidate sets of K parallel campaigns
are fused into ONE batched dispatch per round, which is what makes
:class:`~repro.core.campaign.CampaignRunner` cost ~1 dispatch/round instead
of K.

:class:`~repro.distributed.service.EvalService` generalizes prefetch one
level further — from "one engine batches its own candidates" to "any
concurrent clients coalesce through one queue": an engine whose evaluator
is a service still issues one logical request per step/prefetch, but the
service's tick fuses it with every OTHER client's requests and serves
repeats from a shared cross-client cache, so this per-engine LRU becomes
the second (local) cache level.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import List, Optional, Tuple

import numpy as np

from repro.core.memory import Sample
from repro.core.strategy import Directive
from repro.perfmodel.critical_path import StallReport
from repro.perfmodel.evaluator import EvalRequest, Evaluator, as_evaluator

_CACHE_CAP = 4096        # evaluated-design reports kept per engine (LRU)

ReportPair = Tuple[StallReport, StallReport]


class ExplorationEngine:
    """Wraps an :class:`~repro.perfmodel.evaluator.Evaluator` as the
    evaluation backend of one or many DSE campaigns.

    ``evals`` counts simulator invocations — the sampling budget shared by
    every campaign driving this engine.
    """

    def __init__(self, evaluator: Evaluator):
        self.evaluator = as_evaluator(evaluator)
        if len(self.evaluator.workloads) < 2:
            raise ValueError("the DSE loop needs a two-workload evaluator "
                             "(ttft + tpot)")
        self._wt, self._wp = self.evaluator.workloads[:2]
        self.evals = 0        # simulator invocations (the sampling budget)
        self._reports: "OrderedDict[bytes, ReportPair]" = OrderedDict()
        # per-objective latency scales for the dominant-stall merge; the DSE
        # loop sets this to its reference point so TTFT (whole prefill, ms)
        # and TPOT (per token, us) stalls compare on their own scales
        self.ref_point: Optional[np.ndarray] = None

    # legacy attribute access (a few benches/teardowns poke the models)
    @property
    def ttft_model(self):
        return self.evaluator.models[self._wt]

    @property
    def tpot_model(self):
        return self.evaluator.models[self._wp]

    # -- bounded LRU report cache --------------------------------------
    def _cache_put(self, key: bytes, pair: ReportPair) -> None:
        # bounded LRU: evict only the coldest entries, never the whole map —
        # clearing would drop the hot base design and force a re-dispatch on
        # the SE's very next reports() read
        while len(self._reports) >= _CACHE_CAP:
            self._reports.popitem(last=False)
        self._reports[key] = pair

    def _report_pair(self, idx: np.ndarray) -> ReportPair:
        """Both workloads' critical-path reports from one fused dispatch."""
        idx = np.asarray(idx, dtype=np.int32)
        key = idx.tobytes()
        pair = self._reports.get(key)
        if pair is None:
            rep = self.evaluator.evaluate(EvalRequest(idx, detail="stalls"))
            pair = (rep.stall_report(self._wt), rep.stall_report(self._wp))
            self._cache_put(key, pair)
        else:
            self._reports.move_to_end(key)       # keep the base design hot
        return pair

    def prefetch(self, idx_batch: np.ndarray) -> int:
        """Evaluate many designs in ONE fused batched dispatch.

        Fills the report cache so the follow-up per-design
        :meth:`evaluate`/:meth:`reports` calls are dispatch-free — the
        batched multi-design path behind multi-campaign rounds.  Designs
        already cached are not re-evaluated.  Returns the number of designs
        actually dispatched.
        """
        batch = np.atleast_2d(np.asarray(idx_batch, dtype=np.int32))
        fresh_keys: List[bytes] = []
        fresh_rows: List[np.ndarray] = []
        seen = set()
        for row in batch:
            key = row.tobytes()
            if key in self._reports or key in seen:
                continue
            seen.add(key)
            fresh_keys.append(key)
            fresh_rows.append(row)
        if not fresh_rows:
            return 0
        rep = self.evaluator.evaluate(
            EvalRequest(np.stack(fresh_rows), detail="stalls"))
        for i, key in enumerate(fresh_keys):
            self._cache_put(key, (rep.stall_report(self._wt, i),
                                  rep.stall_report(self._wp, i)))
        return len(fresh_rows)

    # ------------------------------------------------------------------
    def evaluate(self, idx: np.ndarray, step: int,
                 directive: Optional[Directive] = None) -> Sample:
        idx = np.asarray(idx, dtype=np.int32)
        rep_t, rep_p = self._report_pair(idx)
        self.evals += 1
        # the design's dominant stall = the larger ABSOLUTE stall across the
        # two latency objectives (what the SE will attack next)
        dom = self._merge(rep_t, rep_p)
        return Sample(
            step=step, idx=idx.copy(),
            ttft=rep_t.latency, tpot=rep_p.latency, area=rep_t.area,
            dominant_stall=dom.dominant,
            directive=directive.as_dict() if directive else None,
        )

    def reports(self, idx: np.ndarray) -> ReportPair:
        """Critical-path reports for both latency objectives (cached)."""
        return self._report_pair(idx)

    def _merge(self, rep_t: StallReport, rep_p: StallReport) -> StallReport:
        """Latency-weighted dominant-stall merge: the report whose dominant
        stall burns more time — each objective measured on its OWN latency
        scale (``ref_point`` when the loop provides one) — wins.

        Comparing bare ``dominant_fraction``s (or short-circuiting on a raw
        latency ratio, as the old ``ttft >= 50 * tpot`` bypass did)
        misattributes TPOT-bound designs whenever TTFT is merely large;
        comparing raw seconds would bury the per-token TPOT objective under
        the whole-prefill TTFT for good — the reference scales make the two
        commensurable."""
        st, sp = ((float(self.ref_point[0]), float(self.ref_point[1]))
                  if self.ref_point is not None else (1.0, 1.0))
        w_t = rep_t.dominant_fraction * rep_t.latency / st
        w_p = rep_p.dominant_fraction * rep_p.latency / sp
        return rep_t if w_t >= w_p else rep_p
