"""Exploration Engine (EE): directive -> simulator evaluation -> sample.

The EE is the integration layer (§3.3.2): it serializes the SE's directive
into the simulator's design format (choice-index vector), issues the
evaluation, and returns the structured sample for the Trajectory Memory.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.memory import Sample
from repro.core.strategy import Directive
from repro.perfmodel.critical_path import attribute_stalls, StallReport


class ExplorationEngine:
    """Wraps the (ttft_model, tpot_model) pair as the evaluation backend."""

    def __init__(self, ttft_model, tpot_model):
        self.ttft_model = ttft_model
        self.tpot_model = tpot_model
        self.evals = 0        # simulator invocations (the sampling budget)

    def evaluate(self, idx: np.ndarray, step: int,
                 directive: Optional[Directive] = None) -> Sample:
        idx = np.asarray(idx, dtype=np.int32)
        rep_t = attribute_stalls(self.ttft_model, idx)
        rep_p = attribute_stalls(self.tpot_model, idx)
        self.evals += 1
        # the design's dominant stall = the larger absolute stall across the
        # two latency objectives (what the SE will attack next)
        dom = rep_t if rep_t.latency >= rep_p.latency * 50 else self._merge(rep_t, rep_p)
        return Sample(
            step=step, idx=idx.copy(),
            ttft=rep_t.latency, tpot=rep_p.latency, area=rep_t.area,
            dominant_stall=dom.dominant,
            directive=directive.as_dict() if directive else None,
        )

    def reports(self, idx: np.ndarray):
        """Fresh critical-path reports for both latency objectives."""
        return (attribute_stalls(self.ttft_model, idx),
                attribute_stalls(self.tpot_model, idx))

    @staticmethod
    def _merge(rep_t: StallReport, rep_p: StallReport) -> StallReport:
        return rep_t if rep_t.dominant_fraction >= rep_p.dominant_fraction else rep_p
