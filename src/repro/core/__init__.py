"""Lumina core: LLM-guided DSE framework (the paper's primary contribution).

Components (paper Figure 2):
  QualE  — :mod:`repro.core.quale`   influence-map acquisition
  QuanE  — :mod:`repro.core.quane`   sensitivity quantification
  SE     — :mod:`repro.core.strategy` bottleneck-mitigation strategy
  EE     — :mod:`repro.core.explore`  simulator integration layer
  TM     — :mod:`repro.core.memory`   trajectory memory + reflection
  Refine — :mod:`repro.core.refine`   AHK recalibration loop
  Loop   — :mod:`repro.core.loop`     the orchestrated DSE campaign
                                      (stepwise :class:`~repro.core.loop.
                                      Campaign` + closed ``run``)
plus the multi-campaign orchestration layer (:mod:`repro.core.campaign` —
sweep-seeded parallel campaigns sharing one budget, one merged archive and
ONE fused batched dispatch per round, with per-step regret telemetry), the
DSE Benchmark (:mod:`repro.core.bench`), the LLM backends
(:mod:`repro.core.llm`), Pareto/PHV metrics (:mod:`repro.core.pareto`) and
the black-box baselines (:mod:`repro.core.baselines`).
"""

from repro.core.loop import LuminaDSE, DSEResult, Campaign
from repro.core.campaign import CampaignRunner, CampaignSetResult, StepRecord
from repro.core.llm import RuleOracle, DegradedOracle, MCQuery
from repro.core.pareto import (hypervolume, pareto_front, pareto_mask,
                               sample_efficiency, dominates_ref, ParetoArchive)

__all__ = ["LuminaDSE", "DSEResult", "Campaign", "CampaignRunner",
           "CampaignSetResult", "StepRecord", "RuleOracle", "DegradedOracle",
           "MCQuery", "hypervolume", "pareto_front", "pareto_mask",
           "sample_efficiency", "dominates_ref", "ParetoArchive"]
