"""Multi-campaign DSE orchestration: sweep-seeded parallel Lumina campaigns.

The paper's headline result hinges on bottleneck-guided starts;
:class:`CampaignRunner` turns the full-space sweep's per-stall-class seed
designs (:meth:`~repro.perfmodel.sweep.SweepResult.stall_seeds`) into K
parallel :class:`~repro.core.loop.Campaign` trajectories — one campaign per
dominant-stall class that actually occurs in the sweep, plus the A100
reference start — under ONE shared evaluation budget.

The performance core is the fused round dispatch: every live campaign
proposes its next candidate, the K candidates are evaluated in ONE batched
:class:`~repro.perfmodel.evaluator.EvalRequest` via
:meth:`~repro.core.explore.ExplorationEngine.prefetch`, and each campaign
then observes its (now cache-resident) result dispatch-free.  K campaigns
at budget B therefore cost ~B/K + O(1) fused dispatches instead of B.

The runner accepts an ``Evaluator`` OR an :class:`~repro.distributed.
service.EvalService`.  With a service, the runner stops owning the
batching: each campaign submits its own single-design request (tagged with
its campaign label as the service ``client`` for round-robin fairness) and
one ``service.tick()`` coalesces the K clients (plus any interleaved
baseline/benchmark submitters) into the same ONE fused dispatch per round,
with the service's shared cross-client cache serving the follow-up reads.

``scenario=`` (or ``workloads=``) points the whole runner at ONE scenario
of a multi-workload zoo-suite evaluator: the campaigns optimize that
scenario's (prefill, decode) pair, and seeding them from
``SweepResult.stall_seeds(scenario=...)`` launches bottleneck campaigns
per scenario class.

Scheduling is pluggable (``policy=``): ``"uniform"`` gives every live
campaign one evaluation per round (round-robin clipping); ``"adaptive"``
scores each campaign by its regret slope — an EWMA of per-round archive
gains (new Pareto point or per-objective best) — and drains the shared
budget through :func:`allocate_slots`, a weighted-deficit allocator over
``weight_floor + gain_ewma``.  Budget flows CONTINUOUSLY toward campaigns
whose regret is still falling; a stalled campaign's weight decays toward
the floor instead of being binarily early-stopped, so it keeps probing at
a trickle and can win budget back the moment it improves again.

Every observation is instrumented: the merged archive's per-objective
regret against the oracle front (:meth:`~repro.perfmodel.evaluator.
OracleEvaluator.regret`) and its PHV as a fraction of the oracle front's
PHV are recorded per step and persist as a JSON time series
(:meth:`CampaignSetResult.save_telemetry`).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable, Dict, List, Mapping, Optional, TYPE_CHECKING

import numpy as np

from repro.core.explore import ExplorationEngine
from repro.core.llm import LLMBackend
from repro.core.loop import Campaign, DSEResult, LuminaDSE
from repro.core.memory import Sample, TrajectoryMemory
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NOOP
from repro.perfmodel.designspace import DesignSpace, SPACE, A100_REFERENCE
from repro.perfmodel.evaluator import (EvalRequest, Evaluator,
                                       OracleEvaluator, as_evaluator)

if TYPE_CHECKING:                       # avoid perfmodel <-> core import cycle
    from repro.perfmodel.sweep import SweepResult

REFERENCE_CAMPAIGN = "a100"

POLICIES = ("uniform", "adaptive")

TELEMETRY_VERSION = 5    # v5: + metrics (registry snapshot); v4: +
                         # stall_histogram, rule_audit

#: Adaptive policy: minimum scheduling weight of a fully-stalled campaign.
#: Nonzero so no campaign is ever starved outright — a long-stalled
#: trajectory still gets ~floor/total of the budget to probe with.
ADAPTIVE_WEIGHT_FLOOR = 0.05


def allocate_slots(order: List[str], credit: Dict[str, float],
                   weights: Mapping[str, float], slots: int) -> List[str]:
    """Weighted-deficit slot allocation for one scheduling round.

    Each label in ``order`` accrues ``slots * w / sum(w)`` credit (its
    fair share of this round), then the ``slots`` highest-credit labels
    are chosen and debited 1.0 each.  ``credit`` is mutated in place and
    carries between rounds, so fractional shares accumulate: a label
    with 10% of the total weight is chosen ~1 round in 10, never zero —
    the same deficit-round-robin that the EvalService QoS drain uses for
    tiers, applied to campaigns.

    Ties break toward the front of ``order`` (stable sort), and the
    chosen labels are returned in ``order`` sequence.
    """
    if slots <= 0 or not order:
        return []
    slots = min(int(slots), len(order))
    total = sum(weights[lb] for lb in order)
    if total <= 0:
        raise ValueError("allocate_slots needs positive total weight")
    for lb in order:
        credit[lb] = credit.get(lb, 0.0) + slots * weights[lb] / total
    chosen = set(sorted(order, key=lambda lb: -credit[lb])[:slots])
    for lb in chosen:
        credit[lb] -= 1.0
    return [lb for lb in order if lb in chosen]


@dataclasses.dataclass
class StepRecord:
    """One budgeted observation in a multi-campaign run (JSON-serializable)."""
    eval_i: int                        # global evaluations spent (1-based)
    round_i: int                       # fused-dispatch round index
    campaign: str                      # which trajectory observed this design
    step: int                          # campaign-local step
    objectives: List[float]            # [ttft, tpot, area] of the design
    phv: float                         # merged-archive PHV after this step
    phv_frac: Optional[float] = None   # merged PHV / oracle-front PHV
    regret: Optional[List[float]] = None  # per-objective regret vs oracle


@dataclasses.dataclass
class CampaignSetResult:
    per_campaign: Dict[str, DSEResult]
    samples: List[Sample]              # merged, in observation order
    phv: float
    superior_count: int
    pareto: List[Sample]
    telemetry: List[StepRecord]
    dispatches: int                    # fused target-tier dispatches spent
    rounds: int
    policy: str = "uniform"
    early_stopped: Dict[str, int] = dataclasses.field(default_factory=dict)
    # ^ legacy binary early-stop ledger; the continuous adaptive policy
    #   never stops a campaign outright, so this stays empty since v3
    budget_weights: Optional[Dict[str, float]] = None
    # ^ final per-campaign scheduling weights (floor + gain EWMA) under
    #   the adaptive policy; None under uniform
    service_counters: Optional[dict] = None
    # ^ EvalService.telemetry() snapshot (degradation ladder counters,
    #   resubmits) when the runner drove a service; None otherwise
    stall_histogram: Optional[Dict[str, int]] = None
    # ^ dominant-stall counts over all budgeted observations: which AHK
    #   rules fired (and how often) across the campaign set
    rule_audit: Optional[dict] = None
    # ^ source-extracted influence graph vs this run's probe-derived map
    #   (repro.analysis.influence.RuleAudit.as_dict()): the §5.2
    #   auto-correction telemetry — disagreements = candidate corrections
    metrics: Optional[dict] = None
    # ^ the runner's MetricsRegistry.snapshot() at run end (v5): round /
    #   per-campaign observation counters in the unified obs format

    def telemetry_dict(self) -> dict:
        return {
            "version": TELEMETRY_VERSION,
            "campaigns": sorted(self.per_campaign),
            "rounds": self.rounds,
            "dispatches": self.dispatches,
            "policy": self.policy,
            "early_stopped": dict(self.early_stopped),
            "budget_weights": (None if self.budget_weights is None
                               else dict(self.budget_weights)),
            "service": self.service_counters,
            "stall_histogram": (None if self.stall_histogram is None
                                else dict(self.stall_histogram)),
            "rule_audit": self.rule_audit,
            "metrics": self.metrics,
            "records": [dataclasses.asdict(r) for r in self.telemetry],
        }

    def save_telemetry(self, path: str) -> None:
        """Persist the per-step regret / PHV-fraction time series as JSON."""
        with open(path, "w") as f:
            json.dump(self.telemetry_dict(), f, indent=1)

    def regret_curve(self) -> np.ndarray:
        """(n_steps, n_obj) per-objective regret after each observation
        (rows of NaN where no oracle was attached)."""
        return np.array([r.regret if r.regret is not None
                         else [np.nan] * len(r.objectives)
                         for r in self.telemetry])

    def phv_frac_curve(self) -> np.ndarray:
        return np.array([np.nan if r.phv_frac is None else r.phv_frac
                         for r in self.telemetry])


def load_telemetry(path: str) -> dict:
    """Load a :meth:`CampaignSetResult.save_telemetry` JSON, upgrading
    older format versions to the current one in memory.

    v4 (and earlier) files predate the ``metrics`` registry snapshot;
    v3 files predate ``stall_histogram`` / ``rule_audit``.  Missing keys
    are filled with ``None`` and ``version`` is stamped to the current
    :data:`TELEMETRY_VERSION` — a file from a NEWER build refuses to
    load (its keys could mean something this build does not know).
    """
    with open(path) as f:
        data = json.load(f)
    version = int(data.get("version", 1))
    if version > TELEMETRY_VERSION:
        raise ValueError(
            f"telemetry format v{version} is newer than this build's "
            f"v{TELEMETRY_VERSION}; refusing to load")
    if version < 4:
        data.setdefault("stall_histogram", None)
        data.setdefault("rule_audit", None)
    if version < 5:
        data.setdefault("metrics", None)
    data["version"] = TELEMETRY_VERSION
    return data


class CampaignRunner:
    """Launch K parallel Lumina campaigns against one shared budget.

    Parameters
    ----------
    evaluator:
        The budgeted target-tier :class:`~repro.perfmodel.evaluator.
        Evaluator` (every campaign's EE dispatches land here, fused) — or
        an :class:`~repro.distributed.service.EvalService`, in which case
        each campaign submits its own request and the SERVICE coalesces
        the round into one fused dispatch (the runner no longer owns the
        batching, so interleaved external clients fuse too).
    proxy:
        Free acquisition-tier evaluator (QualE/QuanE); defaults to
        ``evaluator``.
    oracle:
        Optional :class:`~repro.perfmodel.evaluator.OracleEvaluator`; when
        given, every step is scored with exact per-objective regret and
        PHV-fraction against the exhaustive front.
    seeds_per_campaign:
        How many sweep seeds each stall-class campaign starts from (its
        step-0 seed list; all are evaluated — they spend budget).
    policy:
        ``"uniform"`` — one evaluation per live campaign per round with
        round-robin clipping.  ``"adaptive"`` — continuous budget
        reallocation by regret slope: each campaign carries an EWMA of
        its per-round archive gains, its scheduling weight is
        ``ADAPTIVE_WEIGHT_FLOOR + gain_ewma``, and each round's slots are
        drained through the weighted-deficit :func:`allocate_slots`.
        Improving campaigns propose (nearly) every round; stalled ones
        decay toward a trickle but are never stopped outright, so a
        late bloomer wins its budget share back the moment it improves.
    patience:
        Adaptive-policy memory horizon: the gain EWMA's smoothing is
        ``alpha = 1 / (1 + patience)``, so a campaign's weight decays to
        ~the floor after a few ``patience`` windows without improvement.
    """

    def __init__(self, evaluator: Evaluator, *,
                 proxy: Optional[Evaluator] = None,
                 oracle: Optional[OracleEvaluator] = None,
                 llm: Optional[LLMBackend] = None,
                 space: DesignSpace = SPACE,
                 ref_point: Optional[np.ndarray] = None,
                 area_budget: Optional[float] = None,
                 seed: int = 0,
                 seeds_per_campaign: int = 1,
                 policy: str = "uniform",
                 patience: int = 3,
                 workloads: Optional[tuple] = None,
                 scenario: Optional[str] = None,
                 primary_map: Optional[Dict[str, str]] = None,
                 registry: Optional[MetricsRegistry] = None,
                 tracer=None):
        # deferred import: repro.distributed pulls perfmodel (and through
        # it this module) back in — binding it lazily breaks the cycle for
        # processes whose import chain starts at repro.distributed
        from repro.distributed.service import EvalService
        self.space = space
        self.evaluator = as_evaluator(evaluator)
        self._service = (self.evaluator
                         if isinstance(self.evaluator, EvalService) else None)
        # default to the service's tracer so campaign spans root the same
        # causal tree its tick/dispatch spans grow under
        self.tracer = (tracer if tracer is not None
                       else getattr(self._service, "tracer", None) or NOOP)
        self.metrics = registry if registry is not None else MetricsRegistry()
        self._c_rounds = self.metrics.counter(
            "campaign_rounds", "fused-dispatch rounds driven")
        self._c_obs = self.metrics.counter(
            "campaign_observations", "budgeted observations, per campaign",
            labelnames=("campaign",))
        self._c_resubmits = self.metrics.counter(
            "campaign_service_resubmits",
            "failed service requests resubmitted once")
        if scenario is not None:
            # pick a zoo-suite scenario by name: its (prefill, decode)
            # workload pair becomes this runner's objective pair
            scenarios = getattr(self.evaluator, "scenarios", None) or ()
            match = [s for s in scenarios if s.name == scenario]
            if not match:
                raise KeyError(
                    f"unknown scenario {scenario!r}; evaluator has "
                    f"{tuple(s.name for s in scenarios)}")
            if workloads is not None:
                raise ValueError("pass workloads= or scenario=, not both")
            workloads = (match[0].prefill, match[0].decode)
        self.scenario = scenario
        self.ee = ExplorationEngine(self.evaluator, workloads=workloads)
        self.oracle = oracle
        self.seeds_per_campaign = int(seeds_per_campaign)
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, "
                             f"got {policy!r}")
        self.policy = policy
        self.patience = max(1, int(patience))
        # one LuminaDSE holds the shared pieces (engine, proxy, imap, ref);
        # campaigns are stepwise views onto it
        self.dse = LuminaDSE(self.evaluator, proxy=proxy, llm=llm,
                             space=space, ref_point=ref_point,
                             area_budget=area_budget, seed=seed,
                             engine=self.ee, workloads=workloads,
                             primary_map=primary_map)
        self.ref_point = self.dse.ref_point

    @property
    def service_resubmits(self) -> int:
        """Failed-request resubmissions across all :meth:`run` calls."""
        return int(self._c_resubmits.value())

    # ------------------------------------------------------------------
    def seed_starts(self, seeds: Mapping[str, np.ndarray],
                    include_reference: bool = True) -> Dict[str, np.ndarray]:
        """{campaign label -> (k, n_params) step-0 seed list}.

        ``seeds`` is :meth:`SweepResult.stall_seeds` output (or any
        {label -> seed array} mapping).  Stall classes with NO seed designs
        (every design in the sweep had some other dominant stall) are
        skipped, not crashed on.  Within a class, seeds are ranked by their
        worst objective ratio vs the reference point (minimax), so the
        campaign starts from the most balanced bottleneck representative.
        """
        starts: Dict[str, np.ndarray] = {}
        claimed: set = set()                 # no design seeds two campaigns
        if include_reference:
            ref_idx = self.space.encode_nearest(A100_REFERENCE)
            starts[REFERENCE_CAMPAIGN] = ref_idx[None, :]
            claimed.add(tuple(ref_idx))
        for label, arr in seeds.items():
            arr = np.asarray(arr, dtype=np.int32)
            arr = arr.reshape(-1, self.space.n_params) if arr.size else arr
            if arr.size == 0:
                continue                      # empty stall class: no campaign
            order = np.argsort(self._minimax_ratio(arr), kind="stable")
            take = [row for row in arr[order]
                    if tuple(row) not in claimed][: self.seeds_per_campaign]
            if not take:                      # every seed already claimed
                continue
            claimed.update(tuple(row) for row in take)
            starts[label] = np.stack(take)
        return starts

    def _minimax_ratio(self, idx: np.ndarray) -> np.ndarray:
        """max_o(objective_o / ref_o) per design — <1 means A100-superior.
        One fused prefetch scores a whole seed class (cache-shared with the
        campaigns that will start there)."""
        self.ee.prefetch(idx)
        ratios = np.empty(idx.shape[0])
        for i, row in enumerate(idx):
            rep_t, rep_p = self.ee.reports(row)
            y = np.array([rep_t.latency, rep_p.latency, rep_t.area])
            ratios[i] = float((y / self.ref_point).max())
        return ratios

    # ------------------------------------------------------------------
    def run(self, budget: int = 20, *,
            seeds: Optional[Mapping[str, np.ndarray]] = None,
            sweep: Optional["SweepResult"] = None,
            include_reference: bool = True,
            step_callback: Optional[Callable[[StepRecord, Sample], None]] = None
            ) -> CampaignSetResult:
        """Run all campaigns round-robin under one shared `budget`.

        Seeds come from ``seeds`` (a {label -> (k, n_params)} mapping),
        from ``sweep.stall_seeds()``, or default to the reference start
        only.  Each round fuses every live campaign's candidate into ONE
        batched dispatch.
        """
        d0 = getattr(self.evaluator, "dispatches", 0)
        if seeds is None:
            seeds = sweep.stall_seeds(self.space) if sweep is not None else {}
        starts = self.seed_starts(seeds, include_reference=include_reference)
        if not starts:
            raise ValueError("no campaigns to run: every seed class was "
                             "empty and include_reference=False")

        shared_visited: set = set()
        campaigns: Dict[str, Campaign] = {
            label: self.dse.start(init, visited=shared_visited, label=label)
            for label, init in starts.items()
        }
        merged = TrajectoryMemory(self.ref_point)
        telemetry: List[StepRecord] = []
        best = np.full(len(self.ref_point), np.inf)
        budget_stop = self.ee.evals + int(budget)
        rounds = 0
        prev_phv = 0.0
        early_stopped: Dict[str, int] = {}
        # adaptive policy state: regret-slope EWMA per campaign
        # (optimistic init 1.0 — every campaign starts fully funded) and
        # the carrying deficit credit for allocate_slots
        gain_alpha = 1.0 / (1.0 + self.patience)
        gain_ewma: Dict[str, float] = {label: 1.0 for label in campaigns}
        credit: Dict[str, float] = {label: 0.0 for label in campaigns}

        order = list(campaigns)
        tr = self.tracer
        with tr.span("campaign.run", budget=int(budget),
                     campaigns=len(campaigns)):
            while self.ee.evals < budget_stop:
                rounds += 1
                self._c_rounds.inc()
                room = budget_stop - self.ee.evals
                if self.policy == "adaptive":
                    # budget flows to falling-regret campaigns continuously:
                    # weighted-deficit allocation over floor + gain EWMA
                    weights = {lb: ADAPTIVE_WEIGHT_FLOOR + gain_ewma[lb]
                               for lb in order}
                    chosen = allocate_slots(order, credit, weights,
                                            min(room, len(order)))
                else:
                    chosen = order[:room]
                with tr.span("campaign.round", round_i=rounds,
                             slots=len(chosen)):
                    proposals = []
                    for label in chosen:
                        camp = campaigns[label]
                        idx, directive = camp.propose()
                        proposals.append((label, camp, idx, directive))
                    # ---- the fused round dispatch: K candidates, ONE
                    # dispatch.  With a plain evaluator the RUNNER batches
                    # (one prefetched EvalRequest); with an EvalService each
                    # campaign submits its own request and the SERVICE's
                    # coalescing tick fuses them.
                    if self._service is not None:
                        # campaign traffic is latency-critical for the human
                        # in the loop: ride the interactive QoS tier so
                        # background batch/scavenger sweeps cannot starve
                        # the DSE rounds
                        futures = [self._service.submit(
                            EvalRequest(p[2][None, :], detail="stalls"),
                            client=p[0],         # campaign label = client
                            tier="interactive")
                            for p in proposals]
                        self._service.tick()
                        while not all(f.done() for f in futures):
                            self._service.tick()  # row-capped service ticks
                        # worker loss heals between ticks: a failed request
                        # gets ONE resubmission before its error is surfaced
                        retried = []
                        for p, fut in zip(proposals, futures):
                            if fut.exception() is not None:
                                self._c_resubmits.inc()
                                retried.append(self._service.submit(
                                    EvalRequest(p[2][None, :],
                                                detail="stalls"),
                                    client=p[0], tier="interactive"))
                        while retried and not all(f.done() for f in retried):
                            self._service.tick()
                        for fut in retried:
                            fut.result()         # second failure is real
                    else:
                        self.ee.prefetch(np.stack([p[2]
                                                   for p in proposals]))
                    for label, camp, idx, directive in proposals:
                        sample = self.ee.evaluate(idx, step=camp.step,
                                                  directive=directive)
                        camp.observe(sample)
                        merged.add(sample)
                        self._c_obs.inc(campaign=label)
                        improved = bool((sample.objectives < best).any())
                        best = np.minimum(best, sample.objectives)
                        record = StepRecord(
                            eval_i=self.ee.evals, round_i=rounds,
                            campaign=label, step=camp.step,
                            objectives=[float(v)
                                        for v in sample.objectives],
                            phv=merged.phv(),
                        )
                        gained = (1.0 if (record.phv > prev_phv or improved)
                                  else 0.0)
                        gain_ewma[label] += gain_alpha * (gained
                                                          - gain_ewma[label])
                        prev_phv = record.phv
                        if self.oracle is not None:
                            record.regret = [
                                float(v)
                                for v in self.oracle.regret(best[None, :])]
                            record.phv_frac = self.oracle.normalized_phv(
                                record.phv, self.ref_point)
                        telemetry.append(record)
                        if step_callback is not None:
                            step_callback(record, sample)
                # round-robin fairness: rotate which campaign is clipped
                # (uniform) or wins credit ties (adaptive) when the
                # remaining budget no longer covers every live campaign
                order = order[1:] + order[:1]

        return CampaignSetResult(
            per_campaign={label: c.result() for label, c in campaigns.items()},
            samples=list(merged.samples),
            phv=merged.phv(),
            superior_count=merged.superior_count(),
            pareto=merged.pareto(),
            telemetry=telemetry,
            dispatches=getattr(self.evaluator, "dispatches", 0) - d0,
            rounds=rounds,
            policy=self.policy,
            early_stopped=early_stopped,
            budget_weights=({lb: round(ADAPTIVE_WEIGHT_FLOOR + g, 4)
                             for lb, g in gain_ewma.items()}
                            if self.policy == "adaptive" else None),
            service_counters=(dict(self._service.telemetry(),
                                   campaign_resubmits=self.service_resubmits)
                              if self._service is not None else None),
            stall_histogram=dict(self.ee.stall_counts),
            rule_audit=self.dse.rule_audit().as_dict(),
            metrics=self.metrics.snapshot(),
        )
