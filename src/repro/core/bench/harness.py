"""Accuracy harness for the DSE Benchmark (paper Table 3).

Ground truth in the scored suites comes from the unified
:mod:`repro.perfmodel.evaluator` contract (the generator computes every
answer through fused evaluator dispatches), so benchmark accuracy and the
live DSE loop exercise the same evaluation path.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Sequence, Tuple

from repro.core.bench.generator import BenchmarkSuite
from repro.core.llm import (LLMBackend, TASK_BOTTLENECK, TASK_PREDICTION,
                            TASK_TUNING)

TASKS = (TASK_BOTTLENECK, TASK_PREDICTION, TASK_TUNING)
TASK_LABELS = {TASK_BOTTLENECK: "Bottleneck Analysis",
               TASK_PREDICTION: "Perf/Area Prediction",
               TASK_TUNING: "Parameter Tuning"}


def evaluate_backend(backend: LLMBackend, suite: BenchmarkSuite) -> Dict[str, float]:
    """Per-task accuracy of one backend."""
    acc = {}
    for task in TASKS:
        qs = suite.by_task(task)
        if not qs:
            acc[task] = float("nan")
            continue
        correct = sum(int(backend.choose(q) == q.answer) for q in qs)
        acc[task] = correct / len(qs)
    return acc


def accuracy_table(backends: Sequence[LLMBackend],
                   suite: BenchmarkSuite) -> List[Tuple[str, str, float]]:
    """Rows of (task_label, backend_name, accuracy) — Table 3 layout."""
    rows = []
    for task in TASKS:
        for b in backends:
            acc = evaluate_backend(b, suite)[task]
            rows.append((TASK_LABELS[task], b.name, acc))
    return rows
