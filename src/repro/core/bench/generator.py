"""DSE Benchmark generator (paper §4).

Produces the three task families as multiple-choice questions whose ground
truth is *computed from the analytical models* (not hand-labeled):

* bottleneck analysis  (paper: 308 questions) — given a design, its stall
  report and an objective, which parameter adjustment helps most?  Ground
  truth: evaluate every candidate move-set on the model, pick the best.
* perf/area prediction (paper: 127 questions) — given a sensitivity table
  around a reference design and a perturbed design, predict the metric.
  Distractors include the paper's reported failure mode (delta computed
  against a zero baseline instead of the sensitivity reference).
* parameter tuning     (paper: 30 questions) — given an initial design,
  constraints and an objective, pick the best full configuration.

Workload targets range from primitive operators (matmul, layernorm, ...) to
the full GPT-3 layer, per the paper ("ranging from primitive operators to
full workload").
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence

import numpy as np

from repro.core.llm import (MCQuery, TASK_BOTTLENECK, TASK_PREDICTION,
                            TASK_TUNING)
from repro.core.quane import sensitivity_analysis
from repro.perfmodel.critical_path import STALL_CLASSES
from repro.perfmodel.designspace import DesignSpace, SPACE
from repro.perfmodel.evaluator import make_evaluator
from repro.perfmodel.hardware import AREA_MODEL_SOURCE
from repro.perfmodel.roofline import SRAM_FEED_WORDS_PER_KB
from repro.perfmodel import workload as W
from repro.perfmodel.workload import Workload, _matmul, _vector, _allreduce


# ---- workload targets: primitives and the full-layer workloads -----------

def _primitive_workloads() -> List[Workload]:
    out = []
    for m, k, n in ((4096, 4096, 4096), (8, 12288, 4608), (16384, 12288, 6144),
                    (2048, 128, 2048), (512, 512, 512)):
        out.append(Workload(f"matmul-{m}x{k}x{n}", [_matmul("mm", m, k, n)]))
    out.append(Workload("layernorm-16Mx", [_vector("ln", 16 << 20, 8.0)]))
    out.append(Workload("softmax-64Mx", [_vector("sm", 64 << 20, 6.0)]))
    out.append(Workload("allreduce-192MB", [_allreduce("ar", 96 << 20)]))
    out.append(Workload("kvread-600MB", [W.Op("kv", W.MEMCPY, bytes=600e6)]))
    return out


def _full_workloads() -> List[Workload]:
    return [W.gpt3_layer_prefill(), W.gpt3_layer_decode()]


@dataclasses.dataclass
class BenchmarkSuite:
    questions: List[MCQuery]

    def by_task(self, task: str) -> List[MCQuery]:
        return [q for q in self.questions if q.task == task]


# ---------------------------------------------------------------------------

PRIMARY = {"tensor_compute": "sa_dim", "vector_compute": "vector_width",
           "memory_bw": "mem_channels", "interconnect": "link_count"}

# coarse relevance sets used to build plausible-but-wrong distractors
RELEVANT = {
    "tensor_compute": ("sa_dim", "core_count", "sublane_count", "sram_kb"),
    "vector_compute": ("vector_width", "core_count", "sublane_count"),
    "memory_bw": ("mem_channels", "gbuf_mb"),
    "interconnect": ("link_count",),
}


def _sa_headroom(space: DesignSpace, idx: np.ndarray) -> bool:
    v = space.decode_np(idx)
    names = list(space.names)
    sa_choices = space.choices[names.index("sa_dim")]
    sa = float(v["sa_dim"])
    bigger = next((c for c in sa_choices if c > sa), sa)
    return (SRAM_FEED_WORDS_PER_KB * float(v["sram_kb"])
            / (bigger * float(v["sublane_count"]))) >= 0.5


def _apply_moves(space: DesignSpace, idx: np.ndarray, moves) -> np.ndarray:
    out = idx.copy()
    for p, d in moves:
        pi = space.names.index(p)
        out[pi] = np.clip(out[pi] + d, 0, space.cardinalities[pi] - 1)
    return out


def generate_bottleneck(n: int = 308, seed: int = 0,
                        space: DesignSpace = SPACE) -> List[MCQuery]:
    rng = np.random.default_rng(seed)
    wls = _primitive_workloads() + _full_workloads()
    # one single-workload evaluator per target, all sharing the jit cache
    evs = {w.name: make_evaluator({"lat": w}, space=space) for w in wls}
    out: List[MCQuery] = []
    while len(out) < n:
        wl = wls[int(rng.integers(len(wls)))]
        ev = evs[wl.name]
        idx = space.sample(rng, 1)[0]
        rep = ev.stalls(idx).stall_report()
        dom = rep.dominant
        primary = PRIMARY[dom]
        rel = RELEVANT[dom]
        irrelevant = [p for p in space.names if p not in rel]

        cand: List[List] = [[(primary, +1)]]
        cand.append([(primary, -1)])                                  # wrong direction
        cand.append([(PRIMARY[_other(dom, rng)], +1)])                # wrong resource
        cand.append([(primary, +1),
                     (str(rng.choice(irrelevant)), +1)])              # + irrelevant
        news = np.stack([_apply_moves(space, idx, c) for c in cand]
                        + [_apply_moves(space, idx, [("sa_dim", +1)]), idx])
        y_all = ev.objectives(news)                     # (rows, 2): lat, area
        # headroom: does growing the systolic array alone still help here?
        # (the corrective rule distilled from observed failure cases)
        sa_helps = bool(y_all[-2, 0] < y_all[-1, 0] * 0.999)
        y = y_all[:len(cand)]
        # ground truth: best latency; ties broken toward fewer moves and
        # lower area (an adjustment that spends area on an irrelevant
        # resource for the same latency is NOT the right answer)
        lat = np.round(y[:, 0] / y[:, 0].min(), 4)
        keys = [(lat[i], len(cand[i]), float(y[i, 1]))
                for i in range(len(cand))]
        truth = int(min(range(len(cand)), key=lambda i: keys[i]))
        perm = rng.permutation(len(cand))
        cand = [cand[i] for i in perm]
        truth = int(np.where(perm == truth)[0][0])
        out.append(MCQuery(
            task=TASK_BOTTLENECK,
            prompt=(f"Workload: {wl.name}. Design {_fmt_design(space, idx)}.\n"
                    f"{rep.as_prompt()}\n"
                    "Objective: minimize latency. Which adjustment helps most?"),
            options=[_fmt_moves(c) for c in cand],
            payload={
                "dominant_stall": dom,
                "option_params": cand,
                "relevant": {dom: rel},
                "sa_headroom": sa_helps,
            },
            answer=truth,
        ))
    return out


def generate_prediction(n: int = 127, seed: int = 1,
                        space: DesignSpace = SPACE) -> List[MCQuery]:
    rng = np.random.default_rng(seed)
    wl = W.gpt3_layer_prefill()
    dec = W.gpt3_layer_decode()
    ev = make_evaluator({"ttft": wl, "tpot": dec}, space=space)
    out: List[MCQuery] = []
    while len(out) < n:
        ref = space.sample(rng, 1)[0]
        sens = sensitivity_analysis(ev, ref, space=space)
        metric = ("ttft", "tpot", "area")[int(rng.integers(3))]
        # perturb 1-3 params by +-1 step
        k = int(rng.integers(1, 4))
        params = rng.choice(space.n_params, size=k, replace=False)
        steps: Dict[str, int] = {}
        new = ref.copy()
        for pi in params:
            d = int(rng.choice([-1, 1]))
            tgt = np.clip(new[pi] + d, 0, space.cardinalities[pi] - 1)
            if tgt != new[pi]:
                steps[space.names[pi]] = int(tgt - new[pi])
                new[pi] = tgt
        if not steps:
            continue
        col = {"ttft": 0, "tpot": 1, "area": 2}[metric]
        y = ev.objectives(np.stack([ref, new]))       # one fused dispatch
        truth_val = float(y[1, col])
        base_val = float(y[0, col])
        lin = base_val + sum(sens.delta[p][metric] * d for p, d in steps.items())
        zero_baseline = lin - base_val        # the paper-reported failure mode
        opts = [truth_val, zero_baseline,
                base_val * (1 + 0.35 * rng.standard_normal()),
                lin * (1 + 0.4 * abs(rng.standard_normal()) + 0.1)]
        perm = rng.permutation(4)
        vals = [opts[i] for i in perm]
        truth = int(np.where(perm == 0)[0][0])
        sens_view = {p: sens.delta[p][metric] for p in steps}
        out.append(MCQuery(
            task=TASK_PREDICTION,
            prompt=(f"Area model source:\n{AREA_MODEL_SOURCE}\n"
                    f"Reference design {_fmt_design(space, ref)} has "
                    f"{metric}={base_val:.6e}.\n{sens.as_prompt()}\n"
                    f"New design changes: {steps}. Predict {metric}."),
            options=[f"{v:.6e}" for v in vals],
            payload={
                "reference_metric": base_val,
                "sensitivity": sens_view,
                "delta_steps": steps,
                "option_values": vals,
            },
            answer=truth,
        ))
    return out


def generate_tuning(n: int = 30, seed: int = 2,
                    space: DesignSpace = SPACE) -> List[MCQuery]:
    rng = np.random.default_rng(seed)
    wl = W.gpt3_layer_prefill()
    dec = W.gpt3_layer_decode()
    ev = make_evaluator({"ttft": wl, "tpot": dec}, space=space)
    out: List[MCQuery] = []
    while len(out) < n:
        idx = space.sample(rng, 1)[0]
        rep = ev.stalls(idx).stall_report("ttft")
        dom = rep.dominant
        primary = PRIMARY[dom]
        sens = sensitivity_analysis(ev, idx, space=space)
        crit = sens.criticality("ttft")
        least = min(crit, key=crit.get)
        most = max(crit, key=crit.get)
        area_budget = rep.area * 1.02

        cand = [
            [(primary, +1), (least, -1)],        # mitigate + trade least-critical
            [(primary, +1), (most, -1)],         # trades away the critical resource
            [(least, +1)],                        # adjusts a non-critical resource
            [(primary, +1), (least, -1), (most, -1)],  # over-aggressive
        ]
        news = [_apply_moves(space, idx, c) for c in cand]
        y = ev.objectives(np.stack(news))             # one fused dispatch
        lat, area = y[:, 0], y[:, 2]
        feasible = area <= area_budget
        score = np.where(feasible, lat, lat * 100.0)
        truth = int(np.argmin(score))
        perm = rng.permutation(len(cand))
        cand = [cand[i] for i in perm]
        truth = int(np.where(perm == truth)[0][0])
        constraints_ok = [bool(feasible[i]) for i in perm]
        out.append(MCQuery(
            task=TASK_TUNING,
            prompt=(f"Initial design {_fmt_design(space, idx)}.\n{rep.as_prompt()}\n"
                    f"{sens.as_prompt()}\n"
                    f"Constraint: area <= {area_budget:.0f} mm2. "
                    "Objective: minimize TTFT. Which tuning is best?"),
            options=[_fmt_moves(c) for c in cand],
            payload={
                "dominant_stall": dom,
                "option_params": cand,
                "criticality": crit,
                "sa_headroom": _sa_headroom(space, idx),
                "constraints_ok": constraints_ok,
                "sensitivity": {p: dict(sens.delta[p]) for p in space.names},
            },
            answer=truth,
        ))
    return out


def generate_suite(n_bottleneck: int = 308, n_prediction: int = 127,
                   n_tuning: int = 30, seed: int = 0) -> BenchmarkSuite:
    return BenchmarkSuite(
        questions=(generate_bottleneck(n_bottleneck, seed)
                   + generate_prediction(n_prediction, seed + 1)
                   + generate_tuning(n_tuning, seed + 2)))


# ---------------------------------------------------------------------------

def _other(dom: str, rng) -> str:
    others = [c for c in STALL_CLASSES if c != dom]
    return str(rng.choice(others))


def _fmt_design(space: DesignSpace, idx) -> str:
    v = space.decode_np(np.asarray(idx))
    return "{" + ", ".join(f"{k}={int(v[k])}" for k in space.names) + "}"


def _fmt_moves(moves) -> str:
    return ", ".join(f"{p}{'+' if d > 0 else '-'}1" for p, d in moves)
