from repro.core.bench.generator import (
    BenchmarkSuite, generate_suite, generate_bottleneck, generate_prediction,
    generate_tuning,
)
from repro.core.bench.harness import evaluate_backend, accuracy_table

__all__ = [
    "BenchmarkSuite", "generate_suite", "generate_bottleneck",
    "generate_prediction", "generate_tuning", "evaluate_backend",
    "accuracy_table",
]
