"""QualE static-analysis path: derive the Influence Map by PARSING the
simulator source code (the literal analogue of the paper's §3.2.1, where
the LLM statically analyses the simulator codebase).

The analyser reads the actual Python sources of the performance model
(``repro.perfmodel.hardware`` / ``roofline``), builds an assignment-level
dataflow graph with :mod:`ast`, and traces which design-space parameters
reach which derived quantities (tensor/vector throughput, memory/ici
bandwidth, area) — e.g. it discovers from code alone that
``vector_flops`` depends on core/sublane/vector width but NOT on
``sa_dim``, the exact example in the paper.

The probing-based QualE (repro.core.quale) remains the default (it also
quantifies *stall-class* reachability, which needs execution); this module
cross-validates it: tests assert the two maps agree on metric edges.
"""
from __future__ import annotations

import ast
import inspect
from typing import Dict, Set

from repro.perfmodel import hardware as HW
from repro.perfmodel.designspace import PARAM_NAMES

# derived quantity -> PPA metrics it feeds (the model's output surface)
DERIVED_TO_METRICS = {
    "tensor_flops": {"ttft", "tpot"},
    "vector_flops": {"ttft", "tpot"},
    "mem_bw": {"ttft", "tpot"},
    "ici_bw": {"ttft", "tpot"},
    "sram_kb": {"ttft", "tpot"},       # utilization terms
    "gbuf_bytes": {"ttft", "tpot"},    # blocked-matmul traffic
    "sa_dim": {"ttft", "tpot"},
    "sublane_count": {"ttft", "tpot"},
    "core_count": {"ttft", "tpot"},
    "vector_width": {"ttft", "tpot"},
    "area_mm2": {"area"},
}


class _DepVisitor(ast.NodeVisitor):
    """Collects, per assignment target, the set of names it reads."""

    def __init__(self):
        self.deps: Dict[str, Set[str]] = {}
        self._func = None

    def visit_FunctionDef(self, node: ast.FunctionDef):
        prev, self._func = self._func, node.name
        self.generic_visit(node)
        self._func = prev

    def visit_Assign(self, node: ast.Assign):
        reads = {n.id for n in ast.walk(node.value)
                 if isinstance(n, ast.Name)}
        reads |= {n.value for n in ast.walk(node.value)
                  if isinstance(n, ast.Constant) and isinstance(n.value, str)}
        # dict-style reads: v["core_count"] -> record the subscript key
        for sub in ast.walk(node.value):
            if isinstance(sub, ast.Subscript) and \
                    isinstance(sub.slice, ast.Constant):
                reads.add(str(sub.slice.value))
        for tgt in node.targets:
            if isinstance(tgt, ast.Name):
                self.deps.setdefault(tgt.id, set()).update(reads)
            elif isinstance(tgt, ast.Tuple):
                for e in tgt.elts:
                    if isinstance(e, ast.Name):
                        self.deps.setdefault(e.id, set()).update(reads)
        self.generic_visit(node)

    def visit_Return(self, node: ast.Return):
        # dict literal returns: {"tensor_flops": expr, ...}
        if isinstance(node.value, ast.Dict):
            for k, v in zip(node.value.keys, node.value.values):
                if isinstance(k, ast.Constant):
                    reads = {n.id for n in ast.walk(v)
                             if isinstance(n, ast.Name)}
                    for sub in ast.walk(v):
                        if isinstance(sub, ast.Subscript) and \
                                isinstance(sub.slice, ast.Constant):
                            reads.add(str(sub.slice.value))
                    self.deps.setdefault(str(k.value), set()).update(reads)
        elif node.value is not None and self._func:
            # plain `return expr`: attribute the reads to the function name
            reads = {n.id for n in ast.walk(node.value)
                     if isinstance(n, ast.Name)}
            for sub in ast.walk(node.value):
                if isinstance(sub, ast.Subscript) and \
                        isinstance(sub.slice, ast.Constant):
                    reads.add(str(sub.slice.value))
            self.deps.setdefault(self._func, set()).update(reads)
        self.generic_visit(node)


def _transitive(deps: Dict[str, Set[str]], target: str,
                params: Set[str]) -> Set[str]:
    """Design-space params reachable from `target` through the assignments."""
    seen, stack, hits = set(), [target], set()
    while stack:
        cur = stack.pop()
        if cur in seen:
            continue
        seen.add(cur)
        if cur in params:
            hits.add(cur)
        stack.extend(deps.get(cur, ()))
    return hits


def derive_influence_map_from_source() -> Dict[str, Set[str]]:
    """param -> set of PPA metrics, discovered from the model SOURCE CODE."""
    src = inspect.getsource(HW.derive_hardware) + "\n" + \
        inspect.getsource(HW.area_mm2)
    tree = ast.parse(src)
    v = _DepVisitor()
    v.visit(tree)
    params = set(PARAM_NAMES)

    out: Dict[str, Set[str]] = {p: set() for p in PARAM_NAMES}
    for derived, metrics in DERIVED_TO_METRICS.items():
        for p in _transitive(v.deps, derived, params):
            out[p].update(metrics)
    # every hardware parameter feeds the area model (checked transitively)
    for p in _transitive(v.deps, "area_mm2", params):
        out[p].add("area")
    return out
