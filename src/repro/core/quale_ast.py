"""DEPRECATED shim: the AST-based QualE path moved to
:mod:`repro.analysis.influence`.

The original single-file walker (``_DepVisitor``) grew into the full
interprocedural extractor in :mod:`repro.analysis` — guard-aware dataflow,
``file:line`` provenance, stall/term edges and the AHK primaries, with a
checked-in artifact guarded by ``python -m repro.analysis.extract --check``.
This module re-exports the compatible surface and warns on import; new code
should import from :mod:`repro.analysis.influence` directly.

Note one intentional table delta: the old hand-coded ``DERIVED_TO_METRICS``
listed the ``vector_width`` passthrough key, which no roofline term reads
(``vector_flops`` carries its influence); the extracted table only contains
edges that exist in the source.  Param-level results are identical.
"""
from __future__ import annotations

import warnings

from repro.analysis.influence import derive_influence_map_from_source

__all__ = ["derive_influence_map_from_source", "DERIVED_TO_METRICS"]

warnings.warn(
    "repro.core.quale_ast is deprecated; use repro.analysis.influence "
    "(the interprocedural extractor) instead",
    DeprecationWarning, stacklevel=2)


def __getattr__(name):
    if name == "DERIVED_TO_METRICS":
        from repro.analysis.influence import derived_to_metrics
        return {k: set(v) for k, v in derived_to_metrics().items()}
    raise AttributeError(name)
