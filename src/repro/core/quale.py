"""Qualitative Engine (QualE): structural Influence-Map acquisition.

The paper's QualE has an LLM statically analyse the simulator codebase and
emit a map {resource parameter -> influenced PPA metrics / stall classes}.
The JAX analogue of "parsing the simulator" is *probing the analytic model's
dependency structure*: perturb each parameter across a set of probe designs
and record which outputs (TTFT, TPOT, area, per-stall-class times) respond.
This discovers, e.g., that vector throughput depends on core/sublane/vector
width but NOT on the systolic array — the exact example in §3.2.1.

The derived map is the structural half of the Architectural Heuristic
Knowledge (AHK); the Quantitative Engine fills in magnitudes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set

import numpy as np

from repro.perfmodel.critical_path import STALL_CLASSES
from repro.perfmodel.designspace import DesignSpace
from repro.perfmodel.evaluator import EvalRequest, as_evaluator

METRICS = ("ttft", "tpot", "area")


@dataclasses.dataclass
class InfluenceMap:
    """param -> metrics it influences; param -> stall classes it relieves."""
    metric_edges: Dict[str, Set[str]]
    stall_edges: Dict[str, Set[str]]

    def params_for_stall(self, stall: str) -> List[str]:
        return sorted(p for p, s in self.stall_edges.items() if stall in s)

    def as_prompt(self) -> str:
        lines = ["Influence map (param -> affected metrics | relieved stalls):"]
        for p in sorted(self.metric_edges):
            lines.append(f"  {p}: metrics={sorted(self.metric_edges[p])}"
                         f" stalls={sorted(self.stall_edges.get(p, ()))}")
        return "\n".join(lines)


def derive_influence_map(evaluator,
                         space: Optional[DesignSpace] = None,
                         n_probes: int = 8, seed: int = 0,
                         rel_eps: float = 1e-4) -> InfluenceMap:
    """Probe the evaluator at `n_probes` random designs, sweeping each
    parameter over its full choice range, and record which outputs move.

    One fused stalls-detail dispatch per parameter covers every workload's
    latency, the per-class stall times AND area.
    """
    ev = as_evaluator(evaluator)
    space = space or ev.space
    rng = np.random.default_rng(seed)
    probes = space.sample(rng, n_probes)
    metric_edges: Dict[str, Set[str]] = {p: set() for p in space.names}
    stall_edges: Dict[str, Set[str]] = {p: set() for p in space.names}

    for pi, pname in enumerate(space.names):
        card = int(space.cardinalities[pi])
        # batch: every probe x every choice of this param
        batch = np.repeat(probes, card, axis=0)
        batch[:, pi] = np.tile(np.arange(card, dtype=np.int32), n_probes)
        rep = ev.evaluate(EvalRequest(batch, detail="stalls"))
        for mname in ev.workloads:
            lat = rep.latency[mname].reshape(n_probes, card)
            stall = rep.stall[mname].reshape(n_probes, card, 4)
            if _responds(lat, rel_eps):
                metric_edges[pname].add(mname)
            for ci, cname in enumerate(STALL_CLASSES):
                if _responds(stall[..., ci], rel_eps):
                    stall_edges[pname].add(cname)
        area = rep.area.reshape(n_probes, card)
        if _responds(area, rel_eps):
            metric_edges[pname].add("area")

    return InfluenceMap(metric_edges=metric_edges, stall_edges=stall_edges)


def _responds(vals: np.ndarray, rel_eps: float) -> bool:
    """True if sweeping the parameter moves the output anywhere."""
    span = vals.max(axis=-1) - vals.min(axis=-1)
    scale = np.maximum(np.abs(vals).max(axis=-1), 1e-30)
    return bool((span / scale > rel_eps).any())


def static_influence_map() -> InfluenceMap:
    """The SAME InfluenceMap contract, acquired WITHOUT executing the model:
    built from the influence graph that :mod:`repro.analysis.influence`
    extracts from the perfmodel source (the paper's literal 'LLM statically
    analyses the simulator codebase' path).  Zero evaluator dispatches —
    usable as ``LuminaDSE(imap=static_influence_map())`` — and the probe
    map cross-validates it (:meth:`LuminaDSE.rule_audit`)."""
    from repro.analysis.influence import extract_influence_graph
    graph = extract_influence_graph()
    metric_edges = {p: set(ms) for p, ms in graph.param_metrics().items()}
    stall_edges: Dict[str, Set[str]] = {p: set() for p in graph.params}
    for stall in graph.stalls:
        for p in graph.params_for_stall(stall):
            stall_edges[p].add(stall)
    return InfluenceMap(metric_edges=metric_edges, stall_edges=stall_edges)
