"""The Lumina DSE loop (Figure 2): AHK acquisition -> iterate
(evaluate -> bottleneck analysis -> strategy -> explore) -> refine.

Both fidelity tiers are :class:`~repro.perfmodel.evaluator.Evaluator`
instances: the *target* evaluator is the budgeted simulation environment
(each EE step = ONE fused jitted dispatch), the *proxy* evaluator serves
QualE probing and QuanE sensitivity for free (§3.2.2: "the QuanE can focus
on estimating only power and area, which are faster to evaluate").  Budget
accounting follows the paper: only EE dispatches on the target tier count.
Either tier may also be an :class:`~repro.distributed.service.EvalService`
(it implements the Evaluator protocol): the loop's requests then coalesce
with any other client's through the service's shared cache — and a
:class:`~repro.distributed.sharded.ShardedEvaluator` fans each request
across workers, transparently to the loop.

The loop is exposed at two altitudes:

* :meth:`LuminaDSE.run` — the closed single-trajectory loop (optionally
  seeded with a LIST of initial designs, with an injectable per-step
  callback for telemetry);
* :meth:`LuminaDSE.start` -> :class:`Campaign` — the stepwise
  propose/observe view that :class:`~repro.core.campaign.CampaignRunner`
  drives to run K campaigns against ONE shared engine, fusing each round's
  candidate evaluations into a single batched dispatch.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Set, Tuple

import numpy as np

from repro.core.explore import ExplorationEngine
from repro.core.llm import LLMBackend, RuleOracle
from repro.core.memory import Sample, TrajectoryMemory
from repro.core.quale import derive_influence_map, InfluenceMap
from repro.core.quane import sensitivity_analysis
from repro.core.refine import RefinementLoop
from repro.core.strategy import Directive, StrategyEngine
from repro.perfmodel.designspace import DesignSpace, SPACE, A100_REFERENCE
from repro.perfmodel.evaluator import Evaluator, as_evaluator, pair_view

FOCUS_CYCLE = ("ttft", "tpot", "area")

# step_callback(campaign, sample) — invoked after every budgeted observation
StepCallback = Callable[["Campaign", Sample], None]


@dataclasses.dataclass
class DSEResult:
    samples: List[Sample]
    phv: float
    sample_efficiency: float
    superior_count: int
    pareto: List[Sample]
    trajectory_notes: List[str]


class Campaign:
    """Stepwise view of ONE Lumina trajectory.

    The driver (``LuminaDSE.run`` or a multi-campaign runner) alternates::

        idx, directive = campaign.propose()
        sample = engine.evaluate(idx, step=campaign.step, directive=directive)
        campaign.observe(sample)

    ``propose`` first drains the campaign's initial seed list (step 0), then
    runs the bottleneck-analysis -> strategy cycle.  A shared ``visited`` set
    may be injected so parallel campaigns never burn budget re-evaluating
    each other's designs.
    """

    def __init__(self, dse: "LuminaDSE", init: np.ndarray,
                 visited: Optional[Set[tuple]] = None,
                 label: str = "lumina"):
        self.dse = dse
        self.label = label
        self.tm = TrajectoryMemory(dse.ref_point)
        self.notes: List[str] = []
        self.se = StrategyEngine(dse.llm, dse.imap, dse.space,
                                 primary_map=dse.primary_map)
        inits = np.atleast_2d(np.asarray(init, dtype=np.int32))
        self._pending_inits = []             # de-duplicated, order-preserving
        seen: Set[tuple] = set()
        for row in inits:
            key = tuple(row)
            if key not in seen:
                seen.add(key)
                self._pending_inits.append(row.copy())
        self.sens = sensitivity_analysis(dse.proxy, inits[0], space=dse.space)
        self.visited: Set[tuple] = visited if visited is not None else set()
        self.step = 0
        self._directive: Optional[Directive] = None

    def propose(self) -> Tuple[np.ndarray, Optional[Directive]]:
        """Next candidate design (and the directive that produced it)."""
        if self._pending_inits:
            self._directive = None
            idx = self._pending_inits.pop(0)
            # claim the seed NOW so sibling campaigns proposing later in the
            # same round never spend budget re-evaluating it
            self.visited.add(tuple(idx))
            return idx, None
        self.step += 1
        focus = FOCUS_CYCLE[(self.step - 1) % len(FOCUS_CYCLE)]
        base = self.tm.best(weights=_focus_weights(focus)) or self.tm.samples[-1]
        rep_t, rep_p = self.dse.ee.reports(base.idx)  # cached reads, cheap
        report = rep_p if focus == "tpot" else rep_t
        directive = self.se.propose(base.idx, report, self.sens, self.tm,
                                    focus, area_budget=self.dse.area_budget,
                                    visited=self.visited)
        self.visited.add(tuple(directive.new_idx))
        self._directive = directive
        return directive.new_idx, directive

    def observe(self, sample: Sample) -> None:
        """Record one evaluated proposal and run the refinement pass."""
        self.tm.add(sample)
        self.visited.add(tuple(sample.idx))
        if self._directive is not None:
            note = self.dse.refiner.update(self.sens, self.tm, sample)
            if note:
                self.notes.append(f"step {self.step}: {note}")
            self.sens = self.dse.refiner.maybe_reanchor(
                self.sens, self.tm, self.dse.proxy, self.step)
        self._directive = None

    def result(self) -> DSEResult:
        return DSEResult(
            samples=list(self.tm.samples),
            phv=self.tm.phv(),
            sample_efficiency=self.tm.sample_efficiency(),
            superior_count=self.tm.superior_count(),
            pareto=self.tm.pareto(),
            trajectory_notes=list(self.notes),
        )


class LuminaDSE:
    def __init__(self, evaluator: Evaluator, *,
                 proxy: Optional[Evaluator] = None,
                 llm: Optional[LLMBackend] = None,
                 space: DesignSpace = SPACE,
                 ref_point: Optional[np.ndarray] = None,
                 area_budget: Optional[float] = None,
                 seed: int = 0,
                 engine: Optional[ExplorationEngine] = None,
                 imap: Optional[InfluenceMap] = None,
                 workloads: Optional[Tuple[str, str]] = None,
                 primary_map: Optional[dict] = None):
        """``engine`` lets parallel campaigns share ONE ExplorationEngine
        (one budget counter, one report cache); ``imap`` injects an already
        derived influence map so K campaigns pay acquisition once;
        ``workloads`` picks the (prefill, decode) pair of a multi-workload
        evaluator this loop optimizes (e.g. one zoo-suite scenario);
        ``primary_map`` overrides the source-extracted AHK primary edges
        (stall -> parameter) for every campaign's SE — the ablation hook."""
        self.space = space
        evaluator = as_evaluator(evaluator)
        self.ee = (engine if engine is not None
                   else ExplorationEngine(evaluator, workloads=workloads))
        proxy = proxy if proxy is not None else evaluator
        if workloads is not None and hasattr(proxy, "models"):
            # scenario campaigns: QualE/QuanE read objective columns 0/1,
            # so the proxy must expose exactly this (prefill, decode) pair
            proxy = pair_view(proxy, workloads)
        self.proxy = proxy
        self.llm = llm or RuleOracle(enhanced=True)
        self.refiner = RefinementLoop()
        self.seed = seed
        self._imap = imap
        self.primary_map = primary_map   # None -> source-extracted default
        if ref_point is None:
            # the reference evaluation is free (given); reports() caches it so
            # a campaign starting at the reference re-reads it for free
            ref_idx = space.encode_nearest(A100_REFERENCE)
            rep_t, rep_p = self.ee.reports(ref_idx)
            ref_point = np.array([rep_t.latency, rep_p.latency, rep_t.area])
        self.ref_point = np.asarray(ref_point, dtype=np.float64)
        if self.ee.ref_point is None:    # objective scales for stall merging
            self.ee.ref_point = self.ref_point
        self.area_budget = (area_budget if area_budget is not None
                            else float(self.ref_point[2]))

    @property
    def imap(self) -> InfluenceMap:
        """QualE influence map (proxy tier, derived once per instance)."""
        if self._imap is None:
            self._imap = derive_influence_map(self.proxy, space=self.space,
                                              seed=self.seed)
        return self._imap

    def rule_audit(self):
        """Cross-validate the source-extracted influence graph against this
        loop's probe-derived map: the auto-correction telemetry of §5.2
        (source-vs-probe disagreements are candidate rule corrections).
        Returns a :class:`repro.analysis.influence.RuleAudit`."""
        from repro.analysis.influence import (cross_validate,
                                              extract_influence_graph)
        return cross_validate(extract_influence_graph(), self.imap)

    # ------------------------------------------------------------------
    def start(self, init: Optional[np.ndarray] = None,
              visited: Optional[Set[tuple]] = None,
              label: str = "lumina") -> Campaign:
        """Open a stepwise campaign seeded at ``init`` (a design-index
        vector OR a list/array of them — a sweep-derived seed list)."""
        if init is None:
            init = self.space.encode_nearest(A100_REFERENCE)
        return Campaign(self, init, visited=visited, label=label)

    def run(self, budget: int = 20,
            init: Optional[np.ndarray] = None,
            step_callback: Optional[StepCallback] = None) -> DSEResult:
        """The closed loop: one campaign, `budget` target-tier evaluations.

        ``init`` may be a single design or a seed list (all seeds are
        evaluated first, then the trajectory continues from the best);
        ``step_callback(campaign, sample)`` fires after every observation —
        the injection point for per-step regret/PHV telemetry.
        """
        campaign = self.start(init)
        budget_stop = self.ee.evals + budget
        while self.ee.evals < budget_stop:
            idx, directive = campaign.propose()
            sample = self.ee.evaluate(idx, step=campaign.step,
                                      directive=directive)
            campaign.observe(sample)
            if step_callback is not None:
                step_callback(campaign, sample)
        return campaign.result()


def _focus_weights(focus: str):
    return {"ttft": (3.0, 1.0, 1.0), "tpot": (1.0, 3.0, 1.0),
            "area": (1.0, 1.0, 3.0)}[focus]
