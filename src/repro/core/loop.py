"""The Lumina DSE loop (Figure 2): AHK acquisition -> iterate
(evaluate -> bottleneck analysis -> strategy -> explore) -> refine.

Both fidelity tiers are :class:`~repro.perfmodel.evaluator.Evaluator`
instances: the *target* evaluator is the budgeted simulation environment
(each EE step = ONE fused jitted dispatch), the *proxy* evaluator serves
QualE probing and QuanE sensitivity for free (§3.2.2: "the QuanE can focus
on estimating only power and area, which are faster to evaluate").  Budget
accounting follows the paper: only EE dispatches on the target tier count.

Construct with evaluators (``LuminaDSE(evaluator, proxy=proxy_ev)``) or the
legacy ``(ttft_model, tpot_model, proxy_models=(rt, rp))`` pair signature,
which is kept as a deprecation shim for one release.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core.explore import ExplorationEngine
from repro.core.llm import LLMBackend, RuleOracle
from repro.core.memory import Sample, TrajectoryMemory
from repro.core.quale import derive_influence_map, InfluenceMap
from repro.core.quane import sensitivity_analysis
from repro.core.refine import RefinementLoop
from repro.core.strategy import StrategyEngine
from repro.perfmodel.designspace import DesignSpace, SPACE, A100_REFERENCE
from repro.perfmodel.evaluator import Evaluator, as_evaluator


@dataclasses.dataclass
class DSEResult:
    samples: List[Sample]
    phv: float
    sample_efficiency: float
    superior_count: int
    pareto: List[Sample]
    trajectory_notes: List[str]


class LuminaDSE:
    def __init__(self, ttft_model, tpot_model=None,
                 proxy_models: Optional[Tuple] = None,
                 llm: Optional[LLMBackend] = None,
                 space: DesignSpace = SPACE,
                 ref_point: Optional[np.ndarray] = None,
                 area_budget: Optional[float] = None,
                 seed: int = 0,
                 proxy: Optional[Evaluator] = None):
        self.space = space
        evaluator = as_evaluator(ttft_model, tpot_model)
        self.ee = ExplorationEngine(evaluator)
        if proxy is None and proxy_models is not None:
            proxy = as_evaluator(*proxy_models) if isinstance(
                proxy_models, tuple) else as_evaluator(proxy_models)
        self.proxy = proxy if proxy is not None else evaluator
        self.llm = llm or RuleOracle(enhanced=True)
        self.refiner = RefinementLoop()
        self.seed = seed
        if ref_point is None:
            ref_idx = space.encode_nearest(A100_REFERENCE)
            r = self.ee.evaluate(ref_idx, step=-1)
            self.ee.evals = 0        # reference evaluation is free (given)
            ref_point = r.objectives
        self.ref_point = np.asarray(ref_point, dtype=np.float64)
        self.area_budget = area_budget if area_budget is not None else float(self.ref_point[2])

    # ------------------------------------------------------------------
    def run(self, budget: int = 20,
            init: Optional[np.ndarray] = None) -> DSEResult:
        space = self.space
        tm = TrajectoryMemory(self.ref_point)
        notes: List[str] = []

        # ---- AHK acquisition (proxy tier, not budgeted) ----
        imap = derive_influence_map(self.proxy, space=space, seed=self.seed)
        se = StrategyEngine(self.llm, imap, space)

        idx = np.asarray(init if init is not None
                         else space.encode_nearest(A100_REFERENCE), dtype=np.int32)
        sens = sensitivity_analysis(self.proxy, idx, space=space)

        sample = self.ee.evaluate(idx, step=0)
        tm.add(sample)
        visited = {tuple(idx)}

        focus_cycle = ("ttft", "tpot", "area")
        step = 0
        while self.ee.evals < budget:
            step += 1
            focus = focus_cycle[(step - 1) % len(focus_cycle)]
            base = tm.best(weights=_focus_weights(focus)) or tm.samples[-1]
            rep_t, rep_p = self.ee.reports(base.idx)  # cached-model calls, cheap
            report = rep_t if focus == "ttft" else rep_p if focus == "tpot" else rep_t
            directive = se.propose(base.idx, report, sens, tm, focus,
                                   area_budget=self.area_budget,
                                   visited=visited)
            visited.add(tuple(directive.new_idx))
            sample = self.ee.evaluate(directive.new_idx, step=step,
                                      directive=directive)
            tm.add(sample)
            note = self.refiner.update(sens, tm, sample)
            if note:
                notes.append(f"step {step}: {note}")
            sens = self.refiner.maybe_reanchor(sens, tm, self.proxy, step)

        return DSEResult(
            samples=list(tm.samples),
            phv=tm.phv(),
            sample_efficiency=tm.sample_efficiency(),
            superior_count=tm.superior_count(),
            pareto=tm.pareto(),
            trajectory_notes=notes,
        )


def _focus_weights(focus: str):
    return {"ttft": (3.0, 1.0, 1.0), "tpot": (1.0, 3.0, 1.0),
            "area": (1.0, 1.0, 3.0)}[focus]
