"""Trajectory Memory (TM): every evaluated sample + reflection helpers."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.pareto import pareto_mask, hypervolume, dominates_ref


@dataclasses.dataclass
class Sample:
    step: int
    idx: np.ndarray                      # design (choice indices)
    ttft: float
    tpot: float
    area: float
    dominant_stall: str
    directive: Optional[dict] = None     # what the SE changed and predicted
    note: str = ""

    @property
    def objectives(self) -> np.ndarray:
        return np.array([self.ttft, self.tpot, self.area])


class TrajectoryMemory:
    def __init__(self, ref_point: np.ndarray):
        self.samples: List[Sample] = []
        self.ref = np.asarray(ref_point, dtype=np.float64)
        # failure patterns discovered by reflection: (param, direction, stall)
        # -> strike count; strategy avoids repeating heavily-struck moves.
        self.deny: Dict[Tuple[str, int, str], int] = {}

    # ------------------------------------------------------------------
    def add(self, s: Sample) -> None:
        self.samples.append(s)

    def objectives(self) -> np.ndarray:
        if not self.samples:
            return np.zeros((0, 3))
        return np.stack([s.objectives for s in self.samples])

    def pareto(self) -> List[Sample]:
        y = self.objectives()
        if len(y) == 0:
            return []
        mask = pareto_mask(y)
        out, seen = [], set()
        for s, m in zip(self.samples, mask):
            key = tuple(s.idx)
            if m and key not in seen:
                seen.add(key)
                out.append(s)
        return out

    def phv(self) -> float:
        return hypervolume(self.objectives(), self.ref)

    def superior_count(self) -> int:
        y = self.objectives()
        return int(dominates_ref(y, self.ref).sum()) if len(y) else 0

    def sample_efficiency(self) -> float:
        n = len(self.samples)
        return self.superior_count() / n if n else 0.0

    def best(self, weights=(1.0, 1.0, 1.0)) -> Optional[Sample]:
        """Best sample under normalized weighted sum (vs reference point)."""
        if not self.samples:
            return None
        y = self.objectives() / self.ref[None, :]
        score = (y * np.asarray(weights)[None, :]).sum(axis=1)
        return self.samples[int(np.argmin(score))]

    # ------------------- reflection --------------------------------
    def reflect(self, s: Sample) -> str:
        """Paper §3.4: identify failed attempts and record the pattern so the
        Strategy Engine avoids repeating them."""
        if s.directive is None or len(self.samples) < 2:
            return ""
        prev = self.samples[-2]
        improved = (s.ttft < prev.ttft) or (s.tpot < prev.tpot) or (s.area < prev.area)
        not_worse = (s.ttft <= prev.ttft * 1.001 and s.tpot <= prev.tpot * 1.001
                     and s.area <= prev.area * 1.001)
        if improved and not_worse:
            # confirmed move: relax any strikes against it
            for (param, direction) in s.directive.get("moves", []):
                key = (param, direction, prev.dominant_stall)
                if key in self.deny:
                    self.deny[key] = max(0, self.deny[key] - 1)
            return ""
        notes = []
        for (param, direction) in s.directive.get("moves", []):
            key = (param, direction, prev.dominant_stall)
            self.deny[key] = self.deny.get(key, 0) + 1
            notes.append(f"avoid {param}{'+' if direction > 0 else '-'} under "
                         f"{prev.dominant_stall} (strike {self.deny[key]})")
        return "; ".join(notes)

    def denied(self, param: str, direction: int, stall: str,
               threshold: int = 2) -> bool:
        return self.deny.get((param, direction, stall), 0) >= threshold
