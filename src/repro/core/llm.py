"""LLM backends for Lumina.

The paper's framework treats the LLM as a swappable reasoning engine that is
(a) benchmarked by the DSE Benchmark and (b) constrained by the Strategy
Engine's corrective rules.  This container is offline, so the default backend
is a deterministic rule engine (:class:`RuleOracle`) encoding exactly the
architectural reasoning the paper prompts for; :class:`DegradedOracle`
injects calibrated error to emulate weaker models (Table 3 structure) and to
exercise the Refinement Loop's error recovery; :class:`ExternalLLM` shows the
wire format a real model would consume.

Every interaction is a multiple-choice :class:`MCQuery` carrying BOTH the
human/LLM-facing prompt text and a structured ``payload`` (the same facts,
machine-readable).  The oracle reasons over the payload — the analogue of the
LLM parsing the prompt.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Protocol

import numpy as np

TASK_BOTTLENECK = "bottleneck_analysis"
TASK_PREDICTION = "perf_area_prediction"
TASK_TUNING = "parameter_tuning"


def _default_primary_map() -> Dict[str, str]:
    """AHK primary edges (stall class -> most-correlated parameter),
    EXTRACTED from the perfmodel source by :mod:`repro.analysis.influence`
    — the paper's 'LLM statically analyses the simulator codebase' step.
    Imported lazily: the analysis pass parses source once and is cached."""
    from repro.analysis.influence import primary_resources
    return primary_resources()


@dataclasses.dataclass
class MCQuery:
    task: str                       # one of the three benchmark task families
    prompt: str                     # full natural-language prompt
    options: List[str]              # formatted answer options
    payload: Dict[str, Any]         # structured facts backing the prompt
    answer: Optional[int] = None    # ground truth (benchmark only)

    def render(self) -> str:
        opts = "\n".join(f"  ({chr(65 + i)}) {o}" for i, o in enumerate(self.options))
        return f"[task={self.task}]\n{self.prompt}\nOptions:\n{opts}"


class LLMBackend(Protocol):
    name: str

    def choose(self, q: MCQuery) -> int:   # returns option index
        ...


# ---------------------------------------------------------------------------
# Rule oracle: the deterministic reasoning engine
# ---------------------------------------------------------------------------

class RuleOracle:
    """Answers the three task families by explicit architectural reasoning.

    ``enhanced=True`` applies the paper's three corrective rules (§5.2):
      1. bottleneck analysis: target ONLY the resource most correlated with
         the dominant stall (never multi-resource options), and respect the
         under-utilization pitfall of enlarging the systolic array;
      2. perf/area prediction: compute deltas against the *sensitivity
         reference*, never against a zero baseline;
      3. parameter tuning: mitigate the dominant stall by adjusting the
         least-critical resource only.
    ``enhanced=False`` disables the guards, reproducing the failure patterns
    the paper reports for un-prompt-hardened models.

    ``primary_map`` (stall class -> parameter) defaults to the AHK edges
    extracted from the perfmodel source by :mod:`repro.analysis.influence`;
    inject an alternative for ablations (e.g. the frozen legacy table).
    """

    def __init__(self, enhanced: bool = True, name: str = "rule-oracle",
                 primary_map: Optional[Dict[str, str]] = None):
        self.enhanced = enhanced
        self.name = name + ("-enhanced" if enhanced else "")
        self._primary_map = primary_map

    @property
    def primary_map(self) -> Dict[str, str]:
        if self._primary_map is None:
            self._primary_map = _default_primary_map()
        return self._primary_map

    # -- task dispatch ------------------------------------------------
    def choose(self, q: MCQuery) -> int:
        if q.task == TASK_BOTTLENECK:
            return self._bottleneck(q)
        if q.task == TASK_PREDICTION:
            return self._prediction(q)
        if q.task == TASK_TUNING:
            return self._tuning(q)
        raise ValueError(f"unknown task {q.task}")

    # -- bottleneck analysis -------------------------------------------
    def _bottleneck(self, q: MCQuery) -> int:
        p = q.payload
        dominant = p["dominant_stall"]
        # AHK: stall class -> the single most-correlated resource parameter
        primary = self.primary_map[dominant]
        candidates = p["option_params"]       # list[list[(param, direction)]]
        scores = []
        for opt in candidates:
            s = 0.0
            for param, direction in opt:
                if param == primary and direction > 0:
                    s += 10.0
                elif direction > 0 and param in p.get("relevant", {}).get(dominant, ()):
                    s += 3.0
                else:
                    s -= 2.0                  # irrelevant param => penalty
            if self.enhanced and len(opt) > 1:
                s -= 5.0                      # corrective rule 1: single-resource focus
            if self.enhanced:
                # under-utilization guard: growing sa_dim without SRAM headroom
                for param, direction in opt:
                    if param == "sa_dim" and direction > 0 and not p.get("sa_headroom", True):
                        s -= 20.0
            scores.append(s)
        return int(np.argmax(scores))

    # -- perf/area prediction -------------------------------------------
    def _prediction(self, q: MCQuery) -> int:
        p = q.payload
        base = np.asarray(p["reference_metric"], dtype=np.float64)
        sens = {k: float(v) for k, v in p["sensitivity"].items()}
        steps = {k: float(v) for k, v in p["delta_steps"].items()}
        delta = sum(sens[k] * steps[k] for k in steps)
        if self.enhanced:
            # corrective rule 2: delta vs the sensitivity reference
            pred = float(base) + delta
        else:
            # failure mode the paper reports ("models frequently computed
            # deltas against a zero baseline"): the unhardened oracle falls
            # into it on a deterministic ~half of the questions
            fails = (hash(q.prompt) & 0xFF) < 128
            pred = delta if fails else float(base) + delta
        vals = np.asarray(p["option_values"], dtype=np.float64)
        return int(np.argmin(np.abs(vals - pred)))

    # -- parameter tuning -------------------------------------------
    def _tuning(self, q: MCQuery) -> int:
        p = q.payload
        dominant = p["dominant_stall"]
        primary = self.primary_map[dominant]
        crit = p["criticality"]               # param -> criticality score
        sens = p.get("sensitivity")           # param -> metric -> delta/step
        ok = p.get("constraints_ok", [True] * len(p["option_params"]))
        scores = []
        for oi, opt in enumerate(p["option_params"]):
            if self.enhanced and sens is not None:
                # enhanced reasoning: linear latency prediction from the
                # sensitivity reference (corrective rule 2), constraints are
                # hard, and ties prefer trading the least-critical resource
                # (corrective rule 3)
                pred = sum(sens[param]["ttft"] * d for param, d in opt)
                s = -pred * 1e6
                for param, d in opt:
                    if d < 0:
                        s += 0.5 * (1.0 - crit.get(param, 0.5))
                if not ok[oi]:
                    s -= 1e12                 # never violate design constraints
            else:
                # unhardened failure pattern the paper reports: compensate
                # for an unresolved bottleneck by touching many non-critical
                # resources, and under-weight the constraints
                s = 0.0
                ups = [param for param, d in opt if d > 0]
                downs = [param for param, d in opt if d < 0]
                if primary in ups:
                    s += 2.0
                s += len(ups) + len(downs)    # prefers busier adjustments
                if not ok[oi]:
                    s -= 1.0                  # constraint barely registers
            scores.append(s)
        return int(np.argmax(scores))


class DegradedOracle:
    """RuleOracle with calibrated error injection (emulates weaker LLMs)."""

    def __init__(self, p_err: float, seed: int = 0, enhanced: bool = True,
                 name: str = "degraded",
                 primary_map: Optional[Dict[str, str]] = None):
        self._inner = RuleOracle(enhanced=enhanced, primary_map=primary_map)
        self._p = float(p_err)
        self._rng = np.random.default_rng(seed)
        self.name = f"{name}(p={p_err:.2f})"

    def choose(self, q: MCQuery) -> int:
        good = self._inner.choose(q)
        if self._rng.random() < self._p and len(q.options) > 1:
            wrong = [i for i in range(len(q.options)) if i != good]
            return int(self._rng.choice(wrong))
        return good


class ExternalLLM:
    """OpenAI-compatible chat endpoint adapter (not used in offline CI)."""

    def __init__(self, url: str, model: str, api_key: str = ""):
        self.url, self.model, self.api_key = url, model, api_key
        self.name = f"external:{model}"

    def choose(self, q: MCQuery) -> int:  # pragma: no cover - needs network
        import urllib.request
        body = json.dumps({
            "model": self.model,
            "messages": [
                {"role": "system", "content":
                 "You are a GPU architecture expert. Answer with the single "
                 "letter of the best option."},
                {"role": "user", "content": q.render()},
            ],
        }).encode()
        req = urllib.request.Request(
            self.url, data=body,
            headers={"Content-Type": "application/json",
                     "Authorization": f"Bearer {self.api_key}"})
        with urllib.request.urlopen(req) as r:
            text = json.load(r)["choices"][0]["message"]["content"]
        for i in range(len(q.options)):
            if chr(65 + i) in text[:8]:
                return i
        return 0
