"""Streaming full-space sweep engine: every design in [0, 4.7M) on device.

The paper's substrate claim is that vectorized PPA evaluation makes the
*entire* 4,741,632-point design space cheaper to evaluate than a handful of
LLMCompass samples.  :class:`SweepEngine` delivers that as a production
path: the flat id range is streamed through the jitted roofline model (or
the Pallas ``ppa_eval`` kernel) in fixed-size chunks, with

* mixed-radix unranking **on device** — no host-side ``flat_to_idx``
  materialization of 4.7M index vectors;
* per-chunk on-device reduction: a running top-k per objective, the count of
  designs strictly dominating the reference point, and a bounded dominance
  filter (the on-device slice of the streaming Pareto archive) that kills
  ~all dominated points before anything leaves the device;
* an exact host-side :class:`~repro.core.pareto.ParetoArchive` absorbing the
  few filter survivors per chunk, so the final front equals the brute-force
  ``pareto_front`` of all evaluated points (while under archive capacity);
* donated carry buffers (no per-chunk reallocation), checkpoint/resume of
  partial sweeps, and optional sharding of the id range across devices;
* **multi-worker sharding of the id range** (``run(workers=N)``): the range
  splits into N contiguous chunk-aligned spans, each worker streams its own
  span (its own carry, archive and checkpoint file in the unchanged
  format), and the host merges top-k, per-stall-class seeds and the Pareto
  archive — reproducing the single-process result exactly;
* ``chunk_size="auto"``: a short timed probe over ``chunk_candidates``
  picks the fastest chunk size for this process (memoized), the same
  benchmark-driven selection ``backend="auto"`` uses for backends;
* **portfolio mode**: an evaluator carrying multiple
  :class:`~repro.perfmodel.workload.Scenario`\\ s (e.g.
  ``get_evaluator(suite="zoo")``) streams the id range ONCE — one stacked
  op-term pass over the deduped workload union per chunk — while
  maintaining per-scenario running top-k, per-scenario exact Pareto
  archives, per-scenario stall-class seeds AND a robust front under
  ``robust="worst" | "geomean"`` scalarization of the reference-normalized
  scenario latencies.  The result's top-level front is the robust one;
  ``SweepResult.per_scenario`` holds every scenario's own result and
  ``stall_seeds(scenario=...)`` feeds bottleneck-seeded campaigns per
  scenario class.

Objectives follow the repo convention: ``[ttft, tpot, area]`` per scenario
(prefill latency, decode latency, area), all minimized.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
import os
import time
import warnings
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pareto import ParetoArchive
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NOOP
from repro.runtime.fault import RetryPolicy, run_with_retries
from repro.perfmodel.designspace import DesignSpace, SPACE, A100_REFERENCE
from repro.perfmodel.hardware import derive_hardware
from repro.perfmodel.roofline import (RooflineModel, _dominant_class,
                                      _workload_fingerprint)
from repro.perfmodel.workload import WorkloadStack

_FMT_VERSION = 3       # v3 adds portfolio (multi-scenario) checkpoints

ROBUST = ("worst", "geomean")

# stall classes in carry order (matches critical_path.STALL_CLASSES)
_N_STALL = 4

# chunk_size="auto" probe results, memoized per (platform, backend, config)
_CHUNK_AUTO_CACHE: Dict[tuple, int] = {}


def _state_digest(payload: Dict) -> str:
    """sha256 over the checkpoint payload (sorted keys; dtype + shape +
    bytes per entry) — detects truncated or bit-flipped checkpoint files
    before their garbage reaches a resumed sweep."""
    h = hashlib.sha256()
    for k in sorted(payload):
        if k == "digest":
            continue
        arr = np.asarray(payload[k])
        h.update(k.encode())
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


# --------------------------------------------------------------------------
# on-device pieces (all traced inside the chunk step)
# --------------------------------------------------------------------------

def _unrank(flat: jnp.ndarray, cards: Tuple[int, ...]) -> jnp.ndarray:
    """Mixed-radix unrank on device: (c,) flat ids -> (c, n_params) int32.

    Matches ``DesignSpace.flat_to_idx`` (last parameter fastest-varying).
    """
    cols = []
    rem = flat
    for c in reversed(cards):
        cols.append(rem % c)
        rem = rem // c
    return jnp.stack(cols[::-1], axis=1).astype(jnp.int32)


def _dominated_on_device(filt: jnp.ndarray, ys: jnp.ndarray) -> jnp.ndarray:
    """(f, m) filter rows x (c, m) points -> (c,) dominated mask.

    Per-objective 2D comparisons (same shape XLA fuses well); +inf-padded
    filter rows can never dominate anything.
    """
    f = filt.shape[0]
    c, m = ys.shape
    all_le = jnp.ones((c, f), dtype=bool)
    any_lt = jnp.zeros((c, f), dtype=bool)
    for j in range(m):
        fj = filt[:, j][None, :]
        yj = ys[:, j][:, None]
        all_le &= fj <= yj
        any_lt |= fj < yj
    return (all_le & any_lt).any(axis=1)


@dataclasses.dataclass
class SweepResult:
    n_evaluated: int
    n_superior: int               # designs strictly dominating the reference
    pareto_y: np.ndarray          # (p, 3) exact front of evaluated points
    pareto_ids: np.ndarray        # (p,) flat design ids of the front
    topk_val: np.ndarray          # (3, k) best objective values seen
    topk_ids: np.ndarray          # (3, k) their flat design ids
    ref_point: np.ndarray
    seconds: float
    points_per_sec: float
    archive_truncated: bool       # capacity pruning fired (front then inexact)
    stall_topk_val: Optional[np.ndarray] = None   # (4, k) best TTFT latency
    stall_topk_ids: Optional[np.ndarray] = None   # (4, k) per dominant stall
    archive_capacity: Optional[int] = None        # final (auto-sized) bound
    # ---- portfolio sweeps: the top-level fields above describe the ROBUST
    # objectives [robust_prefill, robust_decode, area] (reference-normalized
    # latencies scalarized across scenarios); per-scenario results nest here
    scenario_names: Optional[Tuple[str, ...]] = None
    robust: Optional[str] = None                  # "worst" | "geomean"
    per_scenario: Optional[Dict[str, "SweepResult"]] = None

    def pareto_idx(self, space: DesignSpace = SPACE) -> np.ndarray:
        """Front design-index vectors (p, n_params)."""
        return space.flat_to_idx(self.pareto_ids)

    def scenario(self, name: str) -> "SweepResult":
        """One scenario's own sweep result (portfolio sweeps only)."""
        if not self.per_scenario:
            raise ValueError("not a portfolio sweep result")
        if name not in self.per_scenario:
            raise KeyError(f"unknown scenario {name!r}; "
                           f"have {self.scenario_names}")
        return self.per_scenario[name]

    def stall_seeds(self, space: DesignSpace = SPACE,
                    scenario: Optional[str] = None) -> Dict[str, np.ndarray]:
        """Per-stall-class seed designs for bottleneck-guided DSE.

        {stall class -> (k', n_params) index vectors}, the best designs
        (under the engine's ``stall_rank`` key) whose dominant stall is that
        class (requires ``stall_topk > 0``).  A class no swept design was
        dominated by comes back as an EMPTY (0, n_params) array — seeded
        campaign runners must skip it, not crash
        (:meth:`repro.core.campaign.CampaignRunner.seed_starts` does).

        On a portfolio result, ``scenario=<name>`` selects that scenario's
        seed classes; ``scenario=None`` flattens every scenario into
        ``"<scenario>:<stall class>"`` keys — ready-made campaign labels
        for per-scenario-class seeded DSE.
        """
        if self.per_scenario is not None:
            if scenario is not None:
                return self.scenario(scenario).stall_seeds(space)
            return {f"{nm}:{cls}": arr
                    for nm in self.scenario_names
                    for cls, arr in
                    self.per_scenario[nm].stall_seeds(space).items()}
        if scenario is not None:
            raise ValueError("scenario= is only valid on portfolio results")
        if self.stall_topk_ids is None:
            raise ValueError("sweep ran without stall_topk; no stall seeds")
        from repro.perfmodel.critical_path import STALL_CLASSES
        out = {}
        for c, name in enumerate(STALL_CLASSES):
            ids = self.stall_topk_ids[c]
            out[name] = space.flat_to_idx(ids[ids >= 0])
        return out


class SweepEngine:
    """Chunked streaming evaluation of the full (or a partial) design space.

    Parameters
    ----------
    ttft_model, tpot_model:
        Either a two-workload :class:`~repro.perfmodel.evaluator.
        ModelEvaluator` as the single first argument, or a legacy
        RooflineModel/CompassModel pair for the two latency objectives
        (area comes from the shared area model).
    stall_topk:
        When > 0, the chunk step also attributes stalls (TTFT workload) on
        device and keeps the `stall_topk` best designs per dominant stall
        class — sweep-derived seeds for bottleneck analysis
        (``SweepResult.stall_seeds``).
    stall_rank:
        Ranking key for the per-stall-class top-k: ``"ttft"`` (default)
        keeps the lowest-TTFT designs per class; ``"ref"`` ranks by the
        minimax objective ratio vs the reference point
        (``max_o y_o / ref_o`` — < 1 means the design dominates the
        reference), which is what seeded DSE campaigns want: the most
        *competitive* representative of each bottleneck regime instead of
        a latency-minimal max-area corner.
    chunk_size:
        Designs per device step, or ``"auto"`` to pick the fastest of
        ``chunk_candidates`` by a short timed probe (memoized per process,
        like ``backend="auto"``).  Rounded up to a multiple of the device
        count when sharding.
    topk:
        Running best-k designs kept per objective.
    filter_size:
        Rows of the on-device dominance filter (synced from the host archive
        every chunk).  Larger kills more points on device but costs
        c x filter_size comparisons per chunk.
    local_filter:
        Per-objective (and log-sum) chunk-local killer rows added to the
        filter — this is what makes the cold-start chunk cheap.
    archive_capacity:
        Bound on the host Pareto archive; overflow prunes by crowding
        distance and marks the result ``archive_truncated``.
    backend:
        "roofline" inlines the models' lean jitted objectives path;
        "pallas" routes chunk evaluation through the ``ppa_eval`` Pallas
        kernel (TPU-native; interpreted elsewhere, so CPU sweeps should
        keep the default).
    shard:
        Shard the id range over all local devices (no-op on one device).
    registry / tracer:
        Optional :class:`~repro.obs.metrics.MetricsRegistry` and tracer;
        the engine registers run/chunk/id counters and a per-chunk wall
        time histogram, and wraps ``run`` / worker spans in trace spans.
        Defaults: a private registry, and the no-op tracer.
    """

    def __init__(self, ttft_model, tpot_model: Optional[RooflineModel] = None,
                 space: DesignSpace = SPACE, *,
                 chunk_size: Union[int, str, None] = None, topk: int = 16,
                 filter_size: int = 128, local_filter: int = 32,
                 archive_capacity: Union[int, str, None] = 16_384,
                 ref_point: Optional[np.ndarray] = None,
                 backend: str = "roofline", shard: bool = False,
                 stall_topk: int = 0, stall_rank: str = "ttft",
                 robust: str = "worst",
                 chunk_candidates: Tuple[int, ...] = (65_536, 131_072,
                                                      262_144),
                 registry: Optional[MetricsRegistry] = None,
                 tracer=None):
        evaluator = None
        scenarios = None
        if tpot_model is None and hasattr(ttft_model, "models"):
            # unified-API construction: SweepEngine(evaluator)
            evaluator = ttft_model
            if len(evaluator.workloads) < 2:
                raise ValueError("sweep needs a two-workload evaluator "
                                 "(ttft + tpot)")
            scenarios = getattr(evaluator, "scenarios", None)
            if scenarios is not None and len(scenarios) > 1:
                if backend != "roofline":
                    raise ValueError("portfolio sweeps run on the traced "
                                     "roofline path; backend must stay "
                                     "'roofline'")
                if getattr(evaluator, "backend", None) == "pallas":
                    raise ValueError("portfolio sweeps need a traced-backend "
                                     "evaluator, not 'pallas'")
            else:
                scenarios = None
            ttft_model = evaluator.models[evaluator.workloads[0]]
            tpot_model = evaluator.models[evaluator.workloads[1]]
            space = evaluator.space
            if backend == "roofline" and evaluator.backend == "pallas":
                backend = "pallas"
        elif tpot_model is None:
            raise TypeError("pass a ModelEvaluator or a (ttft, tpot) pair")
        if backend not in ("roofline", "pallas"):
            raise ValueError(f"unknown backend {backend!r}")
        if backend == "pallas":
            for m in (ttft_model, tpot_model):
                if (m.op_overhead_s, m.nonoverlap, m.mem_efficiency) != (0.0, 0.0, 1.0):
                    raise ValueError(
                        "backend='pallas' implements the bare roofline tier; "
                        f"{type(m).__name__} carries compass-tier knobs the "
                        "kernel ignores — use backend='roofline'")
        self.ttft_model = ttft_model
        self.tpot_model = tpot_model
        if evaluator is None:
            from repro.perfmodel.evaluator import ModelEvaluator
            evaluator = ModelEvaluator({"ttft": ttft_model,
                                        "tpot": tpot_model})
        self.evaluator = evaluator
        self.space = space
        self.size = space.size
        self.topk = int(topk)
        self.stall_topk = int(stall_topk)
        if stall_rank not in ("ttft", "ref"):
            raise ValueError(f"stall_rank must be 'ttft' or 'ref', "
                             f"got {stall_rank!r}")
        self.stall_rank = stall_rank
        if robust not in ROBUST:
            raise ValueError(f"robust must be one of {ROBUST}, got {robust!r}")
        self.robust = robust
        self.filter_size = int(filter_size)
        self.local_filter = int(local_filter)
        self.backend = backend
        if isinstance(archive_capacity, str) and archive_capacity != "auto":
            raise ValueError("archive_capacity must be an int, None or "
                             f"'auto', got {archive_capacity!r}")
        self.archive_capacity = archive_capacity

        # ---- portfolio mode: S > 1 scenarios over one stacked op union ----
        self.scenarios = scenarios
        self._portfolio = scenarios is not None
        if self._portfolio:
            # deferred import (mirrors the ModelEvaluator import below):
            # evaluator.py pulls this module back in lazily via the oracle
            from repro.perfmodel.evaluator import homogeneous_models
            models = evaluator.models
            if not homogeneous_models(models):
                raise ValueError("portfolio sweeps need homogeneous workload "
                                 "models (one class + compass-knob set)")
            self._scen_names = tuple(s.name for s in scenarios)
            self._wl_order = tuple(nm for s in scenarios
                                   for nm in (s.prefill, s.decode))
            self._stack = WorkloadStack.build(
                {nm: models[nm].wl for nm in self._wl_order})
            self._rep_model = models[self._wl_order[0]]
            # count matrices for the chunk step's matmul reductions:
            # per-workload latency = t_unit @ C^T (ONE (c,U)x(U,W) dot
            # instead of W gather+sum branches), and per-scenario stall
            # sums contract the class-masked t_unit with the PREFILL rows
            stack = self._stack
            self._cmat_all = stack.count_matrix[
                [stack.names.index(nm) for nm in self._wl_order]]
            cmat_prefill = stack.count_matrix[
                [stack.names.index(s.prefill) for s in scenarios]]
            # stall attribution only touches unique ops some PREFILL
            # workload uses — restricting the class-masked traversals to
            # those columns cuts the chunk step's dominant memory traffic
            self._stall_cols = np.flatnonzero(cmat_prefill.sum(axis=0) > 0)
            self._cmat_prefill = cmat_prefill[:, self._stall_cols]
            # per-scenario dominance filters stay lean: the host archive is
            # exact regardless, and S+1 group filters traverse (c, S+1, f)
            self._pf_rows = max(8, min(self.filter_size // 4, 32))

        self._cards = tuple(int(c) for c in space.cardinalities)

        if self._portfolio:
            n_scen = len(scenarios)
            if ref_point is None:
                ref_points = self._scenario_refs()
            else:
                ref_points = np.asarray(ref_point, dtype=np.float64)
                if ref_points.shape != (n_scen, 3):
                    raise ValueError(
                        f"portfolio ref_point must be ({n_scen}, 3) — one "
                        f"[prefill, decode, area] row per scenario — got "
                        f"shape {ref_points.shape}")
            self.ref_points = ref_points
            # the robust reference: every normalized latency is 1 at the
            # reference design, area is the raw reference area
            self.ref_point = np.array([1.0, 1.0, float(ref_points[0, 2])])
        else:
            if ref_point is None:
                ref_idx = space.encode_nearest(A100_REFERENCE)[None, :]
                ref_point = self._host_objectives(ref_idx)[0]
            self.ref_point = np.asarray(ref_point, dtype=np.float64)

        if chunk_size is None:
            # portfolio chunks stream ~10x the op rows per id: keep the
            # working set cache-friendly by default
            chunk_size = 65_536 if self._portfolio else 131_072
        if isinstance(chunk_size, str):
            if chunk_size != "auto":
                raise ValueError(
                    f"chunk_size must be an int or 'auto', got {chunk_size!r}")
            chunk_size = self._autotune_chunk(chunk_candidates, shard)

        self._sharding = None
        ndev = len(jax.devices())
        # the chunk must divide by the device count when sharding AND by the
        # ppa_eval kernel's 256-row block on the pallas backend; ids past
        # `stop` are masked invalid, so padding the chunk is always safe
        multiple = 1
        if shard and ndev > 1:
            from jax.sharding import NamedSharding, PartitionSpec as P
            mesh = jax.make_mesh((ndev,), ("sweep",))
            self._sharding = NamedSharding(mesh, P("sweep"))
            multiple = ndev
        if backend == "pallas":
            multiple = math.lcm(multiple, 256)
        chunk_size = int(chunk_size)
        chunk_size += (-chunk_size) % multiple
        self.chunk_size = int(chunk_size)
        iota = jnp.arange(self.chunk_size, dtype=jnp.int32)
        self._iota = (jax.device_put(iota, self._sharding)
                      if self._sharding is not None else iota)

        self.tracer = tracer if tracer is not None else NOOP
        self.metrics = registry if registry is not None else MetricsRegistry()
        self._c_runs = self.metrics.counter(
            "sweep_runs", "completed run() calls")
        self._c_chunks = self.metrics.counter(
            "sweep_chunks", "device chunk steps executed")
        self._c_ids = self.metrics.counter(
            "sweep_ids", "design ids evaluated (valid rows)")
        self._h_chunk = self.metrics.histogram(
            "sweep_chunk_s", "wall time per chunk step incl. host reduce (s)")

        self._step = jax.jit(
            self._step_portfolio_impl if self._portfolio else self._step_impl,
            donate_argnums=(0,))

    def _scenario_refs(self) -> np.ndarray:
        """(S, 3) reference [prefill, decode, area] per scenario (A100)."""
        from repro.perfmodel.evaluator import EvalRequest
        ref_idx = self.space.encode_nearest(A100_REFERENCE)[None, :]
        rep = self.evaluator.evaluate(EvalRequest(ref_idx,
                                                  detail="objectives"))
        return np.array([[float(rep.latency[s.prefill][0]),
                          float(rep.latency[s.decode][0]),
                          float(rep.area[0])] for s in self.scenarios])

    def _autotune_chunk(self, candidates: Tuple[int, ...],
                        shard: bool) -> int:
        """Timed probe: one warmed chunk step per candidate size, keep the
        highest-throughput one (memoized per process, like backend="auto").
        Probe engines inherit the parent's shard flag so a sharded sweep is
        tuned on the sharded execution path."""
        if not candidates:
            raise ValueError("chunk_size='auto' needs a non-empty "
                             "chunk_candidates tuple")
        key = (jax.default_backend(), self.backend, self.fingerprint(),
               int(self.stall_topk), bool(shard),
               tuple(int(c) for c in candidates))
        cached = _CHUNK_AUTO_CACHE.get(key)
        if cached is not None:
            return cached
        best, best_rate = int(candidates[0]), -1.0
        for cand in candidates:
            eng = SweepEngine(
                self.evaluator, chunk_size=int(cand), topk=self.topk,
                filter_size=self.filter_size, local_filter=self.local_filter,
                archive_capacity=self.archive_capacity,
                ref_point=(self.ref_points if self._portfolio
                           else self.ref_point),
                backend=self.backend, shard=shard, robust=self.robust,
                stall_topk=self.stall_topk, stall_rank=self.stall_rank)
            span = min(eng.chunk_size, self.size)
            eng.run(0, span)                       # compile + warm
            t0 = time.perf_counter()
            eng.run(0, span)
            rate = span / max(time.perf_counter() - t0, 1e-9)
            if rate > best_rate:
                best, best_rate = int(eng.chunk_size), rate
        _CHUNK_AUTO_CACHE[key] = best
        return best

    # ------------------------------------------------------------------
    def _host_objectives(self, idx: np.ndarray) -> np.ndarray:
        """Reference evaluation through the evaluator's fused public path."""
        return self.evaluator.objectives(idx)

    def _chunk_eval(self, idx: jnp.ndarray):
        """(c, n_params) int32 -> ((c, 3) objectives, dominant-stall (c,)
        or None), traced.  Decode + hardware derivation run once per chunk;
        stall attribution is only computed when stall_topk is enabled."""
        if self.backend == "pallas":
            from repro.kernels.ppa_eval.kernel import ppa_eval_fwd
            from repro.kernels.ppa_eval.ref import op_table
            vals = self.space.decode(idx)
            dv = jnp.stack([vals[n] for n in self.space.names],
                           axis=1).astype(jnp.float32)
            interpret = jax.default_backend() != "tpu"
            block_b = min(256, dv.shape[0])
            o1 = ppa_eval_fwd(dv, jnp.asarray(op_table(self.ttft_model.wl),
                                              jnp.float32),
                              tp=float(self.ttft_model.wl.tp),
                              block_b=block_b, interpret=interpret)
            o2 = ppa_eval_fwd(dv, jnp.asarray(op_table(self.tpot_model.wl),
                                              jnp.float32),
                              tp=float(self.tpot_model.wl.tp),
                              block_b=block_b, interpret=interpret)
            ys = jnp.stack([o1[:, 0], o2[:, 0], o1[:, 5]], axis=1)
            dom = (jnp.argmax(o1[:, 1:5], axis=1).astype(jnp.int32)
                   if self.stall_topk else None)
            return ys, dom
        vals = self.space.decode(idx)
        hw = derive_hardware(vals)
        hwb = {kk: vv[:, None] for kk, vv in hw.items()}
        detail_t = "stalls" if self.stall_topk else "objectives"
        out_t = self.ttft_model._workload_batch(hwb, detail_t)
        out_p = self.tpot_model._workload_batch(hwb, "objectives")
        ys = jnp.stack([out_t["latency"], out_p["latency"], hw["area_mm2"]],
                       axis=1)
        dom = (jnp.argmax(out_t["stall"], axis=1).astype(jnp.int32)
               if self.stall_topk else None)
        return ys, dom

    def _step_impl(self, carry: Dict[str, jnp.ndarray], start: jnp.ndarray,
                   stop: jnp.ndarray, filt: jnp.ndarray):
        """One donated-carry chunk step: unrank -> evaluate -> reduce."""
        ids = start + self._iota
        valid = ids < stop
        idx = _unrank(jnp.minimum(ids, self.size - 1), self._cards)
        ys, dom = self._chunk_eval(idx)                       # (c, 3), (c,)
        ysm = jnp.where(valid[:, None], ys, jnp.inf)

        # ---- reference-superiority count (exact, streaming) ----
        ref = jnp.asarray(self.ref_point, ys.dtype)
        sup = (ysm < ref[None, :]).all(axis=1)
        n_super = carry["n_super"] + sup.sum(dtype=jnp.int32)
        n_eval = carry["n_eval"] + valid.sum(dtype=jnp.int32)

        # ---- running top-k per objective ----
        new_vals, new_ids = [], []
        for o in range(3):                                    # static unroll
            vals = jnp.concatenate([carry["topk_val"][o], ysm[:, o]])
            cand = jnp.concatenate([carry["topk_id"][o], ids])
            neg, sel = jax.lax.top_k(-vals, self.topk)
            new_vals.append(-neg)
            new_ids.append(cand[sel])
        topk_val = jnp.stack(new_vals)
        topk_id = jnp.stack(new_ids)

        # ---- running top-k per dominant stall class (optional) ----
        stall_val = stall_id = None
        if self.stall_topk:
            if self.stall_rank == "ref":
                # minimax objective ratio vs the reference (< 1 dominates)
                lat = (ysm / ref[None, :]).max(axis=1)
            else:
                lat = ysm[:, 0]                               # rank by TTFT
            new_vals, new_ids = [], []
            for c in range(_N_STALL):                         # static unroll
                lat_c = jnp.where(dom == c, lat, jnp.inf)
                vals = jnp.concatenate([carry["stall_topk_val"][c], lat_c])
                cand = jnp.concatenate([carry["stall_topk_id"][c], ids])
                neg, sel = jax.lax.top_k(-vals, self.stall_topk)
                new_vals.append(-neg)
                new_ids.append(jnp.where(jnp.isfinite(-neg), cand[sel], -1))
            stall_val = jnp.stack(new_vals)
            stall_id = jnp.stack(new_ids)

        # ---- streaming Pareto reduction ----
        # archive filter (synced from host) + chunk-local killer rows:
        # per-objective minima and smallest log-products dominate most of the
        # chunk, so the cold-start chunk also reduces on device.
        L = self.local_filter
        locals_ = []
        for o in range(3):
            _, sel = jax.lax.top_k(-ysm[:, o], L)
            locals_.append(ysm[sel])
        _, sel = jax.lax.top_k(-jnp.log(jnp.maximum(ysm, 1e-300)).sum(axis=1), L)
        locals_.append(ysm[sel])
        full_filt = jnp.concatenate([filt.astype(ys.dtype)] + locals_, axis=0)
        dominated = _dominated_on_device(full_filt, ysm)
        survivor = valid & ~dominated
        ys_out = jnp.where(survivor[:, None], ys, jnp.inf)

        carry = {"n_super": n_super, "n_eval": n_eval,
                 "topk_val": topk_val, "topk_id": topk_id}
        if self.stall_topk:
            carry["stall_topk_val"] = stall_val
            carry["stall_topk_id"] = stall_id
        return carry, survivor, ys_out, ids

    # ---------------- portfolio (multi-scenario) chunk step ----------------
    def _chunk_eval_portfolio(self, idx: jnp.ndarray):
        """(c, n_params) -> ((c, S, 3) per-scenario objectives, (c, S)
        dominant prefill stall or None).

        ONE stacked op-term pass over the deduped union; every per-workload
        reduction is a count-matrix contraction (latencies:
        ``t_unit @ C_all^T``; per-scenario stall sums: the class-masked
        ``t_unit`` against the prefill rows) — no per-workload unrolling,
        so both compile time and runtime stay near-flat in W.
        """
        vals = self.space.decode(idx)
        hw = derive_hardware(vals)
        hwb = {kk: vv[:, None] for kk, vv in hw.items()}
        stack = self._stack
        uops = {kk: jnp.asarray(vv) for kk, vv in stack.unique.items()}
        uops["count"] = jnp.ones(stack.n_unique)
        t = self._rep_model._op_terms(hwb, ops=uops)
        lat = t["t_unit"] @ jnp.asarray(self._cmat_all).T   # (c, 2S)
        area = hw["area_mm2"]
        S = len(self.scenarios)
        ys = jnp.stack([lat[:, 0::2], lat[:, 1::2],
                        jnp.broadcast_to(area[:, None],
                                         (idx.shape[0], S))], axis=2)
        dom = None
        if self.stall_topk:
            # a SECOND op-term pass statically restricted to prefill-used
            # rows: consuming t_compute/t_memory/t_comm out of the full
            # union pass would force XLA to re-materialize its big (c, U)
            # intermediates — recomputing the small (c, P) chain is 2x
            # cheaper than widening the first pass's fusion
            uop2 = {kk: jnp.asarray(vv[self._stall_cols])
                    for kk, vv in stack.unique.items()}
            uop2["count"] = jnp.ones(len(self._stall_cols))
            t2 = self._rep_model._op_terms(hwb, ops=uop2)
            dom_g = _dominant_class(t2)                     # (c, P)
            cp = jnp.asarray(self._cmat_prefill).T          # (P, S)
            stall = jnp.stack(
                [jnp.where(dom_g == k, t2["t_unit"], 0.0) @ cp
                 for k in range(_N_STALL)], axis=2)         # (c, S, 4)
            dom = jnp.argmax(stall, axis=2).astype(jnp.int32)
        return ys, dom

    def _robust_objectives(self, ys_s: jnp.ndarray) -> jnp.ndarray:
        """(c, S, 3) -> (c, 3) scalarized [robust_p, robust_d, area]: the
        reference-normalized latency aggregated across scenarios (worst
        case or geometric mean), plus the shared raw area."""
        refs = jnp.asarray(self.ref_points, ys_s.dtype)
        ratio = ys_s[:, :, :2] / refs[None, :, :2]
        if self.robust == "worst":
            r = ratio.max(axis=1)
        else:
            r = jnp.exp(jnp.log(jnp.maximum(ratio, 1e-300)).mean(axis=1))
        return jnp.concatenate([r, ys_s[:, 0, 2:3]], axis=1)

    def _step_portfolio_impl(self, carry: Dict[str, jnp.ndarray],
                             start: jnp.ndarray, stop: jnp.ndarray,
                             filt: jnp.ndarray):
        """One donated-carry portfolio chunk step.

        Group axis: S scenarios then the robust scalarization (index S).
        Every reduction is batched across groups — ONE top_k call merges
        all (S+1) x 3 running top-k rows, one merges the S x 4 stall-class
        rows, one picks every group's local-filter killer rows.
        """
        S = len(self.scenarios)
        S1, k, c = S + 1, self.topk, self.chunk_size
        ids = start + self._iota
        valid = ids < stop
        idx = _unrank(jnp.minimum(ids, self.size - 1), self._cards)
        ys_s, dom = self._chunk_eval_portfolio(idx)       # (c,S,3), (c,S)
        ys_r = self._robust_objectives(ys_s)              # (c,3)
        ys_all = jnp.concatenate([ys_s, ys_r[:, None, :]], axis=1)
        ysm = jnp.where(valid[:, None, None], ys_all, jnp.inf)

        # ---- per-group reference-superiority counts ----
        refs_all = jnp.concatenate(
            [jnp.asarray(self.ref_points, ys_all.dtype),
             jnp.asarray(self.ref_point, ys_all.dtype)[None, :]], axis=0)
        sup = (ysm < refs_all[None, :, :]).all(axis=2)    # (c, S1)
        n_super = carry["n_super"] + sup.sum(axis=0, dtype=jnp.int32)
        n_eval = carry["n_eval"] + valid.sum(dtype=jnp.int32)

        # ---- running top-k, batched over (S1 x 3) rows ----
        ysm_rows = jnp.moveaxis(ysm, 0, 2)                # (S1, 3, c)
        vals = jnp.concatenate(
            [carry["topk_val"].reshape(S1 * 3, k),
             ysm_rows.reshape(S1 * 3, c)], axis=1)
        cand = jnp.concatenate(
            [carry["topk_id"].reshape(S1 * 3, k),
             jnp.broadcast_to(ids[None, :], (S1 * 3, c))], axis=1)
        neg, sel = jax.lax.top_k(-vals, k)
        topk_val = (-neg).reshape(S1, 3, k)
        topk_id = jnp.take_along_axis(cand, sel, axis=1).reshape(S1, 3, k)

        # ---- per-scenario stall-class top-k (optional), batched ----
        stall_val = stall_id = None
        if self.stall_topk:
            sk = self.stall_topk
            refs = refs_all[:S]
            if self.stall_rank == "ref":
                rank = (ysm[:, :S, :] / refs[None, :, :]).max(axis=2)
            else:
                rank = ysm[:, :S, 0]                      # scenario prefill
            hit = dom[:, :, None] == jnp.arange(_N_STALL)[None, None, :]
            masked = jnp.where(hit, rank[:, :, None], jnp.inf)  # (c, S, 4)
            rows = jnp.moveaxis(masked, 0, 2).reshape(S * _N_STALL, c)
            vals = jnp.concatenate(
                [carry["stall_topk_val"].reshape(S * _N_STALL, sk), rows],
                axis=1)
            cand = jnp.concatenate(
                [carry["stall_topk_id"].reshape(S * _N_STALL, sk),
                 jnp.broadcast_to(ids[None, :], (S * _N_STALL, c))], axis=1)
            neg, sel = jax.lax.top_k(-vals, sk)
            stall_val = (-neg).reshape(S, _N_STALL, sk)
            stall_id = jnp.where(jnp.isfinite(-neg),
                                 jnp.take_along_axis(cand, sel, axis=1),
                                 -1).reshape(S, _N_STALL, sk)

        # ---- streaming Pareto reduction, batched over all S1 groups ----
        # chunk-local killer rows: each group's per-objective minima plus
        # its best reference-normalized sum (4 rows/group, one argmin pass)
        normsum = (ysm / refs_all[None, :, :]).sum(axis=2)     # (c, S1)
        keys = jnp.concatenate([ysm, normsum[:, :, None]], axis=2)
        sel = jnp.argmin(keys, axis=0)                         # (S1, 4)
        ysm_t = jnp.moveaxis(ysm, 0, 1)                        # (S1, c, 3)
        locals_ = jnp.take_along_axis(ysm_t, sel[:, :, None], axis=1)
        full_filt = jnp.concatenate(
            [filt.astype(ys_all.dtype), locals_], axis=1)      # (S1, f+4, 3)
        all_le = jnp.ones((c, S1, full_filt.shape[1]), bool)
        any_lt = jnp.zeros_like(all_le)
        for j in range(3):
            fj = full_filt[None, :, :, j]
            yj = ysm[:, :, j][:, :, None]
            all_le &= fj <= yj
            any_lt |= fj < yj
        dominated = (all_le & any_lt).any(axis=2)              # (c, S1)
        survivor = valid[:, None] & ~dominated
        ys_out = jnp.where(survivor[:, :, None], ys_all, jnp.inf)

        carry = {"n_super": n_super, "n_eval": n_eval,
                 "topk_val": topk_val, "topk_id": topk_id}
        if self.stall_topk:
            carry["stall_topk_val"] = stall_val
            carry["stall_topk_id"] = stall_id
        return carry, survivor, ys_out, ids

    # ------------------------------------------------------------------
    @property
    def _n_groups(self) -> int:
        """Archive/filter groups: S scenarios + the robust front, or 1."""
        return len(self.scenarios) + 1 if self._portfolio else 1

    def _fresh_state(self, start: int) -> Dict:
        k = self.topk
        if self._portfolio:
            S, S1 = len(self.scenarios), self._n_groups
            carry = {
                "n_super": jnp.zeros((S1,), jnp.int32),
                "n_eval": jnp.zeros((), jnp.int32),
                "topk_val": jnp.full((S1, 3, k), jnp.inf, jnp.float32),
                "topk_id": jnp.full((S1, 3, k), -1, jnp.int32),
            }
            if self.stall_topk:
                carry["stall_topk_val"] = jnp.full(
                    (S, _N_STALL, self.stall_topk), jnp.inf, jnp.float32)
                carry["stall_topk_id"] = jnp.full(
                    (S, _N_STALL, self.stall_topk), -1, jnp.int32)
            return {"next": int(start), "carry": carry,
                    "archives": [ParetoArchive(3,
                                               capacity=self.archive_capacity)
                                 for _ in range(S1)]}
        carry = {
            "n_super": jnp.zeros((), jnp.int32),
            "n_eval": jnp.zeros((), jnp.int32),
            "topk_val": jnp.full((3, k), jnp.inf, jnp.float32),
            "topk_id": jnp.full((3, k), -1, jnp.int32),
        }
        if self.stall_topk:
            carry["stall_topk_val"] = jnp.full(
                (_N_STALL, self.stall_topk), jnp.inf, jnp.float32)
            carry["stall_topk_id"] = jnp.full(
                (_N_STALL, self.stall_topk), -1, jnp.int32)
        return {"next": int(start), "carry": carry,
                "archive": ParetoArchive(3, capacity=self.archive_capacity)}

    def _filter_from_archive(self, archive: ParetoArchive,
                             rows: Optional[int] = None) -> np.ndarray:
        """Up to `rows` (default filter_size) spread-out front rows,
        +inf padded."""
        rows = self.filter_size if rows is None else int(rows)
        filt = np.full((rows, 3), np.inf, dtype=np.float32)
        n = len(archive)
        if n:
            order = np.argsort(archive.y.sum(axis=1), kind="stable")
            take = order[np.linspace(0, n - 1, min(n, rows))
                         .astype(np.int64)]
            filt[: take.size] = archive.y[take]
        return filt

    def fingerprint(self) -> str:
        """Identity of (space, workloads, knobs) for checkpoint validation."""
        if self._portfolio:
            parts = [str(self._cards), self.backend,
                     f"robust={self.robust}",
                     type(self._rep_model).__qualname__]
            for s in self.scenarios:
                parts.append(f"{s.name}="
                             + _workload_fingerprint(
                                 self.evaluator.models[s.prefill].wl)
                             + ":"
                             + _workload_fingerprint(
                                 self.evaluator.models[s.decode].wl))
            if self.stall_rank != "ttft":
                parts.append(f"stall_rank={self.stall_rank}")
            return "|".join(parts)
        parts = [
            str(self._cards), self.backend,
            _workload_fingerprint(self.ttft_model.wl),
            _workload_fingerprint(self.tpot_model.wl),
            type(self.ttft_model).__qualname__,
            type(self.tpot_model).__qualname__,
        ]
        if self.stall_rank != "ttft":   # default omitted: old ckpts stay valid
            parts.append(f"stall_rank={self.stall_rank}")
        return "|".join(parts)

    # ------------------------------------------------------------------
    def run(self, start: int = 0, stop: Optional[int] = None, *,
            workers: int = 1,
            checkpoint_path: Optional[str] = None,
            checkpoint_every: Optional[int] = None,
            resume_from: Optional[str] = None,
            progress: bool = False,
            fault_plan=None,
            span_retry: Optional[RetryPolicy] = None) -> SweepResult:
        """Sweep flat ids [start, stop) and reduce to a SweepResult.

        ``workers=N`` shards the id range into N contiguous chunk-aligned
        spans streamed concurrently (each worker has its own carry and
        archive); the host merge reproduces the single-process result
        exactly.  ``checkpoint_path``/``checkpoint_every`` persist partial
        state every N chunks — atomically (tmp + ``os.replace``) with a
        content digest, so a kill mid-write can never leave a checkpoint
        that poisons a resume; ``resume_from`` restores it (and overrides
        ``start``).  A corrupt or truncated checkpoint is QUARANTINED
        (renamed ``*.quarantined`` + warning) and the span restarts fresh
        instead of crashing — only genuine config mismatches
        (space/workload fingerprint, reference point) still refuse to
        resume.  Multi-worker runs keep one checkpoint file per worker
        (``{path}.w{i}of{N}``, unchanged single-worker format with the
        worker's span stamped into the fingerprint), so a resume must use
        the same range and worker count.

        ``fault_plan`` injects a seeded :class:`~repro.distributed.faults.
        FaultPlan` into the span loop (worker = span index, dispatch =
        chunk ordinal): ``crash`` events abort the span, which is then
        REPLAYED under ``span_retry`` (default: 2 retries) from its own
        last checkpoint if one exists, from scratch otherwise — either
        way the streamed reduction is deterministic, so the merged result
        stays bit-identical to a fault-free run.
        """
        stop = self.size if stop is None else min(int(stop), self.size)
        workers = max(1, int(workers))
        tr = self.tracer
        t0 = time.perf_counter()
        with tr.span("sweep.run", start=int(start), stop=int(stop),
                     workers=workers):
            parent = tr.current_ctx()
            if workers == 1:
                states = [self._run_span(
                    0, start, stop, checkpoint_path=checkpoint_path,
                    checkpoint_every=checkpoint_every,
                    resume_from=resume_from,
                    progress=progress, label="", fp_extra="",
                    fault_plan=fault_plan, span_retry=span_retry,
                    trace_parent=parent)]
            else:
                spans = self._worker_spans(start, stop, workers)
                n = len(spans)
                with ThreadPoolExecutor(max_workers=n,
                                        thread_name_prefix="sweep") as ex:
                    futs = []
                    for w, (s0, s1) in enumerate(spans):
                        suffix = f".w{w}of{n}"
                        futs.append(ex.submit(
                            self._run_span, w, s0, s1,
                            checkpoint_path=(f"{checkpoint_path}{suffix}"
                                             if checkpoint_path else None),
                            checkpoint_every=checkpoint_every,
                            resume_from=(f"{resume_from}{suffix}"
                                         if resume_from else None),
                            progress=progress, label=f"w{w}: ",
                            fp_extra=f"|span={s0}:{s1}",
                            fault_plan=fault_plan, span_retry=span_retry,
                            trace_parent=parent))
                    states = [f.result() for f in futs]
            self._c_runs.inc()
        return self._reduce_states(states, time.perf_counter() - t0)

    def _run_span(self, worker: int, start: int, stop: int, *,
                  checkpoint_path: Optional[str],
                  checkpoint_every: Optional[int],
                  resume_from: Optional[str], progress: bool,
                  label: str, fp_extra: str,
                  fault_plan=None,
                  span_retry: Optional[RetryPolicy] = None,
                  trace_parent=None) -> Dict:
        """One worker span, replayed on crash: a failed attempt resumes
        from the span's own atomic checkpoint when one exists, from
        scratch otherwise — deterministic either way.

        ``trace_parent`` is the sweep.run span ctx: worker spans run on
        pool threads, so parenting is explicit, not thread-inherited."""
        tr = self.tracer
        sp = (tr.start("sweep.span", parent=trace_parent, detached=True,
                       worker=worker, start=int(start), stop=int(stop))
              if tr.enabled else None)

        def attempt(resume: Optional[str]) -> Dict:
            return self._run_range(
                start, stop, checkpoint_path=checkpoint_path,
                checkpoint_every=checkpoint_every, resume_from=resume,
                progress=progress, label=label, fp_extra=fp_extra,
                fault_plan=fault_plan, worker_slot=worker)

        try:
            if fault_plan is None and span_retry is None:
                return attempt(resume_from)
            policy = (span_retry if span_retry is not None
                      else RetryPolicy(max_retries=2,
                                       retryable=(RuntimeError,)))
            resume = {"from": resume_from}

            def restore(attempt_no: int) -> None:
                if sp is not None:
                    sp.attrs["replays"] = attempt_no
                resume["from"] = None
                if checkpoint_path:
                    f = (checkpoint_path if checkpoint_path.endswith(".npz")
                         else f"{checkpoint_path}.npz")
                    if os.path.exists(f):
                        resume["from"] = checkpoint_path

            return run_with_retries(lambda: attempt(resume["from"]), restore,
                                    policy)
        except Exception as exc:
            if sp is not None:
                sp.attrs["error"] = str(exc)
                tr.finish(sp, status="error")
            raise
        finally:
            if sp is not None:
                tr.finish(sp)      # idempotent: no-op on the error path

    def _worker_spans(self, start: int, stop: int,
                      workers: int) -> List[Tuple[int, int]]:
        """Contiguous chunk-aligned spans covering [start, stop) — every
        worker streams the same chunk sequence a single process would."""
        n_chunks = -(-max(0, stop - start) // self.chunk_size)
        if n_chunks == 0:
            return [(start, stop)]
        per = -(-n_chunks // min(workers, n_chunks))
        spans, s = [], start
        while s < stop:
            e = min(stop, s + per * self.chunk_size)
            spans.append((s, e))
            s = e
        return spans

    def _run_range(self, start: int, stop: int, *,
                   checkpoint_path: Optional[str] = None,
                   checkpoint_every: Optional[int] = None,
                   resume_from: Optional[str] = None,
                   progress: bool = False, label: str = "",
                   fp_extra: str = "", fault_plan=None,
                   worker_slot: int = 0) -> Dict:
        """Stream one contiguous id span; returns its final state dict
        (plus the resumed-eval count under ``"resumed"``)."""
        state = self._load(resume_from, fp_extra) if resume_from else None
        if state is None:          # no checkpoint, or quarantined as corrupt
            state = self._fresh_state(start)
        archives: List[ParetoArchive] = (state["archives"] if self._portfolio
                                         else [state["archive"]])
        carry = state["carry"]
        n_eval_resumed = int(carry["n_eval"])
        t0 = time.perf_counter()
        chunk_i = 0
        while state["next"] < stop:
            if fault_plan is not None:
                ev = fault_plan.fire(worker_slot, chunk_i)
                if ev is not None and ev.kind == "crash":
                    from repro.distributed.faults import WorkerFault
                    raise WorkerFault(f"injected sweep crash: worker "
                                      f"{worker_slot} chunk {chunk_i}")
                if ev is not None and ev.kind == "slow":
                    time.sleep(ev.delay_s)
            t_chunk = time.perf_counter()
            s = state["next"]
            rows = self._pf_rows if self._portfolio else None
            filt = np.stack([self._filter_from_archive(a, rows)
                             for a in archives])
            filt = jnp.asarray(filt if self._portfolio else filt[0])
            # ids >= stop are masked invalid on device, so a partial final
            # chunk (or a truncated-range sweep) stays exact for free.
            carry, survivor, ys_out, ids = self._step(
                carry, jnp.int32(s), jnp.int32(stop), filt)
            mask = np.asarray(survivor)       # (c,) or (c, S+1)
            if mask.any():
                ys_np, ids_np = np.asarray(ys_out), np.asarray(ids)
                if self._portfolio:
                    for g, a in enumerate(archives):
                        mg = mask[:, g]
                        if mg.any():
                            a.insert(ys_np[mg, g, :], ids=ids_np[mg])
                else:
                    archives[0].insert(ys_np[mask], ids=ids_np[mask])
            # clamp to `stop`: ids beyond it were masked invalid, and a later
            # resume with a larger stop must re-visit them
            state["next"] = min(s + self.chunk_size, stop)
            state["carry"] = carry
            chunk_i += 1
            self._c_chunks.inc()
            self._c_ids.inc(state["next"] - s)
            self._h_chunk.observe(time.perf_counter() - t_chunk)
            if progress:
                done = min(state["next"], stop)
                # rate counts only ids swept in THIS process (resumed ids
                # were paid for in a previous one)
                here = int(carry["n_eval"]) - n_eval_resumed
                print(f"{label}sweep: {done:,}/{stop:,} ids  "
                      f"front={len(archives[-1])}  "
                      f"{here / max(time.perf_counter() - t0, 1e-9):,.0f} ids/s",
                      flush=True)
            if (checkpoint_path and checkpoint_every
                    and chunk_i % checkpoint_every == 0):
                self._save(checkpoint_path, state, fp_extra)
        if checkpoint_path:
            self._save(checkpoint_path, state, fp_extra)
        state["resumed"] = n_eval_resumed
        return state

    @staticmethod
    def _merge_topk_rows(states: List[Dict], key_val: str, key_id: str,
                         rows: int, k: int) -> Tuple[np.ndarray, np.ndarray]:
        """Stable span-order merge of per-worker running top-k row blocks
        (each worker contributes a (..., rows, k) carry, flattened)."""
        vals = np.concatenate(
            [np.asarray(st["carry"][key_val]).reshape(rows, k)
             for st in states], axis=1)
        cand = np.concatenate(
            [np.asarray(st["carry"][key_id]).reshape(rows, k)
             for st in states], axis=1)
        out_v = np.empty((rows, k), vals.dtype)
        out_i = np.empty((rows, k), cand.dtype)
        for r in range(rows):
            order = np.argsort(vals[r], kind="stable")[:k]
            out_v[r] = vals[r][order]
            out_i[r] = cand[r][order]
        return out_v, out_i

    def _merge_archives(self, archive_lists: List[List[ParetoArchive]],
                        g: int) -> Tuple[ParetoArchive, bool]:
        """Merge group g's archive across workers (exact host reduction)."""
        if len(archive_lists) == 1:
            a = archive_lists[0][g]
            return a, a.truncated
        archive = ParetoArchive(3, capacity=self.archive_capacity)
        truncated = False
        n_seen = 0
        for al in archive_lists:
            a = al[g]
            truncated |= a.truncated
            n_seen += a.n_seen
            if len(a):
                archive.insert(a.y, ids=a.ids)
        truncated |= archive.truncated
        archive.n_seen = n_seen
        archive.truncated = truncated
        return archive, truncated

    def _reduce_states(self, states: List[Dict],
                       seconds: float) -> SweepResult:
        """Merge worker states into one SweepResult.  The top-k merges are
        stable in span order, so ties resolve exactly as the single-process
        streaming reduction would."""
        if self._portfolio:
            return self._reduce_states_portfolio(states, seconds)
        resumed = sum(st.get("resumed", 0) for st in states)
        n_eval = sum(int(st["carry"]["n_eval"]) for st in states)
        n_super = sum(int(st["carry"]["n_super"]) for st in states)

        k = self.topk
        vals = np.concatenate(
            [np.asarray(st["carry"]["topk_val"]) for st in states], axis=1)
        cand = np.concatenate(
            [np.asarray(st["carry"]["topk_id"]) for st in states], axis=1)
        topk_val = np.empty((3, k), vals.dtype)
        topk_id = np.empty((3, k), cand.dtype)
        for o in range(3):
            order = np.argsort(vals[o], kind="stable")[:k]
            topk_val[o] = vals[o][order]
            topk_id[o] = cand[o][order]

        stall_val = stall_id = None
        if self.stall_topk:
            sk = self.stall_topk
            svals = np.concatenate(
                [np.asarray(st["carry"]["stall_topk_val"]) for st in states],
                axis=1)
            scand = np.concatenate(
                [np.asarray(st["carry"]["stall_topk_id"]) for st in states],
                axis=1)
            stall_val = np.empty((_N_STALL, sk), svals.dtype)
            stall_id = np.empty((_N_STALL, sk), scand.dtype)
            for c in range(_N_STALL):
                order = np.argsort(svals[c], kind="stable")[:sk]
                stall_val[c] = svals[c][order]
                stall_id[c] = np.where(np.isfinite(stall_val[c]),
                                       scand[c][order], -1)

        if len(states) == 1:
            archive: ParetoArchive = states[0]["archive"]
            truncated = archive.truncated
        else:
            archive = ParetoArchive(3, capacity=self.archive_capacity)
            truncated = False
            n_seen = 0
            for st in states:
                a: ParetoArchive = st["archive"]
                truncated |= a.truncated
                n_seen += a.n_seen
                if len(a):
                    archive.insert(a.y, ids=a.ids)
            truncated |= archive.truncated
            archive.n_seen = n_seen
            archive.truncated = truncated

        order = np.argsort(archive.ids, kind="stable")
        return SweepResult(
            n_evaluated=n_eval,
            n_superior=n_super,
            pareto_y=archive.y[order],
            pareto_ids=archive.ids[order],
            topk_val=topk_val,
            topk_ids=topk_id,
            ref_point=self.ref_point.copy(),
            seconds=seconds,
            # resumed runs only time the ids swept in *this* process
            points_per_sec=(n_eval - resumed) / max(seconds, 1e-9),
            archive_truncated=truncated,
            stall_topk_val=stall_val,
            stall_topk_ids=stall_id,
            archive_capacity=archive.capacity,
        )

    def _reduce_states_portfolio(self, states: List[Dict],
                                 seconds: float) -> SweepResult:
        """Portfolio merge: per-scenario results nested under the robust
        top-level result (the same stable span-order reduction per group)."""
        S, S1, k = len(self.scenarios), self._n_groups, self.topk
        resumed = sum(st.get("resumed", 0) for st in states)
        n_eval = sum(int(st["carry"]["n_eval"]) for st in states)
        n_super = np.sum([np.asarray(st["carry"]["n_super"])
                          for st in states], axis=0)
        topk_val, topk_id = self._merge_topk_rows(
            states, "topk_val", "topk_id", S1 * 3, k)
        topk_val = topk_val.reshape(S1, 3, k)
        topk_id = topk_id.reshape(S1, 3, k)
        stall_val = stall_id = None
        if self.stall_topk:
            sk = self.stall_topk
            stall_val, stall_id = self._merge_topk_rows(
                states, "stall_topk_val", "stall_topk_id", S * _N_STALL, sk)
            stall_id = np.where(np.isfinite(stall_val), stall_id, -1)
            stall_val = stall_val.reshape(S, _N_STALL, sk)
            stall_id = stall_id.reshape(S, _N_STALL, sk)
        archive_lists = [st["archives"] for st in states]
        pps = (n_eval - resumed) / max(seconds, 1e-9)

        def group_result(g: int, ref: np.ndarray, **extra) -> SweepResult:
            archive, truncated = self._merge_archives(archive_lists, g)
            order = np.argsort(archive.ids, kind="stable")
            return SweepResult(
                n_evaluated=n_eval, n_superior=int(n_super[g]),
                pareto_y=archive.y[order], pareto_ids=archive.ids[order],
                topk_val=topk_val[g], topk_ids=topk_id[g],
                ref_point=np.asarray(ref, dtype=np.float64).copy(),
                seconds=0.0, points_per_sec=0.0,
                archive_truncated=truncated,
                archive_capacity=archive.capacity, **extra)

        per = {s.name: group_result(
                   i, self.ref_points[i],
                   stall_topk_val=(stall_val[i] if self.stall_topk else None),
                   stall_topk_ids=(stall_id[i] if self.stall_topk else None))
               for i, s in enumerate(self.scenarios)}
        res = group_result(S, self.ref_point)
        res.seconds = seconds
        res.points_per_sec = pps
        res.scenario_names = tuple(s.name for s in self.scenarios)
        res.robust = self.robust
        res.per_scenario = per
        return res

    # ------------------------------------------------------------------
    def telemetry(self) -> dict:
        """Registry view of the engine's streaming counters."""
        return {
            "runs": int(self._c_runs.value()),
            "chunks": int(self._c_chunks.value()),
            "ids": int(self._c_ids.value()),
            "chunk_s": self._h_chunk.stats(),
        }

    # ------------------------------------------------------------------
    def _archives_of(self, state: Dict) -> List[ParetoArchive]:
        return state["archives"] if self._portfolio else [state["archive"]]

    def _save(self, path: str, state: Dict, fp_extra: str = "") -> None:
        """Atomic checkpoint write: the payload (plus a sha256 content
        digest) lands in a ``.tmp`` sibling and is published with
        ``os.replace`` — a kill mid-write leaves the previous checkpoint
        intact, never a truncated one."""
        archives = self._archives_of(state)
        extra = {}
        if self.stall_topk:
            extra["stall_topk_val"] = np.asarray(state["carry"]["stall_topk_val"])
            extra["stall_topk_id"] = np.asarray(state["carry"]["stall_topk_id"])
        for g, a in enumerate(archives[1:], start=1):
            # portfolio: scenario archives 1..S1-1 ride alongside the first
            extra[f"archive{g}_y"] = a.y
            extra[f"archive{g}_ids"] = a.ids
            extra[f"archive{g}_seen"] = a.n_seen
            extra[f"archive{g}_truncated"] = a.truncated
        if self._portfolio:
            # the robust ref [1, 1, area] alone cannot detect changed
            # latency refs (its latency entries are 1 by construction)
            extra["ref_points"] = self.ref_points
        payload = dict(
            version=_FMT_VERSION,
            fingerprint=self.fingerprint() + fp_extra,
            next=state["next"],
            n_super=np.asarray(state["carry"]["n_super"]),
            n_eval=np.asarray(state["carry"]["n_eval"]),
            topk_val=np.asarray(state["carry"]["topk_val"]),
            topk_id=np.asarray(state["carry"]["topk_id"]),
            archive_y=archives[0].y,
            archive_ids=archives[0].ids,
            archive_seen=archives[0].n_seen,
            archive_truncated=archives[0].truncated,
            ref_point=self.ref_point,
            **extra,
        )
        payload["digest"] = _state_digest(payload)
        fname = path if str(path).endswith(".npz") else f"{path}.npz"
        tmp = fname + ".tmp"
        # write through an open handle: np.savez would append another
        # ``.npz`` to a bare tmp path, breaking the replace pairing
        with open(tmp, "wb") as f:
            np.savez(f, **payload)
        os.replace(tmp, fname)

    @staticmethod
    def _quarantine(fname: str, reason: str) -> None:
        q = f"{fname}.quarantined"
        try:
            os.replace(fname, q)
        except OSError:
            q = "<could not rename>"
        warnings.warn(f"sweep checkpoint {fname} is corrupt ({reason}); "
                      f"quarantined to {q} — restarting the span fresh",
                      RuntimeWarning, stacklevel=3)

    def _load(self, path: str, fp_extra: str = "") -> Optional[Dict]:
        """Restore a checkpoint, or None after quarantining a corrupt /
        truncated file (config mismatches still raise: the file is VALID,
        resuming it would just be wrong)."""
        fname = path if str(path).endswith(".npz") else f"{path}.npz"
        try:
            with np.load(fname, allow_pickle=False) as zf:
                z = {k: np.asarray(zf[k]) for k in zf.files}
        except FileNotFoundError:
            raise
        except Exception as exc:
            self._quarantine(fname, f"unreadable: {exc}")
            return None
        if "digest" in z:          # pre-digest checkpoints stay loadable
            stored = str(z["digest"])
            body = {k: v for k, v in z.items() if k != "digest"}
            if _state_digest(body) != stored:
                self._quarantine(fname, "content digest mismatch")
                return None
        if int(z["version"]) > _FMT_VERSION:
            raise ValueError(
                f"checkpoint format v{int(z['version'])} is newer than this "
                f"build's v{_FMT_VERSION}; refusing to resume")
        if str(z["fingerprint"]) != self.fingerprint() + fp_extra:
            raise ValueError(
                "checkpoint was produced by a different space/workload/"
                "backend configuration (or a different worker span); "
                "refusing to resume")
        if not np.allclose(np.asarray(z["ref_point"]), self.ref_point,
                           rtol=1e-6):
            raise ValueError(
                "checkpoint was produced with a different reference point; "
                "its superiority counts cannot be continued — refusing to "
                "resume")
        if self._portfolio:
            if "ref_points" not in z or not np.allclose(
                    np.asarray(z["ref_points"]), self.ref_points, rtol=1e-6):
                raise ValueError(
                    "checkpoint was produced with different per-scenario "
                    "reference points; its robust scalarization cannot be "
                    "continued — refusing to resume")

        def load_archive(prefix: str) -> ParetoArchive:
            a = ParetoArchive(3, capacity=self.archive_capacity)
            a.y = np.asarray(z[f"{prefix}_y"], dtype=np.float64)
            a.ids = np.asarray(z[f"{prefix}_ids"], dtype=np.int64)
            a.n_seen = int(z[f"{prefix}_seen"])
            a.truncated = bool(z[f"{prefix}_truncated"])
            if a.auto:
                a._peak = len(a)
                a.capacity = max(a.auto_floor,
                                 int(a.auto_headroom * a._peak))
            return a

        carry = {
            "n_super": jnp.asarray(z["n_super"]),
            "n_eval": jnp.asarray(z["n_eval"]),
            "topk_val": jnp.asarray(z["topk_val"]),
            "topk_id": jnp.asarray(z["topk_id"]),
        }
        if self._portfolio and carry["topk_val"].ndim != 3:
            raise ValueError("checkpoint is single-scenario but this engine "
                             "sweeps a portfolio; refusing to resume")
        if self.stall_topk:
            if "stall_topk_val" not in z:
                raise ValueError(
                    "checkpoint carries no per-stall-class top-k state but "
                    "this engine was built with stall_topk > 0; refusing to "
                    "resume")
            if z["stall_topk_val"].shape[-1] != self.stall_topk:
                raise ValueError(
                    "checkpoint stall_topk width differs from this engine's; "
                    "refusing to resume")
            carry["stall_topk_val"] = jnp.asarray(z["stall_topk_val"])
            carry["stall_topk_id"] = jnp.asarray(z["stall_topk_id"])
        if self._portfolio:
            archives = [load_archive("archive")]
            archives += [load_archive(f"archive{g}")
                         for g in range(1, self._n_groups)]
            return {"next": int(z["next"]), "carry": carry,
                    "archives": archives}
        return {"next": int(z["next"]), "carry": carry,
                "archive": load_archive("archive")}


# --------------------------------------------------------------------------
# persistent oracle store: SweepResult artifacts on disk
# --------------------------------------------------------------------------
# A full-space sweep costs seconds-to-minutes; its SweepResult (front,
# top-k tables, stall seeds, per-scenario nests) is a few MB.  The oracle
# store memoizes exactly that: save/load one SweepResult npz, digested
# and atomically written like the checkpoints above, so a repeat
# OracleEvaluator over the same (fingerprint, stop, knobs) key is an
# O(1) load instead of a re-sweep (see OracleEvaluator's oracle_store=).

ORACLE_STORE_VERSION = 1
DEFAULT_ORACLE_STORE = os.path.join("~", ".cache", "repro-oracle")

_RESULT_REQ = ("n_evaluated", "n_superior", "pareto_y", "pareto_ids",
               "topk_val", "topk_ids", "ref_point", "seconds",
               "points_per_sec", "archive_truncated")
_RESULT_OPT = ("stall_topk_val", "stall_topk_ids", "archive_capacity",
               "robust")


def _result_payload(res: SweepResult, prefix: str = "") -> Dict:
    out = {}
    for f in _RESULT_REQ:
        out[prefix + f] = np.asarray(getattr(res, f))
    for f in _RESULT_OPT:
        v = getattr(res, f)
        if v is not None:
            out[prefix + f] = np.asarray(v)
    if res.scenario_names is not None:
        out[prefix + "scenario_names"] = np.asarray(res.scenario_names)
    if res.per_scenario:
        # flatten scenario nests with positional prefixes (s0., s1., ...)
        for i, nm in enumerate(res.scenario_names):
            out.update(_result_payload(res.per_scenario[nm],
                                       prefix=f"{prefix}s{i}."))
    return out


def _result_from_payload(z: Dict, prefix: str = "") -> SweepResult:
    def opt(name, cast):
        key = prefix + name
        return cast(z[key]) if key in z else None

    names = None
    per = None
    if prefix + "scenario_names" in z:
        names = tuple(str(s) for s in np.asarray(z[prefix
                                                   + "scenario_names"]))
        if any(k.startswith(f"{prefix}s0.") for k in z):
            per = {nm: _result_from_payload(z, prefix=f"{prefix}s{i}.")
                   for i, nm in enumerate(names)}
    return SweepResult(
        n_evaluated=int(z[prefix + "n_evaluated"]),
        n_superior=int(z[prefix + "n_superior"]),
        pareto_y=np.asarray(z[prefix + "pareto_y"], dtype=np.float64),
        pareto_ids=np.asarray(z[prefix + "pareto_ids"], dtype=np.int64),
        topk_val=np.asarray(z[prefix + "topk_val"]),
        topk_ids=np.asarray(z[prefix + "topk_ids"]),
        ref_point=np.asarray(z[prefix + "ref_point"]),
        seconds=float(z[prefix + "seconds"]),
        points_per_sec=float(z[prefix + "points_per_sec"]),
        archive_truncated=bool(z[prefix + "archive_truncated"]),
        stall_topk_val=opt("stall_topk_val", np.asarray),
        stall_topk_ids=opt("stall_topk_ids", np.asarray),
        archive_capacity=opt("archive_capacity", int),
        robust=opt("robust", str),
        scenario_names=names,
        per_scenario=per,
    )


def save_sweep_result(path: str, result: SweepResult, *,
                      key: str = "") -> str:
    """Persist one SweepResult (atomic tmp + ``os.replace``, sha256
    content digest).  ``key`` ties the artifact to its producing
    configuration — loads with a different key refuse.  Returns the
    final filename."""
    payload = _result_payload(result)
    payload["store_version"] = np.asarray(ORACLE_STORE_VERSION)
    payload["oracle_key"] = np.asarray(key)
    payload["digest"] = _state_digest(payload)
    fname = path if str(path).endswith(".npz") else f"{path}.npz"
    os.makedirs(os.path.dirname(os.path.abspath(fname)), exist_ok=True)
    tmp = fname + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **payload)
    os.replace(tmp, fname)
    return fname


def load_sweep_result(path: str, *, key: str = "") -> SweepResult:
    """Load a stored SweepResult; raises ``ValueError`` on a corrupt,
    truncated, newer-format or key-mismatched file (callers quarantine
    and re-sweep)."""
    fname = path if str(path).endswith(".npz") else f"{path}.npz"
    try:
        with np.load(fname, allow_pickle=False) as zf:
            z = {k: np.asarray(zf[k]) for k in zf.files}
    except FileNotFoundError:
        raise
    except Exception as exc:
        raise ValueError(f"unreadable oracle artifact: {exc}") from exc
    stored = str(z.pop("digest", ""))
    if _state_digest(z) != stored:
        raise ValueError("oracle artifact content digest mismatch")
    if int(z["store_version"]) > ORACLE_STORE_VERSION:
        raise ValueError(
            f"oracle artifact format v{int(z['store_version'])} is newer "
            f"than this build's v{ORACLE_STORE_VERSION}")
    if key and str(z["oracle_key"]) != key:
        raise ValueError("oracle artifact belongs to a different "
                         "configuration key")
    return _result_from_payload(z)
