"""Vectorized roofline evaluation of (designs x workload ops).

Per-op time = max(compute-term, memory-term, interconnect-term) under an
effective-throughput model that couples every design-space parameter to the
metrics it physically influences:

* systolic utilization   <- sa_dim vs matmul dims (padding + pipeline fill),
  sublane/core tile parallelism, SRAM double-buffer capacity;
* HBM traffic            <- compulsory bytes vs blocked-matmul I/O lower
  bound 2*M*N*K/sqrt(gbuf) (global-buffer reuse);
* collectives            <- ring all-reduce / all-to-all on the ICI links.

Evaluating the *entire* 4.7M-point space takes ~1 s on one device (the paper
reports 6000 CPU-hours per 1000 LLMCompass samples — this is the substrate
speedup that lets us run 1000-sample DSE campaigns in CI).

This module is the core of the surface :mod:`repro.analysis.influence`
parses: ``RooflineModel._op_terms`` defines the derived -> op-term edges,
``_dominant_class`` the term -> stall attribution (its ``jnp.where`` guard
tree becomes per-edge workload-kind constraints), and the division
denominators the per-class PEAK throughputs from which the AHK primary
stall -> parameter edges are derived.  After restructuring any of these,
re-run ``python -m repro.analysis.extract --check`` (CI does) and refresh
the artifact with ``--write`` if the edge change is intentional.
"""
from __future__ import annotations

import hashlib
from typing import Callable, Dict, Mapping, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.perfmodel import workload as W
from repro.perfmodel.designspace import DesignSpace, SPACE
from repro.perfmodel.hardware import derive_hardware, BYTES_FP16, LINK_LATENCY_S

# stall classes (aligned with critical_path.STALL_CLASSES)
TENSOR, VECTORU, MEMORY, INTERCONNECT = 0, 1, 2, 3

# SRAM operand-feed bandwidth: words/cycle supplied per KB of per-core SRAM
# (more capacity = more banks).  Calibrated so the A100 point (128 KB feeding
# a 16x16 array x 4 sublanes = 64 words/cycle) is exactly unconstrained while
# a 32x32 array x 4 sublanes on the same SRAM runs at 62.5% feed utilization
# — reproducing the Table-4 performance deltas of designs A/B.
SRAM_FEED_WORDS_PER_KB = 0.625


def _ceil_div(a, b):
    return jnp.ceil(a / b)


def matmul_utilization(hw: Dict[str, jnp.ndarray], m, n, k) -> jnp.ndarray:
    """Fraction of peak tensor throughput achieved on an (m,k)x(k,n) matmul.

    Three multiplicative effects:
      u_pad  — K and N pad to the sa_dim grid (weight-stationary mapping);
      u_pipe — pipeline fill: each output tile streams m rows through a
               sa-deep array (m / (m + sa));
      u_par  — not enough independent output tiles to fill cores*sublanes;
      u_sram — double-buffered A/B/C tiles must fit the per-core SRAM;
      u_feed — SRAM operand-feed bandwidth: a sa-wide array consumes
               sa*sublanes words/cycle; SRAM banks supply
               SRAM_FEED_WORDS_PER_KB * sram_kb words/cycle.  This is the
               paper's noted pitfall: enlarging the systolic array without
               scaling SRAM causes significant compute under-utilization.
    """
    sa = hw["sa_dim"]
    u_k = k / (_ceil_div(k, sa) * sa)
    u_n = n / (_ceil_div(n, sa) * sa)
    u_pipe = m / (m + sa)
    n_tiles = _ceil_div(m, sa) * _ceil_div(n, sa)
    u_par = jnp.minimum(1.0, n_tiles / (hw["core_count"] * hw["sublane_count"]))
    sram_need_kb = 3.0 * 2.0 * sa * sa * BYTES_FP16 / 1024.0   # A,B,C x dbuf
    u_sram = jnp.minimum(1.0, hw["sram_kb"] / sram_need_kb)
    u_feed = jnp.minimum(
        1.0, SRAM_FEED_WORDS_PER_KB * hw["sram_kb"]
        / (sa * hw["sublane_count"]))
    return u_k * u_n * u_pipe * u_par * u_sram * u_feed


def matmul_hbm_bytes(hw, compulsory, m, n, k) -> jnp.ndarray:
    """Blocked-matmul HBM traffic: max(compulsory, I/O lower bound given the
    global buffer as the reuse capacity)."""
    f_elems = jnp.maximum(hw["gbuf_bytes"] / BYTES_FP16, 1.0)
    bound = 2.0 * m * n * k / jnp.sqrt(f_elems) * BYTES_FP16
    return jnp.maximum(compulsory, bound)


def ring_allreduce_time(hw, nbytes, tp) -> jnp.ndarray:
    steps = 2.0 * (tp - 1.0)
    return steps / tp * nbytes / hw["ici_bw"] + steps * LINK_LATENCY_S


def a2a_time(hw, nbytes, tp) -> jnp.ndarray:
    return (tp - 1.0) / tp * nbytes / hw["ici_bw"] + (tp - 1.0) * LINK_LATENCY_S


# Shared compiled-evaluator cache.  Keyed by everything that changes the
# traced computation (model class + knobs, design space, workload op arrays,
# TP degree), so every RooflineModel/CompassModel built for the same workload
# — across baselines, DSE campaigns and benchmark modules — reuses one
# XLA executable per batch shape instead of re-tracing per instance.
_JIT_CACHE: Dict[tuple, tuple] = {}


def _space_key(space: DesignSpace) -> tuple:
    return tuple(tuple(float(v) for v in c) for c in space.choices)


def _workload_fingerprint(wl: W.Workload) -> str:
    a = wl.arrays()
    h = hashlib.sha1()
    for kk in sorted(a):
        h.update(kk.encode())
        h.update(np.ascontiguousarray(a[kk]).tobytes())
    return h.hexdigest()


def _batch_bucket(b: int) -> int:
    """Round a batch size up to the next power of two (min 8) so repeated
    odd-size calls hit a handful of compiled shapes instead of retracing."""
    bb = 8
    while bb < b:
        bb *= 2
    return bb


def _strip_sinks(tree):
    """Drop underscore-keyed leaves (device-only materialization sinks like
    ``"_sink"``) so they are never copied to host."""
    if isinstance(tree, dict):
        return {k: _strip_sinks(v) for k, v in tree.items()
                if not str(k).startswith("_")}
    return tree


def _bucketed_call(fn: Callable, idx: np.ndarray):
    """Pad an index batch to its power-of-two bucket, call a jitted `fn`, and
    slice every output leaf back to the true batch size.

    The single pad/slice implementation behind the fused
    :class:`~repro.perfmodel.evaluator.ModelEvaluator` dispatch path.
    Sink outputs (keys starting with ``_``) exist only to pin the traced
    executable's materialization and are dropped BEFORE the host transfer.
    """
    idx = np.atleast_2d(np.asarray(idx, dtype=np.int32))
    b = idx.shape[0]
    bb = _batch_bucket(b)
    if bb != b:                       # pad with the last row; slice back
        idx = np.concatenate([idx, np.repeat(idx[-1:], bb - b, axis=0)])
    out = _strip_sinks(fn(jnp.asarray(idx)))
    return jax.tree_util.tree_map(lambda v: np.asarray(v)[:b], out)


def _dominant_class(t: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Dominant-resource class per op from `_op_terms` components.

    THE attribution rule (ties: comm wins on >=, compute needs a strict >
    over memory; pure memcpy ops always attribute to MEMORY) — shared by
    :func:`_attribute` and the portfolio sweep's union-level stall pass so
    the two can never drift apart.
    """
    t_compute, t_memory, t_comm = t["t_compute"], t["t_memory"], t["t_comm"]
    dom_is_comm = (t_comm >= t_compute) & (t_comm >= t_memory)
    dom_is_compute = (t_compute > t_memory) & ~dom_is_comm
    dom_class = jnp.where(
        dom_is_comm, INTERCONNECT,
        jnp.where(dom_is_compute,
                  jnp.where(t["is_mm"], TENSOR, VECTORU),
                  MEMORY))
    return jnp.where(t["is_mem"], MEMORY, dom_class)


def _attribute(t: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stall attribution for `_op_terms` output: each op's time goes to its
    dominant resource.  Returns (dom_class (B, ops), stall (B, 4))."""
    dom_class = _dominant_class(t)
    t_op = t["t_op"]
    stall = jnp.stack(
        [jnp.where(dom_class == c, t_op, 0.0).sum(axis=1) for c in range(4)],
        axis=1)
    return dom_class, stall


class RooflineModel:
    """Per-workload op-term model: the traced building block every
    :class:`~repro.perfmodel.evaluator.ModelEvaluator` (and the sweep
    engine's chunk step) composes via :meth:`_workload_batch`.

    Evaluate through the unified Evaluator contract — a model instance on
    its own is just the op-term provider for one workload.
    """

    # Compass-tier knobs (overridden by CompassModel)
    op_overhead_s: float = 0.0        # fixed per-op launch overhead
    nonoverlap: float = 0.0           # fraction of the minor term not hidden
    mem_efficiency: float = 1.0       # achievable fraction of peak HBM bw

    def __init__(self, wl: W.Workload, space: DesignSpace = SPACE):
        self.wl = wl
        self.space = space
        a = wl.arrays()
        self._ops = {kk: jnp.asarray(vv) for kk, vv in a.items()}
        self._tp = float(wl.tp)

    # ------------------------------------------------------------------
    def _op_terms(self, hwb: Dict[str, jnp.ndarray],
                  ops: Optional[Dict[str, jnp.ndarray]] = None,
                  ) -> Dict[str, jnp.ndarray]:
        """Per-op time terms for (B, 1)-broadcast hardware dicts.

        Shared by the full eval path and the lean sweep/objectives path.
        ``ops`` overrides the model's own op table — the stacked path feeds
        the deduped union of a :class:`~repro.perfmodel.workload.
        WorkloadStack` through the same traced math (``t_unit`` is the
        count-free per-op time the gather reassembly multiplies back out).
        """
        o = self._ops if ops is None else ops
        kind = o["kind"][None, :]
        flops = o["flops"][None, :]
        m, n, k = o["m"][None, :], o["n"][None, :], o["k"][None, :]
        comm = o["comm_bytes"][None, :]
        count = o["count"][None, :]
        tp = o["tp"][None, :]

        util = matmul_utilization(hwb, m, n, k)
        eff_tensor = hwb["tensor_flops"] * util
        is_mm = kind == W.MATMUL
        is_vec = kind == W.VECTOR
        is_mem = kind == W.MEMCPY
        is_ar = kind == W.ALLREDUCE
        is_p2p = kind == W.P2P

        bytes_eff = jnp.where(
            is_mm, matmul_hbm_bytes(hwb, o["bytes"][None, :], m, n, k),
            o["bytes"][None, :])

        t_compute = jnp.where(
            is_mm, flops / eff_tensor,
            jnp.where(is_vec, flops / hwb["vector_flops"], 0.0))
        t_memory = bytes_eff / (hwb["mem_bw"] * self.mem_efficiency)
        t_comm = jnp.where(
            is_ar, ring_allreduce_time(hwb, comm, tp),
            jnp.where(is_p2p, a2a_time(hwb, comm, tp), 0.0))

        major = jnp.maximum(jnp.maximum(t_compute, t_memory), t_comm)
        minor = t_compute + t_memory + t_comm - major
        t_unit = major + self.nonoverlap * minor + self.op_overhead_s
        t_op = t_unit * count
        return {
            "t_op": t_op, "t_unit": t_unit, "t_compute": t_compute,
            "t_memory": t_memory, "t_comm": t_comm, "count": count,
            "is_mm": is_mm, "is_mem": is_mem,
        }

    def _workload_batch(self, hwb: Dict[str, jnp.ndarray],
                        detail: str = "stalls") -> Dict[str, jnp.ndarray]:
        """Per-workload traced outputs for (B, 1)-broadcast hardware arrays.

        This is the unit the fused :class:`~repro.perfmodel.evaluator`
        dispatch composes: the space decode and hardware derivation happen
        ONCE per batch while each workload model contributes its op terms.

        detail: "objectives" -> latency only; "ppa" adds the per-op
        breakdown; "stalls" adds stall attribution on top of "ppa".
        """
        t = self._op_terms(hwb)
        latency = t["t_op"].sum(axis=1)
        if detail == "objectives":
            return {"latency": latency}
        if detail == "objectives+sink":
            # evaluator path: emit t_op so the latency reduce consumes a
            # materialized buffer exactly as at "ppa"/"stalls" (XLA's fused
            # producer+reduce drifts a ULP on some op tables); the sweep's
            # on-device step keeps plain "objectives" (the sink would be
            # dead code there anyway)
            return {"latency": latency, "_sink": t["t_op"]}
        count = t["count"]
        out = {
            "latency": latency,
            "op_time": t["t_op"],
            "t_compute": t["t_compute"] * count,
            "t_memory": t["t_memory"] * count,
            "t_comm": t["t_comm"] * count,
        }
        if detail == "stalls":
            dom_class, stall = _attribute(t)
            out["op_class"] = dom_class
            out["stall"] = stall            # (B, 4) seconds per stall class
        return out

    # The pre-PR-2 per-model shims (eval_ppa / latency / objectives) were
    # removed after their one-release deprecation window: evaluate through
    # repro.perfmodel.evaluator (ModelEvaluator fuses every workload into
    # one dispatch; evaluator_for_model wraps a single model).


# --------------------------------------------------------------------------
# stacked-workload evaluation: op terms ONCE over the deduped union
# --------------------------------------------------------------------------

def stacked_workload_batches(model: RooflineModel,
                             stack: "W.WorkloadStack",
                             hwb: Dict[str, jnp.ndarray],
                             detail: Union[str, Mapping[str, str]] = "stalls",
                             materialize_objectives: bool = False,
                             ) -> Dict[str, Dict[str, jnp.ndarray]]:
    """Every workload's ``_workload_batch`` outputs from ONE op-term pass.

    ``model`` supplies the op-term math (class + compass knobs — every
    workload in the stack must share them); its :meth:`RooflineModel.
    _op_terms` runs once over ``stack.unique`` (count-free ``t_unit``), and
    each workload's per-op arrays are reassembled by gathering its rows out
    of the union and multiplying its own counts back in.  Because every
    per-op value is elementwise in the op fields and the per-workload
    reductions run over the same (B, n_ops_w) arrays in the same op order,
    the result is BIT-IDENTICAL to looping ``_workload_batch`` per workload
    — with O(n_unique) instead of O(sum n_ops_w) traced op-term cost.

    ``detail`` is one level for all workloads or a per-workload mapping
    (the portfolio sweep attributes stalls only on prefill workloads).

    ``materialize_objectives``: at the "objectives" level, also emit each
    workload's per-op times under a ``"_sink"`` key.  At "ppa"/"stalls"
    ``t_op`` is an executable OUTPUT, and XLA's materialized-buffer
    reduction is what the looped path computes; the objectives-only
    executable otherwise fuses gather+multiply into the latency reduce and
    drifts a ULP.  The evaluator path sets this (bit-identity across
    detail levels and vs the looped path is part of its contract); the
    sweep's on-device step keeps the fully fused reduce.
    """
    ones = np.ones(stack.n_unique, dtype=np.float64)
    uops = {kk: jnp.asarray(vv) for kk, vv in stack.unique.items()}
    uops["count"] = jnp.asarray(ones)
    t = model._op_terms(hwb, ops=uops)
    out: Dict[str, Dict[str, jnp.ndarray]] = {}
    for nm in stack.names:
        d = detail if isinstance(detail, str) else detail[nm]
        mp = jnp.asarray(stack.op_map[nm])
        cnt = jnp.asarray(stack.counts[nm])[None, :]
        t_op = t["t_unit"][:, mp] * cnt
        latency = t_op.sum(axis=1)
        if d == "objectives":
            out[nm] = ({"latency": latency, "_sink": t_op}
                       if materialize_objectives else {"latency": latency})
            continue
        ow = {
            "latency": latency,
            "op_time": t_op,
            "t_compute": t["t_compute"][:, mp] * cnt,
            "t_memory": t["t_memory"][:, mp] * cnt,
            "t_comm": t["t_comm"][:, mp] * cnt,
        }
        if d == "stalls":
            tw = {
                "t_op": t_op,
                "t_compute": t["t_compute"][:, mp],
                "t_memory": t["t_memory"][:, mp],
                "t_comm": t["t_comm"][:, mp],
                "is_mm": t["is_mm"][:, mp],
                "is_mem": t["is_mem"][:, mp],
            }
            dom_class, stall = _attribute(tw)
            ow["op_class"] = dom_class
            ow["stall"] = stall
        out[nm] = ow
    return out
