"""Design point -> derived hardware spec (throughputs, bandwidths, area).

Calibrated against the NVIDIA A100 reference of Table 4:

* tensor FP16 throughput:  cores * sublanes * sa_dim^2 MACs * 2 FLOP * clock
  A100 (108, 4, 16x16, 1.41 GHz) -> 311.9 TFLOP/s  (spec: 312 TFLOP/s)     OK
* HBM bandwidth:           channels * 311 GB/s
  A100 (5 channels)        -> 1555 GB/s            (spec: 1555 GB/s)       OK
* interconnect:            links * 25 GB/s/dir
  A100 (12 links)          -> 300 GB/s/dir         (NVLink3 spec)          OK
* die area model sums component areas, calibrated to ~826 mm^2 for A100.

This module is part of the surface :mod:`repro.analysis.influence` parses:
``derive_hardware``'s dict-literal return defines the param -> derived-
quantity edges of the extracted influence graph (CI checks the artifact via
``python -m repro.analysis.extract --check`` — refresh with ``--write``
after changing which parameters a derived key reads).

All functions accept dicts of scalar-or-batched jnp arrays (the output of
``DesignSpace.decode``) and are jit/vmap friendly.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

# ----------------------------------------------------------------- constants
CLOCK_HZ = 1.41e9              # core clock
BW_PER_CHANNEL = 311.0e9       # bytes/s per HBM channel (A100: 5ch -> 1555 GB/s)
BW_PER_LINK = 25.0e9           # bytes/s per interconnect link, unidirectional
LINK_LATENCY_S = 1.0e-6        # per-hop collective latency

# Area model (mm^2).  Calibrated against Table 4: the A100 reference lands at
# ~824 mm^2 AND Lumina's Design A (64 cores, 32x32 SA) lands at 0.772x A100,
# Design B (96 cores) at 0.96x (paper: 0.952x).  The Table-4 ratios pin the
# MAC-vs-core-overhead split: per-core fixed overhead (control, dispatch,
# regfiles) dominates and systolic MACs are cheap — exactly the property
# behind the paper's counter-intuitive "fewer cores, bigger tensor units"
# strategy (see tests/test_perfmodel.py::test_table4_area_ratios).
AREA_BASE = 140.0              # misc: command processors, PCIe, video, pads
AREA_PER_MAC = 1.826e-4        # fp16 MAC in the systolic array
AREA_PER_VLANE = 0.008         # fp32-capable vector lane
AREA_PER_SRAM_KB = 0.0081      # per-core SRAM
AREA_CORE_BASE = 2.924         # per-core control/dispatch/regfile overhead
AREA_PER_GBUF_MB = 0.72        # global buffer SRAM macro
AREA_PER_CHANNEL = 15.0        # HBM PHY + controller per channel
AREA_PER_LINK = 1.8            # interconnect SerDes per link

BYTES_FP16 = 2


def derive_hardware(v: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    """Map decoded design values -> derived spec. Batched over leading dims."""
    cores = v["core_count"]
    sub = v["sublane_count"]
    sa = v["sa_dim"]
    vw = v["vector_width"]

    tensor_flops = cores * sub * sa * sa * 2.0 * CLOCK_HZ     # FLOP/s, fp16
    vector_flops = cores * sub * vw * 2.0 * CLOCK_HZ          # FLOP/s
    mem_bw = v["mem_channels"] * BW_PER_CHANNEL               # bytes/s
    ici_bw = v["link_count"] * BW_PER_LINK                    # bytes/s/dir

    return {
        "tensor_flops": tensor_flops,
        "vector_flops": vector_flops,
        "mem_bw": mem_bw,
        "ici_bw": ici_bw,
        "sram_kb": v["sram_kb"],
        "gbuf_bytes": v["gbuf_mb"] * 2.0**20,
        "sa_dim": sa,
        "sublane_count": sub,
        "core_count": cores,
        "vector_width": vw,
        "area_mm2": area_mm2(v),
    }


def area_mm2(v: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Analytical die-area model (the paper's 'area model source code' that the
    Perf/Area-prediction benchmark hands to the LLM)."""
    macs_per_core = v["sublane_count"] * v["sa_dim"] * v["sa_dim"]
    vlanes_per_core = v["sublane_count"] * v["vector_width"]
    core_area = (
        AREA_CORE_BASE
        + AREA_PER_MAC * macs_per_core
        + AREA_PER_VLANE * vlanes_per_core
        + AREA_PER_SRAM_KB * v["sram_kb"]
    )
    return (
        AREA_BASE
        + v["core_count"] * core_area
        + AREA_PER_GBUF_MB * v["gbuf_mb"]
        + AREA_PER_CHANNEL * v["mem_channels"]
        + AREA_PER_LINK * v["link_count"]
    )


# Source string handed to the perf/area-prediction benchmark task (the paper
# gives the LLM "the source code of the area model").
AREA_MODEL_SOURCE = r"""
def area_mm2(design):
    macs_per_core  = design.sublane_count * design.sa_dim ** 2
    vlanes_per_core = design.sublane_count * design.vector_width
    core = 2.924 + 1.826e-4 * macs_per_core + 0.008 * vlanes_per_core \
           + 0.0081 * design.sram_kb
    return 140.0 + design.core_count * core + 0.72 * design.gbuf_mb \
           + 15.0 * design.mem_channels + 1.8 * design.link_count
"""
