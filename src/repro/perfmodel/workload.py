"""Operator-graph workload descriptions for the analytical models.

A :class:`Workload` is a struct-of-arrays list of operators, each with
FLOPs, compulsory HBM bytes, matmul dims (for systolic-utilization modelling)
and collective bytes.  The models evaluate ``(designs x ops)`` fully
vectorized.

Builders:

* :func:`gpt3_layer_prefill` / :func:`gpt3_layer_decode` — the paper's
  evaluation workload (single GPT-3 175B layer, TP=8, batch 8, seq 2048,
  FP16; TPOT at output token 1024).
* :func:`from_arch` — operator graph for any assigned architecture config
  (dense / MoE / hybrid-SSM / RWKV / enc-dec / VLM backbone), so every arch
  doubles as a DSE workload.

Two anchors here are parsed by :mod:`repro.analysis.influence`: the op-kind
constants (``MATMUL``/``VECTOR``/...) resolve the roofline guard
comparisons, and ``paper_suite``'s dict literal names the latency metrics
("ttft"/"tpot") of the extracted influence graph.

Portfolio pieces:

* :class:`WorkloadStack` — the deduped union of many workloads' op tables:
  identical ``(kind, flops, bytes, m, n, k, comm_bytes, tp)`` rows across
  workloads collapse to one unique op, with a ``(W x n_unique)`` count
  matrix and per-workload gather maps.  The stacked evaluator path runs the
  op-term model ONCE over the union and reassembles every workload by
  gather — near-flat cost in W.
* :class:`Scenario` + :func:`paper_suite` / :func:`zoo_suite` — named
  (prefill, decode) workload pairs: the paper's GPT-3 pair, or one scenario
  per assigned architecture config (``repro.configs``), so the whole
  workload zoo rides the sweep/campaign stack.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

BYTES = 2  # fp16 everywhere (paper: "all operators are executed in FP16")

# op kinds
MATMUL = 0   # runs on the systolic (tensor) unit
VECTOR = 1   # runs on the vector unit (softmax, norms, activations, scans)
MEMCPY = 2   # pure HBM streaming (KV-cache reads, cache updates)
ALLREDUCE = 3  # ring all-reduce over the interconnect (TP collective)
P2P = 4      # point-to-point transfer over the interconnect

KIND_NAMES = {MATMUL: "matmul", VECTOR: "vector", MEMCPY: "memcpy",
              ALLREDUCE: "allreduce", P2P: "p2p"}


@dataclasses.dataclass
class Op:
    name: str
    kind: int
    flops: float = 0.0
    bytes: float = 0.0        # compulsory HBM traffic (read+write)
    m: float = 1.0            # matmul dims (ignored for non-matmul)
    n: float = 1.0
    k: float = 1.0
    comm_bytes: float = 0.0   # collective payload per participant
    count: float = 1.0        # multiplicity (e.g. layer count)


@dataclasses.dataclass
class Workload:
    name: str
    ops: List[Op]
    tp: int = 8               # tensor-parallel degree (ring size for collectives)

    # ---- struct-of-arrays view consumed by the vectorized models ----
    def arrays(self):
        f = lambda attr: np.array([getattr(o, attr) for o in self.ops], dtype=np.float64)
        kinds = np.array([o.kind for o in self.ops], dtype=np.int32)
        return {
            "kind": kinds, "flops": f("flops"), "bytes": f("bytes"),
            "m": f("m"), "n": f("n"), "k": f("k"),
            "comm_bytes": f("comm_bytes"), "count": f("count"),
            # per-op TP degree: constant within one workload, but the stacked
            # union mixes workloads, so tp rides the op table like every
            # other field (collective times depend on it)
            "tp": np.full(len(self.ops), float(self.tp), dtype=np.float64),
        }

    @property
    def op_names(self) -> List[str]:
        return [o.name for o in self.ops]


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def _matmul(name: str, m: float, k: float, n: float, count: float = 1.0) -> Op:
    """Dense matmul A(m,k) @ B(k,n). Compulsory traffic: A + B + C."""
    return Op(name, MATMUL, flops=2.0 * m * k * n,
              bytes=(m * k + k * n + m * n) * BYTES,
              m=m, n=n, k=k, count=count)


def _vector(name: str, elems: float, flops_per_elem: float = 5.0,
            passes: float = 2.0, count: float = 1.0) -> Op:
    """Elementwise/reduction op over `elems` elements (norms, softmax, act)."""
    return Op(name, VECTOR, flops=flops_per_elem * elems,
              bytes=passes * elems * BYTES, count=count)


def _memcpy(name: str, nbytes: float, count: float = 1.0) -> Op:
    return Op(name, MEMCPY, bytes=nbytes, count=count)


def _allreduce(name: str, elems: float, count: float = 1.0) -> Op:
    return Op(name, ALLREDUCE, comm_bytes=elems * BYTES, count=count)


# --------------------------------------------------------------------------
# Paper workload: one GPT-3 175B layer, TP=8, batch 8, seq 2048, FP16
# --------------------------------------------------------------------------

GPT3 = dict(d_model=12288, n_heads=96, head_dim=128, d_ff=4 * 12288)


def gpt3_layer_prefill(batch: int = 8, seq: int = 2048, tp: int = 8) -> Workload:
    d, H, hd, ff = GPT3["d_model"], GPT3["n_heads"], GPT3["head_dim"], GPT3["d_ff"]
    hl = H // tp                      # heads per TP shard
    M = batch * seq
    ops = [
        _vector("ln1", M * d, flops_per_elem=8.0),
        _matmul("qkv_proj", M, d, 3 * d // tp),
        _matmul("attn_qk", seq, hd, seq, count=batch * hl),
        _vector("softmax", seq * seq * batch * hl, flops_per_elem=6.0),
        _matmul("attn_av", seq, seq, hd, count=batch * hl),
        _matmul("o_proj", M, d // tp, d),
        _allreduce("ar_attn", M * d),
        _vector("ln2", M * d, flops_per_elem=8.0),
        _matmul("mlp_up", M, d, ff // tp),
        _vector("gelu", M * ff // tp, flops_per_elem=8.0),
        _matmul("mlp_down", M, ff // tp, d),
        _allreduce("ar_mlp", M * d),
        _memcpy("kv_write", batch * seq * 2 * hl * hd * BYTES),
    ]
    return Workload(f"gpt3-prefill-b{batch}-s{seq}-tp{tp}", ops, tp=tp)


def gpt3_layer_decode(batch: int = 8, seq: int = 2048, out_pos: int = 1024,
                      tp: int = 8) -> Workload:
    """Time per output token at position `out_pos` (KV length seq+out_pos)."""
    d, H, hd, ff = GPT3["d_model"], GPT3["n_heads"], GPT3["head_dim"], GPT3["d_ff"]
    hl = H // tp
    kv = seq + out_pos
    M = batch                         # one new token per sequence
    ops = [
        _vector("ln1", M * d, flops_per_elem=8.0),
        _matmul("qkv_proj", M, d, 3 * d // tp),
        _memcpy("kv_read", batch * kv * 2 * hl * hd * BYTES),
        Op("attn_gemv", MATMUL, flops=2.0 * batch * hl * kv * hd * 2,
           bytes=batch * hl * (kv * hd * 2 + kv + hd) * BYTES,
           m=batch, n=kv, k=hd, count=1.0),
        _vector("softmax", batch * hl * kv, flops_per_elem=6.0),
        _matmul("o_proj", M, d // tp, d),
        _allreduce("ar_attn", M * d),
        _vector("ln2", M * d, flops_per_elem=8.0),
        _matmul("mlp_up", M, d, ff // tp),
        _vector("gelu", M * ff // tp, flops_per_elem=8.0),
        _matmul("mlp_down", M, ff // tp, d),
        _allreduce("ar_mlp", M * d),
        _memcpy("kv_append", batch * 2 * hl * hd * BYTES),
    ]
    return Workload(f"gpt3-decode-b{batch}-kv{kv}-tp{tp}", ops, tp=tp)


# --------------------------------------------------------------------------
# Assigned-architecture workloads (configs -> operator graphs)
# --------------------------------------------------------------------------

def _attn_block(ops: List[Op], pfx: str, batch: int, q_len: int, kv_len: int,
                d: float, n_heads: int, n_kv: int, head_dim: int, tp: int,
                qkv_bias: bool, count: float, decode: bool) -> None:
    hl = max(1, n_heads // tp)
    kvl = max(1, n_kv // tp)
    M = batch * q_len
    q_n = n_heads * head_dim // tp
    kv_n = 2 * n_kv * head_dim // tp
    ops.append(_matmul(f"{pfx}.qkv", M, d, q_n + kv_n, count=count))
    if decode:
        ops.append(_memcpy(f"{pfx}.kv_read",
                           batch * kv_len * 2 * kvl * head_dim * BYTES, count=count))
        ops.append(Op(f"{pfx}.attn", MATMUL,
                      flops=2.0 * batch * hl * kv_len * head_dim * 2,
                      bytes=batch * hl * (kv_len + head_dim) * BYTES,
                      m=batch, n=kv_len, k=head_dim, count=count))
        ops.append(_vector(f"{pfx}.softmax", batch * hl * kv_len, 6.0, count=count))
        ops.append(_memcpy(f"{pfx}.kv_append", batch * 2 * kvl * head_dim * BYTES,
                           count=count))
    else:
        ops.append(_matmul(f"{pfx}.qk", q_len, head_dim, kv_len, count=count * batch * hl))
        ops.append(_vector(f"{pfx}.softmax", batch * hl * q_len * kv_len, 6.0, count=count))
        ops.append(_matmul(f"{pfx}.av", q_len, kv_len, head_dim, count=count * batch * hl))
        ops.append(_memcpy(f"{pfx}.kv_write",
                           batch * q_len * 2 * kvl * head_dim * BYTES, count=count))
    ops.append(_matmul(f"{pfx}.o", M, n_heads * head_dim // tp, d, count=count))
    ops.append(_allreduce(f"{pfx}.ar", M * d, count=count))


def _ffn_block(ops: List[Op], pfx: str, M: float, d: float, d_ff: float,
               tp: int, gated: bool, count: float) -> None:
    up = (2 if gated else 1) * d_ff // tp
    ops.append(_matmul(f"{pfx}.up", M, d, up, count=count))
    ops.append(_vector(f"{pfx}.act", M * d_ff // tp, 8.0, count=count))
    ops.append(_matmul(f"{pfx}.down", M, d_ff // tp, d, count=count))
    ops.append(_allreduce(f"{pfx}.ar", M * d, count=count))


def _moe_block(ops: List[Op], pfx: str, M: float, d: float, expert_ff: float,
               n_experts: int, top_k: int, n_shared: int, tp: int,
               count: float) -> None:
    """Expert-parallel MoE: router + top-k expert FFNs + shared experts.
    Experts sharded over the TP group (EP=tp); tokens all-to-all'd."""
    ops.append(_matmul(f"{pfx}.router", M, d, n_experts, count=count))
    ops.append(_vector(f"{pfx}.route_topk", M * n_experts, 4.0, count=count))
    # all-to-all dispatch+combine approximated as two p2p rounds of the
    # activated token payload
    payload = M * top_k * d * BYTES
    ops.append(Op(f"{pfx}.a2a_dispatch", P2P, comm_bytes=payload, count=count))
    # expert FFN: M*top_k tokens spread over tp shards -> per-shard M_eff
    m_eff = M * top_k / tp
    ops.append(_matmul(f"{pfx}.exp_up", m_eff, d, 2 * expert_ff, count=count))
    ops.append(_vector(f"{pfx}.exp_act", m_eff * expert_ff, 8.0, count=count))
    ops.append(_matmul(f"{pfx}.exp_down", m_eff, expert_ff, d, count=count))
    ops.append(Op(f"{pfx}.a2a_combine", P2P, comm_bytes=payload, count=count))
    if n_shared:
        _ffn_block(ops, f"{pfx}.shared", M, d, expert_ff * n_shared, tp,
                   gated=True, count=count)


def _ssm_block(ops: List[Op], pfx: str, batch: int, q_len: float, d: float,
               d_state: int, tp: int, count: float, decode: bool) -> None:
    """Mamba-style selective-scan block (memory/vector bound)."""
    d_in = 2 * d  # expansion factor 2
    M = batch * q_len
    ops.append(_matmul(f"{pfx}.in_proj", M, d, 2 * d_in // tp, count=count))
    ops.append(_vector(f"{pfx}.conv1d", M * d_in // tp, 8.0, count=count))
    # selective scan: state (d_in/tp, d_state) per token; flops ~ 6*d_in*d_state
    scan_elems = M * (d_in // tp) * d_state
    ops.append(Op(f"{pfx}.scan", VECTOR, flops=6.0 * scan_elems,
                  bytes=(2.0 if decode else 3.0) * M * (d_in // tp) * BYTES
                  + 2 * batch * (d_in // tp) * d_state * BYTES,
                  count=count))
    ops.append(_matmul(f"{pfx}.out_proj", M, d_in // tp, d, count=count))
    ops.append(_allreduce(f"{pfx}.ar", M * d, count=count))


def _rwkv_block(ops: List[Op], pfx: str, batch: int, q_len: float, d: float,
                d_ff: float, tp: int, count: float, decode: bool) -> None:
    """RWKV6 time-mix (data-dependent decay WKV recurrence) + channel-mix."""
    M = batch * q_len
    head = 64
    n_heads = d // head
    ops.append(_matmul(f"{pfx}.rkvwg", M, d, 5 * d // tp, count=count))
    # WKV recurrence: per token, per head, a (head x head) state update:
    # flops ~ 4 * d * head ; state bytes traffic dominates at decode
    ops.append(Op(f"{pfx}.wkv", VECTOR,
                  flops=4.0 * M * (d // tp) * head,
                  bytes=(2 * batch * (n_heads // max(1, tp)) * head * head
                         + 4 * M * d // tp) * BYTES,
                  count=count))
    ops.append(_matmul(f"{pfx}.out", M, d // tp, d, count=count))
    ops.append(_allreduce(f"{pfx}.ar_tm", M * d, count=count))
    ops.append(_matmul(f"{pfx}.cm_up", M, d, d_ff // tp, count=count))
    ops.append(_vector(f"{pfx}.cm_act", M * d_ff // tp, 8.0, count=count))
    ops.append(_matmul(f"{pfx}.cm_down", M, d_ff // tp, d, count=count))
    ops.append(_allreduce(f"{pfx}.ar_cm", M * d, count=count))


def from_arch(cfg, batch: int, seq: int, tp: int = 8, decode: bool = False,
              kv_len: Optional[int] = None) -> Workload:
    """Operator graph for an assigned ArchConfig (repro.configs schema).

    decode=False: prefill of `seq` tokens.  decode=True: one new token with a
    KV/state history of `kv_len` (default `seq`).
    """
    kv_len = kv_len or seq
    q_len = 1 if decode else seq
    d = cfg.d_model
    M = batch * q_len
    ops: List[Op] = []

    n_layers = cfg.n_layers
    fam = cfg.family

    # embeddings / logits (vocab matmul is TP-sharded on vocab)
    ops.append(_memcpy("embed", M * d * BYTES))

    if fam == "ssm":  # rwkv6
        ops.append(_vector("ln_all", 2 * M * d * n_layers / n_layers, 8.0, count=n_layers))
        _rwkv_block(ops, "rwkv", batch, q_len, d, cfg.d_ff, tp,
                    count=n_layers, decode=decode)
    elif fam == "hybrid":  # jamba: 1 attention per `attn_every` layers, MoE every 2nd
        n_attn = n_layers // cfg.attn_every
        n_mamba = n_layers - n_attn
        n_moe = n_layers // 2
        n_dense = n_layers - n_moe
        ops.append(_vector("ln_all", 2 * M * d, 8.0, count=n_layers))
        _attn_block(ops, "attn", batch, q_len, kv_len, d, cfg.n_heads,
                    cfg.n_kv_heads, cfg.head_dim, tp, cfg.qkv_bias,
                    count=n_attn, decode=decode)
        _ssm_block(ops, "mamba", batch, q_len, d, cfg.d_state, tp,
                   count=n_mamba, decode=decode)
        _moe_block(ops, "moe", M, d, cfg.expert_ff, cfg.n_experts,
                   cfg.top_k, cfg.n_shared_experts, tp, count=n_moe)
        _ffn_block(ops, "ffn", M, d, cfg.d_ff, tp, gated=True, count=n_dense)
    else:
        # transformer families: dense / moe / vlm / audio (backbone only)
        enc_layers = getattr(cfg, "enc_layers", 0)
        if enc_layers and not decode:
            # encoder runs full self-attention over its own context
            enc_ctx = getattr(cfg, "enc_ctx", 1500)
            _attn_block(ops, "enc.attn", batch, enc_ctx, enc_ctx, d,
                        cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, tp,
                        cfg.qkv_bias, count=enc_layers, decode=False)
            _ffn_block(ops, "enc.ffn", batch * enc_ctx, d, cfg.d_ff, tp,
                       gated=False, count=enc_layers)
        ops.append(_vector("ln_all", 2 * M * d, 8.0, count=n_layers))
        _attn_block(ops, "attn", batch, q_len, kv_len, d, cfg.n_heads,
                    cfg.n_kv_heads, cfg.head_dim, tp, cfg.qkv_bias,
                    count=n_layers, decode=decode)
        if enc_layers:
            # cross-attention in every decoder layer (enc-dec only)
            enc_ctx = getattr(cfg, "enc_ctx", 1500)
            _attn_block(ops, "xattn", batch, q_len, enc_ctx, d, cfg.n_heads,
                        cfg.n_kv_heads, cfg.head_dim, tp, cfg.qkv_bias,
                        count=n_layers, decode=decode)
        if fam == "moe":
            _moe_block(ops, "moe", M, d, cfg.expert_ff, cfg.n_experts,
                       cfg.top_k, cfg.n_shared_experts, tp, count=n_layers)
            if getattr(cfg, "dense_residual", False):
                _ffn_block(ops, "ffn", M, d, cfg.d_ff, tp, gated=True,
                           count=n_layers)
        else:
            _ffn_block(ops, "ffn", M, d, cfg.d_ff, tp, gated=cfg.gated_mlp,
                       count=n_layers)

    ops.append(_matmul("logits", M, d, cfg.vocab // tp))
    mode = "decode" if decode else "prefill"
    return Workload(f"{cfg.name}-{mode}-b{batch}-s{seq}-kv{kv_len}-tp{tp}",
                    ops, tp=tp)


# --------------------------------------------------------------------------
# Stacked-workload representation: the deduped union of many op tables
# --------------------------------------------------------------------------

# fields that define an op's identity for dedup (count is multiplicity and
# lives in the count matrix; name is presentation-only)
STACK_KEY_FIELDS = ("kind", "flops", "bytes", "m", "n", "k", "comm_bytes",
                    "tp")


@dataclasses.dataclass(frozen=True)
class WorkloadStack:
    """Flat union of W workloads' op tables with cross-workload dedup.

    ``unique`` holds one row per distinct ``STACK_KEY_FIELDS`` tuple across
    all workloads (first-occurrence order).  Per workload, ``op_map`` gathers
    its ops (in original op order) out of the union and ``counts`` carries
    its own multiplicities, so a model that evaluates the union ONCE can
    reassemble every workload's per-op outputs bit-identically — the
    representation behind the stacked evaluator path and the portfolio
    sweep.  ``count_matrix[w, u]`` aggregates workload w's total count of
    unique op u (duplicate rows within one workload sum).
    """
    names: Tuple[str, ...]
    unique: Dict[str, np.ndarray]            # field -> (n_unique,)
    op_map: Dict[str, np.ndarray]            # name -> (n_ops_w,) int32
    counts: Dict[str, np.ndarray]            # name -> (n_ops_w,) float64
    count_matrix: np.ndarray                 # (W, n_unique) float64

    @property
    def n_unique(self) -> int:
        return int(self.count_matrix.shape[1])

    @property
    def total_ops(self) -> int:
        return sum(m.shape[0] for m in self.op_map.values())

    @classmethod
    def build(cls, workloads: Mapping[str, "Workload"]) -> "WorkloadStack":
        names = tuple(workloads)
        uniq: Dict[tuple, int] = {}
        rows: List[tuple] = []
        op_map: Dict[str, np.ndarray] = {}
        counts: Dict[str, np.ndarray] = {}
        per_wl_keys: Dict[str, List[tuple]] = {}
        for nm in names:
            a = workloads[nm].arrays()
            keys = [tuple(a[f][i] for f in STACK_KEY_FIELDS)
                    for i in range(len(a["count"]))]
            per_wl_keys[nm] = keys
            pos = np.empty(len(keys), dtype=np.int32)
            for i, key in enumerate(keys):
                u = uniq.get(key)
                if u is None:
                    u = uniq[key] = len(rows)
                    rows.append(key)
                pos[i] = u
            op_map[nm] = pos
            counts[nm] = np.asarray(a["count"], dtype=np.float64)
        unique = {
            f: np.array([r[j] for r in rows],
                        dtype=np.int32 if f == "kind" else np.float64)
            for j, f in enumerate(STACK_KEY_FIELDS)
        }
        cmat = np.zeros((len(names), len(rows)), dtype=np.float64)
        for w, nm in enumerate(names):
            np.add.at(cmat[w], op_map[nm], counts[nm])
        return cls(names=names, unique=unique, op_map=op_map, counts=counts,
                   count_matrix=cmat)


# --------------------------------------------------------------------------
# Workload suites: named (prefill, decode) scenario pairs
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Scenario:
    """One latency scenario: a (prefill, decode) workload pair whose
    objective triple is ``[prefill_latency, decode_latency, area]`` — the
    portfolio generalization of the paper's (ttft, tpot, area)."""
    name: str
    prefill: str                 # workload key of the prefill objective
    decode: str                  # workload key of the decode objective


def paper_suite() -> Tuple[Dict[str, "Workload"], Tuple[Scenario, ...]]:
    """The paper's GPT-3 pair as a one-scenario suite."""
    wls = {"ttft": gpt3_layer_prefill(), "tpot": gpt3_layer_decode()}
    return wls, (Scenario("gpt3", "ttft", "tpot"),)


def zoo_suite(batch: int = 8, seq: int = 2048, tp: int = 8,
              out_pos: int = 1024, smoke: bool = False,
              archs: Optional[Tuple[str, ...]] = None,
              ) -> Tuple[Dict[str, "Workload"], Tuple[Scenario, ...]]:
    """Every assigned architecture config as a DSE scenario.

    Each arch contributes a ``<arch>:prefill`` + ``<arch>:decode`` workload
    pair (decode at KV length ``seq + out_pos``, mirroring the paper's TPOT
    operating point).  ``smoke=True`` shrinks every config via
    ``ArchConfig.smoke()`` for CPU-cheap tests; ``archs`` restricts to a
    subset of config names.
    """
    from repro.configs import ARCHS           # leaf import (no cycle)
    wls: Dict[str, Workload] = {}
    scenarios: List[Scenario] = []
    for name in sorted(archs if archs is not None else ARCHS):
        cfg = ARCHS[name]
        if smoke:
            cfg = cfg.smoke()
        wls[f"{name}:prefill"] = from_arch(cfg, batch, seq, tp=tp,
                                           decode=False)
        wls[f"{name}:decode"] = from_arch(cfg, batch, seq, tp=tp,
                                          decode=True, kv_len=seq + out_pos)
        scenarios.append(Scenario(name, f"{name}:prefill", f"{name}:decode"))
    return wls, tuple(scenarios)
