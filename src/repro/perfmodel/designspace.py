"""The GPU-node design space of the paper (Table 1).

Each design point is an 8-vector of *choice indices* (int32), one per
parameter, in the canonical order of :data:`PARAM_NAMES`.  Index-space is the
representation used everywhere (search algorithms, trajectory memory, the
Pallas ``ppa_eval`` kernel); :meth:`DesignSpace.decode` maps indices to
physical values.

Total cardinality: 4 * 14 * 4 * 6 * 6 * 7 * 7 * 12 = 4,741,632  (~4.7M,
matching the paper).

:data:`PARAM_NAMES` is also the parameter universe of the influence graph
:mod:`repro.analysis.influence` extracts from the perfmodel source (every
graph edge chain starts at one of these names).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence

import numpy as np
import jax.numpy as jnp

# Canonical parameter order.  KEEP STABLE: trajectory memory, the DSE
# benchmark generator and the Pallas kernel all index by position.
PARAM_NAMES: tuple = (
    "link_count",        # interconnect links per GPU
    "core_count",        # number of cores (SM / TensorCore-tile analogue)
    "sublane_count",     # sublanes per core (each has one systolic array slice)
    "sa_dim",            # systolic array height == width (square, Table 4)
    "vector_width",      # vector-unit lanes per sublane
    "sram_kb",           # per-core SRAM (VMEM slice) in KB
    "gbuf_mb",           # total global buffer (L2/CMEM analogue) in MB
    "mem_channels",      # HBM memory channel count
)

PARAM_CHOICES: Dict[str, tuple] = {
    "link_count": (6, 12, 18, 24),
    "core_count": (1, 2, 4, 8, 16, 32, 64, 96, 108, 128, 132, 136, 140, 256),
    "sublane_count": (1, 2, 4, 8),
    "sa_dim": (4, 8, 16, 32, 64, 128),
    "vector_width": (4, 8, 16, 32, 64, 128),
    "sram_kb": (32, 64, 128, 192, 256, 512, 1024),
    "gbuf_mb": (32, 64, 128, 256, 320, 512, 1024),
    "mem_channels": tuple(range(1, 13)),
}

# NVIDIA A100 reference design (paper Table 4 rightmost column).  Note the
# 40 MB global buffer is intentionally *outside* the searchable choice list —
# the reference point need not be a member of the design space.
A100_REFERENCE: Dict[str, int] = {
    "link_count": 12,
    "core_count": 108,
    "sublane_count": 4,
    "sa_dim": 16,
    "vector_width": 32,
    "sram_kb": 128,
    "gbuf_mb": 40,
    "mem_channels": 5,
}

# Paper Table 4, designs A and B discovered by Lumina.
DESIGN_A: Dict[str, int] = {
    "link_count": 24, "core_count": 64, "sublane_count": 4, "sa_dim": 32,
    "vector_width": 16, "sram_kb": 128, "gbuf_mb": 40, "mem_channels": 6,
}
DESIGN_B: Dict[str, int] = {
    "link_count": 18, "core_count": 96, "sublane_count": 4, "sa_dim": 32,
    "vector_width": 16, "sram_kb": 128, "gbuf_mb": 40, "mem_channels": 6,
}


@dataclasses.dataclass(frozen=True)
class DesignSpace:
    """Index-coded categorical design space."""

    names: tuple = PARAM_NAMES
    choices: tuple = tuple(PARAM_CHOICES[n] for n in PARAM_NAMES)

    @property
    def n_params(self) -> int:
        return len(self.names)

    @property
    def cardinalities(self) -> np.ndarray:
        return np.array([len(c) for c in self.choices], dtype=np.int64)

    @property
    def size(self) -> int:
        return int(np.prod(self.cardinalities))

    # ---- choice tables, padded to a rectangle for vectorized decode ----
    def choice_table(self) -> np.ndarray:
        """(n_params, max_choices) float64 table; padded with the last value."""
        k = int(self.cardinalities.max())
        tab = np.zeros((self.n_params, k), dtype=np.float64)
        for i, ch in enumerate(self.choices):
            tab[i, : len(ch)] = ch
            tab[i, len(ch):] = ch[-1]
        return tab

    # ---------------- encode / decode ----------------
    def encode(self, values: Dict[str, int]) -> np.ndarray:
        """Physical value dict -> index vector. Values must be exact members."""
        idx = np.zeros(self.n_params, dtype=np.int32)
        for i, name in enumerate(self.names):
            ch = self.choices[i]
            v = values[name]
            if v not in ch:
                raise ValueError(f"{name}={v} not in design space choices {ch}")
            idx[i] = ch.index(v)
        return idx

    def encode_nearest(self, values: Dict[str, int]) -> np.ndarray:
        """Like encode but snaps to the nearest choice (used for references
        that sit outside the space, e.g. the A100's 40 MB global buffer)."""
        idx = np.zeros(self.n_params, dtype=np.int32)
        for i, name in enumerate(self.names):
            ch = np.asarray(self.choices[i], dtype=np.float64)
            idx[i] = int(np.abs(ch - values[name]).argmin())
        return idx

    def decode(self, idx) -> Dict[str, jnp.ndarray]:
        """Index vectors -> dict of physical value arrays.

        ``idx`` may be shape (n_params,) or (batch, n_params); outputs follow.
        Fully traceable (gather from the padded choice table).
        """
        idx = jnp.asarray(idx)
        tab = jnp.asarray(self.choice_table())
        vals = tab[jnp.arange(self.n_params), idx.astype(jnp.int32)]
        return {name: vals[..., i] for i, name in enumerate(self.names)}

    def decode_np(self, idx: np.ndarray) -> Dict[str, np.ndarray]:
        idx = np.asarray(idx)
        tab = self.choice_table()
        vals = tab[np.arange(self.n_params), idx.astype(np.int64)]
        return {name: vals[..., i] for i, name in enumerate(self.names)}

    # ---------------- sampling / enumeration ----------------
    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """Uniform random index vectors, shape (n, n_params)."""
        cards = self.cardinalities
        cols = [rng.integers(0, c, size=n, dtype=np.int32) for c in cards]
        return np.stack(cols, axis=1)

    def flat_to_idx(self, flat: np.ndarray) -> np.ndarray:
        """Mixed-radix unrank: flat id in [0, size) -> index vector(s)."""
        flat = np.asarray(flat, dtype=np.int64)
        out = np.zeros(flat.shape + (self.n_params,), dtype=np.int32)
        rem = flat.copy()
        for i in range(self.n_params - 1, -1, -1):
            c = int(self.cardinalities[i])
            out[..., i] = rem % c
            rem //= c
        return out

    def idx_to_flat(self, idx: np.ndarray) -> np.ndarray:
        idx = np.asarray(idx, dtype=np.int64)
        flat = np.zeros(idx.shape[:-1], dtype=np.int64)
        for i in range(self.n_params):
            flat = flat * int(self.cardinalities[i]) + idx[..., i]
        return flat

    def clip(self, idx: np.ndarray) -> np.ndarray:
        """Clamp index vectors into valid ranges (after mutation steps)."""
        hi = (self.cardinalities - 1)[None, :] if np.asarray(idx).ndim == 2 else self.cardinalities - 1
        return np.clip(idx, 0, hi).astype(np.int32)

    def neighbors(self, idx: np.ndarray) -> np.ndarray:
        """All +-1-step neighbors of one design (for QuanE sensitivity and
        RW moves). Returns (m, n_params)."""
        idx = np.asarray(idx, dtype=np.int32)
        out = []
        for i in range(self.n_params):
            for d in (-1, +1):
                j = idx.copy()
                j[i] += d
                if 0 <= j[i] < self.cardinalities[i]:
                    out.append(j)
        return np.stack(out, axis=0)


SPACE = DesignSpace()
