"""LLMCompass-style higher-fidelity analytical model.

Same vectorized evaluation core as the roofline model, plus the effects the
LLMCompass simulator captures and the pure roofline misses:

* fixed per-op launch/setup overhead (kernel launch + tile scheduling);
* imperfect overlap between compute and memory streams (a fraction of the
  minor term is exposed);
* achievable (not peak) HBM efficiency;
* collective software overhead.

The paper treats LLMCompass as the expensive, high-fidelity tier (20-sample
budget, ~1 week); here both tiers are cheap, but the *relative* fidelity gap
and the distinct bottleneck landscapes are preserved, which is what the DSE
methodology exercises.
"""
from __future__ import annotations

from repro.perfmodel.roofline import RooflineModel


class CompassModel(RooflineModel):
    """Knobs calibrated against the paper's Table 4 (grid search over
    physically-plausible ranges; see tests/test_perfmodel.py):

        normalized TTFT   Design A: 0.7174 (paper 0.717)
                          Design B: 0.5955 (paper 0.592)
        normalized TPOT   Design A: 0.897  (paper 0.947)
                          Design B: 0.895  (paper 0.948)
        normalized area   Design A: 0.772  (paper 0.772)
                          Design B: 0.962  (paper 0.952)
    """
    op_overhead_s = 2.0e-5     # per-op launch + TP-group sync/setup
    nonoverlap = 0.5           # minor-term exposure (no double buffering)
    mem_efficiency = 0.85      # achievable HBM fraction
