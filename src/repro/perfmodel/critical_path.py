"""Critical-path / stall attribution (the paper's extension of LLMCompass).

``attribute_stalls`` reduces a model evaluation into the structured
critical-path feedback the Strategy Engine consumes: per-stall-class times,
the dominant stall, and the top offending operators.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

STALL_CLASSES = ("tensor_compute", "vector_compute", "memory_bw", "interconnect")


@dataclasses.dataclass
class StallReport:
    """Critical-path feedback for ONE design point."""
    stall_seconds: Dict[str, float]          # per-class attributed time
    dominant: str                            # argmax class
    dominant_fraction: float                 # its share of total latency
    top_ops: List[tuple]                     # [(op_name, class, seconds)] desc
    latency: float
    area: float

    def as_prompt(self) -> str:
        """Serialize the way the simulator feedback is presented to the LLM."""
        lines = [f"total_latency={self.latency:.6e}s area={self.area:.1f}mm2",
                 "stall breakdown:"]
        for c in STALL_CLASSES:
            lines.append(f"  {c}: {self.stall_seconds[c]:.6e}s"
                         f" ({self.stall_seconds[c] / max(self.latency, 1e-30):.1%})")
        lines.append(f"dominant stall: {self.dominant}"
                     f" ({self.dominant_fraction:.1%} of latency)")
        lines.append("top ops: " + ", ".join(
            f"{nm}[{cl}]={t:.3e}s" for nm, cl, t in self.top_ops))
        return "\n".join(lines)


def build_report(latency: float, area: float, stall: np.ndarray,
                 op_time: np.ndarray, op_class: np.ndarray,
                 op_names, top: int = 5) -> StallReport:
    """Assemble a :class:`StallReport` from one design's evaluated arrays.

    The single report-construction path shared by the legacy
    :func:`attribute_stalls` and :meth:`repro.perfmodel.evaluator.PPAReport.
    stall_report`.
    """
    latency = float(latency)
    order = np.argsort(op_time)[::-1][:top]
    top_ops = [(op_names[i], STALL_CLASSES[int(op_class[i])],
                float(op_time[i])) for i in order]
    per = {c: float(stall[i]) for i, c in enumerate(STALL_CLASSES)}
    dom_i = int(np.argmax(stall))
    return StallReport(
        stall_seconds=per,
        dominant=STALL_CLASSES[dom_i],
        dominant_fraction=float(stall[dom_i] / max(latency, 1e-30)),
        top_ops=top_ops,
        latency=latency,
        area=float(area),
    )


def attribute_stalls(model, idx: np.ndarray, top: int = 5) -> StallReport:
    """Evaluate one design and produce its critical-path report.

    Convenience wrapper over the unified Evaluator contract: the model is
    wrapped in a (memoized) single-workload evaluator, so repeated calls
    share its fused jit cache with every other consumer.
    """
    from repro.perfmodel.evaluator import evaluator_for_model
    rep = evaluator_for_model(model).stalls(np.atleast_2d(idx))
    return rep.stall_report(i=0, top=top)
