"""Analytical accelerator PPA models behind ONE service boundary (the
paper's "simulation environment").

Every consumer — the Lumina DSE loop, QualE/QuanE acquisition, the five
black-box baselines, the full-space sweep, the DSE Benchmark generator and
every ``benchmarks/*`` module — evaluates designs through the **unified
tiered Evaluator API** of :mod:`repro.perfmodel.evaluator`:

* :class:`~repro.perfmodel.evaluator.EvalRequest` — design-index batch +
  workload subset + detail level (``objectives`` | ``ppa`` | ``stalls``);
* :class:`~repro.perfmodel.evaluator.PPAReport` — the structured result
  (per-workload latencies, area, stall attribution, per-op breakdown);
* :func:`~repro.perfmodel.evaluator.get_evaluator` — the paper's GPT-3
  workload evaluator per fidelity **tier**:

  =========  ==========================================================
  ``proxy``   fast roofline models (paper Fig. 1/4/5) — acquisition tier
  ``target``  LLMCompass-calibrated models (paper §5.3, Table 4) — the
              budgeted high-fidelity tier
  ``oracle``  the exhaustive 4.7M-point sweep front
              (:class:`~repro.perfmodel.evaluator.OracleEvaluator`) for
              exact regret / PHV normalization
  =========  ==========================================================

* a **backend registry** (``roofline`` | ``compass`` | ``pallas`` with
  ``backend="auto"`` benchmark-driven selection) choosing the compute
  substrate independently of the tier;
* **workload suites** (``get_evaluator(suite="paper" | "zoo")``): the
  GPT-3 pair, or every assigned architecture config as a
  :class:`~repro.perfmodel.workload.Scenario` — all workloads stacked
  into one deduped op union (:class:`~repro.perfmodel.workload.
  WorkloadStack`) so a single dispatch (and a single sweep pass) scores
  the whole zoo.

The evaluator's traced path is *fused*: one jitted dispatch decodes the
batch, derives hardware once, and evaluates every workload (TTFT + TPOT +
stall attribution).  The request is batched end to end — K parallel
campaigns' candidates ride one dispatch (see
:class:`~repro.core.campaign.CampaignRunner`).  The pre-PR-2 per-model
shims (``eval_ppa`` / ``objectives`` / pair signatures) have been removed.

Supporting pieces:

* :mod:`repro.perfmodel.roofline`   — roofline op-term model (shared core).
* :mod:`repro.perfmodel.compass`    — LLMCompass-style per-op-overhead tier.
* :mod:`repro.perfmodel.designspace` — the 4.7M-point design space (Table 1).
* :mod:`repro.perfmodel.hardware`    — design point -> derived hardware spec
  (throughputs, bandwidths, area), calibrated against NVIDIA A100.
* :mod:`repro.perfmodel.workload`    — operator graphs (GPT-3 layer and every
  assigned architecture) for TTFT / TPOT evaluation.
* :mod:`repro.perfmodel.critical_path` — per-op stall attribution (the
  paper's critical-path extension of LLMCompass).
* :mod:`repro.perfmodel.sweep`       — streaming full-space sweep engine
  (the oracle tier's substrate; also emits per-stall-class seed designs;
  ``run(workers=N)`` shards the id range with an exact host merge).
* :mod:`repro.distributed`           — the service layer above this one:
  :class:`~repro.distributed.sharded.ShardedEvaluator` fans one request
  across worker pools (``get_evaluator(..., workers=N)``) and
  :class:`~repro.distributed.service.EvalService` coalesces concurrent
  clients into one fused dispatch per tick.
"""

from repro.perfmodel.designspace import DesignSpace, A100_REFERENCE
from repro.perfmodel.hardware import derive_hardware, area_mm2
from repro.perfmodel.workload import (Workload, Op, WorkloadStack, Scenario,
                                      gpt3_layer_prefill, gpt3_layer_decode,
                                      from_arch, paper_suite, zoo_suite)
from repro.perfmodel.roofline import RooflineModel, stacked_workload_batches
from repro.perfmodel.compass import CompassModel
from repro.perfmodel.critical_path import attribute_stalls, STALL_CLASSES
from repro.perfmodel.evaluator import (Evaluator, EvalRequest, PPAReport,
                                       ModelEvaluator, OracleEvaluator,
                                       RowCache, get_evaluator,
                                       make_evaluator, as_evaluator,
                                       pair_view, register_backend,
                                       backend_names, TIERS, DETAILS, SUITES)
from repro.perfmodel.sweep import SweepEngine, SweepResult

__all__ = [
    "DesignSpace", "A100_REFERENCE", "derive_hardware", "area_mm2",
    "Workload", "Op", "WorkloadStack", "Scenario",
    "gpt3_layer_prefill", "gpt3_layer_decode", "from_arch",
    "paper_suite", "zoo_suite",
    "RooflineModel", "CompassModel", "stacked_workload_batches",
    "attribute_stalls", "STALL_CLASSES",
    "Evaluator", "EvalRequest", "PPAReport", "ModelEvaluator",
    "OracleEvaluator", "RowCache", "get_evaluator", "make_evaluator",
    "as_evaluator", "pair_view", "register_backend", "backend_names",
    "TIERS", "DETAILS", "SUITES",
    "SweepEngine", "SweepResult",
]
