"""Analytical accelerator performance/area models (the paper's "simulation environment").

Two fidelity tiers, both fully vectorized over design points in JAX:

* :mod:`repro.perfmodel.roofline`  — fast roofline model (paper Fig. 1/4/5).
* :mod:`repro.perfmodel.compass`   — LLMCompass-style tile-level analytical
  model with per-op overheads and utilization effects (paper §5.3, Table 4).

Supporting pieces:

* :mod:`repro.perfmodel.designspace` — the 4.7M-point design space (Table 1).
* :mod:`repro.perfmodel.hardware`    — design point -> derived hardware spec
  (throughputs, bandwidths, area), calibrated against NVIDIA A100.
* :mod:`repro.perfmodel.workload`    — operator graphs (GPT-3 layer and every
  assigned architecture) for TTFT / TPOT evaluation.
* :mod:`repro.perfmodel.critical_path` — per-op stall attribution (the
  paper's critical-path extension of LLMCompass).
"""

from repro.perfmodel.designspace import DesignSpace, A100_REFERENCE
from repro.perfmodel.hardware import derive_hardware, area_mm2
from repro.perfmodel.workload import Workload, Op, gpt3_layer_prefill, gpt3_layer_decode
from repro.perfmodel.roofline import RooflineModel
from repro.perfmodel.compass import CompassModel
from repro.perfmodel.critical_path import attribute_stalls, STALL_CLASSES
from repro.perfmodel.sweep import SweepEngine, SweepResult, make_paper_evaluator

__all__ = [
    "DesignSpace", "A100_REFERENCE", "derive_hardware", "area_mm2",
    "Workload", "Op", "gpt3_layer_prefill", "gpt3_layer_decode",
    "RooflineModel", "CompassModel", "attribute_stalls", "STALL_CLASSES",
    "SweepEngine", "SweepResult", "make_paper_evaluator",
]
