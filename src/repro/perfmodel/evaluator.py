"""Unified tiered Evaluator API: ONE PPA contract for every consumer.

The paper's whole pipeline — QualE/QuanE acquisition, bottleneck analysis,
the 20-step DSE loop, the Table 2/3 baselines and the DSE Benchmark — hangs
off a single notion: *evaluate a batch of designs under a workload set at
some fidelity tier*.  This module is that service boundary:

* :class:`EvalRequest`  — design-index batch + workload subset + detail
  level (``objectives`` | ``ppa`` | ``stalls``);
* :class:`PPAReport`    — the structured result pytree (per-workload
  latencies, area, stall attribution, per-op breakdown) with
  :meth:`PPAReport.stall_report` bridging to the Strategy Engine;
* :class:`ModelEvaluator` — the analytical-model implementation with a
  **fused multi-workload traced path**: TTFT, TPOT (and stall attribution)
  are evaluated in ONE jitted dispatch per step — the space decode and
  hardware derivation run once per batch and every workload's op terms are
  computed inside the same XLA executable, instead of two-to-four separate
  model calls.  Compiled executables live in the same workload-keyed jit
  cache the models use, so every evaluator in a process shares them.
* a **backend registry** (``roofline`` | ``compass`` | ``pallas``) with
  benchmark-driven auto-selection (``backend="auto"`` times the candidates
  on a probe batch and keeps the fastest for this process);
* **tiers**: ``proxy`` (cheap roofline acquisition tier), ``target``
  (LLMCompass-calibrated budgeted tier) and ``oracle`` — the exhaustive
  :class:`~repro.perfmodel.sweep.SweepEngine` front wrapped as
  :class:`OracleEvaluator`, serving exact regret / PHV normalization.

The request shape is batched end to end: ``EvalRequest.idx`` may carry any
number of designs — K parallel campaigns' candidates ride ONE fused
dispatch and :meth:`PPAReport.stall_report` extracts any row's
critical-path view (the multi-design path behind
:class:`~repro.core.campaign.CampaignRunner`).

The pre-PR-2 per-model shims (``eval_ppa`` / ``objectives`` / the
``(ttft_model, tpot_model)`` pair threading) are gone after their
one-release deprecation window.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from typing import Callable, Dict, Mapping, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.perfmodel.critical_path import StallReport, build_report
from repro.perfmodel.designspace import DesignSpace, SPACE
from repro.perfmodel.hardware import derive_hardware
from repro.perfmodel.roofline import (RooflineModel, _JIT_CACHE,
                                      _bucketed_call, _space_key,
                                      _workload_fingerprint,
                                      stacked_workload_batches)
from repro.perfmodel.workload import Scenario, WorkloadStack

DETAILS = ("objectives", "ppa", "stalls")
TIERS = ("proxy", "target", "oracle")
SUITES = ("paper", "zoo")

_DETAIL_LEVEL = {name: i for i, name in enumerate(DETAILS)}


# ---------------------------------------------------------------------------
# request / report
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EvalRequest:
    """One evaluation call: design-index batch, workload subset, detail.

    idx:       (n, n_params) int32 choice-index vectors (or a single vector).
    detail:    "objectives" (latency per workload + area, lean traced path),
               "ppa" (adds the per-op time breakdown),
               "stalls" (adds per-stall-class attribution + per-op classes).
    workloads: subset of the evaluator's workload names; None = all.
    """
    idx: np.ndarray
    detail: str = "objectives"
    workloads: Optional[Tuple[str, ...]] = None

    def __post_init__(self):
        if self.detail not in DETAILS:
            raise ValueError(f"detail must be one of {DETAILS}, "
                             f"got {self.detail!r}")


@dataclasses.dataclass
class PPAReport:
    """Structured PPA result: a host-side pytree of numpy arrays.

    objectives follow the repo convention ``[*latencies, area]`` in workload
    order — for the paper workloads that is ``[ttft, tpot, area]``.
    """
    workloads: Tuple[str, ...]
    detail: str
    area: np.ndarray                                # (n,)
    latency: Dict[str, np.ndarray]                  # workload -> (n,)
    stall: Optional[Dict[str, np.ndarray]] = None   # workload -> (n, 4)
    op_time: Optional[Dict[str, np.ndarray]] = None
    op_class: Optional[Dict[str, np.ndarray]] = None
    op_names: Optional[Dict[str, tuple]] = None

    @property
    def n(self) -> int:
        return int(self.area.shape[0])

    @property
    def objectives(self) -> np.ndarray:
        """(n, len(workloads) + 1) objective matrix [*latencies, area]."""
        cols = [self.latency[w] for w in self.workloads] + [self.area]
        return np.stack(cols, axis=1)

    def stall_report(self, workload: Optional[str] = None, i: int = 0,
                     top: int = 5) -> StallReport:
        """Critical-path report for design row `i` on one workload."""
        if self.detail != "stalls":
            raise ValueError(
                f"stall_report needs detail='stalls', have {self.detail!r}")
        w = workload if workload is not None else self.workloads[0]
        return build_report(
            self.latency[w][i], self.area[i], self.stall[w][i],
            self.op_time[w][i], self.op_class[w][i], self.op_names[w],
            top=top)

    def stall_reports(self, i: int = 0, top: int = 5) -> Dict[str, StallReport]:
        return {w: self.stall_report(w, i, top) for w in self.workloads}

    def row(self, i: int) -> "PPAReport":
        """Single-design view of batch row `i` — the slicing half of the
        batched multi-design path (one fused dispatch, per-design reads)."""
        def sl(d):
            return {nm: v[i:i + 1] for nm, v in d.items()} if d else None
        return PPAReport(
            workloads=self.workloads, detail=self.detail,
            area=self.area[i:i + 1],
            latency={nm: self.latency[nm][i:i + 1] for nm in self.workloads},
            stall=sl(self.stall), op_time=sl(self.op_time),
            op_class=sl(self.op_class), op_names=self.op_names)


class Evaluator(Protocol):
    """The one PPA contract: everything downstream programs against this."""
    space: DesignSpace
    workloads: Tuple[str, ...]
    tier: str

    def evaluate(self, request: EvalRequest) -> PPAReport: ...

    def objectives(self, idx: np.ndarray) -> np.ndarray: ...


# ---------------------------------------------------------------------------
# shared per-design report-row cache
# ---------------------------------------------------------------------------

class RowCache:
    """Bounded LRU of single-design :class:`PPAReport` rows.

    THE report cache: :class:`~repro.distributed.service.EvalService` shares
    one instance across all its clients, and :class:`~repro.core.explore.
    ExplorationEngine` uses the service's instance when its evaluator IS a
    service (one cache, not two) or a private one otherwise.

    Entries are keyed by the design row's index bytes and hold the
    highest-detail report seen for that design.  A lookup hits only when the
    cached detail covers the requested level AND the cached report covers
    the requested workloads — a pair-only row never masquerades as a
    full-suite one.  Eviction is strictly LRU (hot rows are touched on every
    hit, so a campaign's base design survives any number of colder
    evictions).  Thread-safe.
    """

    def __init__(self, capacity: int = 65_536):
        self.capacity = int(capacity)
        self._lock = threading.RLock()
        self._d: "OrderedDict[bytes, Tuple[int, PPAReport]]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._d)

    @staticmethod
    def key(row: np.ndarray) -> bytes:
        return np.ascontiguousarray(row, dtype=np.int32).tobytes()

    def get(self, key: bytes, detail: str,
            names: Tuple[str, ...]) -> Optional[PPAReport]:
        """The cached row, or None if absent / too shallow / wrong suite."""
        level = _DETAIL_LEVEL[detail]
        with self._lock:
            ent = self._d.get(key)
            if (ent is None or ent[0] < level
                    or not set(names) <= set(ent[1].workloads)):
                self.misses += 1
                return None
            self._d.move_to_end(key)
            self.hits += 1
            return ent[1]

    def get_any(self, key: bytes,
                names: Tuple[str, ...]) -> Optional[Tuple[str, PPAReport]]:
        """The cached row at WHATEVER detail it has — ``(detail, row)`` —
        or None if absent / wrong suite.  The graceful-degradation path:
        when the evaluator is down, a shallower cached row beats an error.
        """
        with self._lock:
            ent = self._d.get(key)
            if ent is None or not set(names) <= set(ent[1].workloads):
                self.misses += 1
                return None
            self._d.move_to_end(key)
            self.hits += 1
            return DETAILS[ent[0]], ent[1]

    def put(self, key: bytes, detail: str, row: PPAReport) -> None:
        """Insert one single-design report row (never downgrades: an entry
        with higher detail AND at least the same workloads is kept)."""
        level = _DETAIL_LEVEL[detail]
        with self._lock:
            ent = self._d.get(key)
            if (ent is not None and ent[0] >= level
                    and set(row.workloads) <= set(ent[1].workloads)):
                self._d.move_to_end(key)
                return
            self._d[key] = (level, row)
            self._d.move_to_end(key)
            while len(self._d) > self.capacity:
                self._d.popitem(last=False)

    def clear(self) -> None:
        with self._lock:
            self._d.clear()


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BackendSpec:
    name: str
    model_cls: type            # RooflineModel subclass providing the op terms
    kernel: bool = False       # route the objectives dispatch through the
                               # Pallas ppa_eval kernel (TPU-native)

_BACKENDS: Dict[str, BackendSpec] = {}


def register_backend(name: str, model_cls: type, *, kernel: bool = False) -> None:
    _BACKENDS[name] = BackendSpec(name=name, model_cls=model_cls, kernel=kernel)


def backend_names() -> Tuple[str, ...]:
    return tuple(_BACKENDS)


def _backend(name: str) -> BackendSpec:
    if name not in _BACKENDS:
        raise ValueError(f"unknown backend {name!r}; "
                         f"registered: {sorted(_BACKENDS)}")
    return _BACKENDS[name]


# tier -> default backend for model construction
TIER_BACKEND = {"proxy": "roofline", "target": "compass"}

_AUTO_CACHE: Dict[tuple, str] = {}


def _bare_roofline(models: Mapping[str, RooflineModel]) -> bool:
    return all((m.op_overhead_s, m.nonoverlap, m.mem_efficiency) == (0.0, 0.0, 1.0)
               for m in models.values())


def homogeneous_models(models: Mapping[str, RooflineModel]) -> bool:
    """True when every model shares one op-term implementation (class +
    compass knobs) — the eligibility rule for the stacked evaluator path
    AND the portfolio sweep's union-level chunk math (one definition, two
    consumers)."""
    return len({(type(m), m.op_overhead_s, m.nonoverlap, m.mem_efficiency)
                for m in models.values()}) == 1


def resolve_backend(backend: Optional[str],
                    models: Mapping[str, RooflineModel]) -> str:
    """Map None/"auto" to a concrete backend for these models.

    "auto" benchmarks the candidate fused objective dispatches on a probe
    batch and keeps the fastest (memoized per process + device platform).
    Only bare-roofline models are eligible for the Pallas kernel; compass-
    tier knobs force the traced roofline path.
    """
    if backend is None:
        return "roofline"
    if backend != "auto":
        spec = _backend(backend)
        if spec.kernel and not _bare_roofline(models):
            raise ValueError(
                f"backend={backend!r} implements the bare roofline tier; "
                "these models carry compass-tier knobs the kernel ignores")
        return backend
    if not _bare_roofline(models):
        return "roofline"
    key = (jax.default_backend(),
           tuple(_workload_fingerprint(m.wl) for m in models.values()))
    cached = _AUTO_CACHE.get(key)
    if cached is None:
        cached = _benchmark_backends(models)
        _AUTO_CACHE[key] = cached
    return cached


def _benchmark_backends(models: Mapping[str, RooflineModel],
                        probe: int = 1024) -> str:
    """Time each kernel-capable candidate's fused objectives dispatch."""
    best_name, best_t = "roofline", np.inf
    rng = np.random.default_rng(0)
    space = next(iter(models.values())).space
    idx = space.sample(rng, probe)
    for name, spec in _BACKENDS.items():
        if spec.model_cls is not type(next(iter(models.values()))) and not spec.kernel:
            continue
        try:
            ev = ModelEvaluator(models, backend=name)
            ev.objectives(idx)                      # compile + warm
            t0 = time.perf_counter()
            ev.objectives(idx)
            dt = time.perf_counter() - t0
        except Exception:
            continue
        if dt < best_t:
            best_name, best_t = name, dt
    return best_name


# ---------------------------------------------------------------------------
# the analytical-model evaluator (proxy / target tiers)
# ---------------------------------------------------------------------------

class ModelEvaluator:
    """Evaluator over a set of named workload models sharing one design space.

    The traced path is FUSED: one jitted executable decodes the index batch,
    derives the hardware spec once, and computes every workload's op terms —
    a single device dispatch per :meth:`evaluate` call regardless of the
    number of workloads or the detail level.  ``dispatches`` counts them
    (the DSE loop asserts one per step).
    """

    def __init__(self, models: Mapping[str, RooflineModel], *,
                 tier: str = "proxy", backend: Optional[str] = None,
                 scenarios: Optional[Tuple[Scenario, ...]] = None,
                 stacked: Optional[bool] = None):
        if not models:
            raise ValueError("need at least one workload model")
        self.models: Dict[str, RooflineModel] = dict(models)
        spaces = {id(m.space): m.space for m in self.models.values()}
        if len(spaces) > 1:
            keys = {_space_key(s) for s in spaces.values()}
            if len(keys) > 1:
                raise ValueError("all workload models must share one design space")
        self.space: DesignSpace = next(iter(self.models.values())).space
        self.tier = tier
        self.backend = resolve_backend(backend, self.models)
        self.scenarios = scenarios
        # stacked path: ONE op-term pass over the deduped union of all
        # workloads' op tables instead of a per-workload traced loop —
        # bit-identical, near-flat cost in the workload count.  Eligible
        # when every model shares the op-term math (class + compass knobs).
        eligible = homogeneous_models(self.models)
        if stacked and not eligible:
            raise ValueError(
                "stacked=True needs every workload model to share one class "
                "and compass-knob set (their op terms fuse into one pass)")
        self.stacked = eligible if stacked is None else bool(stacked)
        self.dispatches = 0            # fused jitted dispatch count
        self._fns: Dict[tuple, Callable] = {}
        self._stacks: Dict[Tuple[str, ...], WorkloadStack] = {}

    # -- identity ------------------------------------------------------
    @property
    def workloads(self) -> Tuple[str, ...]:
        return tuple(self.models)

    def _stack(self, names: Tuple[str, ...]) -> WorkloadStack:
        stack = self._stacks.get(names)
        if stack is None:
            stack = WorkloadStack.build({nm: self.models[nm].wl
                                         for nm in names})
            self._stacks[names] = stack
        return stack

    def _cache_key(self, detail: str, names: Tuple[str, ...]) -> tuple:
        return ("stacked" if self.stacked else "fused", detail, self.backend,
                _space_key(self.space),
                tuple((nm, type(m).__qualname__, m._tp,
                       (m.op_overhead_s, m.nonoverlap, m.mem_efficiency),
                       _workload_fingerprint(m.wl))
                      for nm, m in self.models.items() if nm in names))

    # -- fused traced path ---------------------------------------------
    def _fused_fn(self, detail: str, names: Tuple[str, ...]) -> Callable:
        local = self._fns.get((detail, names))
        if local is not None:
            return local
        key = self._cache_key(detail, names)
        fn = _JIT_CACHE.get(key)
        if fn is None:
            if self.backend != "roofline" and _backend(self.backend).kernel \
                    and detail == "objectives":
                fn = jax.jit(self._build_kernel_objectives(names))
            else:
                fn = jax.jit(self._build_traced(detail, names))
            _JIT_CACHE[key] = fn
        self._fns[(detail, names)] = fn
        return fn

    def _build_traced(self, detail: str, names: Tuple[str, ...]) -> Callable:
        models = {nm: self.models[nm] for nm in names}
        if self.stacked:
            stack = self._stack(names)
            rep_model = models[names[0]]

            def fused(idx: jnp.ndarray) -> Dict:
                vals = self.space.decode(idx)        # once per batch
                hw = derive_hardware(vals)           # once per batch
                hwb = {kk: vv[:, None] for kk, vv in hw.items()}
                return {"area": hw["area_mm2"],
                        "per_workload": stacked_workload_batches(
                            rep_model, stack, hwb, detail,
                            materialize_objectives=True)}

            return fused

        wl_detail = "objectives+sink" if detail == "objectives" else detail

        def fused(idx: jnp.ndarray) -> Dict:
            vals = self.space.decode(idx)            # once per batch
            hw = derive_hardware(vals)               # once per batch
            hwb = {kk: vv[:, None] for kk, vv in hw.items()}
            out = {"area": hw["area_mm2"]}
            out["per_workload"] = {
                nm: m._workload_batch(hwb, wl_detail)
                for nm, m in models.items()}
            return out

        return fused

    def _build_kernel_objectives(self, names: Tuple[str, ...]) -> Callable:
        """Objectives dispatch through the Pallas ppa_eval kernel."""
        from repro.kernels.ppa_eval.kernel import ppa_eval_fwd
        from repro.kernels.ppa_eval.ref import op_table
        models = {nm: self.models[nm] for nm in names}
        tables = {nm: jnp.asarray(op_table(m.wl), jnp.float32)
                  for nm, m in models.items()}
        interpret = jax.default_backend() != "tpu"

        def fused(idx: jnp.ndarray) -> Dict:
            vals = self.space.decode(idx)
            dv = jnp.stack([vals[n] for n in self.space.names],
                           axis=1).astype(jnp.float32)
            per, area = {}, None
            for nm, m in models.items():
                o = ppa_eval_fwd(dv, tables[nm], tp=float(m.wl.tp),
                                 block_b=min(256, dv.shape[0]),
                                 interpret=interpret)
                per[nm] = {"latency": o[:, 0]}
                area = o[:, 5]
            return {"area": area, "per_workload": per}

        return fused

    # -- public API -----------------------------------------------------
    def evaluate(self, request: EvalRequest) -> PPAReport:
        names = (self.workloads if request.workloads is None
                 else tuple(request.workloads))
        unknown = set(names) - set(self.models)
        if unknown:
            raise KeyError(f"unknown workloads {sorted(unknown)}; "
                           f"have {self.workloads}")
        fn = self._fused_fn(request.detail, names)
        out = _bucketed_call(fn, request.idx)        # ONE fused dispatch
        self.dispatches += 1
        per = out["per_workload"]
        detail = request.detail
        rep = PPAReport(
            workloads=names, detail=detail, area=out["area"],
            latency={nm: per[nm]["latency"] for nm in names})
        if detail in ("ppa", "stalls"):
            rep.op_time = {nm: per[nm]["op_time"] for nm in names}
            rep.op_names = {nm: tuple(self.models[nm].wl.op_names)
                            for nm in names}
        if detail == "stalls":
            rep.stall = {nm: per[nm]["stall"] for nm in names}
            rep.op_class = {nm: per[nm]["op_class"] for nm in names}
        return rep

    def objectives(self, idx: np.ndarray) -> np.ndarray:
        """(n, len(workloads)+1) objectives [*latencies, area], one dispatch."""
        return self.evaluate(EvalRequest(idx, detail="objectives")).objectives

    def ppa(self, idx: np.ndarray) -> PPAReport:
        return self.evaluate(EvalRequest(idx, detail="ppa"))

    def stalls(self, idx: np.ndarray) -> PPAReport:
        return self.evaluate(EvalRequest(idx, detail="stalls"))

    # baseline drivers (`run_method`) accept plain callables; the evaluator
    # IS one, so legacy `evaluator(X) -> (n, 3)` call sites keep working
    def __call__(self, idx: np.ndarray) -> np.ndarray:
        return self.objectives(idx)


# ---------------------------------------------------------------------------
# oracle tier: the exhaustive sweep front as ground truth
# ---------------------------------------------------------------------------

class OracleEvaluator:
    """Wraps a base evaluator with the exhaustive-sweep ground truth.

    Point evaluations delegate to the base (same fused dispatch); the oracle
    adds the exact full-space Pareto front from
    :class:`~repro.perfmodel.sweep.SweepEngine` — lazily swept once per
    process — so campaign metrics can be normalized against ground truth:
    ``normalized_phv`` reports PHV as a fraction of the exhaustive-front PHV
    (the ROADMAP's oracle-normalized Table 2/3 metric) and ``regret``
    measures distance from the true per-objective optima.

    ``oracle_store=`` opts into the persistent oracle store: ``True``
    uses ``~/.cache/repro-oracle/``, a string names a directory.  The
    sweep artifact is keyed by the engine's configuration fingerprint
    (space cards, backend, workload fingerprints, model classes, stop +
    sweep knobs), so a repeat OracleEvaluator anywhere on the machine is
    an O(1) ``load_sweep_result`` instead of a re-sweep; a corrupt
    artifact is quarantined and re-swept, never trusted.
    """

    tier = "oracle"

    def __init__(self, base: ModelEvaluator, *, stop: Optional[int] = None,
                 sweep_kwargs: Optional[dict] = None,
                 oracle_store=None):
        self.base = base
        self.space = base.space
        self.stop = stop                      # None = the full space
        self._sweep_kwargs = dict(sweep_kwargs or {})
        self.oracle_store = oracle_store
        self._result = None
        self._phv_cache: Dict[bytes, float] = {}

    @property
    def workloads(self) -> Tuple[str, ...]:
        return self.base.workloads

    @property
    def dispatches(self) -> int:
        return self.base.dispatches

    def evaluate(self, request: EvalRequest) -> PPAReport:
        return self.base.evaluate(request)

    def objectives(self, idx: np.ndarray) -> np.ndarray:
        return self.base.objectives(idx)

    def __call__(self, idx: np.ndarray) -> np.ndarray:
        return self.base.objectives(idx)

    # -- ground truth ---------------------------------------------------
    def _store_path(self, eng) -> Optional[Tuple[str, str]]:
        """(artifact path, content key) under the oracle store, or None
        when the store is off."""
        if not self.oracle_store:
            return None
        import hashlib
        import os
        from repro.perfmodel.sweep import DEFAULT_ORACLE_STORE
        root = (DEFAULT_ORACLE_STORE if self.oracle_store is True
                else str(self.oracle_store))
        root = os.path.expanduser(root)
        knobs = "|".join(f"{k}={self._sweep_kwargs[k]}"
                         for k in sorted(self._sweep_kwargs))
        key = f"{eng.fingerprint()}|stop={self.stop}|{knobs}"
        digest = hashlib.sha256(key.encode()).hexdigest()[:32]
        return os.path.join(root, f"oracle-{digest}.npz"), key

    def sweep_result(self):
        """The (memoized) exhaustive sweep over [0, stop or size) — loaded
        from the oracle store when enabled and populated, swept (and
        stored) otherwise."""
        if self._result is None:
            from repro.perfmodel.sweep import (SweepEngine,
                                               load_sweep_result,
                                               save_sweep_result)
            eng = SweepEngine(self.base, **self._sweep_kwargs)
            loc = self._store_path(eng)
            if loc is not None:
                import os
                import warnings
                path, key = loc
                if os.path.exists(path):
                    try:
                        self._result = load_sweep_result(path, key=key)
                        return self._result
                    except ValueError as exc:
                        q = path + ".quarantined"
                        try:
                            os.replace(path, q)
                        except OSError:
                            q = "<could not rename>"
                        warnings.warn(
                            f"oracle store artifact {path} is invalid "
                            f"({exc}); quarantined to {q} — re-sweeping",
                            RuntimeWarning, stacklevel=2)
                self._result = eng.run(0, self.stop)
                save_sweep_result(path, self._result, key=key)
            else:
                self._result = eng.run(0, self.stop)
        return self._result

    def front(self) -> np.ndarray:
        """Exact Pareto-front objective rows (p, n_obj)."""
        return self.sweep_result().pareto_y

    def front_idx(self) -> np.ndarray:
        return self.sweep_result().pareto_idx(self.space)

    def oracle_phv(self, ref_point: np.ndarray) -> float:
        """Hypervolume of the exhaustive front w.r.t. `ref_point`."""
        from repro.core.pareto import hypervolume
        ref = np.asarray(ref_point, dtype=np.float64)
        key = ref.tobytes()
        if key not in self._phv_cache:
            self._phv_cache[key] = hypervolume(self.front(), ref)
        return self._phv_cache[key]

    def normalized_phv(self, phv: float, ref_point: np.ndarray) -> float:
        """Campaign PHV as a fraction of the exhaustive-front PHV."""
        oracle = self.oracle_phv(ref_point)
        return float(phv) / oracle if oracle > 0 else 0.0

    def regret(self, y: np.ndarray) -> np.ndarray:
        """Per-objective relative regret of a campaign's best points vs the
        true optima: (best_found - best_possible) / best_possible.

        ``y`` must live in the oracle front's objective space — for a
        zoo-suite oracle that is the ROBUST [r_prefill, r_decode, area]
        triple, not raw workload latencies.
        """
        y = np.atleast_2d(np.asarray(y, dtype=np.float64))
        best_true = self.sweep_result().topk_val[:, 0]
        if y.shape[1] != best_true.shape[0]:
            raise ValueError(
                f"regret expects {best_true.shape[0]}-objective rows "
                f"(the oracle front's space), got {y.shape[1]}")
        best_found = y.min(axis=0)
        return (best_found - best_true) / np.maximum(best_true, 1e-300)


# ---------------------------------------------------------------------------
# construction helpers
# ---------------------------------------------------------------------------

def make_evaluator(workloads: Mapping[str, "object"], *, tier: str = "proxy",
                   backend: Optional[str] = None,
                   space: DesignSpace = SPACE,
                   scenarios: Optional[Tuple[Scenario, ...]] = None,
                   stacked: Optional[bool] = None) -> ModelEvaluator:
    """Build a ModelEvaluator from {name: Workload} at a fidelity tier."""
    if tier not in TIER_BACKEND:
        raise ValueError(f"tier must be one of {sorted(TIER_BACKEND)} here; "
                         "use get_evaluator('oracle') for the oracle tier")
    cls = _backend(TIER_BACKEND[tier]).model_cls
    models = {nm: cls(wl, space) for nm, wl in workloads.items()}
    return ModelEvaluator(models, tier=tier, backend=backend,
                          scenarios=scenarios, stacked=stacked)


_PAPER_EVALUATORS: Dict[tuple, "Evaluator"] = {}


def get_evaluator(tier: str = "proxy", backend: Optional[str] = None,
                  *, oracle_stop: Optional[int] = None,
                  oracle_store=None,
                  workers: int = 1, mode: str = "auto",
                  suite: str = "paper") -> Evaluator:
    """The paper-workload (or zoo-portfolio) evaluator per tier (memoized).

    tier="proxy"  -> roofline models (cheap acquisition tier);
    tier="target" -> compass models (the budgeted high-fidelity tier);
    tier="oracle" -> OracleEvaluator over the chosen backend's models
                     (default roofline), exposing the exhaustive front.
    backend: "roofline" | "compass" | "pallas" | "auto" | None.
    oracle_store: opt-in persistent sweep-artifact store for the oracle
             tier (``True`` = ``~/.cache/repro-oracle/``, or a directory
             path) — repeat oracle construction loads the stored front
             in O(1) instead of re-sweeping.
    workers: > 1 wraps the evaluator in a :class:`~repro.distributed.
             sharded.ShardedEvaluator` that fans each EvalRequest's batch
             across N workers (`mode`: "thread" | "process" | "device" |
             "auto"); the report stays bit-identical to the local path.
    suite: "paper" — the GPT-3 (ttft, tpot) pair, one scenario;
           "zoo"   — every assigned architecture config as a scenario
           (``<arch>:prefill`` / ``<arch>:decode`` workload pairs built via
           :func:`~repro.perfmodel.workload.zoo_suite`).  All workloads
           evaluate in ONE stacked dispatch over the deduped op union, and
           ``.scenarios`` drives the portfolio sweep's per-scenario fronts.
    """
    if tier not in TIERS:
        raise ValueError(f"tier must be one of {TIERS}, got {tier!r}")
    if suite not in SUITES:
        raise ValueError(f"suite must be one of {SUITES}, got {suite!r}")
    from repro.distributed.sharded import MODES  # leaf dep (mode validation)
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    workers = max(1, int(workers))
    if workers == 1:
        mode = "auto"      # inert knobs: collapse onto the memoized base key
    key = (tier, backend, oracle_stop, workers, mode, suite,
           None if not oracle_store else str(oracle_store))
    cached = _PAPER_EVALUATORS.get(key)
    if cached is not None:
        return cached
    from repro.perfmodel.workload import paper_suite, zoo_suite
    if tier == "oracle":
        base_backend = backend or "roofline"
        base_tier = "target" if base_backend == "compass" else "proxy"
        base = get_evaluator(base_tier, base_backend,
                             workers=workers, mode=mode, suite=suite)
        ev: Evaluator = OracleEvaluator(base, stop=oracle_stop,
                                        oracle_store=oracle_store)
    else:
        model_backend = backend if backend not in (None, "auto", "pallas") \
            else TIER_BACKEND[tier]
        cls = _backend(model_backend).model_cls
        wls, scenarios = (paper_suite() if suite == "paper" else zoo_suite())
        models = {nm: cls(wl) for nm, wl in wls.items()}
        ev = ModelEvaluator(models, tier=tier, backend=backend,
                            scenarios=scenarios)
        if workers > 1:
            from repro.distributed.sharded import ShardedEvaluator  # leaf dep
            ev = ShardedEvaluator(ev, workers=workers, mode=mode)
    _PAPER_EVALUATORS[key] = ev
    return ev


_MODEL_EVALUATORS: Dict[int, ModelEvaluator] = {}


def evaluator_for_model(model: RooflineModel, name: str = "lat") -> ModelEvaluator:
    """Memoized single-workload evaluator for one legacy model instance."""
    key = id(model)
    ev = _MODEL_EVALUATORS.get(key)
    if ev is None or ev.models.get(name) is not model:
        ev = ModelEvaluator({name: model})
        if len(_MODEL_EVALUATORS) >= 256:     # bound the id-keyed memo
            _MODEL_EVALUATORS.clear()
        _MODEL_EVALUATORS[key] = ev
    return ev


def pair_view(evaluator, names: Tuple[str, str]) -> Evaluator:
    """A two-workload view over ``names`` of a model-backed evaluator.

    Scenario campaigns point the DSE stack (QualE probing, QuanE
    sensitivity — both read objectives columns 0/1) at ONE (prefill,
    decode) pair of a multi-workload suite.  The view shares the base's
    model objects, so its compiled executables come out of the same
    workload-keyed jit cache.
    """
    names = tuple(names)
    if tuple(evaluator.workloads) == names:
        return evaluator
    models = evaluator.models
    unknown = set(names) - set(models)
    if unknown:
        raise KeyError(f"unknown workloads {sorted(unknown)}; "
                       f"have {tuple(models)}")
    backend = getattr(evaluator, "backend", None)
    return ModelEvaluator({nm: models[nm] for nm in names},
                          tier=evaluator.tier,
                          backend=backend if backend in _BACKENDS else None)


def as_evaluator(obj) -> Evaluator:
    """Coerce onto the Evaluator contract.

    - an Evaluator passes through;
    - a single model becomes a (memoized) single-workload evaluator.

    The pre-PR-2 ``(ttft_model, tpot_model)`` pair signature was removed
    after its one-release deprecation window; build a two-workload
    evaluator with ``ModelEvaluator({"ttft": mt, "tpot": mp})`` or use
    :func:`get_evaluator`.
    """
    if hasattr(obj, "evaluate") and hasattr(obj, "workloads"):
        return obj
    if isinstance(obj, RooflineModel):
        return evaluator_for_model(obj)
    raise TypeError(f"cannot interpret {type(obj).__name__} as an Evaluator")


# default registry entries
register_backend("roofline", RooflineModel)
from repro.perfmodel.compass import CompassModel  # noqa: E402  (leaf import)
register_backend("compass", CompassModel)
register_backend("pallas", RooflineModel, kernel=True)
