"""Mixture-of-Experts layer: top-k routing with capacity-bounded dispatch.

TPU-native formulation (MaxText-style "dropping" dispatch): tokens are
sorted by assigned expert and scattered into a dense (E, C, d) buffer, so
the expert computation is ONE batched einsum with FLOPs proportional to
*active* tokens (times the capacity factor) — not n_experts.  The expert
dimension shards over the `model` mesh axis (expert parallelism); GSPMD
inserts the dispatch/combine all-to-alls.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import init_mlp, mlp


def init_moe(key, d_model: int, expert_ff: int, n_experts: int,
             n_shared: int, shared_ff: int, dtype=jnp.bfloat16,
             expert_pad: int = 0) -> Dict:
    """expert_pad adds zero-traffic experts so the expert-stack dim divides
    the TP axis (EP layout); the router only ever emits n_experts logits."""
    kr, ke1, ke2, ke3, ks = jax.random.split(key, 5)
    s_in = float(1.0 / np.sqrt(d_model))
    s_ff = float(1.0 / np.sqrt(expert_ff))
    e_tot = n_experts + expert_pad
    p = {
        "router": jax.random.normal(kr, (d_model, n_experts), jnp.float32) * s_in,
        "w_gate": jax.random.normal(ke1, (e_tot, d_model, expert_ff), dtype) * s_in,
        "w_up": jax.random.normal(ke2, (e_tot, d_model, expert_ff), dtype) * s_in,
        "w_down": jax.random.normal(ke3, (e_tot, expert_ff, d_model), dtype) * s_ff,
    }
    if n_shared:
        p["shared"] = init_mlp(ks, d_model, shared_ff, gated=True, dtype=dtype)
    return p


def moe_block(params: Dict, x: jnp.ndarray, *, n_experts: int, top_k: int,
              capacity_factor: float = 1.25, n_groups: int = 1,
              buf_pspec=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out, aux_loss).

    Grouped capacity-bounded dispatch: tokens are split into `n_groups`
    groups (aligned with the data-parallel axis by the launcher), routing
    positions are computed WITHIN each group (parallel cumsum, local
    scatter), and the dispatch buffer is (G, E, C, d) with G sharded over
    the data axes and E over the model axis — so dispatch/combine lower to
    local scatters plus one all-to-all instead of global gathers (perf
    iteration, EXPERIMENTS.md §Perf qwen2-moe).  Per-group capacity
    C = ceil(Tg * top_k / E * capacity_factor); overflow tokens drop (their
    contribution is the shared-expert/residual path only).
    """
    import math
    b, s, d = x.shape
    t = b * s
    e_tot = params["w_up"].shape[0]       # includes zero-traffic pad experts
    g_n = max(1, math.gcd(n_groups, t))
    tg = t // g_n
    xg = x.reshape(g_n, tg, d)
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        params["router"])                         # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)             # (G, Tg, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = int(np.ceil(tg * top_k / n_experts * capacity_factor))
    cap = max(cap, 1)

    # position of each (token, k) assignment within its (group, expert) slot
    flat_expert = gate_idx.reshape(g_n, tg * top_k)               # (G, Tg*k)
    onehot = jax.nn.one_hot(flat_expert, e_tot, dtype=jnp.int32)  # (G, ., E)
    pos_in_expert = jnp.cumsum(onehot, axis=1) - 1
    pos = jnp.take_along_axis(pos_in_expert, flat_expert[..., None],
                              axis=2)[..., 0]
    keep = pos < cap

    # scatter tokens into the (G, E, C, d) dispatch buffer (group-local)
    buf = jnp.zeros((g_n, e_tot, cap, d), x.dtype)
    src = jnp.repeat(xg, top_k, axis=1)                           # (G, Tg*k, d)
    e_idx = jnp.where(keep, flat_expert, 0)
    c_idx = jnp.where(keep, pos, 0)
    src = jnp.where(keep[..., None], src, 0)
    g_idx = jnp.arange(g_n)[:, None] * jnp.ones_like(e_idx)
    buf = buf.at[g_idx, e_idx, c_idx].add(src)
    if buf_pspec is not None:
        buf = jax.lax.with_sharding_constraint(buf, buf_pspec)

    # expert FFN: one batched einsum over the (group, expert) dims
    gme = jnp.einsum("gecd,edf->gecf", buf, params["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", buf, params["w_up"])
    h = jax.nn.silu(gme.astype(jnp.float32)).astype(x.dtype) * u
    y = jnp.einsum("gecf,efd->gecd", h, params["w_down"])         # (G, E, C, d)

    # combine: gather each assignment's expert output, weight by the gate
    out_flat = y[g_idx, e_idx, c_idx]                             # (G, Tg*k, d)
    w = (gate_vals.reshape(g_n, tg * top_k) * keep).astype(x.dtype)
    out = (out_flat * w[..., None]).reshape(g_n, tg, top_k, d).sum(axis=2)
    out = out.reshape(b, s, d)

    # load-balancing auxiliary loss (Switch-style)
    me = probs.reshape(t, -1).mean(axis=0)[:n_experts]
    ce = jnp.zeros(e_tot).at[flat_expert.reshape(-1)].add(1.0)[:n_experts] \
        / (t * top_k)
    aux = n_experts * jnp.sum(me * ce)

    if "shared" in params:
        out = out + mlp(params["shared"], x, gated=True)
    return out, aux
