"""GQA attention: full, memory-efficient chunked (online softmax), and
cached decode.

``chunked_attention`` is the pure-JAX flash-attention algorithm (two-level
scan over q/kv blocks with online-softmax rescaling) — it is also the oracle
for the Pallas ``flash_attention`` kernel.  The model layer picks the
implementation by sequence length (and can be forced via ``impl``).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import apply_rope, init_linear

NEG_INF = -1e30


def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """(B, S, kvH, hd) -> (B, S, kvH*n_rep, hd)."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)) \
              .reshape(b, s, h * n_rep, d)


def full_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   causal: bool = True,
                   q_offset: int = 0) -> jnp.ndarray:
    """q: (B, Sq, H, hd); k, v: (B, Sk, H, hd). Materializes scores."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        qi = jnp.arange(sq)[:, None] + q_offset
        ki = jnp.arange(sk)[None, :]
        logits = jnp.where(ki <= qi, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      causal: bool = True, q_chunk: int = 512,
                      k_chunk: int = 512) -> jnp.ndarray:
    """Memory-efficient attention: never materializes (Sq, Sk) scores.

    Outer lax.map over q blocks; inner lax.scan over kv blocks carrying
    (max, sum, acc) online-softmax state.  Equivalent to full_attention
    (see tests/test_kernels.py).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    q_chunk = min(q_chunk, sq)
    k_chunk = min(k_chunk, sk)
    nq, nk = sq // q_chunk, sk // k_chunk
    assert sq % q_chunk == 0 and sk % k_chunk == 0, (sq, q_chunk, sk, k_chunk)
    scale = 1.0 / np.sqrt(hd)

    qb = q.reshape(b, nq, q_chunk, h, hd)
    kb = k.reshape(b, nk, k_chunk, h, hd)
    vb = v.reshape(b, nk, k_chunk, h, hd)

    def process_q_block(qi_and_block):
        qi, qblk = qi_and_block                      # (b, q_chunk, h, hd)
        m0 = jnp.full((b, h, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, q_chunk, h, hd), jnp.float32)

        def kv_step(carry, ki_and_blocks):
            m, l, acc = carry
            ki, kblk, vblk = ki_and_blocks
            s = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk).astype(jnp.float32) * scale
            if causal:
                qpos = qi * q_chunk + jnp.arange(q_chunk)[:, None]
                kpos = ki * k_chunk + jnp.arange(k_chunk)[None, :]
                s = jnp.where(kpos <= qpos, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr.transpose(0, 2, 1)[..., None] + jnp.einsum(
                "bhqk,bkhd->bqhd", p.astype(vblk.dtype), vblk).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        ks = (jnp.arange(nk), kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4))
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), ks)
        out = acc / jnp.maximum(l.transpose(0, 2, 1)[..., None], 1e-30)
        return out.astype(q.dtype)

    outs = jax.lax.map(process_q_block,
                       (jnp.arange(nq), qb.transpose(1, 0, 2, 3, 4)))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     kv_len: jnp.ndarray) -> jnp.ndarray:
    """Single-token decode: q (B, 1, H, hd) against cache (B, S, H, hd);
    positions >= kv_len are masked."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k_cache).astype(jnp.float32) * scale
    mask = jnp.arange(k_cache.shape[1])[None, None, None, :] < kv_len
    logits = jnp.where(mask, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(v_cache.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v_cache)


def gqa_decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                         v_cache: jnp.ndarray,
                         kv_len: jnp.ndarray) -> jnp.ndarray:
    """Grouped-query decode WITHOUT materializing a repeated KV cache.

    Perf iteration (EXPERIMENTS.md §Perf, jamba long_500k): `_repeat_kv`
    broadcast an 8-kv-head 500k cache to 64 heads (8x HBM traffic and, under
    GSPMD, an 8x replicated temp).  Grouping the query heads instead keeps
    the cache in its native layout: q (B, 1, H, hd) -> (B, kvH, G, hd),
    attention runs per kv-head over the group dim.
    """
    b, one, h, hd = q.shape
    kvh = k_cache.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, hd)                     # fold the q-seq dim (1)
    scale = 1.0 / np.sqrt(hd)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache).astype(jnp.float32)
    logits = logits * scale
    mask = jnp.arange(k_cache.shape[1])[None, None, None, :] < kv_len
    logits = jnp.where(mask, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v_cache)
    return out.reshape(b, 1, h, hd)


# ---------------------------------------------------------------------------
# attention layer (projections + rope + impl dispatch)
# ---------------------------------------------------------------------------

# Above this seq len the memory-efficient chunked (flash) impl is used.
# Perf iteration 4 (EXPERIMENTS.md §Perf): materialized (S,S) scores at
# S=4096 dominated the HBM roofline term in training; 2048 keeps every
# assigned train/prefill shape on the flash path.
CHUNKED_THRESHOLD = 2048


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, bias: bool, dtype=jnp.bfloat16) -> Dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "q": init_linear(kq, d_model, n_heads * head_dim, bias, dtype),
        "k": init_linear(kk, d_model, n_kv_heads * head_dim, bias, dtype),
        "v": init_linear(kv, d_model, n_kv_heads * head_dim, bias, dtype),
        "o": init_linear(ko, n_heads * head_dim, d_model, False, dtype),
    }


def attention_block(params: Dict, x: jnp.ndarray, *, n_heads: int,
                    n_kv_heads: int, head_dim: int, rope_theta: Optional[float],
                    positions: Optional[jnp.ndarray] = None,
                    kv: Optional[jnp.ndarray] = None,
                    causal: bool = True,
                    impl: str = "auto") -> jnp.ndarray:
    """Self-attention (kv=None) or cross-attention (kv=encoder output)."""
    from repro.models.layers import linear
    b, s, d = x.shape
    src = kv if kv is not None else x
    q = linear(params["q"], x).reshape(b, s, n_heads, head_dim)
    k = linear(params["k"], src).reshape(b, src.shape[1], n_kv_heads, head_dim)
    v = linear(params["v"], src).reshape(b, src.shape[1], n_kv_heads, head_dim)
    if rope_theta is not None and kv is None:
        pos = positions if positions is not None else jnp.arange(s)[None, :]
        q = apply_rope(q, pos, rope_theta)
        k = apply_rope(k, pos, rope_theta)
    k = _repeat_kv(k, n_heads // n_kv_heads)
    v = _repeat_kv(v, n_heads // n_kv_heads)
    use_chunked = impl == "chunked" or (impl == "auto" and s > CHUNKED_THRESHOLD)
    if use_chunked and causal and kv is None:
        o = chunked_attention(q, k, v, causal=True)
    else:
        o = full_attention(q, k, v, causal=causal and kv is None)
    o = o.reshape(b, s, n_heads * head_dim)
    return linear(params["o"], o)


def cached_attention_step(params: Dict, x: jnp.ndarray, cache: Dict, *,
                          n_heads: int, n_kv_heads: int, head_dim: int,
                          rope_theta: Optional[float]) -> Tuple[jnp.ndarray, Dict]:
    """One decode step.

    x: (B, 1, d).  cache: {"k","v": (B, S, kvH, hd), "len": scalar int32 —
    the shared history length}.  Returns (out (B, 1, d), updated cache).
    """
    from repro.models.layers import linear
    b, _, d = x.shape
    q = linear(params["q"], x).reshape(b, 1, n_heads, head_dim)
    k = linear(params["k"], x).reshape(b, 1, n_kv_heads, head_dim)
    v = linear(params["v"], x).reshape(b, 1, n_kv_heads, head_dim)
    pos = cache["len"][None, None]                    # (1, 1) position
    if rope_theta is not None:
        q = apply_rope(q, pos, rope_theta)
        k = apply_rope(k, pos, rope_theta)
    # cache insert via one-hot masked add: dynamic_update_slice on a SHARDED
    # seq dim triggers GSPMD "involuntary full rematerialization" (the whole
    # cache gets replicated to repartition).  The masked add is elementwise,
    # so the cache keeps its seq/head sharding (EXPERIMENTS.md §Perf,
    # jamba long_500k: 102 GiB -> fits; also removes the SPMD warnings on
    # every GQA decode cell).
    hot = (jnp.arange(cache["k"].shape[1]) == cache["len"]) \
        .astype(cache["k"].dtype)[None, :, None, None]
    k_cache = cache["k"] * (1 - hot) + hot * k.astype(cache["k"].dtype)
    v_cache = cache["v"] * (1 - hot) + hot * v.astype(cache["v"].dtype)
    kv_len = (cache["len"] + 1).reshape(1, 1, 1, 1)
    o = gqa_decode_attention(q, k_cache, v_cache, kv_len)
    o = o.reshape(b, 1, n_heads * head_dim)
    out = linear(params["o"], o)
    return out, {"k": k_cache, "v": v_cache, "len": cache["len"] + 1}
