"""State-space / linear-recurrence layers: Mamba selective scan and the
RWKV6 ("Finch") time-mix with data-dependent decay.

Both are expressed as chunked `lax.scan`s over time with O(1) carried state
— the property that makes the `long_500k` decode shape feasible.  The Pallas
kernels in repro.kernels implement the same recurrences with VMEM tiling;
the functions here double as their oracles.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import init_linear, linear


# =====================================================================
# Mamba (selective scan), expansion factor 2
# =====================================================================

def init_mamba(key, d_model: int, d_state: int, d_conv: int,
               dtype=jnp.bfloat16) -> Dict:
    d_in = 2 * d_model
    ks = jax.random.split(key, 7)
    return {
        "in_proj": init_linear(ks[0], d_model, 2 * d_in, dtype=dtype),
        "conv_w": jax.random.normal(ks[1], (d_conv, d_in), dtype) * float(1.0 / np.sqrt(d_conv)),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": init_linear(ks[2], d_in, d_state * 2 + 1, dtype=dtype),
        "dt_bias": jnp.zeros((d_in,), jnp.float32),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32),
                                  (d_in, 1))),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": init_linear(ks[3], d_in, d_model, dtype=dtype),
    }


def _selective_scan(u, dt, A, B, C, D, h0=None):
    """u: (B, L, d_in); dt: (B, L, d_in); A: (d_in, N); B, C: (B, L, N).

    h_{t} = exp(dt*A) h_{t-1} + dt * B_t * u_t ;  y_t = C_t . h_t + D*u_t
    Scan over time, state (B, d_in, N).
    """
    bsz, L, d_in = u.shape
    n = A.shape[1]
    h0 = h0 if h0 is not None else jnp.zeros((bsz, d_in, n), jnp.float32)

    def step(h, inp):
        u_t, dt_t, B_t, C_t = inp                       # (B,d), (B,d), (B,N), (B,N)
        dA = jnp.exp(dt_t[..., None] * A[None])         # (B, d, N)
        dBu = dt_t[..., None] * B_t[:, None, :] * u_t[..., None]
        h = dA * h + dBu
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    xs = (u.transpose(1, 0, 2).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          B.transpose(1, 0, 2).astype(jnp.float32),
          C.transpose(1, 0, 2).astype(jnp.float32))
    h, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2) + D[None, None, :] * u.astype(jnp.float32)
    return y, h


def mamba_block(params: Dict, x: jnp.ndarray,
                state: Optional[Dict] = None) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, L, d).  state (decode): {"h": (B, d_in, N), "conv": (B, d_conv-1, d_in)}.
    Returns (y, new_state)."""
    b, L, d = x.shape
    d_in = params["conv_w"].shape[1]
    n = params["A_log"].shape[1]
    d_conv = params["conv_w"].shape[0]

    xz = linear(params["in_proj"], x)                   # (B, L, 2*d_in)
    u, z = jnp.split(xz, 2, axis=-1)

    # causal depthwise conv1d
    prev = (state["conv"] if state is not None
            else jnp.zeros((b, d_conv - 1, d_in), u.dtype))
    upad = jnp.concatenate([prev, u], axis=1)           # (B, L+dc-1, d_in)
    new_conv = upad[:, -(d_conv - 1):, :] if d_conv > 1 else prev
    conv = sum(upad[:, i:i + L, :] * params["conv_w"][i][None, None]
               for i in range(d_conv)) + params["conv_b"]
    u = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)

    proj = linear(params["x_proj"], u)                  # (B, L, 2N+1)
    Bm, Cm, dt_raw = (proj[..., :n], proj[..., n:2 * n], proj[..., 2 * n:])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"][None, None])
    A = -jnp.exp(params["A_log"])

    h0 = state["h"] if state is not None else None
    y, h = _selective_scan(u, jnp.broadcast_to(dt, u.shape), A, Bm, Cm,
                           params["D"], h0)
    y = y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = linear(params["out_proj"], y)
    return out, {"h": h, "conv": new_conv}


def mamba_init_state(b: int, d_model: int, d_state: int, d_conv: int,
                     dtype=jnp.bfloat16) -> Dict:
    d_in = 2 * d_model
    return {"h": jnp.zeros((b, d_in, d_state), jnp.float32),
            "conv": jnp.zeros((b, d_conv - 1, d_in), dtype)}


# =====================================================================
# RWKV6 "Finch": time-mix with data-dependent decay + channel-mix
# =====================================================================

def init_rwkv(key, d_model: int, head_size: int, d_ff: int,
              dtype=jnp.bfloat16) -> Dict:
    ks = jax.random.split(key, 9)
    h = d_model // head_size
    return {
        "mix_r": jnp.full((d_model,), 0.5, dtype),
        "mix_k": jnp.full((d_model,), 0.5, dtype),
        "mix_v": jnp.full((d_model,), 0.5, dtype),
        "mix_w": jnp.full((d_model,), 0.5, dtype),
        "mix_g": jnp.full((d_model,), 0.5, dtype),
        "r": init_linear(ks[0], d_model, d_model, dtype=dtype),
        "k": init_linear(ks[1], d_model, d_model, dtype=dtype),
        "v": init_linear(ks[2], d_model, d_model, dtype=dtype),
        "g": init_linear(ks[3], d_model, d_model, dtype=dtype),
        "w_proj": init_linear(ks[4], d_model, d_model, dtype=dtype),
        "w_bias": jnp.full((d_model,), -6.0, jnp.float32),
        "u": jax.random.normal(ks[5], (h, head_size), jnp.float32) * 0.1,
        "out": init_linear(ks[6], d_model, d_model, dtype=dtype),
        "ln_x_w": jnp.ones((d_model,), jnp.float32),
        # channel-mix
        "cm_mix_k": jnp.full((d_model,), 0.5, dtype),
        "cm_k": init_linear(ks[7], d_model, d_ff, dtype=dtype),
        "cm_v": init_linear(ks[8], d_ff, d_model, dtype=dtype),
    }


def wkv6_scan(r, k, v, w, u, s0=None):
    """RWKV6 recurrence. r,k,v: (B, L, H, hd); w: (B, L, H, hd) decay in (0,1);
    u: (H, hd) bonus. State s: (B, H, hd, hd). Returns (out (B,L,H,hd), s)."""
    b, L, h, hd = r.shape
    s = s0 if s0 is not None else jnp.zeros((b, h, hd, hd), jnp.float32)

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                        # (B, H, hd) each, fp32
        kv = k_t[..., :, None] * v_t[..., None, :]      # (B, H, hd, hd)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, y

    xs = tuple(a.transpose(1, 0, 2, 3).astype(jnp.float32) for a in (r, k, v, w))
    s, ys = jax.lax.scan(step, s, xs)
    return ys.transpose(1, 0, 2, 3), s


def rwkv_time_mix(params: Dict, x: jnp.ndarray, head_size: int,
                  state: Optional[Dict] = None) -> Tuple[jnp.ndarray, Dict]:
    b, L, d = x.shape
    h = d // head_size
    prev = (state["shift"] if state is not None
            else jnp.zeros((b, 1, d), x.dtype))
    xs = jnp.concatenate([prev, x[:, :-1]], axis=1)     # token shift
    new_shift = x[:, -1:, :]

    def mix(name):
        m = params[f"mix_{name}"][None, None]
        return x * m + xs * (1 - m)

    r = linear(params["r"], mix("r")).reshape(b, L, h, head_size)
    k = linear(params["k"], mix("k")).reshape(b, L, h, head_size)
    v = linear(params["v"], mix("v")).reshape(b, L, h, head_size)
    g = linear(params["g"], mix("g"))
    # data-dependent decay (the Finch contribution)
    w_ = linear(params["w_proj"], mix("w")).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_ + params["w_bias"][None, None]))
    w = w.reshape(b, L, h, head_size)

    s0 = state["wkv"] if state is not None else None
    y, s = wkv6_scan(r, k, v, w, params["u"], s0)
    y = y.reshape(b, L, d)
    # group norm over heads (approximated by rms over head groups)
    yf = y.reshape(b, L, h, head_size)
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, axis=-1, keepdims=True) + 1e-5)
    y = (yf.reshape(b, L, d) * params["ln_x_w"][None, None]).astype(x.dtype)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    out = linear(params["out"], y)
    return out, {"wkv": s, "shift": new_shift}


def rwkv_channel_mix(params: Dict, x: jnp.ndarray,
                     state: Optional[Dict] = None) -> Tuple[jnp.ndarray, Dict]:
    b, L, d = x.shape
    prev = (state["shift"] if state is not None
            else jnp.zeros((b, 1, d), x.dtype))
    xs = jnp.concatenate([prev, x[:, :-1]], axis=1)
    m = params["cm_mix_k"][None, None]
    xk = x * m + xs * (1 - m)
    hdn = linear(params["cm_k"], xk)
    hdn = jnp.square(jax.nn.relu(hdn.astype(jnp.float32))).astype(x.dtype)
    out = linear(params["cm_v"], hdn)
    return out, {"shift": x[:, -1:, :]}


def rwkv_init_state(b: int, d_model: int, head_size: int,
                    dtype=jnp.bfloat16) -> Dict:
    h = d_model // head_size
    return {
        "tm": {"wkv": jnp.zeros((b, h, head_size, head_size), jnp.float32),
               "shift": jnp.zeros((b, 1, d_model), dtype)},
        "cm": {"shift": jnp.zeros((b, 1, d_model), dtype)},
    }
