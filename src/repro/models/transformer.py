"""Model assembly for every assigned architecture family.

One public entry point: :func:`build_model` -> :class:`Model`, exposing

* ``init(rng)``                          -> params pytree
* ``forward(params, batch)``             -> logits (train / prefill)
* ``loss(params, batch)``                -> scalar LM loss (+ MoE aux)
* ``init_cache(batch, max_len)``         -> decode cache pytree
* ``decode_step(params, cache, tokens)`` -> (logits, cache)

Layer stacks are ``jax.lax.scan`` over stacked parameters (leading layer
dim) so the HLO stays compact at 72 layers; the scan body is rematerialized
(``jax.checkpoint``) in training mode.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.layers import (init_linear, init_mlp, layer_norm, linear,
                                 mlp, rms_norm)

PyTree = Any


def _stack_init(key, n: int, init_fn):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    dtype: Any = jnp.bfloat16
    remat: bool = True
    # optional PartitionSpec for the residual stream (sequence parallelism);
    # set by the launcher, applied between blocks when the (batch, seq) dims
    # divide the mesh axes exactly (jax rejects uneven shardings)
    hidden_pspec: Any = None
    hidden_divisors: Any = None          # (dp_size, model_size)
    # MoE token-dropping capacity factor; set to n_experts to disable drops
    moe_capacity: float = 1.25
    # grouped-dispatch group count (launcher sets to the DP degree) and the
    # dispatch-buffer PartitionSpec (P(dp, 'model', None, None))
    moe_groups: int = 1
    moe_buf_pspec: Any = None
    # MoE implementation: "dense" (pjit-partitioned scatter) or "shard_map"
    # (manual-collective expert parallelism — the production train/prefill
    # path, see repro.models.moe_shard); decode always uses "dense"
    moe_impl: str = "dense"
    moe_mesh: Any = None
    moe_dp_axes: Any = ("data",)
    # fully unroll layer scans (used by shallow-depth dry-run compiles so
    # cost_analysis sees every layer; scans count their body once)
    scan_unroll: bool = False

    def _moe(self, lp_moe, hin):
        cfg = self.cfg
        if self.moe_impl == "shard_map" and self.moe_mesh is not None:
            from repro.models import moe_shard as MS
            return MS.moe_block_sharded(
                lp_moe, hin, n_experts=cfg.n_experts, top_k=cfg.top_k,
                mesh=self.moe_mesh, dp_axes=self.moe_dp_axes,
                capacity_factor=self.moe_capacity)
        return M.moe_block(lp_moe, hin, n_experts=cfg.n_experts,
                           top_k=cfg.top_k,
                           capacity_factor=self.moe_capacity,
                           n_groups=self.moe_groups,
                           buf_pspec=self.moe_buf_pspec)

    def _scan(self, body, init, xs):
        return jax.lax.scan(body, init, xs, unroll=True if self.scan_unroll
                            else 1)

    def _constrain(self, x: jnp.ndarray) -> jnp.ndarray:
        if self.hidden_pspec is None or x.ndim != 3:
            return x
        dp, mp = self.hidden_divisors or (1, 1)
        if x.shape[0] % max(dp, 1) == 0 and x.shape[1] % max(mp, 1) == 0 \
                and x.shape[1] >= mp > 1:
            return jax.lax.with_sharding_constraint(x, self.hidden_pspec)
        return x

    # ------------------------------------------------------------------
    def init(self, rng) -> PyTree:
        cfg = self.cfg
        k_emb, k_layers, k_head, k_enc = jax.random.split(rng, 4)
        params: Dict[str, PyTree] = {
            "embed": jax.random.normal(
                k_emb, (cfg.vocab, cfg.d_model), self.dtype) * 0.02,
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = init_linear(k_head, cfg.d_model, cfg.vocab,
                                            dtype=self.dtype)
        fam = cfg.family
        if fam == "ssm":
            params["layers"] = _stack_init(k_layers, cfg.n_layers,
                                           self._init_rwkv_layer)
        elif fam == "hybrid":
            n_blocks = cfg.n_layers // cfg.attn_every
            params["layers"] = _stack_init(k_layers, n_blocks,
                                           self._init_jamba_block)
        elif fam == "audio":
            params["enc_layers"] = _stack_init(k_enc, cfg.enc_layers,
                                               self._init_encoder_layer)
            params["enc_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
            params["layers"] = _stack_init(k_layers, cfg.n_layers,
                                           self._init_decoder_layer)
        else:  # dense / moe / vlm
            params["layers"] = _stack_init(k_layers, cfg.n_layers,
                                           self._init_decoder_layer)
        return params

    # ---------------- per-layer initializers ----------------
    def _init_attn(self, key):
        cfg = self.cfg
        return A.init_attention(key, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.head_dim, cfg.qkv_bias, self.dtype)

    def _init_decoder_layer(self, key):
        cfg = self.cfg
        ka, kf, kx = jax.random.split(key, 3)
        p = {"ln1": jnp.ones((cfg.d_model,), jnp.float32),
             "ln2": jnp.ones((cfg.d_model,), jnp.float32),
             "attn": self._init_attn(ka)}
        if cfg.family == "audio":
            p["ln_x"] = jnp.ones((cfg.d_model,), jnp.float32)
            p["xattn"] = self._init_attn(kx)
        if cfg.n_experts:
            p["moe"] = M.init_moe(kf, cfg.d_model, cfg.expert_ff,
                                  cfg.n_experts, cfg.n_shared_experts,
                                  cfg.d_ff, self.dtype,
                                  expert_pad=cfg.expert_pad)
            if cfg.dense_residual:
                kd = jax.random.fold_in(kf, 1)
                p["mlp"] = init_mlp(kd, cfg.d_model, cfg.d_ff,
                                    gated=cfg.gated_mlp, dtype=self.dtype)
        else:
            p["mlp"] = init_mlp(kf, cfg.d_model, cfg.d_ff,
                                gated=cfg.gated_mlp, dtype=self.dtype)
        return p

    def _init_encoder_layer(self, key):
        cfg = self.cfg
        ka, kf = jax.random.split(key)
        return {"ln1": jnp.ones((cfg.d_model,), jnp.float32),
                "ln2": jnp.ones((cfg.d_model,), jnp.float32),
                "attn": self._init_attn(ka),
                "mlp": init_mlp(kf, cfg.d_model, cfg.d_ff, gated=False,
                                dtype=self.dtype)}

    def _init_rwkv_layer(self, key):
        cfg = self.cfg
        return {"ln1": jnp.ones((cfg.d_model,), jnp.float32),
                "ln2": jnp.ones((cfg.d_model,), jnp.float32),
                **{"rwkv_" + k: v for k, v in S.init_rwkv(
                    key, cfg.d_model, cfg.rwkv_head_size, cfg.d_ff,
                    self.dtype).items()}}

    def _init_jamba_block(self, key):
        """One Jamba period: `attn_every` sub-layers; sub-layer 0 is
        attention, the rest are Mamba; FFN alternates MoE/dense."""
        cfg = self.cfg
        per = cfg.attn_every
        keys = jax.random.split(key, 2 * per + 1)
        p: Dict[str, PyTree] = {
            "attn": self._init_attn(keys[0]),
            "attn_ln": jnp.ones((cfg.d_model,), jnp.float32),
        }
        mamba = [S.init_mamba(keys[1 + i], cfg.d_model, cfg.d_state,
                              cfg.d_conv, self.dtype) for i in range(per - 1)]
        p["mamba"] = jax.tree.map(lambda *xs: jnp.stack(xs), *mamba)
        p["mamba_ln"] = jnp.ones((per - 1, cfg.d_model), jnp.float32)
        n_moe = per // 2
        moe = [M.init_moe(keys[per + i], cfg.d_model, cfg.expert_ff,
                          cfg.n_experts, 0, 0, self.dtype,
                          expert_pad=cfg.expert_pad)
               for i in range(n_moe)]
        p["moe"] = jax.tree.map(lambda *xs: jnp.stack(xs), *moe)
        dense = [init_mlp(keys[per + n_moe + i], cfg.d_model, cfg.d_ff,
                          gated=True, dtype=self.dtype)
                 for i in range(per - n_moe)]
        p["mlp"] = jax.tree.map(lambda *xs: jnp.stack(xs), *dense)
        p["ffn_ln"] = jnp.ones((per, cfg.d_model), jnp.float32)
        return p

    # ==================================================================
    # forward (train / prefill)
    # ==================================================================
    def forward(self, params: PyTree, batch: Dict[str, jnp.ndarray],
                collect_aux: bool = False):
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        aux_total = jnp.zeros((), jnp.float32)

        fam = cfg.family
        if fam == "ssm":
            x, aux_total = self._rwkv_stack(params, x)
        elif fam == "hybrid":
            x, aux_total = self._jamba_stack(params, x)
        elif fam == "audio":
            enc = self._encoder_stack(params, batch["frames"].astype(self.dtype))
            x, aux_total = self._decoder_stack(params, x, enc=enc)
        else:
            x, aux_total = self._decoder_stack(params, x)

        x = rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = self._logits(params, x)
        if collect_aux:
            return logits, aux_total
        return logits

    def loss(self, params: PyTree, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        logits, aux = self.forward(params, batch, collect_aux=True)
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        mask = (labels >= 0).astype(jnp.float32)
        nll = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        return nll + 0.01 * aux

    # ---------------- shared pieces ----------------
    def _embed_inputs(self, params, batch):
        if "embeds" in batch:                       # vlm/audio-style stub input
            return batch["embeds"].astype(self.dtype)
        return params["embed"][batch["tokens"]]

    def _logits(self, params, x):
        if self.cfg.tie_embeddings:
            return jnp.einsum("...d,vd->...v", x, params["embed"])
        return linear(params["lm_head"], x)

    def _maybe_remat(self, f):
        return jax.checkpoint(f) if self.remat else f

    # ---------------- dense / moe / vlm decoder stack ----------------
    def _decoder_stack(self, params, x, enc=None):
        cfg = self.cfg

        def body(carry, lp):
            h, aux = carry
            a = A.attention_block(
                lp["attn"], rms_norm(lp["ln1"], h, cfg.norm_eps),
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.head_dim,
                rope_theta=None if cfg.family == "audio" else cfg.rope_theta)
            h = h + a
            if enc is not None:
                c = A.attention_block(
                    lp["xattn"], rms_norm(lp["ln_x"], h, cfg.norm_eps),
                    n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                    head_dim=cfg.head_dim, rope_theta=None, kv=enc)
                h = h + c
            hin = rms_norm(lp["ln2"], h, cfg.norm_eps)
            if cfg.n_experts:
                f, a_loss = self._moe(lp["moe"], hin)
                aux = aux + a_loss
                if cfg.dense_residual:
                    f = f + mlp(lp["mlp"], hin, gated=cfg.gated_mlp)
            else:
                f = mlp(lp["mlp"], hin, gated=cfg.gated_mlp)
            return (self._constrain(h + f), aux), None

        (x, aux), _ = self._scan(self._maybe_remat(body),
                                 (x, jnp.zeros((), jnp.float32)),
                                 params["layers"])
        return x, aux

    def _encoder_stack(self, params, frames):
        cfg = self.cfg

        def body(h, lp):
            a = A.attention_block(
                lp["attn"], rms_norm(lp["ln1"], h, cfg.norm_eps),
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.head_dim, rope_theta=None, causal=False)
            h = h + a
            f = mlp(lp["mlp"], rms_norm(lp["ln2"], h, cfg.norm_eps), gated=False)
            return self._constrain(h + f), None

        h, _ = self._scan(self._maybe_remat(body), frames,
                          params["enc_layers"])
        return rms_norm(params["enc_norm"], h, cfg.norm_eps)

    # ---------------- rwkv stack ----------------
    def _rwkv_stack(self, params, x):
        cfg = self.cfg

        def body(h, lp):
            rp = {k[5:]: v for k, v in lp.items() if k.startswith("rwkv_")}
            t, _ = S.rwkv_time_mix(rp, rms_norm(lp["ln1"], h, cfg.norm_eps),
                                   cfg.rwkv_head_size)
            h = h + t
            c, _ = S.rwkv_channel_mix(rp, rms_norm(lp["ln2"], h, cfg.norm_eps))
            return self._constrain(h + c), None

        x, _ = self._scan(self._maybe_remat(body), x, params["layers"])
        return x, jnp.zeros((), jnp.float32)

    # ---------------- jamba stack ----------------
    def _jamba_stack(self, params, x):
        cfg = self.cfg
        per = cfg.attn_every

        def block(carry, bp):
            h, aux = carry
            n_moe = per // 2
            mi = di = 0
            for i in range(per):
                if i == 0:
                    a = A.attention_block(
                        bp["attn"], rms_norm(bp["attn_ln"], h, cfg.norm_eps),
                        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                        head_dim=cfg.head_dim, rope_theta=cfg.rope_theta)
                    h = h + a
                else:
                    mp = jax.tree.map(lambda v, j=i - 1: v[j], bp["mamba"])
                    m, _ = S.mamba_block(
                        mp, rms_norm(bp["mamba_ln"][i - 1], h, cfg.norm_eps))
                    h = h + m
                hin = rms_norm(bp["ffn_ln"][i], h, cfg.norm_eps)
                if i % 2 == 0:
                    ep = jax.tree.map(lambda v, j=mi: v[j], bp["moe"])
                    f, al = self._moe(ep, hin)
                    aux = aux + al
                    mi += 1
                else:
                    dp = jax.tree.map(lambda v, j=di: v[j], bp["mlp"])
                    f = mlp(dp, hin, gated=True)
                    di += 1
                h = h + f
            return (self._constrain(h), aux), None

        (x, aux), _ = self._scan(self._maybe_remat(block),
                                 (x, jnp.zeros((), jnp.float32)),
                                 params["layers"])
        return x, aux

    # ==================================================================
    # decode path
    # ==================================================================
    def init_cache(self, batch_size: int, max_len: int,
                   enc_out: Optional[jnp.ndarray] = None) -> PyTree:
        cfg = self.cfg
        fam = cfg.family

        def kv(n):
            return {"k": jnp.zeros((n, batch_size, max_len, cfg.n_kv_heads,
                                    cfg.head_dim), self.dtype),
                    "v": jnp.zeros((n, batch_size, max_len, cfg.n_kv_heads,
                                    cfg.head_dim), self.dtype)}

        if fam == "ssm":
            st = S.rwkv_init_state(batch_size, cfg.d_model,
                                   cfg.rwkv_head_size, self.dtype)
            return {"layers": jax.tree.map(
                lambda a: jnp.stack([a] * cfg.n_layers), st),
                "len": jnp.zeros((), jnp.int32)}
        if fam == "hybrid":
            nb = cfg.n_layers // cfg.attn_every
            ms = S.mamba_init_state(batch_size, cfg.d_model, cfg.d_state,
                                    cfg.d_conv, self.dtype)
            stacked_m = jax.tree.map(
                lambda a: jnp.stack([jnp.stack([a] * (cfg.attn_every - 1))] * nb), ms)
            return {**kv(nb), "mamba": stacked_m, "len": jnp.zeros((), jnp.int32)}
        cache = {**kv(cfg.n_layers), "len": jnp.zeros((), jnp.int32)}
        if fam == "audio":
            cache["enc"] = enc_out
        return cache

    def decode_step(self, params: PyTree, cache: PyTree,
                    tokens: jnp.ndarray) -> Tuple[jnp.ndarray, PyTree]:
        """tokens: (B,) int32 -> logits (B, vocab), updated cache."""
        cfg = self.cfg
        x = params["embed"][tokens][:, None, :]           # (B, 1, d)
        fam = cfg.family
        if fam == "ssm":
            x, cache = self._rwkv_decode(params, cache, x)
        elif fam == "hybrid":
            x, cache = self._jamba_decode(params, cache, x)
        else:
            x, cache = self._decoder_decode(params, cache, x)
        x = rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = self._logits(params, x)[:, 0]
        return logits, cache

    def _decoder_decode(self, params, cache, x):
        cfg = self.cfg
        enc = cache.get("enc")

        def body(carry, lp_and_cache):
            h = carry
            lp, kc, vc = lp_and_cache
            a, new_c = A.cached_attention_step(
                lp["attn"], rms_norm(lp["ln1"], h, cfg.norm_eps),
                {"k": kc, "v": vc, "len": cache["len"]},
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.head_dim,
                rope_theta=None if cfg.family == "audio" else cfg.rope_theta)
            h = h + a
            if enc is not None:
                c = A.attention_block(
                    lp["xattn"], rms_norm(lp["ln_x"], h, cfg.norm_eps),
                    n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                    head_dim=cfg.head_dim, rope_theta=None, kv=enc)
                h = h + c
            hin = rms_norm(lp["ln2"], h, cfg.norm_eps)
            if cfg.n_experts:
                f, _ = M.moe_block(lp["moe"], hin, n_experts=cfg.n_experts,
                                   top_k=cfg.top_k,
                                   capacity_factor=self.moe_capacity,
                                        n_groups=self.moe_groups,
                                        buf_pspec=self.moe_buf_pspec)
                if cfg.dense_residual:
                    f = f + mlp(lp["mlp"], hin, gated=cfg.gated_mlp)
            else:
                f = mlp(lp["mlp"], hin, gated=cfg.gated_mlp)
            return h + f, (new_c["k"], new_c["v"])

        h, (new_k, new_v) = self._scan(
            body, x, (params["layers"], cache["k"], cache["v"]))
        new_cache = dict(cache, k=new_k, v=new_v, len=cache["len"] + 1)
        return h, new_cache

    def _rwkv_decode(self, params, cache, x):
        cfg = self.cfg

        def body(h, lp_and_state):
            lp, st = lp_and_state
            rp = {k[5:]: v for k, v in lp.items() if k.startswith("rwkv_")}
            t, tm = S.rwkv_time_mix(rp, rms_norm(lp["ln1"], h, cfg.norm_eps),
                                    cfg.rwkv_head_size, state=st["tm"])
            h = h + t
            c, cm = S.rwkv_channel_mix(
                rp, rms_norm(lp["ln2"], h, cfg.norm_eps), state=st["cm"])
            return h + c, {"tm": tm, "cm": cm}

        h, new_state = self._scan(body, x,
                                  (params["layers"], cache["layers"]))
        return h, {"layers": new_state, "len": cache["len"] + 1}

    def _jamba_decode(self, params, cache, x):
        cfg = self.cfg
        per = cfg.attn_every

        def block(h, bp_and_cache):
            bp, kc, vc, mstates = bp_and_cache
            new_m = []
            for i in range(per):
                if i == 0:
                    a, new_kv = A.cached_attention_step(
                        bp["attn"], rms_norm(bp["attn_ln"], h, cfg.norm_eps),
                        {"k": kc, "v": vc, "len": cache["len"]},
                        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                        head_dim=cfg.head_dim, rope_theta=cfg.rope_theta)
                    h = h + a
                else:
                    mp = jax.tree.map(lambda v, j=i - 1: v[j], bp["mamba"])
                    st = jax.tree.map(lambda v, j=i - 1: v[j], mstates)
                    m, ns = S.mamba_block(
                        mp, rms_norm(bp["mamba_ln"][i - 1], h, cfg.norm_eps),
                        state=st)
                    new_m.append(ns)
                    h = h + m
                hin = rms_norm(bp["ffn_ln"][i], h, cfg.norm_eps)
                if i % 2 == 0:
                    ep = jax.tree.map(lambda v, j=i // 2: v[j], bp["moe"])
                    f, _ = M.moe_block(ep, hin, n_experts=cfg.n_experts,
                                       top_k=cfg.top_k,
                                       capacity_factor=self.moe_capacity,
                                        n_groups=self.moe_groups,
                                        buf_pspec=self.moe_buf_pspec)
                else:
                    dp = jax.tree.map(lambda v, j=i // 2: v[j], bp["mlp"])
                    f = mlp(dp, hin, gated=True)
                h = h + f
            stacked_m = jax.tree.map(lambda *xs: jnp.stack(xs), *new_m)
            return h, (new_kv["k"], new_kv["v"], stacked_m)

        h, (nk, nv, nm) = self._scan(
            block, x, (params["layers"], cache["k"], cache["v"],
                       cache["mamba"]))
        return h, {"k": nk, "v": nv, "mamba": nm, "len": cache["len"] + 1}


def build_model(cfg: ArchConfig, dtype=jnp.bfloat16, remat: bool = True,
                moe_capacity: float = 1.25) -> Model:
    return Model(cfg=cfg, dtype=dtype, remat=remat, moe_capacity=moe_capacity)
