"""Manual-collective MoE (shard_map): the production expert-parallel path.

GSPMD cannot partition a scatter whose indices are computed at runtime, so
the pure-jnp dispatch (repro.models.moe.moe_block) gets replicated under
pjit — the dominant collective cost of every MoE train/prefill cell in the
baseline dry-run (EXPERIMENTS.md §Perf, qwen2-moe: 4.2 s collective term).

This module instead expresses the dispatch with explicit collectives inside
``jax.shard_map``:

  * each (data-shard, model-rank) routes its OWN token slice locally —
    routing math (top-k, cumsum positions, scatter) is per-device dense
    compute, invisible to the partitioner;
  * one ``all_to_all`` over the model axis moves capacity-bounded token
    buffers to their experts (E sharded over 'model' = expert parallelism);
  * expert FFN runs as a local einsum on the device's E/MP experts;
  * a reverse ``all_to_all`` + local combine + ``all_gather`` (token slices)
    returns outputs to every model rank.

Per-device collective volume per layer: 2 x (T_loc * k/MP * cap_factor * d)
for the a2a pair + T_loc * d for the gather — independent of E and ~25x
less than the replicated-scatter fallback at qwen2-moe scale.
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.layers import mlp


def _local_dispatch(xs, router, n_experts, e_tot, top_k, cap):
    """Route a local token slice. xs: (t, d). Returns buf (E, cap, d),
    combine indices and gates for the reverse path, and the aux-loss stats."""
    t, d = xs.shape
    logits = xs.astype(jnp.float32) @ router                      # (t, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    flat_e = gate_idx.reshape(-1)                                 # (t*k,)
    onehot = jax.nn.one_hot(flat_e, e_tot, dtype=jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1,
                              flat_e[:, None], axis=1)[:, 0]
    keep = pos < cap
    e_idx = jnp.where(keep, flat_e, 0)
    c_idx = jnp.where(keep, pos, 0)
    src = jnp.where(keep[:, None], jnp.repeat(xs, top_k, axis=0), 0)
    buf = jnp.zeros((e_tot, cap, d), xs.dtype).at[e_idx, c_idx].add(src)
    gates = (gate_vals.reshape(-1) * keep).astype(xs.dtype)
    me = probs.mean(axis=0)[:n_experts]
    ce = (jnp.zeros(e_tot).at[flat_e].add(1.0)[:n_experts]
          / (t * top_k))
    return buf, (e_idx, c_idx, gates), (me, ce)


def moe_block_sharded(params: Dict, x: jnp.ndarray, *, n_experts: int,
                      top_k: int, mesh, dp_axes: Tuple[str, ...],
                      model_axis: str = "model",
                      capacity_factor: float = 1.25
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Drop-in replacement for moe_block under a (data, model) mesh.

    x: (B, S, d) sharded P(dp, None, None); expert stacks P('model', ...).
    Returns (out with the same sharding, scalar aux loss).
    """
    b, s, d = x.shape
    mp = int(mesh.shape[model_axis])
    e_tot = params["w_up"].shape[0]
    assert e_tot % mp == 0, (e_tot, mp)
    dp_size = int(np.prod([mesh.shape[a] for a in dp_axes]))
    t_loc = (b // dp_size) * s                # tokens per data shard
    per = max(t_loc // mp, 1)                 # token slice per model rank
    cap = max(int(np.ceil(per * top_k / n_experts * capacity_factor)), 1)

    def inner(router, wg, wu, wd, shared, xl):
        # xl: (b_loc, S, d) — identical across model ranks
        rank = jax.lax.axis_index(model_axis)
        xf = xl.reshape(-1, d)
        xs = jax.lax.dynamic_slice_in_dim(xf, rank * per, per)
        buf, (e_idx, c_idx, gates), (me, ce) = _local_dispatch(
            xs, router, n_experts, e_tot, top_k, cap)

        # dispatch a2a (split==concat axis: the VJP of mixed-axis all_to_all
        # is broken in jax 0.8): (MP, E_loc, cap, d) -> (MP=src, E_loc, cap, d)
        bufr = buf.reshape(mp, e_tot // mp, cap, d)
        recv = jax.lax.all_to_all(bufr, model_axis, split_axis=0,
                                  concat_axis=0)
        h_in = recv.transpose(1, 0, 2, 3).reshape(e_tot // mp, mp * cap, d)

        g = jnp.einsum("ecd,edf->ecf", h_in, wg)
        u = jnp.einsum("ecd,edf->ecf", h_in, wu)
        hh = jax.nn.silu(g.astype(jnp.float32)).astype(xl.dtype) * u
        y = jnp.einsum("ecf,efd->ecd", hh, wd)        # (E_loc, MP*cap, d)

        # reverse a2a: (E_loc, MP, cap, d) -> (MP=dst, E_loc, cap, d)
        yr = y.reshape(e_tot // mp, mp, cap, d).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(yr, model_axis, split_axis=0,
                                  concat_axis=0)
        y_buf = back.reshape(e_tot, cap, d)

        out_flat = y_buf[e_idx, c_idx] * gates[:, None]          # (per*k, d)
        out_slice = out_flat.reshape(per, top_k, d).sum(axis=1)  # (per, d)
        out = jax.lax.all_gather(out_slice, model_axis, axis=0,
                                 tiled=True)                     # (t_loc, d)
        aux = n_experts * jnp.sum(
            jax.lax.pmean(me, model_axis) * jax.lax.pmean(ce, model_axis))
        return out.reshape(xl.shape), aux

    dp = tuple(dp_axes)
    out, aux = jax.shard_map(
        inner, mesh=mesh,
        in_specs=(P(None, None), P(model_axis, None, None),
                  P(model_axis, None, None), P(model_axis, None, None),
                  P(), P(dp, None, None)),
        out_specs=(P(dp, None, None), P()),
        check_vma=False,
    )(params["router"], params["w_gate"], params["w_up"], params["w_down"],
      jnp.zeros((), x.dtype), x)

    if "shared" in params:
        out = out + mlp(params["shared"], x, gated=True)
    return out, aux
