"""Model primitives: norms, RoPE, MLPs, initializers.

Pure-functional JAX: parameters are pytrees of arrays; every layer is a
function ``f(params, x, ...) -> y``.  Norm math runs in fp32 regardless of
activation dtype.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(w: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return out.astype(x.dtype)


def layer_norm(w: jnp.ndarray, b: jnp.ndarray, x: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * w.astype(jnp.float32) + b.astype(jnp.float32)
    return out.astype(x.dtype)


# --------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                              # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]                        # (..., seq, 1, hd/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------- MLP
def mlp(params: Dict, x: jnp.ndarray, gated: bool) -> jnp.ndarray:
    if gated:
        g = jnp.einsum("...d,df->...f", x, params["w_gate"])
        u = jnp.einsum("...d,df->...f", x, params["w_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = jnp.einsum("...d,df->...f", x, params["w_up"])
        if "b_up" in params:
            h = h + params["b_up"]
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("...f,fd->...d", h, params["w_down"])
    if "b_down" in params:
        out = out + params["b_down"]
    return out


def init_mlp(key, d_model: int, d_ff: int, gated: bool, bias: bool = False,
             dtype=jnp.bfloat16) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = float(1.0 / np.sqrt(d_model))
    s_ff = float(1.0 / np.sqrt(d_ff))
    p = {"w_up": jax.random.normal(k1, (d_model, d_ff), dtype) * s_in,
         "w_down": jax.random.normal(k2, (d_ff, d_model), dtype) * s_ff}
    if gated:
        p["w_gate"] = jax.random.normal(k3, (d_model, d_ff), dtype) * s_in
    if bias:
        p["b_up"] = jnp.zeros((d_ff,), dtype)
        p["b_down"] = jnp.zeros((d_model,), dtype)
    return p


def init_linear(key, d_in: int, d_out: int, bias: bool = False,
                dtype=jnp.bfloat16) -> Dict:
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * float(1.0 / np.sqrt(d_in))}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    y = jnp.einsum("...d,df->...f", x, params["w"])
    if "b" in params:
        y = y + params["b"]
    return y
