"""AdamW + cosine schedule + global-norm clipping (pure-pytree, pjit-ready).

Optimizer moments inherit the parameter sharding (pass the param specs for
`m`/`v` in the step's in/out shardings); with DP+TP meshes this is the
ZeRO-0 layout, and moments can additionally be sharded over the data axes by
supplying zero1 specs (see repro.launch.train.opt_specs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def cosine_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    return cfg.lr * warm * 0.5 * (1 + jnp.cos(jnp.pi * prog))


def adamw_init(params: Any) -> dict:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(cfg: AdamWConfig, grads: Any, opt_state: dict,
                 params: Any) -> Tuple[Any, dict, dict]:
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = cosine_lr(cfg, step)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / (1 - cfg.b1 ** step)
        vhat = v / (1 - cfg.b2 ** step)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"], params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
