from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, cosine_lr
from repro.optim.compress import compress_grads, decompress_grads, ef_init

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_lr",
           "compress_grads", "decompress_grads", "ef_init"]
