"""Int8 gradient compression with error feedback.

Distributed-optimization trick for DP gradient exchange: quantize gradients
to int8 with a per-row scale before the data-parallel reduction, carry the
quantization residual in an error-feedback buffer so the compression is
unbiased over time.  Used by repro.launch.train when --compress-grads is on
(the decompress happens after the psum; at 4x fewer bytes on the wire the
DP all-reduce term of the roofline drops accordingly).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def ef_init(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def _quant(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    flat = x.reshape(x.shape[0], -1) if x.ndim > 1 else x.reshape(1, -1)
    scale = jnp.max(jnp.abs(flat), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).reshape(shape)


def compress_grads(grads: Any, ef: Any) -> Tuple[Any, Any]:
    """Returns (compressed {q, scale, shape}, new error-feedback buffers)."""
    def one(g, e):
        total = g.astype(jnp.float32) + e
        q, s = _quant(total)
        recon = _dequant(q, s, g.shape)
        return {"q": q, "scale": s}, total - recon

    flat = jax.tree.map(one, grads, ef,
                        is_leaf=lambda x: isinstance(x, jnp.ndarray))
    comp = jax.tree.map(lambda t: t[0], flat,
                        is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
    return comp, new_ef


def decompress_grads(comp: Any, like: Any) -> Any:
    # NB: the leaf predicate must require BOTH keys — attention param
    # subtrees legitimately contain a "q" (query projection) entry
    return jax.tree.map(
        lambda c, g: _dequant(c["q"], c["scale"], g.shape), comp, like,
        is_leaf=lambda x: isinstance(x, dict) and "q" in x and "scale" in x)
