"""Public flash-attention op: jit'd wrapper + interpret fallback.

On TPU the Pallas kernel runs compiled; on CPU (this container) it runs in
interpret mode, which executes the kernel body in Python and validates the
exact tiling/indexing logic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, block_q: int = 256,
                    block_k: int = 256,
                    interpret: bool = None) -> jnp.ndarray:
    """q: (B, Sq, H, hd); k, v: (B, Sk, H, hd) — heads already repeated for
    GQA. Returns (B, Sq, H, hd)."""
    if interpret is None:
        interpret = not _on_tpu()
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, sk, hd)
    of = flash_attention_fwd(qf, kf, vf, causal=causal, block_q=block_q,
                             block_k=block_k, interpret=interpret)
    return of.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
