"""Flash attention forward kernel (TPU Pallas).

Tiling: grid = (batch*heads, n_q_blocks, n_k_blocks); the k-block dimension
is grid-minor, i.e. sequential on TPU, so the online-softmax running state
(m, l, acc) lives in VMEM scratch and persists across k steps.  Q/K/V tiles
are (block, head_dim) VMEM blocks; head_dim is MXU-lane aligned (128) for
all assigned archs except whisper/llama3.2 (64, still lane-aligned).

Causal blocks strictly above the diagonal are skipped via @pl.when (the
kernel still visits the grid point but does no compute or DMA-dependent
work — Pallas TPU prefetches the block, the FLOP cost is skipped).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, block_q: int, block_k: int,
               n_k_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = (not causal) or (ki * block_k <= qi * block_q + block_q - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                           (block_q, block_k), 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                           (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(p, v)
        m_scr[...] = m_new

    @pl.when(ki == n_k_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, block_q: int = 256,
                        block_k: int = 256,
                        interpret: bool = False) -> jnp.ndarray:
    """q, k, v: (BH, S, hd) — heads pre-flattened into the batch dim."""
    bh, sq, hd = q.shape
    sk = k.shape[1]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, block_q, sk, block_k)
    n_q, n_k = sq // block_q, sk // block_k
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, n_k_blocks=n_k)

    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            # online-softmax running state (fp32, persists across k blocks)
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum l
            pltpu.VMEM((block_q, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
