"""Pure-jnp oracle for the flash_attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True) -> jnp.ndarray:
    """q, k, v: (BH, S, hd). Naive materialized-scores attention, fp32."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)).astype(q.dtype)
