"""Public rwkv6_scan op: jit'd wrapper + interpret fallback on CPU."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_scan.kernel import rwkv6_scan_fwd


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def rwkv6_scan(r, k, v, w, u, *, block_t: int = 64, interpret: bool = None):
    """r/k/v/w: (B, T, H, hd); u: (H, hd). Returns (B, T, H, hd)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, t, h, hd = r.shape
    def flat(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, t, hd)
    uf = jnp.broadcast_to(u[None], (b, h, hd)).reshape(b * h, 1, hd)
    y = rwkv6_scan_fwd(flat(r), flat(k), flat(v), flat(w), uf,
                       block_t=block_t, interpret=interpret)
    return y.reshape(b, h, t, hd).transpose(0, 2, 1, 3)
