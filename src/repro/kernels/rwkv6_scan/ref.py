"""Pure-jnp oracle for the rwkv6_scan kernel (same math as models.ssm)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_scan_ref(r, k, v, w, u):
    """r/k/v/w: (BH, T, hd); u: (BH, 1, hd). Returns y: (BH, T, hd)."""
    bh, t, hd = r.shape

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                       # (BH, hd)
        kv = k_t[:, :, None] * v_t[:, None, :]         # (BH, hd, hd)
        y = jnp.einsum("bk,bkv->bv", r_t, s + u[:, 0, :, None] * kv)
        s = w_t[:, :, None] * s + kv
        return s, y

    xs = tuple(a.transpose(1, 0, 2).astype(jnp.float32) for a in (r, k, v, w))
    s0 = jnp.zeros((bh, hd, hd), jnp.float32)
    _, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2).astype(r.dtype)
