"""RWKV6 (Finch) WKV recurrence kernel (TPU Pallas).

Recurrence per head (state S: (hd, hd) fp32):

    y_t = r_t @ (S + u * (k_t^T v_t))
    S   = diag(w_t) @ S + k_t^T v_t

Tiling: grid = (B*H, T // block_t); the time dimension is grid-minor
(sequential), so the state matrix persists in VMEM scratch across time
blocks.  r/k/v/w tiles are (block_t, hd) VMEM blocks; the u bonus vector is
broadcast to every grid step.  Inside a block the recurrence steps with a
fori_loop over block_t (each step is an outer-product + (hd,hd) matvec on
the VPU/MXU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_scr, *,
                 block_t: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)          # (bt, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)          # (1, hd) -> broadcast

    def step(t, carry):
        s, ys = carry
        kv = k[t][:, None] * v[t][None, :]    # (hd, hd)
        y = (r[t][None, :] @ (s + u[0][:, None] * kv))[0]
        s = w[t][:, None] * s + kv
        ys = jax.lax.dynamic_update_index_in_dim(ys, y, t, axis=0)
        return s, ys

    s0 = s_scr[...]
    ys0 = jnp.zeros((block_t, r.shape[1]), jnp.float32)
    s, ys = jax.lax.fori_loop(0, block_t, step, (s0, ys0))
    s_scr[...] = s
    y_ref[0] = ys.astype(y_ref.dtype)


def rwkv6_scan_fwd(r, k, v, w, u, *, block_t: int = 64,
                   interpret: bool = False):
    """r/k/v/w: (BH, T, hd); u: (BH, 1, hd). Returns y: (BH, T, hd)."""
    bh, t, hd = r.shape
    block_t = min(block_t, t)
    assert t % block_t == 0, (t, block_t)
    n_t = t // block_t

    kernel = functools.partial(_wkv6_kernel, block_t=block_t)
    return pl.pallas_call(
        kernel,
        grid=(bh, n_t),
        in_specs=[
            pl.BlockSpec((1, block_t, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_t, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_t, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_t, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, 1, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_t, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, hd), r.dtype),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
