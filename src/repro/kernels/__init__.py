"""Pallas TPU kernels for the perf-critical hot spots.

Each kernel package has:
  kernel.py — ``pl.pallas_call`` + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (with interpret-mode fallback on CPU)
  ref.py    — pure-jnp oracle used by the allclose test sweeps

Kernels:
  flash_attention — tiled online-softmax attention (prefill hot spot)
  rwkv6_scan      — RWKV6 data-dependent-decay recurrence (Finch time-mix)
  ssm_scan        — Mamba selective scan (Jamba hot spot)
  ppa_eval        — batched design-point PPA evaluation (the Lumina DSE
                    substrate hot loop: one kernel call evaluates a block of
                    candidate architectures against an operator table)
"""

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.rwkv6_scan.ops import rwkv6_scan
from repro.kernels.ssm_scan.ops import ssm_scan
from repro.kernels.ppa_eval.ops import ppa_eval

__all__ = ["flash_attention", "rwkv6_scan", "ssm_scan", "ppa_eval"]
