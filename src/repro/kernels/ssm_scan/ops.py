"""Public ssm_scan op: jit'd wrapper + interpret fallback on CPU."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssm_scan.kernel import ssm_scan_fwd


@functools.partial(jax.jit, static_argnames=("block_t", "block_d", "interpret"))
def ssm_scan(u, dt, a, b, c, *, block_t: int = 64, block_d: int = 128,
             interpret: bool = None):
    """Selective scan: u, dt (B,T,D); a (D,N); b, c (B,T,N) -> y (B,T,D)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return ssm_scan_fwd(u, dt, a, b, c, block_t=block_t, block_d=block_d,
                        interpret=interpret)
